# PLoRA build entry points.
#
# The Rust system builds and runs WITHOUT any of these targets: the default
# reference backend synthesizes its manifest and base weights (see
# rust/src/runtime/reference/). `make artifacts` is the optional L2 AOT
# step: it pretrains the TinyLM bases and lowers the packed train/eval
# steps + Pallas kernels to HLO text for the PJRT backend (`--features
# pjrt`). It requires a Python environment with jax installed.

ARTIFACTS := rust/artifacts

.PHONY: build test bench artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench planner

# L2 AOT compile path (optional; python + jax required). Produces
# $(ARTIFACTS)/manifest.json, weights_<model>.bin and *.hlo.txt — the
# runtime picks them up automatically on the next start.
artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
