# PLoRA build entry points.
#
# The Rust system builds and runs WITHOUT any of these targets: the default
# reference backend synthesizes its manifest and base weights (see
# rust/src/runtime/reference/). `make artifacts` is the optional L2 AOT
# step: it pretrains the TinyLM bases and lowers the packed train/eval
# steps + Pallas kernels to HLO text for the PJRT backend (`--features
# pjrt`). It requires a Python environment with jax installed.

ARTIFACTS := rust/artifacts

.PHONY: build test bench bench-snapshot artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench planner

# Refresh the committed perf-budget snapshots (bench/history/): run both
# perf benches to a scratch dir, print the budget checks, and install the
# new numbers as each snapshot's `record`. Review the diff before
# committing — the next perf-budget run gates against it.
bench-snapshot: build
	mkdir -p target/bench-out
	cargo bench --bench session -- --out target/bench-out/BENCH_session.json
	cargo bench --bench train_step -- --out target/bench-out/BENCH_train_step.json
	./target/release/plora perf-budget --current target/bench-out/BENCH_session.json \
		--baseline bench/history/BENCH_session.json --warn-only --update-baseline
	./target/release/plora perf-budget --current target/bench-out/BENCH_train_step.json \
		--baseline bench/history/BENCH_train_step.json --warn-only --update-baseline

# L2 AOT compile path (optional; python + jax required). Produces
# $(ARTIFACTS)/manifest.json, weights_<model>.bin and *.hlo.txt — the
# runtime picks them up automatically on the next start.
artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

clean-artifacts:
	rm -rf $(ARTIFACTS)
