//! Paper-scale makespan study (Figures 4 & 6): simulate the full
//! 120-configuration hyperparameter sweep on 8×A100-40G for every base
//! model the paper evaluates, with all four methods, and print the
//! normalized makespans the figures report.
//!
//! ```bash
//! cargo run --release --example makespan_sim             # all 6 models
//! cargo run --release --example makespan_sim -- --model qwen2.5-7b
//! ```

use anyhow::Result;

use plora::config::{geometry, pool, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::metrics::{fmt_dur, fmt_x, Table};
use plora::planner::{max_gpu_plan, min_gpu_plan, sequential_plora_plan, JobPlanner};
use plora::sim::{SimOptions, Simulator};
use plora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let models: Vec<&str> = match args.get("model") {
        Some(m) => vec![m],
        None => vec![
            "qwen2.5-3b",
            "qwen2.5-7b",
            "qwen2.5-14b",
            "qwen2.5-32b",
            "llama3.2-3b",
            "llama3.1-8b",
        ],
    };
    let gpus = args.usize("gpus", 8)?;
    let budget = TrainBudget { dataset: args.usize("budget", 256)?, epochs: 3 };
    let grid = SearchSpace::default().grid("gsm8k");

    let mut fig4 = Table::new(
        &format!(
            "Figure 4 — makespan of the 120-config sweep on {gpus} x A100-40G \
             (normalized to Min GPU)"
        ),
        &[
            "model", "Min GPU", "Max GPU", "Seq PLoRA", "PLoRA", "PLoRA speedup", "AR bound",
            "emp ratio",
        ],
    );

    for model in models {
        let cm = CostModel::new(geometry::geom(model).unwrap(), &pool::A100_40G);
        let sim = Simulator { cm: cm.clone(), budget, gpus };
        let opts = SimOptions::default();
        let run = |p: &plora::planner::Plan| {
            let q: Vec<_> = p.jobs.iter().map(|j| j.job.clone()).collect();
            sim.run_queue(&q, &opts)
        };
        eprintln!("[{model}] planning 4 methods ...");
        let min = run(&min_gpu_plan(&cm, &budget, gpus, &grid)?);
        let max = run(&max_gpu_plan(&cm, &budget, gpus, &grid)?);
        let seq = run(&sequential_plora_plan(&cm, &budget, gpus, &grid)?);
        let mut planner = JobPlanner::new(cm, gpus);
        planner.budget = budget;
        let plan = planner.plan(&grid)?;
        let plora = run(&plan);
        fig4.row(vec![
            model.to_string(),
            format!("{} (1.00)", fmt_dur(min.makespan)),
            format!("{:.2}", max.makespan / min.makespan),
            format!("{:.2}", seq.makespan / min.makespan),
            format!("{:.2}", plora.makespan / min.makespan),
            fmt_x(min.makespan / plora.makespan),
            format!("{:.2}", plan.ar_bound),
            format!("{:.2}", plan.empirical_ratio()),
        ]);
    }
    fig4.print();
    println!(
        "\npaper reference: PLoRA reduces makespan 7.08x/6.52x/6.51x/6.33x (QWen 3B/7B/14B/32B) \
         and 7.52x/6.78x (LLaMa-3.2-3B/3.1-8B); Sequential PLoRA alone ~1.8x (Fig. 6)."
    );
    Ok(())
}
