//! Quickstart: fine-tune two LoRA adapters *packed* into one job on the
//! TinyLM `nano` model, fully live through the default pure-Rust reference
//! backend — no artifacts or native libraries required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! # optional: `make artifacts` + `--features pjrt` to run the same job
//! # through the AOT/PJRT path with a pretrained base.
//! ```
//!
//! This is the paper's Figure-2 workflow end to end: two adapters with
//! different hyperparameters and different tasks share one frozen base
//! model inside a single fine-tuning job; each gets its own data stream,
//! learning rate, and alpha.

use anyhow::Result;

use plora::config::LoraConfig;
use plora::costmodel::TrainBudget;
use plora::runtime::Runtime;
use plora::train::{run_pack, TrainOptions};

fn main() -> Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    println!("execution backend: {}", rt.platform());

    // Two LoRA configurations — different tasks, learning rates, and
    // alphas, packed into ONE job (the paper's core idea, §3.2).
    let configs = vec![
        LoraConfig {
            id: 0,
            lr: 2e-3,
            batch: 1,
            rank: 8,
            alpha_ratio: 1.0,
            task: "parity".into(), // logic-reasoning stand-in
        },
        LoraConfig {
            id: 1,
            lr: 1e-3,
            batch: 1,
            rank: 8,
            alpha_ratio: 0.5,
            task: "needle".into(), // lookup/retrieval stand-in
        },
    ];

    let opts = TrainOptions {
        budget: TrainBudget { dataset: 128, epochs: 1 },
        eval_batches: 4,
        seed: 7,
        log_every: 16,
    };

    println!("fine-tuning {} packed adapters on `nano` ...", configs.len());
    let report = run_pack(&rt, "nano", &configs, &opts)?;

    println!(
        "\nartifact {}  bucket (n={}, r={}, bs={})  {} steps in {:.1}s ({:.0} ms/step)",
        report.artifact,
        report.bucket_n,
        report.bucket_r,
        report.bucket_bs,
        report.steps,
        report.wall_secs,
        report.step_secs * 1e3,
    );
    for a in &report.adapters {
        println!(
            "\nadapter {} [{}] rank={} lr={:.0e} alpha={}",
            a.config.id, a.config.task, a.config.rank, a.config.lr, a.config.alpha_ratio
        );
        println!("  base model:  loss {:.3}  acc {:.3}", a.base_loss, a.base_acc);
        println!("  fine-tuned:  loss {:.3}  acc {:.3}", a.eval_loss, a.eval_acc);
        for (s, l) in &a.curve {
            println!("    step {s:>4}  train loss {l:.4}");
        }
        assert!(a.eval_loss < a.base_loss, "fine-tuning must improve held-out loss");
    }
    println!("\nquickstart OK — both adapters improved over the frozen base.");
    Ok(())
}
