//! Live quality sweep (Tables 2/3/4/6 analogues): grid-search LoRA
//! hyperparameters on a TinyLM model over the four synthetic tasks and
//! reproduce the paper's empirical observations at testbed scale:
//!
//!  - Obs. 1: every hyperparameter moves downstream accuracy;
//!  - Obs. 2: bad configurations can be *worse* than the frozen base;
//!  - Obs. 3: the best configuration differs per task;
//!  - Table 6: the searched best beats the one-size default config.
//!
//! ```bash
//! cargo run --release --example sweep_e2e             # nano, ~5 min
//! cargo run --release --example sweep_e2e -- --model tiny --steps 160
//! ```

use std::sync::Arc;

use anyhow::Result;

use plora::config::{LoraConfig, SearchSpace};
use plora::costmodel::TrainBudget;
use plora::runtime::Runtime;
use plora::search;
use plora::train::{run_pack, TrainOptions};
use plora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let model = args.get_or("model", "nano").to_string();
    let steps = args.usize("steps", 96)?;
    let per_task = args.usize("per-task", 8)?;

    let rt = Arc::new(Runtime::load(&Runtime::default_dir())?);
    let tasks = rt.manifest.tasks.clone();
    println!("== live hyperparameter sweep on `{model}` over {tasks:?} ==");

    // Live-scale grid (the nano bucket caps rank at 8; tiny allows 32).
    let ranks = if model == "nano" { vec![8] } else { vec![8, 32] };
    let space = SearchSpace {
        lrs: vec![5e-4, 2e-3, 8e-3],
        batches: vec![1, 2],
        ranks,
        alpha_ratios: vec![0.5, 1.0],
    };
    let opts = search::SweepOptions {
        budget: TrainBudget { dataset: steps, epochs: 1 },
        eval_batches: 4,
        seed: 23,
        gpus: 2,
        ..Default::default()
    };

    let mut all = vec![];
    let mut defaults = vec![];
    for task in &tasks {
        let mut g = space.grid(task);
        g.truncate(per_task);
        for (i, c) in g.iter_mut().enumerate() {
            c.id = i;
        }
        println!("[{task}] {} configurations ...", g.len());
        all.extend(search::sweep(&rt, &model, &g, &opts)?);

        // The practitioner default (Table 6 middle column), at live scale.
        let d = LoraConfig {
            id: 9000,
            lr: 2e-3,
            batch: 2,
            rank: *space.ranks.last().unwrap(),
            alpha_ratio: 1.0,
            task: task.clone(),
        };
        let rep = run_pack(
            &rt,
            &model,
            &[d],
            &TrainOptions {
                budget: opts.budget,
                eval_batches: opts.eval_batches,
                seed: opts.seed,
                log_every: 0,
            },
        )?;
        defaults.extend(rep.adapters);
    }

    search::table2(&all).print();
    search::table3(&all).print();
    search::table4(&model, &all).print();
    search::table6(&model, &all, &defaults).print();

    // Observation 3: best configs differ across tasks.
    let best = search::best_per_task(&all);
    let mut distinct = std::collections::BTreeSet::new();
    for a in best.values() {
        distinct.insert(format!(
            "{}-{}-{:.0e}-{}",
            a.config.rank, a.config.batch, a.config.lr, a.config.alpha_ratio
        ));
    }
    println!(
        "\ndistinct best configurations across {} tasks: {} (paper Obs. 3: they differ)",
        best.len(),
        distinct.len()
    );
    Ok(())
}
