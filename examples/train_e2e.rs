//! End-to-end training driver — the recorded run of EXPERIMENTS.md §E2E.
//!
//! Exercises every layer of the system on a real (small) workload:
//! plan a 16-configuration hyperparameter space with the PLoRA planner
//! (ILP + DTM + Alg. 2), execute the resulting packed-job queue live on
//! the PJRT runtime through the execution engine (concurrent jobs,
//! resource monitor, checkpoint pool), train the `tiny` TinyLM (~1.1M
//! params) for a few hundred steps per configuration, log loss curves,
//! and report the best adapter per task — proving L3 ⇄ runtime ⇄ L2/L1
//! compose.
//!
//! ```bash
//! cargo run --release --example train_e2e            # full (~10 min)
//! cargo run --release --example train_e2e -- --fast  # CI-sized
//! ```

use std::sync::Arc;

use anyhow::Result;

use plora::cluster::ResourceMonitor;
use plora::config::{geometry, pool, LoraConfig, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::engine::{CheckpointPool, Engine};
use plora::metrics::{fmt_dur, Table};
use plora::planner::JobPlanner;
use plora::runtime::Runtime;
use plora::util::cli::Args;
use plora::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse();
    let fast = args.flag("fast");
    let model = args.get_or("model", if fast { "nano" } else { "tiny" }).to_string();
    let steps = args.usize("steps", if fast { 24 } else { 192 })?;
    let n_configs = args.usize("configs", 16)?;
    let gpus = args.usize("gpus", 4)?;

    let rt = Arc::new(Runtime::load(&Runtime::default_dir())?);
    let mi = rt.manifest.model(&model)?.clone();
    println!(
        "== PLoRA end-to-end == model `{model}` ({:.2}M params, {} layers, \
         seq {}) on {} pool slots",
        mi.params as f64 / 1e6,
        mi.n_layers,
        mi.seq,
        gpus
    );

    // 1. Build the search space: 4 tasks x hyperparameter draws.
    let tasks = rt.manifest.tasks.clone();
    let space = SearchSpace {
        lrs: vec![5e-4, 2e-3, 6e-3],
        batches: vec![1, 2, 4],
        ranks: vec![8, 16, 32],
        alpha_ratios: vec![0.5, 1.0],
    };
    let mut rng = Rng::new(2026);
    let mut configs: Vec<LoraConfig> = vec![];
    for i in 0..n_configs {
        let mut c = space.sample(&tasks[i % tasks.len()], 1, &mut rng).remove(0);
        c.id = i;
        // Keep rank/bs inside the tiny artifact bucket grid.
        c.rank = c.rank.min(32);
        if model == "nano" {
            c.rank = 8;
            c.batch = c.batch.min(2);
        } else {
            c.batch = c.batch.min(4);
        }
        configs.push(c);
    }
    println!("search space: {} configurations over tasks {:?}", configs.len(), tasks);

    // 2. Offline planning (Figure 3 left): pack configurations into jobs.
    let geom = geometry::tiny_geom(
        Box::leak(model.clone().into_boxed_str()),
        mi.n_layers,
        mi.d_model,
        mi.d_ff,
        mi.n_heads,
        mi.vocab,
        mi.seq,
    );
    let mut cm = CostModel::new(&geom, &pool::CPU_SIM);
    cm.charge_padding = true;
    cm.buckets = Some(rt.manifest.train_buckets(&model));
    let mut planner = JobPlanner::new(cm, gpus);
    planner.budget = TrainBudget { dataset: steps, epochs: 1 };
    let plan = planner.plan(&configs)?;
    println!(
        "plan: {} packed jobs, predicted makespan {} (model time), AR bound {:.2}",
        plan.jobs.len(),
        fmt_dur(plan.makespan),
        plan.ar_bound
    );
    for j in &plan.jobs {
        println!("  {}", j.job.summary());
    }

    // 3. Online execution (Figure 3 right): live engine over PJRT.
    let ckpt_dir = std::env::temp_dir().join("plora_e2e_ckpts");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut engine = Engine::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, gpus));
    engine.options.budget = planner.budget;
    engine.options.eval_batches = 4;
    engine.options.log_every = (steps / 6).max(1);
    engine.options.seed = 11;
    engine.checkpoints = Some(CheckpointPool::new(&ckpt_dir, rt.clone())?);
    let report = engine.run(&model, &queue_of(&plan))?;

    // 4. Report: per-adapter quality + loss curves + best per task.
    let mut t = Table::new(
        "E2E results (per adapter)",
        &["cfg", "task", "rank", "bs", "lr", "alpha", "steps", "base acc", "eval acc", "Δ"],
    );
    let mut all = vec![];
    for o in &report.outcomes {
        for a in &o.report.adapters {
            t.row(vec![
                a.config.id.to_string(),
                a.config.task.clone(),
                a.config.rank.to_string(),
                a.config.batch.to_string(),
                format!("{:.0e}", a.config.lr),
                format!("{}", a.config.alpha_ratio),
                a.steps.to_string(),
                format!("{:.3}", a.base_acc),
                format!("{:.3}", a.eval_acc),
                format!("{:+.3}", a.eval_acc - a.base_acc),
            ]);
            all.push(a.clone());
        }
    }
    t.print();

    println!("\nloss curves (first adapter of each job):");
    for o in &report.outcomes {
        if let Some(a) = o.report.adapters.first() {
            let pts: Vec<String> =
                a.curve.iter().map(|(s, l)| format!("{s}:{l:.2}")).collect();
            println!("  job{} [{}] {}", o.job_id, a.config.task, pts.join(" "));
        }
    }

    let best = plora::search::best_per_task(&all);
    println!("\nbest adapter per task:");
    for (task, a) in &best {
        println!(
            "  {task:<8} cfg {} (r={}, lr={:.0e}, bs={}, α={}) eval acc {:.3} (base {:.3})",
            a.config.id,
            a.config.rank,
            a.config.lr,
            a.config.batch,
            a.config.alpha_ratio,
            a.eval_acc,
            a.base_acc
        );
    }

    let ckpts = engine.checkpoints.as_ref().unwrap().list(&model);
    let (a, b, c) = report.calib_fit;
    println!(
        "\nlive makespan {}  adapters {}  checkpoints saved {}  calib fit \
         t = {:.4} + {:.2e}·tok + {:.2e}·n",
        fmt_dur(report.makespan),
        report.total_adapters(),
        ckpts.len(),
        a,
        b,
        c
    );
    assert_eq!(ckpts.len(), configs.len(), "every adapter checkpointed");
    // The sweep must have found an improvement on most tasks.
    let improved = best.values().filter(|a| a.eval_acc > a.base_acc + 0.01).count();
    println!("tasks improved over base: {improved}/{}", best.len());
    Ok(())
}

fn queue_of(plan: &plora::planner::Plan) -> Vec<plora::planner::PlannedJob> {
    plan.jobs.iter().map(|j| j.job.clone()).collect()
}
