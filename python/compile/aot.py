"""AOT compile path: lower L2/L1 computations to HLO text + build manifest.

``make artifacts`` runs this module once; afterwards Python is never on the
request path. For every (model, pack-size, rank, batch) variant in the grid
we lower a fused packed-LoRA train step and an eval step; for the Table-7/8
kernel microbenchmarks we lower standalone packed fwd/bwd kernels. The Rust
runtime discovers everything through ``artifacts/manifest.json``.

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version the
``xla`` crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import io_bin, pretrain, tasks
from compile import model as M
from compile.kernels import packed_lora as pk

# ---------------------------------------------------------------------------
# Variant grids (kept small enough for single-core compile times; the Rust
# planner maps any requested pack onto the nearest available bucket).
# ---------------------------------------------------------------------------

# (n_adapters, r_pad, batch) buckets per model.
TRAIN_GRID = {
    "nano": [(1, 8, 1), (2, 8, 1), (4, 8, 1), (2, 8, 2)],
    "tiny": [
        (n, r, b)
        for n in (1, 2, 4, 8)
        for r in (8, 32)
        for b in (1, 4)
    ],
    "small": [(1, 32, 1), (4, 32, 1), (8, 32, 1)],
    "base": [(1, 32, 1), (2, 32, 1)],
}

# Pretraining budgets: (steps, batch) — see pretrain.py for why these exist.
PRETRAIN = {"nano": (200, 16), "tiny": (300, 16), "small": (120, 8), "base": (60, 4)}

DEFAULT_MODELS = ["nano", "tiny", "small", "base"]

# Kernel microbenchmark geometries (Table 7/8 scaled to testbed: the paper
# uses d in {2048, 3584, 11008, 18944} with r=64 at seq 512-2048; we scale to
# the `small` TinyLM geometry with r=16, m=128 — DESIGN.md §3).
KERNEL_GEOMS = {"attn": (256, 256), "mlp": (256, 1024)}
KERNEL_NS = [1, 2, 8, 32]
KERNEL_R = 16
KERNEL_M = 16  # small m: the paper's low-arithmetic-intensity regime — per-adapter compute sits below the dispatch overhead that packing amortizes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(d) -> str:
    return {jnp.dtype(jnp.float32): "f32", jnp.dtype(jnp.int32): "i32"}[jnp.dtype(d)]


def _io_entry(name, s):
    return {"name": name, "dtype": _dt(s.dtype), "shape": list(s.shape)}


# ---------------------------------------------------------------------------
# Train / eval step signatures (flat argument lists; order is the contract
# with rust/src/runtime — names recorded per-artifact in the manifest).
# ---------------------------------------------------------------------------


def train_signature(spec: M.ModelSpec, n: int, r: int, bs: int):
    """Ordered (name, ShapeDtypeStruct) inputs for a train-step artifact."""
    sig = []
    base = M.init_base(spec, jax.random.PRNGKey(0))
    for k in M.BASE_ORDER:
        sig.append((k, _sds(base[k].shape)))
    lora_shapes = {}
    for p in M.PROJS:
        din, dout = M.proj_dims(spec, p)
        lora_shapes[f"a_{p}"] = (spec.n_layers, n, din, r)
        lora_shapes[f"b_{p}"] = (spec.n_layers, n, r, dout)
    for k in M.LORA_ORDER:
        sig.append((k, _sds(lora_shapes[k])))
    for k in M.LORA_ORDER:
        sig.append((f"m_{k}", _sds(lora_shapes[k])))
    for k in M.LORA_ORDER:
        sig.append((f"v_{k}", _sds(lora_shapes[k])))
    sig += [
        ("t", _sds((n,))),
        ("tokens", _sds((n, bs, spec.seq), jnp.int32)),
        ("targets", _sds((n, bs, spec.seq), jnp.int32)),
        ("loss_mask", _sds((n, bs, spec.seq))),
        ("scale", _sds((n,))),
        ("lr", _sds((n,))),
        ("rmask", _sds((n, r))),
    ]
    return sig


def make_train_fn(spec: M.ModelSpec):
    nb, nl = len(M.BASE_ORDER), len(M.LORA_ORDER)

    def fn(*flat):
        base = M.unflatten_base(flat[:nb])
        lora = M.unflatten_lora(flat[nb : nb + nl])
        m = M.unflatten_lora(flat[nb + nl : nb + 2 * nl])
        v = M.unflatten_lora(flat[nb + 2 * nl : nb + 3 * nl])
        t, tokens, targets, mask, scale, lr, rmask = flat[nb + 3 * nl :]
        lora2, m2, v2, t2, per = M.train_step(
            spec, base, lora, m, v, t, tokens, targets, mask, scale, lr, rmask
        )
        return (
            tuple(M.flatten_lora(lora2))
            + tuple(M.flatten_lora(m2))
            + tuple(M.flatten_lora(v2))
            + (t2, per)
        )

    return fn


def train_output_names():
    return (
        list(M.LORA_ORDER)
        + [f"m_{k}" for k in M.LORA_ORDER]
        + [f"v_{k}" for k in M.LORA_ORDER]
        + ["t", "per_loss"]
    )


def eval_signature(spec: M.ModelSpec, n: int, r: int, bs: int):
    sig = train_signature(spec, n, r, bs)
    names = {"tokens", "targets", "loss_mask", "scale"}
    keep = [e for e in sig if e[0] in set(M.BASE_ORDER) | set(M.LORA_ORDER) | names]
    return keep


def make_eval_fn(spec: M.ModelSpec):
    nb, nl = len(M.BASE_ORDER), len(M.LORA_ORDER)

    def fn(*flat):
        base = M.unflatten_base(flat[:nb])
        lora = M.unflatten_lora(flat[nb : nb + nl])
        tokens, targets, mask, scale = flat[nb + nl :]
        loss, acc = M.eval_step(spec, base, lora, scale, tokens, targets, mask)
        return (loss, acc)

    return fn


# NB: eval_signature ordering must match make_eval_fn: base, lora, then
# (tokens, targets, loss_mask, scale) — train_signature lists them in exactly
# that relative order, so the filtered list is already correct.


# ---------------------------------------------------------------------------
# Kernel microbenchmark artifacts (Table 7/8)
# ---------------------------------------------------------------------------


def kernel_fwd_signature(n, d, k, r, m):
    return [
        ("x", _sds((n, m, d))),
        ("a", _sds((n, d, r))),
        ("b", _sds((n, r, k))),
        ("alpha", _sds((n,))),
    ]


def kernel_bwd_signature(n, d, k, r, m):
    return kernel_fwd_signature(n, d, k, r, m) + [("g", _sds((n, m, k)))]


def kernel_fwd_fn(x, a, b, alpha):
    # Full-block tiling (tile_n = n, tile_k = k): one interpret-mode grid
    # block — the CPU-roofline configuration found in the §Perf L1 pass
    # (tile_n=1 costs O(blocks x output) interpreter copies). A real-TPU
    # build would keep k-tiling and let auto_tile_n bound VMEM.
    n, _, _ = x.shape
    k = b.shape[2]
    return (pk.packed_lora_fwd(x, a, b, alpha, tile_n=n, tile_k=k),)


def kernel_bwd_fn(x, a, b, alpha, g):
    n, m, _ = x.shape
    k = g.shape[2]
    d = x.shape[2]
    db = pk.packed_lora_db(x, a, g, alpha, tile_n=n, tile_k=k)
    dh = pk.packed_lora_dh(g, b, alpha, tile_n=n, tile_k=k)
    da = pk.packed_lora_da(x, dh, tile_n=n, tile_d=d)
    dx = pk.packed_lora_dx(dh, a, tile_n=n, tile_d=d)
    return (dx, da, db)


def kernel_report(n, d, k, r, m):
    """Analytic VMEM/MXU estimate for a packed-LoRA fwd block (DESIGN.md §8).

    interpret=True gives CPU-numpy timing only, so real-TPU efficiency is
    estimated structurally: VMEM residency of one grid block and the MXU
    utilization implied by the inner dot shapes (128x128 systolic array).
    """
    bm = min(m, pk.DEF_TILE_M)
    bk = min(k, pk.DEF_TILE_K)
    vmem = 4 * (bm * d + d * r + r * bk + bm * bk)  # x, a, b, y blocks (f32)
    # Two chained dots per block: (bm,d)x(d,r) and (bm,r)x(r,bk).
    # MXU lanes used are bounded by each dot's inner/outer dims vs 128.
    util1 = min(bm, 128) * min(r, 128) / (128 * 128)
    util2 = min(bm, 128) * min(bk, 128) / (128 * 128)
    flops = 2 * n * m * r * (d + k)
    return {
        "vmem_bytes_per_block": vmem,
        "mxu_util_dot1": util1,
        "mxu_util_dot2": util2,
        "flops": flops,
    }


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def lower_artifact(out_dir, name, fn, sig, kind, meta, out_names=None):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*[s for _, s in sig])
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *[s for _, s in sig])
    outs = [
        _io_entry(out_names[i] if out_names else f"out{i}", s)
        for i, s in enumerate(out_shapes)
    ]
    entry = {
        "name": name,
        "kind": kind,
        "path": path,
        "inputs": [_io_entry(nm, s) for nm, s in sig],
        "outputs": outs,
        **meta,
    }
    print(f"  lowered {name} ({len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s)")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=DEFAULT_MODELS)
    ap.add_argument("--force-pretrain", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--kernels-only", action="store_true",
                    help="re-lower only the kernel artifacts, patching the existing manifest")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    if args.kernels_only:
        mpath = os.path.join(out, "manifest.json")
        manifest = json.load(open(mpath))
        manifest["artifacts"] = [
            a for a in manifest["artifacts"]
            if a["kind"] not in ("kernel_fwd", "kernel_bwd")
        ]
        manifest["kernel_report"] = {}
        for geom, (d, k) in KERNEL_GEOMS.items():
            for n in KERNEL_NS:
                meta = {"geom": geom, "n": n, "d": d, "k": k,
                        "r": KERNEL_R, "m": KERNEL_M}
                manifest["artifacts"].append(
                    lower_artifact(out, f"kfwd_{geom}_n{n}", kernel_fwd_fn,
                                   kernel_fwd_signature(n, d, k, KERNEL_R, KERNEL_M),
                                   "kernel_fwd", meta, out_names=["y"]))
                manifest["artifacts"].append(
                    lower_artifact(out, f"kbwd_{geom}_n{n}", kernel_bwd_fn,
                                   kernel_bwd_signature(n, d, k, KERNEL_R, KERNEL_M),
                                   "kernel_bwd", meta, out_names=["dx", "da", "db"]))
                manifest["kernel_report"][f"{geom}_n{n}"] = kernel_report(
                    n, d, k, KERNEL_R, KERNEL_M)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"patched {mpath} (kernels only)")
        return

    manifest = {
        "version": 1,
        "token_layout": {
            "pad": tasks.PAD, "bos": tasks.BOS, "sep": tasks.SEP,
            "eos": tasks.EOS, "alpha0": tasks.ALPHA0,
        },
        "tasks": list(tasks.TASKS),
        "models": {},
        "artifacts": [],
        "kernel_report": {},
    }

    for mname in args.models:
        spec = M.MODELS[mname]
        wpath = os.path.join(out, f"weights_{mname}.bin")
        metrics = {}
        if os.path.exists(wpath) and not args.force_pretrain:
            print(f"[{mname}] reusing pretrained weights {wpath}")
            mpath = wpath + ".metrics.json"
            if os.path.exists(mpath):
                metrics = json.load(open(mpath))
        else:
            steps, bsz = PRETRAIN[mname]
            print(f"[{mname}] pretraining base ({spec.param_count()/1e6:.2f}M params, "
                  f"{steps} steps, bs {bsz})")
            base, metrics = pretrain.pretrain(spec, steps=steps, bsz=bsz)
            io_bin.write_tensors(
                wpath, [(k, np.asarray(base[k])) for k in M.BASE_ORDER]
            )
            json.dump(metrics, open(wpath + ".metrics.json", "w"))
        manifest["models"][mname] = {
            "vocab": spec.vocab, "d_model": spec.d_model,
            "n_layers": spec.n_layers, "n_heads": spec.n_heads,
            "d_ff": spec.d_ff, "seq": spec.seq,
            "params": spec.param_count(),
            "weights": f"weights_{mname}.bin",
            "pretrain": metrics,
        }

        for (n, r, bs) in TRAIN_GRID[mname]:
            meta = {"model": mname, "n": n, "r": r, "bs": bs, "seq": spec.seq}
            manifest["artifacts"].append(
                lower_artifact(
                    out, f"train_{mname}_n{n}_r{r}_b{bs}", make_train_fn(spec),
                    train_signature(spec, n, r, bs), "train", meta,
                    out_names=train_output_names(),
                )
            )
            manifest["artifacts"].append(
                lower_artifact(
                    out, f"eval_{mname}_n{n}_r{r}_b{bs}", make_eval_fn(spec),
                    eval_signature(spec, n, r, bs), "eval", meta,
                    out_names=["loss", "acc"],
                )
            )

    if not args.skip_kernels:
        for geom, (d, k) in KERNEL_GEOMS.items():
            for n in KERNEL_NS:
                meta = {"geom": geom, "n": n, "d": d, "k": k,
                        "r": KERNEL_R, "m": KERNEL_M}
                manifest["artifacts"].append(
                    lower_artifact(
                        out, f"kfwd_{geom}_n{n}", kernel_fwd_fn,
                        kernel_fwd_signature(n, d, k, KERNEL_R, KERNEL_M),
                        "kernel_fwd", meta, out_names=["y"],
                    )
                )
                manifest["artifacts"].append(
                    lower_artifact(
                        out, f"kbwd_{geom}_n{n}", kernel_bwd_fn,
                        kernel_bwd_signature(n, d, k, KERNEL_R, KERNEL_M),
                        "kernel_bwd", meta, out_names=["dx", "da", "db"],
                    )
                )
                manifest["kernel_report"][f"{geom}_n{n}"] = kernel_report(
                    n, d, k, KERNEL_R, KERNEL_M
                )

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
