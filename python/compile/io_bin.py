"""PLORAT01 tensor container — the weights interchange format.

Written once at build time (pretrained base checkpoints, initial LoRA/opt
state), read by the Rust runtime (``rust/src/runtime/tensor_file.rs``). The
format is deliberately trivial so both sides stay in lock-step:

    magic   8 bytes  b"PLORAT01"
    count   u32 LE
    tensor* count times:
        name_len u32 LE, name utf-8
        dtype    u8      (0 = f32, 1 = i32)
        ndim     u8
        dims     u32 LE * ndim
        data     raw LE bytes (prod(dims) * itemsize)
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"PLORAT01"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.dtype(np.float32), 1: np.dtype(np.int32)}


def write_tensors(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = DTYPES_INV[dt]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims)
    return out
