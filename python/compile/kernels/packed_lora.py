"""L1: Packed multi-adapter LoRA kernels (Pallas).

This is the PLoRA §5 kernel contribution re-thought for the TPU/Pallas
programming model (see DESIGN.md §Hardware-Adaptation):

  paper (CUDA/CUTLASS)                      here (Pallas)
  ------------------------------------      ----------------------------------
  threadblock tiles over (seq, hidden)      BlockSpec grid (adapter, seq-tile,
                                            out-tile)
  never tile the rank dim (r is tiny)       rank lives whole inside every block
  shared-memory staging of A/B slices       A_i / B_i blocks are VMEM-resident
  warp MMA (16,8,16) on tensor cores        MXU-shaped jnp.dot per block
  streams for concurrent adapters           adapters are a leading grid axis

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret-mode lowers to plain HLO that runs everywhere.
Correctness oracle: :mod:`compile.kernels.ref` (pure jnp), checked by pytest +
hypothesis sweeps in ``python/tests/test_kernel.py``.

Shapes (n = adapters packed in the job, m = batch*seq flattened):
  x      (n, m, d)   per-adapter input activations
  a      (n, d, r)   LoRA A (rank-padded to the pack's r_pad)
  b      (n, r, k)   LoRA B
  alpha  (n,)        per-adapter scaling factor
  y      (n, m, k)   LoRA delta output:  y_i = alpha_i * (x_i @ a_i) @ b_i

Backward (upstream g = dL/dy, shape (n, m, k)) — the paper's four cases:
  case 1  dB_i = alpha_i * (x_i a_i)^T g_i      tile k, accumulate over m
  case 2  dH_i = alpha_i * g_i b_i^T            tile m, accumulate over k
  case 3  dA_i = x_i^T dH_i                     tile d, accumulate over m
  case 4  dX_i = dH_i a_i^T                     tile (m, d), reduce over r
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles. Actual tiles shrink to divisors for small
# problems (tests sweep tiny shapes); see _tile().
DEF_TILE_M = 128
DEF_TILE_K = 128
DEF_TILE_D = 128

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (keeps grids exact)."""
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


# Adapters per block (the CUTLASS "threadblock shape" analogue for the pack
# axis). On TPU, VMEM bounds tile_n near 1-4; on interpret-mode CPU, large
# tile_n collapses the grid and avoids the O(blocks x output) copy cost of
# dynamic-update-slice in the interpreter's while loop (§Perf L1 — measured
# quadratic blow-up with tile_n=1). `auto_tile_n` picks the largest tile_n
# whose block working set stays under a VMEM budget.
VMEM_BUDGET = 12 * 1024 * 1024  # bytes (TPU v4 VMEM is 16 MiB/core)


def auto_tile_n(n: int, block_bytes_per_adapter: int, budget: int = VMEM_BUDGET) -> int:
    per = max(block_bytes_per_adapter, 1)
    return _tile(n, max(budget // per, 1))


# ---------------------------------------------------------------------------
# Forward: y_i = alpha_i * (x_i @ a_i) @ b_i
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, a_ref, b_ref, alpha_ref, y_ref):
    # Blocks: x (bn, bm, d), a (bn, d, r), b (bn, r, bk), alpha (bn,),
    # y (bn, bm, bk) — batched over the bn adapters resident in the block.
    h = jnp.einsum(
        "nmd,ndr->nmr", x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )
    y = jnp.einsum("nmr,nrk->nmk", h, b_ref[...], preferred_element_type=jnp.float32)
    y_ref[...] = (alpha_ref[...][:, None, None] * y).astype(y_ref.dtype)


def packed_lora_fwd(
    x, a, b, alpha, *, tile_m: int = DEF_TILE_M, tile_k: int = DEF_TILE_K, tile_n: int = 0
):
    """Packed LoRA delta forward for n adapters in one kernel launch."""
    n, m, d = x.shape
    _, _, r = a.shape
    k = b.shape[2]
    bm, bk = _tile(m, tile_m), _tile(k, tile_k)
    bn = _tile(n, tile_n) if tile_n else auto_tile_n(n, 4 * (bm * d + d * r + r * bk + bm * bk))
    grid = (n // bn, m // bm, k // bk)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm, d), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((bn, d, r), lambda i, j, l: (i, 0, 0)),
            pl.BlockSpec((bn, r, bk), lambda i, j, l: (i, 0, l)),
            pl.BlockSpec((bn,), lambda i, j, l: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bm, bk), lambda i, j, l: (i, j, l)),
        out_shape=jax.ShapeDtypeStruct((n, m, k), x.dtype),
        interpret=INTERPRET,
    )(x, a, b, alpha)


# ---------------------------------------------------------------------------
# Backward case 1: dB_i = alpha_i * (x_i @ a_i)^T @ g_i
#   Grid (n, k-tiles, m-tiles); m is the innermost (accumulation) axis so the
#   output block for a (n, k-tile) pair is revisited consecutively.
# ---------------------------------------------------------------------------


def _db_kernel(x_ref, a_ref, g_ref, alpha_ref, db_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        db_ref[...] = jnp.zeros_like(db_ref)

    h = jnp.einsum(
        "nmd,ndr->nmr", x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )
    part = jnp.einsum("nmr,nmk->nrk", h, g_ref[...].astype(jnp.float32))
    db_ref[...] += (alpha_ref[...][:, None, None] * part).astype(db_ref.dtype)


def packed_lora_db(
    x, a, g, alpha, *, tile_m: int = DEF_TILE_M, tile_k: int = DEF_TILE_K, tile_n: int = 0
):
    n, m, d = x.shape
    r = a.shape[2]
    k = g.shape[2]
    bm, bk = _tile(m, tile_m), _tile(k, tile_k)
    bn = _tile(n, tile_n) if tile_n else auto_tile_n(n, 4 * (bm * d + d * r + bm * bk + r * bk))
    grid = (n // bn, k // bk, m // bm)
    return pl.pallas_call(
        _db_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm, d), lambda i, l, j: (i, j, 0)),
            pl.BlockSpec((bn, d, r), lambda i, l, j: (i, 0, 0)),
            pl.BlockSpec((bn, bm, bk), lambda i, l, j: (i, j, l)),
            pl.BlockSpec((bn,), lambda i, l, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, r, bk), lambda i, l, j: (i, 0, l)),
        out_shape=jax.ShapeDtypeStruct((n, r, k), a.dtype),
        interpret=INTERPRET,
    )(x, a, g, alpha)


# ---------------------------------------------------------------------------
# Backward case 2: dH_i = alpha_i * g_i @ b_i^T   (grad wrt h = x a)
#   Tile over the sequence dim; accumulate over k-tiles (innermost axis).
# ---------------------------------------------------------------------------


def _dh_kernel(g_ref, b_ref, alpha_ref, dh_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        dh_ref[...] = jnp.zeros_like(dh_ref)

    part = jnp.einsum(
        "nmk,nrk->nmr",
        g_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
    )
    dh_ref[...] += (alpha_ref[...][:, None, None] * part).astype(dh_ref.dtype)


def packed_lora_dh(
    g, b, alpha, *, tile_m: int = DEF_TILE_M, tile_k: int = DEF_TILE_K, tile_n: int = 0
):
    n, m, k = g.shape
    r = b.shape[1]
    bm, bk = _tile(m, tile_m), _tile(k, tile_k)
    bn = _tile(n, tile_n) if tile_n else auto_tile_n(n, 4 * (bm * bk + r * bk + bm * r))
    grid = (n // bn, m // bm, k // bk)
    return pl.pallas_call(
        _dh_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm, bk), lambda i, j, l: (i, j, l)),
            pl.BlockSpec((bn, r, bk), lambda i, j, l: (i, 0, l)),
            pl.BlockSpec((bn,), lambda i, j, l: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bm, r), lambda i, j, l: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m, r), g.dtype),
        interpret=INTERPRET,
    )(g, b, alpha)


# ---------------------------------------------------------------------------
# Backward case 3: dA_i = x_i^T @ dH_i
#   Tile over the hidden dim d; accumulate over m-tiles (innermost axis).
# ---------------------------------------------------------------------------


def _da_kernel(x_ref, dh_ref, da_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)

    part = jnp.einsum(
        "nmd,nmr->ndr",
        x_ref[...].astype(jnp.float32),
        dh_ref[...].astype(jnp.float32),
    )
    da_ref[...] += part.astype(da_ref.dtype)


def packed_lora_da(
    x, dh, *, tile_m: int = DEF_TILE_M, tile_d: int = DEF_TILE_D, tile_n: int = 0
):
    n, m, d = x.shape
    r = dh.shape[2]
    bm, bd = _tile(m, tile_m), _tile(d, tile_d)
    bn = _tile(n, tile_n) if tile_n else auto_tile_n(n, 4 * (bm * bd + bm * r + bd * r))
    grid = (n // bn, d // bd, m // bm)
    return pl.pallas_call(
        _da_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm, bd), lambda i, l, j: (i, j, l)),
            pl.BlockSpec((bn, bm, r), lambda i, l, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd, r), lambda i, l, j: (i, l, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d, r), x.dtype),
        interpret=INTERPRET,
    )(x, dh)


# ---------------------------------------------------------------------------
# Backward case 4: dX_i = dH_i @ a_i^T
#   Tile over (m, d); the rank dim is the (whole, in-VMEM) reduction axis.
# ---------------------------------------------------------------------------


def _dx_kernel(dh_ref, a_ref, dx_ref):
    part = jnp.einsum(
        "nmr,ndr->nmd",
        dh_ref[...].astype(jnp.float32),
        a_ref[...].astype(jnp.float32),
    )
    dx_ref[...] = part.astype(dx_ref.dtype)


def packed_lora_dx(
    dh, a, *, tile_m: int = DEF_TILE_M, tile_d: int = DEF_TILE_D, tile_n: int = 0
):
    n, m, r = dh.shape
    d = a.shape[1]
    bm, bd = _tile(m, tile_m), _tile(d, tile_d)
    bn = _tile(n, tile_n) if tile_n else auto_tile_n(n, 4 * (bm * r + bd * r + bm * bd))
    grid = (n // bn, m // bm, d // bd)
    return pl.pallas_call(
        _dx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm, r), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((bn, bd, r), lambda i, j, l: (i, l, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm, bd), lambda i, j, l: (i, j, l)),
        out_shape=jax.ShapeDtypeStruct((n, m, d), dh.dtype),
        interpret=INTERPRET,
    )(dh, a)


# ---------------------------------------------------------------------------
# Differentiable packed LoRA delta (custom VJP wiring the four cases).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def packed_lora_delta(x, a, b, alpha):
    """alpha_i * (x_i @ a_i) @ b_i for every adapter i, as one fused kernel.

    ``alpha`` is a hyperparameter (per-adapter scaling), not a trained
    weight — its cotangent is zero.
    """
    return packed_lora_fwd(x, a, b, alpha)


def _delta_fwd(x, a, b, alpha):
    return packed_lora_fwd(x, a, b, alpha), (x, a, b, alpha)


def _delta_bwd(res, g):
    x, a, b, alpha = res
    db = packed_lora_db(x, a, g, alpha)  # case 1
    dh = packed_lora_dh(g, b, alpha)  # case 2
    da = packed_lora_da(x, dh)  # case 3
    dx = packed_lora_dx(dh, a)  # case 4
    dalpha = jnp.zeros_like(alpha)
    return dx, da, db, dalpha


packed_lora_delta.defvjp(_delta_fwd, _delta_bwd)


def packed_lora_apply(x, w, a, b, alpha):
    """Full packed-LoRA projection: y_i = x_i @ W + alpha_i (x_i a_i) b_i.

    The frozen base weight ``w (d, k)`` is shared: its GEMM is batched over
    the concatenation of every adapter's tokens (the paper's §3.2 workflow),
    while the adapter deltas go through the packed kernels.
    """
    n, m, d = x.shape
    k = w.shape[1]
    base = jnp.dot(x.reshape(n * m, d), w).reshape(n, m, k)
    return base + packed_lora_delta(x, a, b, alpha)


def sequential_lora_apply(x, w, a, b, alpha):
    """Naive baseline (paper §5.1): batch the base GEMM, then loop adapters.

    Used by the Table-7/8 benches as the 'sequential LoRA computation'
    comparator and by tests as a second oracle.
    """
    n, m, d = x.shape
    k = w.shape[1]
    base = jnp.dot(x.reshape(n * m, d), w).reshape(n, m, k)
    deltas = []
    for i in range(n):
        h = jnp.dot(x[i], a[i])
        deltas.append(alpha[i] * jnp.dot(h, b[i]))
    return base + jnp.stack(deltas)
