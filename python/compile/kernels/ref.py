"""Pure-jnp correctness oracle for the packed LoRA kernels.

Every Pallas kernel in :mod:`compile.kernels.packed_lora` is checked against
these einsum references by the pytest/hypothesis suite. The references are
also the autodiff ground truth: the kernel custom-VJP must match
``jax.vjp`` of :func:`ref_delta`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_delta(x, a, b, alpha):
    """alpha_i * (x_i @ a_i) @ b_i, computed densely with einsum."""
    h = jnp.einsum("nmd,ndr->nmr", x, a)
    y = jnp.einsum("nmr,nrk->nmk", h, b)
    return alpha[:, None, None] * y


def ref_apply(x, w, a, b, alpha):
    """x_i @ W + delta_i — full packed-LoRA projection."""
    return jnp.einsum("nmd,dk->nmk", x, w) + ref_delta(x, a, b, alpha)


def ref_grads(x, a, b, alpha, g):
    """Reference cotangents for (x, a, b) under upstream gradient ``g``."""
    h = jnp.einsum("nmd,ndr->nmr", x, a)
    db = alpha[:, None, None] * jnp.einsum("nmr,nmk->nrk", h, g)  # case 1
    dh = alpha[:, None, None] * jnp.einsum("nmk,nrk->nmr", g, b)  # case 2
    da = jnp.einsum("nmd,nmr->ndr", x, dh)  # case 3
    dx = jnp.einsum("nmr,ndr->nmd", dh, a)  # case 4
    return dx, da, db


def ref_vjp(x, a, b, alpha, g):
    """Autodiff ground truth via jax.vjp (alpha excluded: hyperparameter)."""
    _, pull = jax.vjp(lambda x_, a_, b_: ref_delta(x_, a_, b_, alpha), x, a, b)
    return pull(g)
