"""L2: TinyLM — a decoder-only transformer with *packed* multi-adapter LoRA.

This is the compute graph that PLoRA fine-tunes. It mirrors the paper's
setup at testbed scale (see DESIGN.md §3 substitution ledger):

- A frozen base model (the paper: Qwen-2.5 / LLaMa-3; here: TinyLM sizes
  ``nano``/``tiny``/``small``/``base`` with the same architectural skeleton —
  pre-LN attention + gated MLP).
- LoRA adapters on the paper's seven projections: Q, K, V, O in attention and
  up, gate, down in the MLP (Appendix A, Eq. 20).
- ``n`` adapters are packed into one job: every adapter receives its own
  token batch; the base GEMMs are batched across adapters while the adapter
  deltas go through the L1 packed Pallas kernels (§5).
- Heterogeneous packs: ranks are zero-padded to the pack's ``r_pad`` and
  batches padded to the pack max with a loss mask (gradient-stable; tested).

Everything here is build-time Python: ``aot.py`` lowers ``train_step`` /
``eval_step`` to HLO text once, and the Rust engine replays them via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.packed_lora import packed_lora_delta

# ---------------------------------------------------------------------------
# Model geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int  # fixed training sequence length (paper uses 1024)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # attn + mlp + 2 LN
        return v * d + self.seq * d + L * per_layer + d

    def lora_param_count(self, r: int) -> int:
        d, f, L = self.d_model, self.d_ff, self.n_layers
        # Q,K,V,O: (d->d) x4 ; up,gate: d->f ; down: f->d
        per_layer = 4 * (d * r + r * d) + 2 * (d * r + r * f) + (f * r + r * d)
        return L * per_layer


MODELS: Dict[str, ModelSpec] = {
    "nano": ModelSpec("nano", vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=256, seq=32),
    "tiny": ModelSpec("tiny", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=512, seq=64),
    "small": ModelSpec("small", vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=1024, seq=64),
    "base": ModelSpec("base", vocab=4096, d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq=128),
}

# The seven LoRA-able projections (paper Appendix A): name -> (in, out) dims.
PROJS = ("q", "k", "v", "o", "up", "gate", "down")


def proj_dims(spec: ModelSpec, p: str) -> Tuple[int, int]:
    d, f = spec.d_model, spec.d_ff
    return {
        "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
        "up": (d, f), "gate": (d, f), "down": (f, d),
    }[p]


# ---------------------------------------------------------------------------
# Parameter initialisation (base is "pretrained" by pretrain.py at build time)
# ---------------------------------------------------------------------------


def init_base(spec: ModelSpec, key) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 12)
    d, f, v, L, s = spec.d_model, spec.d_ff, spec.vocab, spec.n_layers, spec.seq

    def norm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    return {
        "embed": norm(ks[0], (v, d), 0.02),
        "pos": norm(ks[1], (s, d), 0.02),
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "wq": norm(ks[2], (L, d, d), d ** -0.5),
        "wk": norm(ks[3], (L, d, d), d ** -0.5),
        "wv": norm(ks[4], (L, d, d), d ** -0.5),
        "wo": norm(ks[5], (L, d, d), d ** -0.5),
        "wup": norm(ks[6], (L, d, f), d ** -0.5),
        "wgate": norm(ks[7], (L, d, f), d ** -0.5),
        "wdown": norm(ks[8], (L, f, d), f ** -0.5),
        "lnf": jnp.ones((d,), jnp.float32),
    }


def init_lora(spec: ModelSpec, n: int, r: int, key) -> Dict[str, jnp.ndarray]:
    """LoRA params for a pack of ``n`` adapters at (padded) rank ``r``.

    A ~ N(0, 1/d_in); B = 0 (the standard LoRA init: delta starts at zero).
    Layout: {"a_<p>": (L, n, d_in, r), "b_<p>": (L, n, r, d_out)}.
    """
    params = {}
    ks = jax.random.split(key, len(PROJS))
    for kk, p in zip(ks, PROJS):
        din, dout = proj_dims(spec, p)
        params[f"a_{p}"] = (
            jax.random.normal(kk, (spec.n_layers, n, din, r)) / np.sqrt(din)
        ).astype(jnp.float32)
        params[f"b_{p}"] = jnp.zeros((spec.n_layers, n, r, dout), jnp.float32)
    return params


def rank_mask(n: int, r_pad: int, ranks) -> jnp.ndarray:
    """(n, r_pad) 0/1 mask: adapter i uses its true rank ranks[i] <= r_pad."""
    ranks = jnp.asarray(ranks)
    return (jnp.arange(r_pad)[None, :] < ranks[:, None]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layernorm(x, g):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def _proj(x_flat, w, a, b, scale):
    """Packed-LoRA projection: x (n, m, din) -> (n, m, dout)."""
    n, m, din = x_flat.shape
    base = jnp.dot(x_flat.reshape(n * m, din), w).reshape(n, m, -1)
    return base + packed_lora_delta(x_flat, a, b, scale)


def forward(spec: ModelSpec, base, lora, scale, tokens):
    """Packed forward. tokens (n, bsz, s) int32 -> logits (n, bsz, s, vocab).

    ``scale`` is the per-adapter effective scaling alpha_i / r_i (n,).
    The base weights are shared across adapters (frozen); adapter deltas use
    the L1 packed kernels. Layers run under lax.scan to keep the lowered HLO
    compact (DESIGN.md §Perf L2).
    """
    n, bsz, s = tokens.shape
    d, H, dh = spec.d_model, spec.n_heads, spec.d_head
    x = base["embed"][tokens] + base["pos"][None, None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    layer_ws = (
        base["ln1"], base["wq"], base["wk"], base["wv"], base["wo"],
        base["ln2"], base["wup"], base["wgate"], base["wdown"],
    )
    layer_lora = tuple(lora[f"a_{p}"] for p in PROJS) + tuple(
        lora[f"b_{p}"] for p in PROJS
    )

    def layer(x, ws):
        (ln1, wq, wk, wv, wo, ln2, wup, wgate, wdown), (
            aq, ak, av, ao, aup, agate, adown,
            bq, bk, bv, bo, bup, bgate, bdown,
        ) = ws
        h = _layernorm(x, ln1)
        hf = h.reshape(n, bsz * s, d)
        q = _proj(hf, wq, aq, bq, scale).reshape(n, bsz, s, H, dh)
        k = _proj(hf, wk, ak, bk, scale).reshape(n, bsz, s, H, dh)
        v = _proj(hf, wv, av, bv, scale).reshape(n, bsz, s, H, dh)
        att = jnp.einsum("nbqhd,nbkhd->nbhqk", q, k) / np.sqrt(dh)
        att = jnp.where(causal[None, None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("nbhqk,nbkhd->nbqhd", att, v).reshape(n, bsz * s, d)
        x = x + _proj(o, wo, ao, bo, scale).reshape(n, bsz, s, d)

        h = _layernorm(x, ln2)
        hf = h.reshape(n, bsz * s, d)
        up = _proj(hf, wup, aup, bup, scale)
        gate = _proj(hf, wgate, agate, bgate, scale)
        act = jax.nn.silu(gate) * up
        x = x + _proj(act, wdown, adown, bdown, scale).reshape(n, bsz, s, d)
        return x, None

    x, _ = jax.lax.scan(layer, x, (layer_ws, layer_lora))
    x = _layernorm(x, base["lnf"])
    logits = jnp.einsum("nbsd,vd->nbsv", x, base["embed"])
    return logits


# ---------------------------------------------------------------------------
# Loss / train step (AdamW on LoRA params only, per-adapter learning rate)
# ---------------------------------------------------------------------------


def packed_loss(spec, base, lora, scale, tokens, targets, loss_mask):
    """Per-adapter mean CE loss. loss_mask (n, bsz, s): 1 on answer tokens of
    real (non-padding) samples, 0 elsewhere. Returns (sum_loss, per_adapter)."""
    logits = forward(spec, base, lora, scale, tokens)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per = jnp.sum(nll * loss_mask, axis=(1, 2)) / jnp.maximum(
        jnp.sum(loss_mask, axis=(1, 2)), 1.0
    )
    # Sum (not mean) over adapters: gradients of adapter i must not depend on
    # how many other adapters are packed with it (paper §3.2: computation is
    # identical to single-adapter fine-tuning).
    return jnp.sum(per), per


ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.999, 1e-8, 0.0


def train_step(spec, base, lora, m, v, t, tokens, targets, loss_mask, scale, lr, rmask):
    """One packed fine-tuning step: fwd + bwd + per-adapter AdamW on LoRA.

    ``lr`` (n,) per-adapter learning rate; ``rmask`` (n, r_pad) keeps padded
    rank columns exactly zero (belt-and-braces on top of the zero-grad
    property). ``t`` (n,) is the per-adapter step counter: each adapter's
    bias correction runs on its own clock, so one admitted into a running
    pack mid-job starts at its own step 1 (identical to a solo run).
    Returns (lora', m', v', t+1, per_adapter_loss).
    """
    (_, per), grads = jax.value_and_grad(
        lambda lp: packed_loss(spec, base, lp, scale, tokens, targets, loss_mask),
        has_aux=True,
    )(lora)

    t = t + 1.0
    bc1 = (1.0 - ADAM_B1 ** t)[None, :, None, None]
    bc2 = (1.0 - ADAM_B2 ** t)[None, :, None, None]

    new_lora, new_m, new_v = {}, {}, {}
    for key in sorted(lora):
        g = grads[key]
        # mask padded ranks: a_* has rank on axis -1, b_* on axis -2
        if key.startswith("a_"):
            km = rmask[None, :, None, :]
        else:
            km = rmask[None, :, :, None]
        g = g * km
        m1 = ADAM_B1 * m[key] + (1 - ADAM_B1) * g
        v1 = ADAM_B2 * v[key] + (1 - ADAM_B2) * g * g
        mh = m1 / bc1
        vh = v1 / bc2
        lr_b = lr[None, :, None, None]
        upd = lr_b * mh / (jnp.sqrt(vh) + ADAM_EPS)
        if WEIGHT_DECAY:
            upd = upd + lr_b * WEIGHT_DECAY * lora[key]
        new_lora[key] = (lora[key] - upd) * km
        new_m[key] = m1
        new_v[key] = v1
    return new_lora, new_m, new_v, t, per


def eval_step(spec, base, lora, scale, tokens, targets, loss_mask):
    """Per-adapter eval: (loss, token-level accuracy on masked positions)."""
    logits = forward(spec, base, lora, scale, tokens)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask, axis=(1, 2)), 1.0)
    loss = jnp.sum(nll * loss_mask, axis=(1, 2)) / denom
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.sum((pred == targets) * loss_mask, axis=(1, 2)) / denom
    return loss, acc


# ---------------------------------------------------------------------------
# Deterministic flatten order (shared with aot.py and the Rust runtime)
# ---------------------------------------------------------------------------

BASE_ORDER = [
    "embed", "pos", "ln1", "ln2", "wq", "wk", "wv", "wo",
    "wup", "wgate", "wdown", "lnf",
]
LORA_ORDER = sorted(f"{t}_{p}" for p in PROJS for t in ("a", "b"))


def flatten_base(base) -> List[jnp.ndarray]:
    return [base[k] for k in BASE_ORDER]


def unflatten_base(flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(BASE_ORDER, flat))


def flatten_lora(lora) -> List[jnp.ndarray]:
    return [lora[k] for k in LORA_ORDER]


def unflatten_lora(flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(LORA_ORDER, flat))
