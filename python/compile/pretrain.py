"""Build-time pretraining of the TinyLM base models.

The paper fine-tunes pretrained Qwen/LLaMa checkpoints; we have no weights to
download, so ``make artifacts`` *produces* the frozen base checkpoints by
pretraining each TinyLM size on a mixture of the four synthetic tasks
(DESIGN.md §3). The mixture gives the base partial competence on every task —
LoRA fine-tuning then specializes it, which is exactly the regime the paper's
quality study (Tables 2–4, 6) needs: a base that is decent but improvable.

This module is plain jitted JAX (no Pallas) — it never ships to the Rust
side; only the resulting weights do, via ``io_bin.write_tensors``.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import tasks as T
from compile.model import ModelSpec, init_base


def forward_base(spec: ModelSpec, base, tokens):
    """Base-only forward (no LoRA), tokens (b, s) -> logits (b, s, v)."""
    b, s = tokens.shape
    d, H, dh = spec.d_model, spec.n_heads, spec.d_head
    x = base["embed"][tokens] + base["pos"][None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    def ln(x, g):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g

    layer_ws = tuple(
        base[k]
        for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wup", "wgate", "wdown")
    )

    def layer(x, ws):
        ln1, wq, wk, wv, wo, ln2, wup, wgate, wdown = ws
        h = ln(x, ln1)
        q = (h @ wq).reshape(b, s, H, dh)
        k = (h @ wk).reshape(b, s, H, dh)
        v = (h @ wv).reshape(b, s, H, dh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        x = x + o @ wo
        h = ln(x, ln2)
        x = x + (jax.nn.silu(h @ wgate) * (h @ wup)) @ wdown
        return x, None

    x, _ = jax.lax.scan(layer, x, layer_ws)
    x = ln(x, base["lnf"])
    return jnp.einsum("bsd,vd->bsv", x, base["embed"])


def _loss(spec, base, tokens, targets, mask):
    logits = forward_base(spec, base, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    # Pretrain on the full sequence (LM objective), not just answer spans:
    lm_mask = (targets != T.PAD).astype(jnp.float32)
    return jnp.sum(nll * lm_mask) / jnp.maximum(jnp.sum(lm_mask), 1.0)


def pretrain(
    spec: ModelSpec,
    steps: int = 400,
    bsz: int = 32,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 100,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, float]]:
    """AdamW pretraining on the uniform task mixture; returns (weights, metrics)."""
    rng = np.random.default_rng(seed)
    base = init_base(spec, jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, base)
    v = jax.tree.map(jnp.zeros_like, base)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(base, m, v, t, tokens, targets, mask):
        loss, g = jax.value_and_grad(lambda p: _loss(spec, p, tokens, targets, mask))(base)
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
        base = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
            base, m, v,
        )
        return base, m, v, loss

    t0 = time.time()
    first = last = None
    for i in range(1, steps + 1):
        task = T.TASKS[(i - 1) % len(T.TASKS)]
        tokens, targets, mask = T.batch(task, rng, bsz, spec.seq, spec.vocab)
        base, m, v, loss = step(base, m, v, float(i), tokens, targets, mask)
        loss = float(loss)
        if first is None:
            first = loss
        last = loss
        if i % log_every == 0 or i == 1:
            print(f"  pretrain[{spec.name}] step {i:4d}/{steps} loss {loss:.4f}")

    # Per-task answer-span accuracy of the pretrained base (manifest metric).
    accs = {}
    for task in T.TASKS:
        tokens, targets, mask = T.batch(task, rng, 64, spec.seq, spec.vocab)
        logits = forward_base(spec, base, tokens)
        pred = np.asarray(jnp.argmax(logits, axis=-1))
        hit = ((pred == targets) * mask).sum() / max(mask.sum(), 1.0)
        accs[task] = float(hit)
    metrics = {
        "loss_first": first,
        "loss_last": last,
        "seconds": time.time() - t0,
        **{f"acc_{k}": v for k, v in accs.items()},
    }
    return base, metrics
