"""Synthetic downstream tasks (build-time side).

The paper evaluates on GSM8K / mrpc / cola / wnli; those need model+data
downloads this environment does not have (repro band 0/5), so we substitute
four synthetic seq2seq tasks with the same *role*: distinguishable skills
whose optimal LoRA hyperparameters differ (DESIGN.md §3).

Token layout (shared with the Rust generators in ``rust/src/train/tasks.rs``
— keep in sync, the layout is also recorded in artifacts/manifest.json):

    0 PAD   1 BOS   2 SEP   3 EOS   4.. unused   8.. payload alphabet

Each sample is a fixed-length next-token-prediction triple
``(tokens, targets, loss_mask)`` of length ``seq``: ``targets`` is the
one-step shift and ``loss_mask`` is 1 exactly on positions whose target is
part of the answer span.
"""

from __future__ import annotations

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
ALPHA0 = 8  # first payload token

TASKS = ("modadd", "copy", "parity", "needle")


def _finalize(seq_tokens, answer_lo, answer_hi, seq):
    """Build (tokens, targets, mask) from a full sequence + answer span."""
    full = np.full(seq + 1, PAD, dtype=np.int32)
    L = min(len(seq_tokens), seq + 1)
    full[:L] = seq_tokens[:L]
    tokens = full[:-1]
    targets = full[1:]
    mask = np.zeros(seq, dtype=np.float32)
    # target position t predicts full[t+1]; answers live at [lo, hi) in full
    lo = max(answer_lo - 1, 0)
    hi = min(answer_hi - 1, seq)
    mask[lo:hi] = 1.0
    return tokens, targets, mask


def gen_modadd(rng: np.random.Generator, seq: int, vocab: int):
    """a + b = c (mod P): mathematical-reasoning stand-in (gsm8k)."""
    p = min(vocab - ALPHA0, 97)
    a, b = int(rng.integers(p)), int(rng.integers(p))
    c = (a + b) % p
    s = [BOS, ALPHA0 + a, ALPHA0 + b, SEP, ALPHA0 + c, EOS]
    return _finalize(s, 4, 5, seq)


def gen_copy(rng: np.random.Generator, seq: int, vocab: int):
    """Copy a random string after SEP: language-understanding stand-in (mrpc)."""
    alpha = min(vocab - ALPHA0, 64)
    ln = (seq - 3) // 2
    payload = rng.integers(alpha, size=ln)
    s = [BOS] + [ALPHA0 + int(t) for t in payload] + [SEP] + [
        ALPHA0 + int(t) for t in payload
    ] + [EOS]
    return _finalize(s, ln + 2, 2 * ln + 2, seq)


def gen_parity(rng: np.random.Generator, seq: int, vocab: int):
    """Parity of a bit string: logic-reasoning stand-in (wnli)."""
    ln = max(seq - 4, 1)
    bits = rng.integers(2, size=ln)
    ans = int(bits.sum() % 2)
    s = [BOS] + [ALPHA0 + int(b) for b in bits] + [SEP, ALPHA0 + ans, EOS]
    return _finalize(s, ln + 2, ln + 3, seq)


def gen_needle(rng: np.random.Generator, seq: int, vocab: int):
    """Key-value retrieval: commonsense/lookup stand-in (cola)."""
    nk = min((seq - 5) // 2, 8)
    key_alpha = min((vocab - ALPHA0) // 2, 32)
    val_base = ALPHA0 + key_alpha
    keys = rng.permutation(key_alpha)[:nk]
    vals = rng.integers(key_alpha, size=nk)
    qi = int(rng.integers(nk))
    s = [BOS]
    for kk, vv in zip(keys, vals):
        s += [ALPHA0 + int(kk), val_base + int(vv)]
    s += [SEP, ALPHA0 + int(keys[qi]), SEP, val_base + int(vals[qi]), EOS]
    return _finalize(s, 2 * nk + 4, 2 * nk + 5, seq)


GEN = {"modadd": gen_modadd, "copy": gen_copy, "parity": gen_parity, "needle": gen_needle}


def batch(task: str, rng: np.random.Generator, bsz: int, seq: int, vocab: int):
    toks, tgts, masks = [], [], []
    for _ in range(bsz):
        t, g, m = GEN[task](rng, seq, vocab)
        toks.append(t)
        tgts.append(g)
        masks.append(m)
    return (
        np.stack(toks).astype(np.int32),
        np.stack(tgts).astype(np.int32),
        np.stack(masks).astype(np.float32),
    )


def packed_batch(tasks, rng, bsz: int, seq: int, vocab: int, real_bsz=None):
    """A packed batch for n adapters: tokens (n,bsz,seq), targets, mask.

    ``real_bsz[i] <= bsz`` pads adapter i's batch with zero-mask samples
    (heterogeneous batch sizes inside a pack, DESIGN.md §2).
    """
    n = len(tasks)
    toks = np.zeros((n, bsz, seq), np.int32)
    tgts = np.zeros((n, bsz, seq), np.int32)
    mask = np.zeros((n, bsz, seq), np.float32)
    for i, task in enumerate(tasks):
        rb = bsz if real_bsz is None else real_bsz[i]
        t, g, m = batch(task, rng, rb, seq, vocab)
        toks[i, :rb], tgts[i, :rb], mask[i, :rb] = t, g, m
    return toks, tgts, mask
