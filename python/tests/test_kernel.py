# pytest: packed LoRA Pallas kernels vs the pure-jnp oracle — the CORE
# correctness signal for L1. Hypothesis sweeps shapes/dtypes; explicit cases
# pin the paper's geometries (Table 7: d in {2048, 3584, 11008, 18944}-like).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import packed_lora as pk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def make_inputs(n, m, d, r, k, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(ks[0], (n, m, d), dtype)
    a = rand(ks[1], (n, d, r), dtype, scale=1.0 / np.sqrt(d))
    b = rand(ks[2], (n, r, k), dtype, scale=1.0 / np.sqrt(r))
    alpha = jnp.abs(rand(ks[3], (n,), jnp.float32)) + 0.25
    return x, a, b, alpha


TOL = dict(rtol=2e-4, atol=2e-4)
# Small-but-representative geometry grid (m = batch*seq flattened).
GRID = [
    (1, 8, 16, 4, 16),
    (2, 16, 32, 8, 24),
    (3, 24, 48, 8, 32),
    (4, 32, 64, 16, 64),
    (8, 16, 128, 8, 96),
]


@pytest.mark.parametrize("n,m,d,r,k", GRID)
def test_fwd_matches_ref(n, m, d, r, k):
    x, a, b, alpha = make_inputs(n, m, d, r, k)
    got = pk.packed_lora_fwd(x, a, b, alpha)
    want = ref.ref_delta(x, a, b, alpha)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("n,m,d,r,k", GRID)
def test_backward_cases_match_ref(n, m, d, r, k):
    x, a, b, alpha = make_inputs(n, m, d, r, k)
    g = rand(jax.random.PRNGKey(7), (n, m, k))
    dx_r, da_r, db_r = ref.ref_grads(x, a, b, alpha, g)
    dh = pk.packed_lora_dh(g, b, alpha)
    np.testing.assert_allclose(pk.packed_lora_db(x, a, g, alpha), db_r, **TOL)
    np.testing.assert_allclose(pk.packed_lora_da(x, dh), da_r, **TOL)
    np.testing.assert_allclose(pk.packed_lora_dx(dh, a), dx_r, **TOL)


@pytest.mark.parametrize("n,m,d,r,k", GRID[:3])
def test_custom_vjp_matches_jax_vjp(n, m, d, r, k):
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=3)
    g = rand(jax.random.PRNGKey(11), (n, m, k))
    out, pull = jax.vjp(lambda x_, a_, b_: pk.packed_lora_delta(x_, a_, b_, alpha), x, a, b)
    np.testing.assert_allclose(out, ref.ref_delta(x, a, b, alpha), **TOL)
    got = pull(g)
    want = ref.ref_vjp(x, a, b, alpha, g)
    for gi, wi in zip(got, want):
        np.testing.assert_allclose(gi, wi, **TOL)


def test_apply_includes_base_weight():
    n, m, d, r, k = 2, 16, 32, 8, 24
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=5)
    w = rand(jax.random.PRNGKey(9), (d, k))
    got = pk.packed_lora_apply(x, w, a, b, alpha)
    np.testing.assert_allclose(got, ref.ref_apply(x, w, a, b, alpha), **TOL)


def test_sequential_matches_packed():
    # The §5.1 naive baseline must be numerically identical to the packed path.
    n, m, d, r, k = 4, 8, 32, 8, 16
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=8)
    w = rand(jax.random.PRNGKey(2), (d, k))
    np.testing.assert_allclose(
        pk.sequential_lora_apply(x, w, a, b, alpha),
        pk.packed_lora_apply(x, w, a, b, alpha),
        **TOL,
    )


def test_rank_padding_is_gradient_stable():
    # DESIGN.md: packs mix ranks by zero-padding to r_pad. Padded entries of
    # A (columns) and B (rows) must receive exactly-zero gradients.
    n, m, d, r, k = 2, 16, 32, 8, 24
    r_pad = 16
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=13)
    a_p = jnp.pad(a, ((0, 0), (0, 0), (0, r_pad - r)))
    b_p = jnp.pad(b, ((0, 0), (0, r_pad - r), (0, 0)))
    g = rand(jax.random.PRNGKey(17), (n, m, k))
    # Padded forward must equal unpadded forward.
    np.testing.assert_allclose(
        pk.packed_lora_fwd(x, a_p, b_p, alpha), pk.packed_lora_fwd(x, a, b, alpha), **TOL
    )
    _, pull = jax.vjp(lambda a_, b_: pk.packed_lora_delta(x, a_, b_, alpha), a_p, b_p)
    da, db = pull(g)
    np.testing.assert_array_equal(np.asarray(da[:, :, r:]), 0.0)
    np.testing.assert_array_equal(np.asarray(db[:, r:, :]), 0.0)


def test_alpha_scales_linearly():
    n, m, d, r, k = 2, 8, 16, 4, 8
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=21)
    y1 = pk.packed_lora_fwd(x, a, b, alpha)
    y2 = pk.packed_lora_fwd(x, a, b, 2.0 * alpha)
    np.testing.assert_allclose(y2, 2.0 * y1, **TOL)


def test_bfloat16_forward():
    n, m, d, r, k = 2, 16, 32, 8, 16
    x, a, b, alpha = make_inputs(n, m, d, r, k, dtype=jnp.bfloat16, seed=4)
    got = pk.packed_lora_fwd(x, a, b, alpha).astype(jnp.float32)
    want = ref.ref_delta(
        x.astype(jnp.float32), a.astype(jnp.float32), b.astype(jnp.float32), alpha
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5),
    m=st.integers(1, 48),
    d=st.integers(1, 96),
    r=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_fwd_bwd(n, m, d, r, k, seed):
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=seed)
    got = pk.packed_lora_fwd(x, a, b, alpha)
    np.testing.assert_allclose(got, ref.ref_delta(x, a, b, alpha), **TOL)
    g = rand(jax.random.PRNGKey(seed + 1), (n, m, k))
    dx_r, da_r, db_r = ref.ref_grads(x, a, b, alpha, g)
    dh = pk.packed_lora_dh(g, b, alpha)
    np.testing.assert_allclose(pk.packed_lora_db(x, a, g, alpha), db_r, **TOL)
    np.testing.assert_allclose(pk.packed_lora_da(x, dh), da_r, **TOL)
    np.testing.assert_allclose(pk.packed_lora_dx(dh, a), dx_r, **TOL)


@settings(max_examples=10, deadline=None)
@given(
    tile_m=st.sampled_from([1, 2, 3, 8, 128]),
    tile_k=st.sampled_from([1, 2, 3, 8, 128]),
)
def test_tiling_invariance(tile_m, tile_k):
    # Output must not depend on the tile choice (grid decomposition).
    n, m, d, r, k = 2, 12, 24, 4, 18
    x, a, b, alpha = make_inputs(n, m, d, r, k, seed=30)
    base = ref.ref_delta(x, a, b, alpha)
    got = pk.packed_lora_fwd(x, a, b, alpha, tile_m=tile_m, tile_k=tile_k)
    np.testing.assert_allclose(got, base, **TOL)
