//! Figure 6 — speedup breakdown (planner vs kernels) plus design-choice
//! ablations DESIGN.md calls out:
//!
//!  - **Fig. 6**: Min GPU → Sequential PLoRA (planner only) → PLoRA
//!    (planner + packed kernels) on 3B and 7B.
//!  - **Rebalance ablation**: Alg. 2 with and without the round
//!    load-balancing pass.
//!  - **Padding-charge ablation**: planning with true-shape memory (paper
//!    CUDA kernels) vs static-bucket padded shapes (our AOT live path).
//!  - **Noise robustness**: makespan under ±20% lognormal job-duration
//!    noise (plans are made on clean estimates).
//!
//! Run: `cargo bench --bench ablation`

use plora::bench::Bench;
use plora::config::{geometry::geom, pool, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::metrics::{fmt_x, Table};
use plora::planner::{min_gpu_plan, sequential_plora_plan, JobPlanner};
use plora::sim::{SimOptions, Simulator};
use plora::util::json::Json;

fn main() {
    let gpus = 8;
    let budget = TrainBudget::default();
    let grid = SearchSpace::default().grid("gsm8k");
    let mut bench = Bench::new("ablation");

    // -- Fig. 6: speedup breakdown -----------------------------------------
    let mut fig6 = Table::new(
        "Figure 6 — speedup breakdown over Min GPU (8 x A100-40G, 120 configs)",
        &["model", "Sequential PLoRA (planner only)", "PLoRA (planner+kernels)"],
    );
    for model in ["qwen2.5-3b", "qwen2.5-7b"] {
        let cm = CostModel::new(geom(model).unwrap(), &pool::A100_40G);
        let sim = Simulator { cm: cm.clone(), budget, gpus };
        let run = |p: &plora::planner::Plan| {
            let q: Vec<_> = p.jobs.iter().map(|j| j.job.clone()).collect();
            sim.run_queue(&q, &SimOptions::default()).makespan
        };
        let min = run(&min_gpu_plan(&cm, &budget, gpus, &grid).unwrap());
        let seq = run(&sequential_plora_plan(&cm, &budget, gpus, &grid).unwrap());
        let mut planner = JobPlanner::new(cm, gpus);
        planner.budget = budget;
        let plora = run(&planner.plan(&grid).unwrap());
        bench.record(
            &format!("fig6/{model}"),
            &[min / plora],
            Json::obj(vec![
                ("model", Json::str(model)),
                ("seq_speedup", Json::num(min / seq)),
                ("plora_speedup", Json::num(min / plora)),
            ]),
        );
        fig6.row(vec![model.to_string(), fmt_x(min / seq), fmt_x(min / plora)]);
    }
    fig6.print();
    println!("paper: Sequential PLoRA ~1.8x on both; kernels add up to 3.93x more (Fig. 6).\n");

    // -- Rebalance ablation ---------------------------------------------------
    // Without the rebalance pass the first ILP pack hoards long (bs=1)
    // configs and the round's tail job dominates the makespan. We emulate
    // "off" by planning with a crippled budget of rebalance moves.
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &pool::A100_40G);
    let sim = Simulator { cm: cm.clone(), budget, gpus };
    let run_queue = |plan: &plora::planner::Plan, noise: f64, seed: u64| {
        let q: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        sim.run_queue(&q, &SimOptions { noise, seed, ..Default::default() }).makespan
    };
    let mut planner = JobPlanner::new(cm.clone(), gpus);
    planner.budget = budget;
    let balanced = planner.plan(&grid).unwrap();

    // "off": DTM policies straight from the ILP (re-run DTM manually).
    let unbalanced = {
        use plora::planner::{Dtm, PlannedJob};
        let mut remaining = grid.clone();
        let mut queue: Vec<PlannedJob> = vec![];
        let mut running: Vec<(f64, usize)> = vec![];
        let mut g_avail = gpus;
        let mut now = 0.0;
        let mut id = 0;
        while !remaining.is_empty() {
            if g_avail > 0 {
                let dtm = Dtm::new(&cm, &budget, plora::costmodel::ExecMode::Packed);
                let (jobs, _) = dtm.plan(g_avail, &remaining);
                for mut j in jobs {
                    j.id = id;
                    id += 1;
                    let dur = cm.job_time(&j.pack, j.d, j.mode, &budget);
                    remaining.retain(|c| !j.pack.configs.iter().any(|u| u.id == c.id));
                    g_avail -= j.d;
                    running.push((now + dur, j.d));
                    queue.push(j);
                }
            }
            if remaining.is_empty() {
                break;
            }
            let (i, _) =
                running.iter().enumerate().min_by(|a, b| a.1 .0.total_cmp(&b.1 .0)).unwrap();
            let (end, d) = running.swap_remove(i);
            now = end.max(now);
            g_avail += d;
        }
        queue
    };
    let t_bal = run_queue(&balanced, 0.0, 0);
    let t_unbal = sim.run_queue(&unbalanced, &SimOptions::default()).makespan;
    bench.record(
        "rebalance/on_vs_off",
        &[t_unbal / t_bal],
        Json::obj(vec![("on_s", Json::num(t_bal)), ("off_s", Json::num(t_unbal))]),
    );
    println!(
        "rebalance ablation (7B): off {:.0}s vs on {:.0}s -> {} from round balancing",
        t_unbal,
        t_bal,
        fmt_x(t_unbal / t_bal)
    );

    // -- Padding-charge ablation ------------------------------------------
    let mut cm_pad = cm.clone();
    cm_pad.charge_padding = true;
    let mut planner_pad = JobPlanner::new(cm_pad, gpus);
    planner_pad.budget = budget;
    let plan_pad = planner_pad.plan(&grid).unwrap();
    let t_pad = {
        let q: Vec<_> = plan_pad.jobs.iter().map(|j| j.job.clone()).collect();
        Simulator { cm: planner_pad.cm.clone(), budget, gpus }
            .run_queue(&q, &SimOptions::default())
            .makespan
    };
    bench.record(
        "padding/true_vs_padded",
        &[t_pad / t_bal],
        Json::obj(vec![("true_s", Json::num(t_bal)), ("padded_s", Json::num(t_pad))]),
    );
    println!(
        "padding-charge ablation (7B): true shapes {:.0}s vs static buckets {:.0}s ({} overhead)",
        t_bal,
        t_pad,
        fmt_x(t_pad / t_bal)
    );

    // -- Noise robustness ----------------------------------------------------
    let noisy: Vec<f64> = (0..8).map(|s| run_queue(&balanced, 0.2, s as u64)).collect();
    let mean_noisy = noisy.iter().sum::<f64>() / noisy.len() as f64;
    bench.record(
        "noise/sigma0.2",
        &noisy,
        Json::obj(vec![("clean_s", Json::num(t_bal))]),
    );
    println!(
        "noise robustness (7B, sigma=0.2, 8 seeds): clean {:.0}s, noisy mean {:.0}s ({} drift)",
        t_bal,
        mean_noisy,
        fmt_x(mean_noisy / t_bal)
    );

    bench.finish().unwrap();
}
