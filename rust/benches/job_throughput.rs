//! Figures 5 & 7 — packed fine-tuning *job* throughput vs the Min GPU
//! baseline, per model size and batch size; A100, A10, and A10+QLoRA.
//!
//! Two parts:
//! 1. **Paper scale** (cost model): normalized job throughput for
//!    Qwen-2.5 3B/7B/14B/32B at batch sizes 1/2/4 on A100 (Fig. 5), then
//!    3B/7B and 7B+QLoRA on A10 (Fig. 7).
//! 2. **Live** (PJRT): a packed 4-adapter job vs four sequential
//!    single-adapter jobs on the `nano` TinyLM — the same ratio measured
//!    on real execution.
//!
//! Run: `cargo bench --bench job_throughput`

use plora::bench::Bench;
use plora::config::{geometry::geom, pool, GpuProfile, LoraConfig};
use plora::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use plora::metrics::{fmt_x, Table};
use plora::runtime::Runtime;
use plora::train::{run_pack, TrainOptions};
use plora::util::json::Json;

fn cfg(id: usize, r: usize, bs: usize, task: &str) -> LoraConfig {
    LoraConfig { id, lr: 1e-3, batch: bs, rank: r, alpha_ratio: 1.0, task: task.into() }
}

/// Normalized packed-job throughput vs Min GPU for one (model, profile, bs).
fn gain(model: &str, prof: &GpuProfile, bs: usize, qlora: bool) -> (usize, f64) {
    let mut g = geom(model).unwrap().clone();
    if qlora {
        g.base_bytes = 0.5;
    }
    let cm = CostModel::new(&g, prof);
    let budget = TrainBudget::default();
    let d = cm
        .memory
        .min_tp(&cfg(0, 32, bs, "t"), prof, cm.c_load, 8)
        .unwrap_or(8);
    let nmax = {
        // Largest rank-32 pack that fits d devices.
        let mut n = 1;
        while n < 256 {
            let pack = Pack::new(vec![cfg(0, 32, bs, "t"); n + 1]);
            if !cm.fits(&pack, d) {
                break;
            }
            n += 1;
        }
        n
    };
    let packed = Pack::new((0..nmax).map(|i| cfg(i, 32, bs, "t")).collect());
    let single = Pack::new(vec![cfg(0, 32, bs, "t")]);
    let plora = cm.throughput(&packed, d, ExecMode::Packed, &budget) / d as f64;
    let min_gpu = cm.throughput(&single, d, ExecMode::Sequential, &budget) / d as f64;
    (nmax, plora / min_gpu)
}

fn main() {
    let mut bench = Bench::new("job_throughput");

    // -- Fig. 5: A100, Qwen family, bs in {1, 2, 4} ------------------------
    let mut fig5 = Table::new(
        "Figure 5 — packed job throughput vs Min GPU (A100-40G, r=32)",
        &["model", "bs=1", "bs=2", "bs=4", "pack size @bs1"],
    );
    for model in ["qwen2.5-3b", "qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b"] {
        let (n1, g1) = gain(model, &pool::A100_40G, 1, false);
        let (_, g2) = gain(model, &pool::A100_40G, 2, false);
        let (_, g4) = gain(model, &pool::A100_40G, 4, false);
        bench.record(
            &format!("fig5/{model}"),
            &[g1],
            Json::obj(vec![("model", Json::str(model)), ("bs", Json::num(1.0))]),
        );
        fig5.row(vec![model.to_string(), fmt_x(g1), fmt_x(g2), fmt_x(g4), n1.to_string()]);
    }
    fig5.print();
    println!("paper: up to 12.8x at bs=1, shrinking as bs grows (Fig. 5).\n");

    // -- Fig. 7: A10, 3B/7B + QLoRA ----------------------------------------
    let mut fig7 = Table::new(
        "Figure 7 — packed job throughput vs Min GPU (A10-24G, r=32, bs=1)",
        &["model", "speedup", "pack size"],
    );
    for (model, qlora) in [("qwen2.5-3b", false), ("qwen2.5-7b", false), ("qwen2.5-7b", true)] {
        let (n, g) = gain(model, &pool::A10_24G, 1, qlora);
        let label = if qlora { format!("{model}+qlora") } else { model.to_string() };
        bench.record(
            &format!("fig7/{label}"),
            &[g],
            Json::obj(vec![("model", Json::str(label.clone()))]),
        );
        fig7.row(vec![label, fmt_x(g), n.to_string()]);
    }
    fig7.print();
    println!("paper: 5.94x (3B), 2.56x (7B); QLoRA packs more adapters → 4.72x (§7.5).\n");

    // -- Live ratio on the PJRT runtime -------------------------------------
    if let Ok(rt) = Runtime::load(&Runtime::default_dir()) {
        let opts = TrainOptions {
            budget: TrainBudget { dataset: 8, epochs: 1 },
            eval_batches: 1,
            seed: 3,
            log_every: 0,
        };
        let tasks = ["modadd", "copy", "parity", "needle"];
        let packed_cfgs: Vec<LoraConfig> =
            (0..4).map(|i| cfg(i, 8, 1, tasks[i % 4])).collect();
        // Warm the executable cache outside the measurement.
        run_pack(&rt, "nano", &packed_cfgs, &opts).unwrap();
        run_pack(&rt, "nano", &packed_cfgs[..1], &opts).unwrap();

        let sp = bench.measure("live/packed4", || {
            run_pack(&rt, "nano", &packed_cfgs, &opts).unwrap();
        });
        let ss = bench.measure("live/sequential4", || {
            for c in &packed_cfgs {
                run_pack(&rt, "nano", std::slice::from_ref(c), &opts).unwrap();
            }
        });
        println!(
            "\nlive nano 4-adapter job: packed {} vs 4 sequential jobs {} -> {} speedup",
            plora::util::stats::fmt_secs(sp.p50),
            plora::util::stats::fmt_secs(ss.p50),
            fmt_x(ss.p50 / sp.p50)
        );
    } else {
        eprintln!("live part skipped: artifacts not built");
    }

    bench.finish().unwrap();
}
