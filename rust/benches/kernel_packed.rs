//! Table 7/8 — packed-LoRA kernel throughput vs the sequential
//! per-adapter baseline, measured **live** on the PJRT runtime against the
//! AOT kernel artifacts (L1 Pallas kernels lowered through L2).
//!
//! The paper reports near-linear speedup up to 32 packed adapters on both
//! Attention (d = 2048/3584) and MLP (d = 11008/18944) projections; at
//! testbed scale the artifacts use the `small` TinyLM dims (attn 256x256,
//! mlp 256x1024, r=16, m=16 — DESIGN.md §6) and per-launch overhead on
//! the CPU backend plays the role of GPU underutilization.
//!
//! Run: `cargo bench --bench kernel_packed`

use plora::bench::Bench;
use plora::metrics::{fmt_x, Table};
use plora::runtime::{HostTensor, Runtime};
use plora::util::json::Json;

fn inputs(n: usize, d: usize, k: usize, r: usize, m: usize, bwd: bool) -> Vec<HostTensor> {
    let mut v = vec![
        HostTensor::f32(vec![n, m, d], vec![0.01; n * m * d]).unwrap(),
        HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r]).unwrap(),
        HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k]).unwrap(),
        HostTensor::f32(vec![n], vec![1.0; n]).unwrap(),
    ];
    if bwd {
        v.push(HostTensor::f32(vec![n, m, k], vec![0.05; n * m * k]).unwrap());
    }
    v
}

fn main() {
    let rt = match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("kernel_packed: artifacts not built ({e}); run `make artifacts`");
            return;
        }
    };
    let mut bench = Bench::new("kernel_packed");
    bench.target_secs = 1.0;

    let ns = [1usize, 2, 8, 32];
    let mut table = Table::new(
        "Table 7/8 analogue — packed kernel speedup over sequential (live CPU-PJRT)",
        &["geom", "n", "fwd", "bwd"],
    );

    for geom in ["attn", "mlp"] {
        let mut base: Option<(f64, f64)> = None;
        for &n in &ns {
            let fwd = rt.executable(&format!("kfwd_{geom}_n{n}")).unwrap();
            let bwd = rt.executable(&format!("kbwd_{geom}_n{n}")).unwrap();
            let (d, k, r, m) = (
                fwd.info.meta_usize("d").unwrap(),
                fwd.info.meta_usize("k").unwrap(),
                fwd.info.meta_usize("r").unwrap(),
                fwd.info.meta_usize("m").unwrap(),
            );
            let fin = inputs(n, d, k, r, m, false);
            let bin = inputs(n, d, k, r, m, true);
            let meta = Json::obj(vec![
                ("geom", Json::str(geom)),
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("k", Json::num(k as f64)),
            ]);
            let sf = bench.measure_meta(&format!("{geom}/fwd/n{n}"), meta.clone(), &mut || {
                fwd.run(&fin).unwrap();
            });
            let sb = bench.measure_meta(&format!("{geom}/bwd/n{n}"), meta, &mut || {
                bwd.run(&bin).unwrap();
            });
            if n == 1 {
                base = Some((sf.p50, sb.p50));
            }
            let (bf, bb) = base.unwrap();
            // Sequential baseline: n independent single-adapter launches.
            table.row(vec![
                geom.to_string(),
                n.to_string(),
                fmt_x(n as f64 * bf / sf.p50),
                fmt_x(n as f64 * bb / sb.p50),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper (A100, r=64): n=2 ~2.0x, n=8 ~7.5-8.0x, n=32 ~26.5-31x.\n\
         On single-core CPU-PJRT the amortizable overhead is the executable\n\
         dispatch (~0.2-0.3 ms) while per-adapter compute is *serial* — the\n\
         measured ratio is bounded by overhead/compute and saturates near\n\
         1-1.6x (attn) instead of the GPU's ~30x, where the n adapters run\n\
         on idle SMs at zero marginal cost. The GPU-regime near-linearity\n\
         is pinned by the calibrated cost model\n\
         (costmodel::throughput::tests::packed_kernel_speedup_is_near_linear)."
    );
    bench.finish().unwrap();
}
