//! Figure 4 — end-to-end makespan of the 120-configuration sweep on
//! 8×A100-40G for all six base models, four methods each (Min GPU,
//! Max GPU, Sequential PLoRA, PLoRA), via the planner + discrete-event
//! simulator. Also measures planner wall time per model.
//!
//! Run: `cargo bench --bench makespan`
//! (one model: `cargo bench --bench makespan -- --model qwen2.5-7b`)

use plora::bench::Bench;
use plora::config::{geometry::geom, pool, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::metrics::{fmt_dur, fmt_x, Table};
use plora::planner::{max_gpu_plan, min_gpu_plan, sequential_plora_plan, JobPlanner};
use plora::sim::{SimOptions, Simulator};
use plora::util::cli::Args;
use plora::util::json::Json;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    let models: Vec<String> = match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => [
            "qwen2.5-3b",
            "qwen2.5-7b",
            "qwen2.5-14b",
            "qwen2.5-32b",
            "llama3.2-3b",
            "llama3.1-8b",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    };
    let gpus = 8;
    let budget = TrainBudget::default();
    let grid = SearchSpace::default().grid("gsm8k");
    let mut bench = Bench::new("makespan");

    let mut fig4 = Table::new(
        "Figure 4 — normalized makespan, 120 configs on 8 x A100-40G (Min GPU = 1.00)",
        &["model", "Min GPU", "Max GPU", "Seq PLoRA", "PLoRA", "PLoRA speedup"],
    );
    // The paper's reported speedups, for side-by-side comparison.
    let paper: &[(&str, f64)] = &[
        ("qwen2.5-3b", 7.08),
        ("qwen2.5-7b", 6.52),
        ("qwen2.5-14b", 6.51),
        ("qwen2.5-32b", 6.33),
        ("llama3.2-3b", 7.52),
        ("llama3.1-8b", 6.78),
    ];

    for model in &models {
        let cm = CostModel::new(geom(model).unwrap(), &pool::A100_40G);
        let sim = Simulator { cm: cm.clone(), budget, gpus };
        let opts = SimOptions::default();
        let run = |p: &plora::planner::Plan| {
            let q: Vec<_> = p.jobs.iter().map(|j| j.job.clone()).collect();
            sim.run_queue(&q, &opts).makespan
        };
        eprintln!("[{model}] planning + simulating 4 methods ...");
        let min = run(&min_gpu_plan(&cm, &budget, gpus, &grid).unwrap());
        let max = run(&max_gpu_plan(&cm, &budget, gpus, &grid).unwrap());
        let seq = run(&sequential_plora_plan(&cm, &budget, gpus, &grid).unwrap());
        let mut planner = JobPlanner::new(cm, gpus);
        planner.budget = budget;
        let t0 = std::time::Instant::now();
        let plan = planner.plan(&grid).unwrap();
        let plan_secs = t0.elapsed().as_secs_f64();
        let plora = run(&plan);

        let speedup = min / plora;
        let paper_x = paper.iter().find(|(m, _)| m == model).map(|(_, x)| *x).unwrap_or(f64::NAN);
        bench.record(
            &format!("{model}/plora_makespan"),
            &[plora],
            Json::obj(vec![
                ("model", Json::str(model.clone())),
                ("min_gpu_s", Json::num(min)),
                ("max_gpu_s", Json::num(max)),
                ("seq_plora_s", Json::num(seq)),
                ("speedup", Json::num(speedup)),
                ("paper_speedup", Json::num(paper_x)),
                ("plan_secs", Json::num(plan_secs)),
                ("ar_bound", Json::num(plan.ar_bound)),
                ("empirical_ratio", Json::num(plan.empirical_ratio())),
            ]),
        );
        fig4.row(vec![
            model.clone(),
            format!("1.00 ({})", fmt_dur(min)),
            format!("{:.2}", max / min),
            format!("{:.2}", seq / min),
            format!("{:.2}", plora / min),
            format!("{} (paper {})", fmt_x(speedup), fmt_x(paper_x)),
        ]);
    }
    fig4.print();
    bench.finish().unwrap();
}
