//! §6 — planner performance and quality:
//!
//!  - ILP `F(D, K)` solve time vs configuration-set size (the paper quotes
//!    "< 1 s per optimization instance");
//!  - DTM invocation cost on 8 GPUs (paper: 286 ILP calls, Alg. 1 within
//!    10 minutes offline at 120 configs);
//!  - full Alg.-2 planning wall time;
//!  - the Theorem-6.1 AR bound and the certified empirical optimality
//!    ratio (paper reports AR ∈ [1.05, 1.14]).
//!
//! Run: `cargo bench --bench planner`

use plora::bench::Bench;
use plora::config::{geometry::geom, pool, SearchSpace};
use plora::costmodel::{CostModel, ExecMode, TrainBudget};
use plora::metrics::Table;
use plora::planner::{Dtm, JobPlanner, PackProblem};
use plora::util::json::Json;

fn main() {
    let budget = TrainBudget::default();
    let grid = SearchSpace::default().grid("gsm8k");
    let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &pool::A100_40G);
    let mut bench = Bench::new("planner");
    bench.min_iters = 3;
    bench.max_iters = 10;
    bench.target_secs = 3.0;

    // -- ILP solve time vs |K| ----------------------------------------------
    for k in [15usize, 30, 60, 120] {
        let configs = &grid[..k];
        let s = bench.measure_meta(
            &format!("ilp/F(1,K)/k{k}"),
            Json::obj(vec![("k", Json::num(k as f64))]),
            &mut || {
                let p = PackProblem::new(&cm, 1, ExecMode::Packed, &budget);
                plora::bench::black_box(p.solve(configs).unwrap());
            },
        );
        // Paper quotes <1 s per Gurobi instance; allow headroom for slow
        // shared runners — the point is the order of magnitude.
        assert!(s.p50 < 5.0, "ILP instance far beyond the paper's <1s budget: {:.2}s", s.p50);
    }

    // -- DTM on 8 GPUs -------------------------------------------------------
    let mut dtm_calls = 0usize;
    bench.measure("dtm/g8/k120", || {
        let dtm = Dtm::new(&cm, &budget, ExecMode::Packed);
        let (_, stats) = dtm.plan(8, &grid);
        dtm_calls = stats.ilp_calls;
    });
    println!("DTM(8, 120 cfgs): {dtm_calls} ILP calls (paper: 286 per DTM on 8 GPUs)");

    // -- Full Alg. 2 plan + quality metrics ----------------------------------
    let mut quality = Table::new(
        "§6 planner quality — AR bound and certified empirical ratio",
        &["model", "plan secs", "AR bound (Thm 6.1)", "empirical ratio", "occupancy"],
    );
    for model in ["qwen2.5-3b", "qwen2.5-7b", "qwen2.5-14b"] {
        let cm = CostModel::new(geom(model).unwrap(), &pool::A100_40G);
        let mut planner = JobPlanner::new(cm, 8);
        planner.budget = budget;
        let t0 = std::time::Instant::now();
        let plan = planner.plan(&grid).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        bench.record(
            &format!("plan/{model}"),
            &[secs],
            Json::obj(vec![
                ("model", Json::str(model)),
                ("ar_bound", Json::num(plan.ar_bound)),
                ("empirical_ratio", Json::num(plan.empirical_ratio())),
                ("ilp_calls", Json::num(plan.stats.ilp_calls as f64)),
            ]),
        );
        quality.row(vec![
            model.to_string(),
            format!("{secs:.1}"),
            format!("{:.2}", plan.ar_bound),
            format!("{:.3}", plan.empirical_ratio()),
            format!("{:.0}%", plan.occupancy() * 100.0),
        ]);
        assert!(secs < 600.0, "paper: planning stays within 10 minutes");
    }
    quality.print();
    println!(
        "paper: AR in [1.05, 1.14]; our certified empirical ratio is the \
         comparable tight metric."
    );

    bench.finish().unwrap();
}
