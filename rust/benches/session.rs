//! Session orchestration benchmark: a fixed 8-job queue on the reference
//! backend, measured end-to-end through `Session::submit`/`drain` — FIFO
//! admission, concurrent packed jobs, adapter-completion re-bucketing.
//!
//! Emits `target/BENCH_session.json` (makespan + throughput + event
//! counts) so the repo's perf trajectory is recorded run over run, and
//! appends to the shared `target/plora-bench.jsonl` like every bench.
//!
//! Run: `cargo bench --bench session`

use std::sync::Arc;

use plora::bench::Bench;
use plora::cluster::ResourceMonitor;
use plora::config::{pool, LoraConfig};
use plora::costmodel::{ExecMode, Pack, TrainBudget};
use plora::planner::PlannedJob;
use plora::runtime::Runtime;
use plora::session::{Session, SessionReport};
use plora::train::TrainOptions;
use plora::util::json::Json;

fn cfg(id: usize, task: &str, rank: usize, bs: usize) -> LoraConfig {
    LoraConfig { id, lr: 2e-3, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
}

/// The fixed queue: 8 jobs / 12 adapters on `nano`, mixed batch sizes so
/// several jobs hit an adapter-completion boundary and re-bucket.
fn queue() -> Vec<PlannedJob> {
    let tasks = ["modadd", "copy", "parity", "needle"];
    let mut jobs = vec![];
    let mut id = 0usize;
    for j in 0..8usize {
        let n = if j % 2 == 0 { 2 } else { 1 };
        let mut configs = vec![];
        for s in 0..n {
            let bs = if s == 0 { 1 } else { 2 };
            configs.push(cfg(id, tasks[(j + s) % tasks.len()], 8, bs));
            id += 1;
        }
        jobs.push(PlannedJob { id: j, pack: Pack::new(configs), d: 1, mode: ExecMode::Packed });
    }
    jobs
}

fn run_once(rt: &Arc<Runtime>, gpus: usize, rebucket: bool) -> SessionReport {
    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, gpus), "nano");
    session.options = TrainOptions {
        budget: TrainBudget { dataset: 24, epochs: 1 },
        eval_batches: 2,
        seed: 11,
        log_every: 0,
    };
    session.rebucket = rebucket;
    for job in queue() {
        session.submit_planned(job).expect("submit");
    }
    session.drain().expect("drain")
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(&Runtime::default_dir())?);
    let gpus = 2usize;
    let mut b = Bench::new("session");
    b.min_iters = 3;
    b.max_iters = 5;

    let mut last: Option<SessionReport> = None;
    let s = b.measure("queue8_rebucket", || {
        last = Some(run_once(&rt, gpus, true));
    });
    let report = last.take().expect("at least one measured run");
    let s_off = b.measure("queue8_norebucket", || {
        last = Some(run_once(&rt, gpus, false));
    });
    let report_off = last.take().expect("at least one measured run");
    b.finish()?;

    let rank_units: usize = report
        .outcomes
        .iter()
        .flat_map(|o| &o.report.adapters)
        .map(|a| a.config.rank)
        .sum();
    let padded_rows: usize = report.outcomes.iter().map(|o| o.report.padded_rows).sum();
    let padded_rows_off: usize =
        report_off.outcomes.iter().map(|o| o.report.padded_rows).sum();
    let rec = Json::obj(vec![
        ("bench", Json::str("session")),
        ("jobs", Json::num(report.outcomes.len() as f64)),
        ("adapters", Json::num(report.total_adapters() as f64)),
        ("gpus", Json::num(gpus as f64)),
        ("makespan_s", Json::num(report.makespan)),
        ("makespan_norebucket_s", Json::num(report_off.makespan)),
        ("mean_wall_s", Json::num(s.mean)),
        ("mean_wall_norebucket_s", Json::num(s_off.mean)),
        ("rank_units_per_s", Json::num(rank_units as f64 / report.makespan.max(1e-9))),
        ("rebucket_events", Json::num(report.rebuckets() as f64)),
        ("padded_rows", Json::num(padded_rows as f64)),
        ("padded_rows_norebucket", Json::num(padded_rows_off as f64)),
        ("events", Json::num(report.events.len() as f64)),
    ]);
    let mut out = String::new();
    rec.write(&mut out);
    // Anchor on the crate root: cargo runs benches with CWD = package root,
    // but the workspace target dir lives one level up.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("BENCH_session.json"), &out)?;
    println!(
        "\nsession queue8: makespan {:.2}s (no-rebucket {:.2}s), {} rebuckets, \
         padded rows {} -> {}",
        report.makespan,
        report_off.makespan,
        report.rebuckets(),
        padded_rows_off,
        padded_rows,
    );
    println!("wrote rust/target/BENCH_session.json");
    Ok(())
}
