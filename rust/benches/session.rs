//! Session orchestration benchmark: a fixed 8-job queue plus a
//! skewed-arrival scenario on the reference backend, measured end-to-end
//! through `Session::submit`/`drain` — policy dispatch, concurrent packed
//! jobs, adapter-completion re-bucketing, elastic mid-job admission —
//! plus the device axis: per-`d` sharded step times, the measured
//! dp-efficiency figure, and the device-count-aware planner against a
//! fixed-d baseline on the skewed scenario. The pipeline axis rides
//! along: per-`s` stage-pipelined step times on a fixed pack, and the
//! heterogeneous-fleet placement gate — per-device-class calibration
//! builds a skewed 1-fast + 3-slow fleet and hetero-aware LPT placement
//! must beat the identical-device baseline on it. The tuner gate closes
//! the set: the same LR sweep through `FullSweep` and `Asha`, with the
//! ASHA makespan ratio and best-per-task quality parity CI enforces.
//!
//! Emits `BENCH_session.json` (makespans + throughput + event counts:
//! rebuckets, admissions, preemptions, the elastic-vs-FIFO makespan ratio
//! and the d-aware-vs-fixed-d ratio CI enforces) to `target/` by default —
//! `--out <path>` or `PLORA_BENCH_OUT=<dir>` redirect it for the
//! perf-budget harness (`bench/history/`) — and appends to the shared
//! `target/plora-bench.jsonl` like every bench.
//!
//! Run: `cargo bench --bench session`

use std::sync::Arc;

use plora::bench::Bench;
use plora::cluster::{Allocation, ResourceMonitor};
use plora::config::{pool, LoraConfig};
use plora::costmodel::{DpStat, ExecMode, Pack, TrainBudget};
use plora::planner::{hosts_from_fits, place_jobs, JobPlanner, PlannedJob};
use plora::runtime::Runtime;
use plora::search::{best_per_task, Asha, FullSweep, SweepOptions, Tuner, TunerOutcome};
use plora::session::{Policy, Session, SessionReport};
use plora::train::{run_pack_on, TrainOptions};
use plora::util::json::Json;

fn cfg(id: usize, task: &str, rank: usize, bs: usize) -> LoraConfig {
    LoraConfig { id, lr: 2e-3, batch: bs, rank, alpha_ratio: 1.0, task: task.into() }
}

/// The fixed queue: 8 jobs / 12 adapters on `nano`, mixed batch sizes so
/// several jobs hit an adapter-completion boundary and re-bucket.
fn queue() -> Vec<PlannedJob> {
    let tasks = ["modadd", "copy", "parity", "needle"];
    let mut jobs = vec![];
    let mut id = 0usize;
    for j in 0..8usize {
        let n = if j % 2 == 0 { 2 } else { 1 };
        let mut configs = vec![];
        for s in 0..n {
            let bs = if s == 0 { 1 } else { 2 };
            configs.push(cfg(id, tasks[(j + s) % tasks.len()], 8, bs));
            id += 1;
        }
        jobs.push(PlannedJob {
            id: j,
            pack: Pack::new(configs),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        });
    }
    jobs
}

/// The skewed-arrival scenario (the acceptance gate): one mixed-batch
/// pack holds the device while three short bs2 singles queue behind it.
/// FIFO/no-rebucket runs each single on a padded `(2, 8, 2)` bucket;
/// the elastic session admits one into the pack's freed slot at each
/// completion boundary instead.
fn skewed_queue() -> Vec<PlannedJob> {
    let mut jobs = vec![PlannedJob {
        id: 0,
        pack: Pack::new(vec![cfg(0, "modadd", 8, 1), cfg(1, "parity", 8, 2)]),
        d: 1,
        s: 0,
        mode: ExecMode::Packed,
    }];
    for (i, task) in ["copy", "needle", "parity"].iter().enumerate() {
        jobs.push(PlannedJob {
            id: 1 + i,
            pack: Pack::new(vec![cfg(2 + i, task, 8, 2)]),
            d: 1,
            s: 0,
            mode: ExecMode::Packed,
        });
    }
    jobs
}

fn options(dataset: usize) -> TrainOptions {
    TrainOptions {
        budget: TrainBudget { dataset, epochs: 1 },
        eval_batches: 2,
        seed: 11,
        log_every: 0,
    }
}

fn run_session(
    rt: &Arc<Runtime>,
    jobs: Vec<PlannedJob>,
    gpus: usize,
    dataset: usize,
    policy: Policy,
    elastic: bool,
    rebucket: bool,
) -> SessionReport {
    let mut session =
        Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, gpus), "nano");
    session.options = options(dataset);
    session.rebucket = rebucket;
    session.set_policy(policy);
    session.set_elastic(elastic);
    // Priorities descend in submit order so priority policies preserve
    // the scenario's queue shape (the big pack outranks the singles).
    let njobs = jobs.len() as i32;
    for (i, job) in jobs.into_iter().enumerate() {
        session.submit_planned_at(job, njobs - i as i32).expect("submit");
    }
    session.drain().expect("drain")
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(&Runtime::default_dir())?);
    let gpus = 2usize;
    let mut b = Bench::new("session");
    b.min_iters = 3;
    b.max_iters = 5;

    let mut last: Option<SessionReport> = None;
    let s = b.measure("queue8_rebucket", || {
        last = Some(run_session(&rt, queue(), gpus, 24, Policy::Fifo, false, true));
    });
    let report = last.take().expect("at least one measured run");
    let s_off = b.measure("queue8_norebucket", || {
        last = Some(run_session(&rt, queue(), gpus, 24, Policy::Fifo, false, false));
    });
    let report_off = last.take().expect("at least one measured run");

    // The skewed-arrival acceptance scenario: FIFO/no-rebucket baseline
    // vs the elastic session (priority policy + admission + retarget).
    let s_fifo = b.measure("skew_fifo_norebucket", || {
        last = Some(run_session(&rt, skewed_queue(), 1, 32, Policy::Fifo, false, false));
    });
    let skew_fifo = last.take().expect("at least one measured run");
    let s_el = b.measure("skew_priority_elastic", || {
        last = Some(run_session(&rt, skewed_queue(), 1, 32, Policy::Priority, true, true));
    });
    let skew_elastic = last.take().expect("at least one measured run");

    // Per-`d` sharded step times on a fixed 4-adapter nano pack: the
    // dp-efficiency figure (eff_d = t_1 / (d · t_d)) plus the Amdahl fit
    // the device-count-aware planner consumes.
    let dp_tasks = ["modadd", "copy", "parity", "needle"];
    let dp_cfgs: Vec<LoraConfig> =
        (0..4).map(|i| cfg(100 + i, dp_tasks[i % 4], 8, 1)).collect();
    let dp_stat = DpStat::new();
    let mut dp_secs = std::collections::BTreeMap::new();
    for d in [1usize, 2, 4] {
        let mut step_secs = 0.0;
        b.measure(&format!("sharded_step_d{d}"), || {
            let rep = run_pack_on(
                &rt,
                "nano",
                &dp_cfgs,
                &options(16),
                &Allocation::local(d),
            )
            .expect("sharded run");
            step_secs = rep.step_secs;
            for _ in 0..rep.steps {
                dp_stat.record(d, 4.0, step_secs);
            }
        });
        dp_secs.insert(d, step_secs);
    }
    let dp_eff = |d: usize| dp_secs[&1] / (d as f64 * dp_secs[&d]).max(1e-12);

    // Device-count-aware planner vs fixed d=1 on the skewed scenario:
    // plan the same configs with the *measured* dp fit (the planner
    // chooses each job's d, keeping d=1 whenever the fit shows sharding
    // doesn't pay on this machine), then run both queues on 2 devices.
    let mut cm = plora::search::live_cost_model(&rt, "nano")?;
    cm.calib.dp_fit = dp_stat.fit();
    let mut planner = JobPlanner::new(cm, 2);
    planner.budget = TrainBudget { dataset: 32, epochs: 1 };
    let cfg_list: Vec<LoraConfig> =
        skewed_queue().iter().flat_map(|j| j.pack.configs.clone()).collect();
    let plan = planner.plan(&cfg_list)?;
    let aware_jobs: Vec<PlannedJob> = plan.jobs.iter().map(|j| j.job.clone()).collect();
    let fixed_jobs: Vec<PlannedJob> = aware_jobs
        .iter()
        .cloned()
        .map(|mut j| {
            j.d = 1;
            j
        })
        .collect();
    let aware_ds: Vec<usize> = aware_jobs.iter().map(|j| j.d).collect();
    b.measure("skew_d_aware_planner", || {
        last = Some(run_session(&rt, aware_jobs.clone(), 2, 32, Policy::Fifo, false, true));
    });
    let d_aware = last.take().expect("at least one measured run");
    b.measure("skew_fixed_d", || {
        last = Some(run_session(&rt, fixed_jobs.clone(), 2, 32, Policy::Fifo, false, true));
    });
    let d_fixed = last.take().expect("at least one measured run");

    // Stage axis: per-depth step times on the same fixed 4-adapter pack,
    // run as a solo session job planned at depth `s`. nano's 2-layer
    // stack clamps anything deeper to 2, so s=2 is the deepest effective
    // depth here; the exported depth proves the pipeline actually ran.
    let mut pipe_secs = std::collections::BTreeMap::new();
    let mut pipe_depth = std::collections::BTreeMap::new();
    for st in [1usize, 2] {
        let job = PlannedJob {
            id: 0,
            pack: Pack::new(dp_cfgs.clone()),
            d: 1,
            s: st,
            mode: ExecMode::Packed,
        };
        let mut step_secs = 0.0;
        let mut depth = 0usize;
        b.measure(&format!("pipelined_step_s{st}"), || {
            let rep = run_session(&rt, vec![job.clone()], 1, 16, Policy::Fifo, false, false);
            step_secs = rep.outcomes[0].report.step_secs;
            depth = rep.outcomes[0].report.s;
        });
        pipe_secs.insert(st, step_secs);
        pipe_depth.insert(st, depth);
    }

    // Heterogeneous-fleet placement gate: feed per-device-class step
    // times into the calibrator (the measured per-d times as the fast
    // tier, a synthetic 4x-slower tier alongside), build a skewed fleet
    // (1 fast + 3 slow) from the per-class Amdahl fits exactly as the
    // hetero planner would, and place the 8-job queue's modeled
    // durations on it: hetero-aware LPT vs the identical-device
    // baseline, both evaluated under the fleet's true speeds.
    let class_stat = DpStat::new();
    for (&d, &secs) in &dp_secs {
        class_stat.record_class("fast", d, 4.0, secs);
        class_stat.record_class("slow", d, 4.0, secs * 4.0);
    }
    let mut hcm = plora::search::live_cost_model(&rt, "nano")?;
    hcm.calib.dp_fit_class = class_stat.class_fits();
    let fleet =
        hosts_from_fits(&hcm.calib, &[("fast".to_string(), 1), ("slow".to_string(), 3)], 1);
    let slow_speed = fleet.last().map(|h| h.speed).unwrap_or(f64::NAN);
    // Modeled reference duration of each queue job: its padded bucket
    // rows at the measured d=1 per-step cost, over the queue's step
    // budget. Only the *spread* matters to the placement ratio.
    let durs: Vec<f64> = queue()
        .iter()
        .map(|j| {
            let rows: usize = j.pack.configs.iter().map(|c| c.batch).sum();
            rows as f64 * dp_secs[&1].max(1e-9) * 24.0
        })
        .collect();
    let hetero_aware = place_jobs(&durs, &fleet, true);
    let hetero_blind = place_jobs(&durs, &fleet, false);

    // ASHA-vs-full tuner scenario: the same 8-trial LR sweep (two task
    // groups with one clearly-best LR each) through both tuners on the
    // same seed and policy. ASHA's eta=2 / 2-rung ladder trains every
    // trial to 16 samples and only the top half of each group to the
    // full 32, so its makespan must land strictly below the exhaustive
    // sweep while the surviving best-per-task results stay bitwise
    // identical to the full sweep's (CI pins both).
    let asha_lrs = [2e-3, 1e-5, 2e-5, 5e-5];
    let asha_cfgs: Vec<LoraConfig> = (0..8usize)
        .map(|i| {
            let task = if i < 4 { "modadd" } else { "copy" };
            LoraConfig {
                id: i,
                lr: asha_lrs[i % 4],
                batch: 1,
                rank: 8,
                alpha_ratio: 1.0,
                task: task.into(),
            }
        })
        .collect();
    let sweep_opts = SweepOptions {
        budget: TrainBudget { dataset: 32, epochs: 1 },
        eval_batches: 2,
        seed: 11,
        gpus,
        policy: Policy::Fifo,
        elastic: false,
    };
    let mut tuner_out: Option<TunerOutcome> = None;
    let s_full = b.measure("sweep_full", || {
        let full = FullSweep::default();
        tuner_out = Some(full.run(&rt, "nano", &asha_cfgs, &sweep_opts, None).expect("full sweep"));
    });
    let full_out = tuner_out.take().expect("at least one measured run");
    let asha = Asha { eta: 2, rungs: 2, ckpt_dir: None };
    let s_asha = b.measure("sweep_asha", || {
        tuner_out = Some(asha.run(&rt, "nano", &asha_cfgs, &sweep_opts, None).expect("asha sweep"));
    });
    let asha_out = tuner_out.take().expect("at least one measured run");
    let full_best = best_per_task(&full_out.reports);
    let asha_best = best_per_task(&asha_out.reports);
    let parity = full_best.iter().all(|(task, fb)| {
        asha_best.get(task).map_or(false, |ab| ab.eval_acc.to_bits() == fb.eval_acc.to_bits())
    });
    b.finish()?;

    let rank_units: usize = report
        .outcomes
        .iter()
        .flat_map(|o| &o.report.adapters)
        .map(|a| a.config.rank)
        .sum();
    let rec = Json::obj(vec![
        ("schema", Json::num(plora::trace::perf::SNAPSHOT_SCHEMA as f64)),
        ("bench", Json::str("session")),
        ("jobs", Json::num(report.outcomes.len() as f64)),
        ("adapters", Json::num(report.total_adapters() as f64)),
        ("gpus", Json::num(gpus as f64)),
        ("makespan_s", Json::num(report.makespan)),
        ("makespan_norebucket_s", Json::num(report_off.makespan)),
        ("mean_wall_s", Json::num(s.mean)),
        ("mean_wall_norebucket_s", Json::num(s_off.mean)),
        ("rank_units_per_s", Json::num(rank_units as f64 / report.makespan.max(1e-9))),
        ("rebucket_events", Json::num(report.rebuckets() as f64)),
        ("padded_rows", Json::num(report.padded_rows() as f64)),
        ("padded_rows_norebucket", Json::num(report_off.padded_rows() as f64)),
        ("events", Json::num(report.events.len() as f64)),
        ("switch_cost_s", Json::num(report.switch_cost)),
        // Skewed-arrival acceptance numbers (CI gates on these).
        ("skew_makespan_fifo_s", Json::num(skew_fifo.makespan)),
        ("skew_makespan_elastic_s", Json::num(skew_elastic.makespan)),
        ("skew_mean_wall_fifo_s", Json::num(s_fifo.mean)),
        ("skew_mean_wall_elastic_s", Json::num(s_el.mean)),
        (
            "skew_elastic_vs_fifo",
            Json::num(skew_elastic.makespan / skew_fifo.makespan.max(1e-9)),
        ),
        ("skew_padded_rows_fifo", Json::num(skew_fifo.padded_rows() as f64)),
        ("skew_padded_rows_elastic", Json::num(skew_elastic.padded_rows() as f64)),
        ("skew_admissions", Json::num(skew_elastic.admissions() as f64)),
        ("skew_rebuckets", Json::num(skew_elastic.rebuckets() as f64)),
        ("skew_preemptions", Json::num(skew_elastic.preemptions() as f64)),
        // Device axis: per-d sharded step times, the dp-efficiency
        // figure, and the d-aware-planner-vs-fixed-d gate numbers.
        ("dp_step_secs_d1", Json::num(dp_secs[&1])),
        ("dp_step_secs_d2", Json::num(dp_secs[&2])),
        ("dp_step_secs_d4", Json::num(dp_secs[&4])),
        ("dp_efficiency_d2", Json::num(dp_eff(2))),
        ("dp_efficiency_d4", Json::num(dp_eff(4))),
        (
            "dp_fit_serial_per_row_s",
            Json::num(dp_stat.fit().map(|(a, _)| a).unwrap_or(f64::NAN)),
        ),
        (
            "dp_fit_parallel_per_row_s",
            Json::num(dp_stat.fit().map(|(_, b)| b).unwrap_or(f64::NAN)),
        ),
        (
            "d_aware_job_ds",
            Json::arr(aware_ds.iter().map(|&d| Json::num(d as f64))),
        ),
        ("skew_makespan_d_aware_s", Json::num(d_aware.makespan)),
        ("skew_makespan_fixed_d_s", Json::num(d_fixed.makespan)),
        (
            "skew_d_aware_vs_fixed_d",
            Json::num(d_aware.makespan / d_fixed.makespan.max(1e-9)),
        ),
        // Stage axis: per-depth step times plus the effective depth the
        // runtime actually executed (nano clamps s to its layer count).
        ("pipe_step_secs_s1", Json::num(pipe_secs[&1])),
        ("pipe_step_secs_s2", Json::num(pipe_secs[&2])),
        ("pipe_effective_depth_s2", Json::num(pipe_depth[&2] as f64)),
        // Skewed-fleet placement gate: hetero-aware must not lose to the
        // identical-device baseline (CI pins the ratio's max).
        ("hetero_fleet_slow_speed", Json::num(slow_speed)),
        ("hetero_makespan_aware_s", Json::num(hetero_aware.makespan)),
        ("hetero_makespan_identical_s", Json::num(hetero_blind.makespan)),
        (
            "hetero_aware_vs_identical",
            Json::num(hetero_aware.makespan / hetero_blind.makespan.max(1e-9)),
        ),
        // ASHA tuner gate: early stopping must cut the sweep makespan
        // without losing the full sweep's best-per-task result.
        ("sweep_full_makespan_s", Json::num(full_out.session.makespan)),
        ("sweep_asha_makespan_s", Json::num(asha_out.session.makespan)),
        ("sweep_full_mean_wall_s", Json::num(s_full.mean)),
        ("sweep_asha_mean_wall_s", Json::num(s_asha.mean)),
        (
            "asha_vs_full_makespan",
            Json::num(asha_out.session.makespan / full_out.session.makespan.max(1e-9)),
        ),
        ("asha_quality_parity", Json::num(if parity { 1.0 } else { 0.0 })),
        (
            "asha_rung_trials",
            Json::arr(asha_out.rungs.iter().map(|r| Json::num(r.trials as f64))),
        ),
    ]);
    let mut out = String::new();
    rec.write(&mut out);
    // Default path anchors on the crate root (cargo runs benches with
    // CWD = package root); `--out`/`PLORA_BENCH_OUT` override it.
    let path = plora::bench::out_path(env!("CARGO_MANIFEST_DIR"), "BENCH_session.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, &out)?;
    println!(
        "\nsession queue8: makespan {:.2}s (no-rebucket {:.2}s), {} rebuckets, \
         padded rows {} -> {}",
        report.makespan,
        report_off.makespan,
        report.rebuckets(),
        report_off.padded_rows(),
        report.padded_rows(),
    );
    println!(
        "skewed arrival: elastic {:.2}s vs fifo {:.2}s ({:.0}% work: {} -> {} rows, \
         {} admissions, {} rebuckets)",
        skew_elastic.makespan,
        skew_fifo.makespan,
        100.0 * skew_elastic.padded_rows() as f64 / skew_fifo.padded_rows().max(1) as f64,
        skew_fifo.padded_rows(),
        skew_elastic.padded_rows(),
        skew_elastic.admissions(),
        skew_elastic.rebuckets(),
    );
    println!(
        "sharded steps: d1 {:.4}s  d2 {:.4}s (eff {:.2})  d4 {:.4}s (eff {:.2})",
        dp_secs[&1],
        dp_secs[&2],
        dp_eff(2),
        dp_secs[&4],
        dp_eff(4),
    );
    println!(
        "d-aware planner (d = {aware_ds:?}): {:.2}s vs fixed d=1 {:.2}s",
        d_aware.makespan, d_fixed.makespan,
    );
    println!(
        "pipelined steps: s1 {:.4}s  s2 {:.4}s (effective depth {})",
        pipe_secs[&1], pipe_secs[&2], pipe_depth[&2],
    );
    println!(
        "skewed fleet (1 fast + 3 slow at {:.2}x): hetero-aware {:.2}s vs identical {:.2}s \
         (ratio {:.2})",
        slow_speed,
        hetero_aware.makespan,
        hetero_blind.makespan,
        hetero_aware.makespan / hetero_blind.makespan.max(1e-9),
    );
    println!(
        "asha tuner: {:.2}s vs full sweep {:.2}s (ratio {:.2}, quality parity {})",
        asha_out.session.makespan,
        full_out.session.makespan,
        asha_out.session.makespan / full_out.session.makespan.max(1e-9),
        parity,
    );
    println!("wrote {}", path.display());
    Ok(())
}
