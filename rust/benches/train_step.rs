//! Reference-backend train-step throughput: per-step wall time and
//! tokens/sec on the `nano` and `small` TinyLM geometries, comparing
//!
//! - **naive** — the pre-tiling triple-loop GEMMs with a fresh scratch
//!   every step (the allocate-~30-buffers-per-layer-per-step behavior the
//!   workspace arena replaced), per-adapter weight-gradient loop,
//! - **tiled** — register-blocked/cache-tiled kernels + the persistent
//!   workspace arena, single worker, per-adapter weight-gradient loop
//!   (`PLORA_FUSED=0`),
//! - **fused** — tiled + the batched multi-adapter dA/dB weight-gradient
//!   GEMMs and hoisted shared-base projections (the default path),
//! - **simd** — fused + the explicit-vector `PLORA_GEMM=simd` microkernel,
//!   and
//! - **threads4** — fused + `PLORA_THREADS`-style row parallelism at 4
//!   workers.
//!
//! All variants produce bit-identical trajectories (pinned by
//! `tests/properties.rs` and the reference-backend invariance test); only
//! the wall clock moves. A separate microbench isolates the fused batched
//! dA/dB reduction against the per-adapter tiled loop on the exact shapes
//! `proj_bwd_wgrads` issues, emitting the `*_wgrads_fused_vs_tiled_x`
//! ratios the perf budget gates. Emits `BENCH_train_step.json` to
//! `target/` by default — `--out <path>` or `PLORA_BENCH_OUT=<dir>`
//! redirect it for the perf-budget harness (`bench/history/`) — and
//! appends to the shared `target/plora-bench.jsonl` like every bench.
//!
//! Run: `cargo bench --bench train_step`

use plora::bench::Bench;
use plora::runtime::reference::gemm;
use plora::runtime::{HostTensor, Runtime, TrainState};
use plora::util::json::Json;
use plora::util::rng::Rng;

/// One measured configuration of the step kernel path.
#[derive(Clone, Copy)]
struct Variant {
    label: &'static str,
    mode: gemm::Mode,
    threads: usize,
    /// Batched multi-adapter weight-gradient GEMMs (`PLORA_FUSED`).
    fused: bool,
    /// Drop the scratch before every step (pre-arena behavior).
    fresh_scratch: bool,
}

const VARIANTS: [Variant; 5] = [
    Variant {
        label: "naive",
        mode: gemm::Mode::Naive,
        threads: 1,
        fused: false,
        fresh_scratch: true,
    },
    Variant {
        label: "tiled",
        mode: gemm::Mode::Tiled,
        threads: 1,
        fused: false,
        fresh_scratch: false,
    },
    Variant {
        label: "fused",
        mode: gemm::Mode::Tiled,
        threads: 1,
        fused: true,
        fresh_scratch: false,
    },
    Variant {
        label: "simd",
        mode: gemm::Mode::Simd,
        threads: 1,
        fused: true,
        fresh_scratch: false,
    },
    Variant {
        label: "threads4",
        mode: gemm::Mode::Tiled,
        threads: 4,
        fused: true,
        fresh_scratch: false,
    },
];

/// Median per-step seconds for one `(model, n, r, bs)` bucket under a
/// variant. The same seeded batch stream is replayed for every variant, so
/// the compared work is identical.
fn measure(
    bench: &mut Bench,
    rt: &Runtime,
    model: &str,
    n: usize,
    r: usize,
    bs: usize,
    var: Variant,
) -> anyhow::Result<f64> {
    let mi = rt.manifest.model(model)?.clone();
    let info = rt
        .manifest
        .train_bucket(model, n, r, bs)
        .ok_or_else(|| anyhow::anyhow!("no bucket {model} n={n} r={r} bs={bs}"))?
        .clone();
    let exe = rt.executable(&info.name)?;
    let base = rt.base_weights(model)?;
    let seq = mi.seq;

    gemm::set_mode(var.mode);
    gemm::set_threads(var.threads);
    gemm::set_fused(var.fused);
    let mut state = TrainState::init(&mi, n, r, 17);
    let rmask = state.rank_mask(&vec![r; n])?;
    let scale = vec![1.0f32; n];
    let lr = vec![1e-3f32; n];
    // One fixed seeded batch, replayed every step and for every variant,
    // so all variants time identical work.
    let mut rng = Rng::new(11);
    let tokens: Vec<i32> =
        (0..n * bs * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    let tok = HostTensor::i32(vec![n, bs, seq], tokens)?;
    let tgt = HostTensor::i32(vec![n, bs, seq], targets)?;
    let msk = HostTensor::f32(vec![n, bs, seq], vec![1.0; n * bs * seq])?;

    let meta = Json::obj(vec![
        ("model", Json::str(model)),
        ("n", Json::num(n as f64)),
        ("r", Json::num(r as f64)),
        ("bs", Json::num(bs as f64)),
        ("variant", Json::str(var.label)),
        ("fused", Json::Bool(var.fused)),
    ]);
    let s = bench.measure_meta(&format!("{model}_n{n}/{}", var.label), meta, &mut || {
        if var.fresh_scratch {
            state.reset_scratch();
        }
        state.step(&exe, &base, &tok, &tgt, &msk, &scale, &lr, &rmask).unwrap();
    });
    gemm::set_mode(gemm::Mode::Tiled);
    gemm::set_threads(1);
    gemm::set_fused(true);
    Ok(s.p50)
}

/// Isolated dA/dB weight-gradient reduction: the per-adapter tiled
/// `mm_tn_acc` loop vs the fused `gemm::batched` driver, both
/// single-threaded, on synthetic buffers with the exact adapter-major
/// layouts `proj_bwd_wgrads` issues (`rows` token-rows per adapter,
/// `d`-wide activations, rank `r`). `reps` passes per measured call keep
/// the closure well above timer resolution. Returns per-pass
/// `(tiled_s, fused_s)` medians.
fn wgrads(
    bench: &mut Bench,
    model: &str,
    nb: usize,
    rows: usize,
    d: usize,
    r: usize,
    reps: usize,
) -> (f64, f64) {
    let mut rng = Rng::new(23);
    let mut buf = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal() as f32).collect() };
    let input = buf(nb * rows * d);
    let dmid = buf(nb * rows * r);
    let mid = buf(nb * rows * r);
    let dy = buf(nb * rows * d);
    let scale: Vec<f32> = (0..nb).map(|i| 0.5 + 0.25 * i as f32).collect();
    let mut da = vec![0.0f32; nb * d * r];
    let mut db = vec![0.0f32; nb * r * d];

    gemm::set_mode(gemm::Mode::Tiled);
    gemm::set_threads(1);
    let meta = |variant: &str| {
        Json::obj(vec![
            ("model", Json::str(model)),
            ("n", Json::num(nb as f64)),
            ("variant", Json::str(variant)),
            ("reps", Json::num(reps as f64)),
        ])
    };
    let mt = meta("wgrads_tiled");
    let t = bench.measure_meta(&format!("{model}_n{nb}/wgrads_tiled"), mt, &mut || {
        for _ in 0..reps {
            da.iter_mut().for_each(|x| *x = 0.0);
            db.iter_mut().for_each(|x| *x = 0.0);
            for i in 0..nb {
                gemm::mm_tn_acc(
                    &mut da[i * d * r..(i + 1) * d * r],
                    &input[i * rows * d..(i + 1) * rows * d],
                    &dmid[i * rows * r..(i + 1) * rows * r],
                    rows,
                    d,
                    r,
                    1.0,
                );
                gemm::mm_tn_acc(
                    &mut db[i * r * d..(i + 1) * r * d],
                    &mid[i * rows * r..(i + 1) * rows * r],
                    &dy[i * rows * d..(i + 1) * rows * d],
                    rows,
                    r,
                    d,
                    scale[i],
                );
            }
        }
    });
    let mf = meta("wgrads_fused");
    let f = bench.measure_meta(&format!("{model}_n{nb}/wgrads_fused"), mf, &mut || {
        for _ in 0..reps {
            da.iter_mut().for_each(|x| *x = 0.0);
            db.iter_mut().for_each(|x| *x = 0.0);
            gemm::batched::mm_tn_acc_par(&mut da, &input, &dmid, nb, rows, d, r, None, 1);
            gemm::batched::mm_tn_acc_par(&mut db, &mid, &dy, nb, rows, r, d, Some(&scale), 1);
        }
    });
    (t.p50 / reps as f64, f.p50 / reps as f64)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut bench = Bench::new("train_step");
    bench.warmup_iters = 1;
    bench.min_iters = 3;
    bench.max_iters = 8;
    bench.target_secs = 2.0;

    // (model, n, r, bs) buckets from the built-in grid. `small` n=1 is the
    // acceptance geometry for the tiled speedup; the n=4 buckets exercise
    // the fused multi-adapter path where batching has adapters to batch.
    let geoms = [
        ("nano", 2usize, 8usize, 1usize),
        ("nano", 4, 8, 1),
        ("small", 1, 32, 1),
        ("small", 4, 32, 1),
    ];
    let mut rows = vec![];
    // Flat `{model}_n{n}_*` copies of the per-geom metrics ride at the
    // top level so the perf-budget harness can gate them by name.
    let mut flat = std::collections::BTreeMap::new();
    for (model, n, r, bs) in geoms {
        let mi = rt.manifest.model(model)?.clone();
        let tokens_per_step = (n * bs * mi.seq) as f64;
        let mut secs = [0.0f64; VARIANTS.len()];
        for (vi, var) in VARIANTS.iter().enumerate() {
            secs[vi] = measure(&mut bench, &rt, model, n, r, bs, *var)?;
        }
        let (naive, tiled, fused, simd, thr) = (secs[0], secs[1], secs[2], secs[3], secs[4]);
        let metrics = [
            ("step_naive_s", naive),
            ("step_tiled_s", tiled),
            ("step_fused_s", fused),
            ("step_simd_s", simd),
            ("step_threads4_s", thr),
            ("speedup_tiled_x", naive / tiled.max(1e-12)),
            ("speedup_fused_x", naive / fused.max(1e-12)),
            ("speedup_simd_x", naive / simd.max(1e-12)),
            ("speedup_threads4_x", naive / thr.max(1e-12)),
            ("fused_vs_tiled_x", tiled / fused.max(1e-12)),
            ("simd_vs_tiled_x", tiled / simd.max(1e-12)),
        ];
        for (k, v) in metrics {
            flat.insert(format!("{model}_n{n}_{k}"), Json::num(v));
        }
        let mut row = vec![
            ("model", Json::str(model)),
            ("n", Json::num(n as f64)),
            ("r", Json::num(r as f64)),
            ("bs", Json::num(bs as f64)),
        ];
        for (k, v) in metrics {
            row.push((k, Json::num(v)));
        }
        row.push(("tokens_per_s_naive", Json::num(tokens_per_step / naive.max(1e-12))));
        row.push(("tokens_per_s_fused", Json::num(tokens_per_step / fused.max(1e-12))));
        rows.push(Json::obj(row));
        println!(
            "{model} n={n} r={r} bs={bs}: naive {naive:.4}s -> tiled {tiled:.4}s \
             ({:.2}x) -> fused {fused:.4}s ({:.2}x vs tiled) -> simd {simd:.4}s \
             -> threads4 {thr:.4}s",
            naive / tiled.max(1e-12),
            tiled / fused.max(1e-12),
        );
    }

    // Isolated fused-vs-tiled weight-gradient reduction at n=4 (the
    // acceptance geometries): nano rows = bs·seq = 32, small rows = 64.
    for (model, nb, rows_per, d, r, reps) in
        [("nano", 4usize, 32usize, 64usize, 8usize, 256usize), ("small", 4, 64, 256, 32, 16)]
    {
        let (tiled, fused) = wgrads(&mut bench, model, nb, rows_per, d, r, reps);
        let ratio = tiled / fused.max(1e-12);
        flat.insert(format!("{model}_n{nb}_wgrads_tiled_s"), Json::num(tiled));
        flat.insert(format!("{model}_n{nb}_wgrads_fused_s"), Json::num(fused));
        flat.insert(format!("{model}_n{nb}_wgrads_fused_vs_tiled_x"), Json::num(ratio));
        println!(
            "{model} n={nb} wgrads: tiled {:.1}us -> fused {:.1}us ({ratio:.2}x)",
            tiled * 1e6,
            fused * 1e6,
        );
    }
    bench.finish()?;

    flat.insert(
        "schema".to_string(),
        Json::num(plora::trace::perf::SNAPSHOT_SCHEMA as f64),
    );
    flat.insert("bench".to_string(), Json::str("train_step"));
    flat.insert("geoms".to_string(), Json::arr(rows));
    let rec = Json::Obj(flat);
    let mut out = String::new();
    rec.write(&mut out);
    // Default path anchors on the crate root (cargo runs benches with
    // CWD = package root); `--out`/`PLORA_BENCH_OUT` override it.
    let path = plora::bench::out_path(env!("CARGO_MANIFEST_DIR"), "BENCH_train_step.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
