//! Reference-backend train-step throughput: per-step wall time and
//! tokens/sec on the `nano` and `small` TinyLM geometries, comparing
//!
//! - **naive** — the pre-tiling triple-loop GEMMs with a fresh scratch
//!   every step (the allocate-~30-buffers-per-layer-per-step behavior the
//!   workspace arena replaced),
//! - **tiled** — register-blocked/cache-tiled kernels + the persistent
//!   workspace arena, single worker, and
//! - **threads4** — tiled + arena with `PLORA_THREADS`-style row
//!   parallelism at 4 workers.
//!
//! All three produce bit-identical trajectories (pinned by
//! `tests/properties.rs` and the reference-backend invariance test); only
//! the wall clock moves. Emits `BENCH_train_step.json` (speedups +
//! tokens/sec) to `target/` by default — `--out <path>` or
//! `PLORA_BENCH_OUT=<dir>` redirect it for the perf-budget harness
//! (`bench/history/`) — and appends to the shared
//! `target/plora-bench.jsonl` like every bench.
//!
//! Run: `cargo bench --bench train_step`

use plora::bench::Bench;
use plora::runtime::reference::gemm;
use plora::runtime::{HostTensor, Runtime, TrainState};
use plora::util::json::Json;
use plora::util::rng::Rng;

/// One measured configuration of the step kernel path.
#[derive(Clone, Copy)]
struct Variant {
    label: &'static str,
    mode: gemm::Mode,
    threads: usize,
    /// Drop the scratch before every step (pre-arena behavior).
    fresh_scratch: bool,
}

const VARIANTS: [Variant; 3] = [
    Variant { label: "naive", mode: gemm::Mode::Naive, threads: 1, fresh_scratch: true },
    Variant { label: "tiled", mode: gemm::Mode::Tiled, threads: 1, fresh_scratch: false },
    Variant { label: "threads4", mode: gemm::Mode::Tiled, threads: 4, fresh_scratch: false },
];

/// Median per-step seconds for one `(model, n, r, bs)` bucket under a
/// variant. The same seeded batch stream is replayed for every variant, so
/// the compared work is identical.
fn measure(
    bench: &mut Bench,
    rt: &Runtime,
    model: &str,
    n: usize,
    r: usize,
    bs: usize,
    var: Variant,
) -> anyhow::Result<f64> {
    let mi = rt.manifest.model(model)?.clone();
    let info = rt
        .manifest
        .train_bucket(model, n, r, bs)
        .ok_or_else(|| anyhow::anyhow!("no bucket {model} n={n} r={r} bs={bs}"))?
        .clone();
    let exe = rt.executable(&info.name)?;
    let base = rt.base_weights(model)?;
    let seq = mi.seq;

    gemm::set_mode(var.mode);
    gemm::set_threads(var.threads);
    let mut state = TrainState::init(&mi, n, r, 17);
    let rmask = state.rank_mask(&vec![r; n])?;
    let scale = vec![1.0f32; n];
    let lr = vec![1e-3f32; n];
    // One fixed seeded batch, replayed every step and for every variant,
    // so all variants time identical work.
    let mut rng = Rng::new(11);
    let tokens: Vec<i32> =
        (0..n * bs * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    let tok = HostTensor::i32(vec![n, bs, seq], tokens)?;
    let tgt = HostTensor::i32(vec![n, bs, seq], targets)?;
    let msk = HostTensor::f32(vec![n, bs, seq], vec![1.0; n * bs * seq])?;

    let meta = Json::obj(vec![
        ("model", Json::str(model)),
        ("n", Json::num(n as f64)),
        ("r", Json::num(r as f64)),
        ("bs", Json::num(bs as f64)),
        ("variant", Json::str(var.label)),
    ]);
    let s = bench.measure_meta(&format!("{model}_n{n}/{}", var.label), meta, &mut || {
        if var.fresh_scratch {
            state.reset_scratch();
        }
        state.step(&exe, &base, &tok, &tgt, &msk, &scale, &lr, &rmask).unwrap();
    });
    gemm::set_mode(gemm::Mode::Tiled);
    gemm::set_threads(1);
    Ok(s.p50)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut bench = Bench::new("train_step");
    bench.warmup_iters = 1;
    bench.min_iters = 3;
    bench.max_iters = 8;
    bench.target_secs = 2.0;

    // (model, n, r, bs) buckets from the built-in grid. `small` n=1 is the
    // acceptance geometry; nano covers the many-small-steps regime.
    let geoms = [("nano", 2usize, 8usize, 1usize), ("small", 1, 32, 1)];
    let mut rows = vec![];
    // Flat `{model}_n{n}_*` copies of the per-geom metrics ride at the
    // top level so the perf-budget harness can gate them by name.
    let mut flat = std::collections::BTreeMap::new();
    for (model, n, r, bs) in geoms {
        let mi = rt.manifest.model(model)?.clone();
        let tokens_per_step = (n * bs * mi.seq) as f64;
        let mut secs = [0.0f64; VARIANTS.len()];
        for (vi, var) in VARIANTS.iter().enumerate() {
            secs[vi] = measure(&mut bench, &rt, model, n, r, bs, *var)?;
        }
        let (naive, tiled, thr) = (secs[0], secs[1], secs[2]);
        let metrics = [
            ("step_naive_s", naive),
            ("step_tiled_s", tiled),
            ("step_threads4_s", thr),
            ("speedup_tiled_x", naive / tiled.max(1e-12)),
            ("speedup_threads4_x", naive / thr.max(1e-12)),
        ];
        for (k, v) in metrics {
            flat.insert(format!("{model}_n{n}_{k}"), Json::num(v));
        }
        rows.push(Json::obj(vec![
            ("model", Json::str(model)),
            ("n", Json::num(n as f64)),
            ("r", Json::num(r as f64)),
            ("bs", Json::num(bs as f64)),
            ("step_naive_s", Json::num(naive)),
            ("step_tiled_s", Json::num(tiled)),
            ("step_threads4_s", Json::num(thr)),
            ("speedup_tiled_x", Json::num(naive / tiled.max(1e-12))),
            ("speedup_threads4_x", Json::num(naive / thr.max(1e-12))),
            ("tokens_per_s_naive", Json::num(tokens_per_step / naive.max(1e-12))),
            ("tokens_per_s_tiled", Json::num(tokens_per_step / tiled.max(1e-12))),
            ("tokens_per_s_threads4", Json::num(tokens_per_step / thr.max(1e-12))),
        ]));
        println!(
            "{model} n={n} r={r} bs={bs}: naive {naive:.4}s -> tiled {tiled:.4}s \
             ({:.2}x) -> threads4 {thr:.4}s ({:.2}x)",
            naive / tiled.max(1e-12),
            naive / thr.max(1e-12),
        );
    }
    bench.finish()?;

    flat.insert(
        "schema".to_string(),
        Json::num(plora::trace::perf::SNAPSHOT_SCHEMA as f64),
    );
    flat.insert("bench".to_string(), Json::str("train_step"));
    flat.insert("geoms".to_string(), Json::arr(rows));
    let rec = Json::Obj(flat);
    let mut out = String::new();
    rec.write(&mut out);
    // Default path anchors on the crate root (cargo runs benches with
    // CWD = package root); `--out`/`PLORA_BENCH_OUT` override it.
    let path = plora::bench::out_path(env!("CARGO_MANIFEST_DIR"), "BENCH_train_step.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, &out)?;
    println!("wrote {}", path.display());
    Ok(())
}
