//! Criterion-like benchmark harness (criterion is not in the offline set).
//!
//! Each `cargo bench` target is a `harness = false` binary that builds a
//! [`Bench`] and registers measurements. The harness does warmup, adaptive
//! iteration counts, and prints a compact table; results are also appended
//! as JSON lines to `target/plora-bench.jsonl` so EXPERIMENTS.md tables can
//! be regenerated from raw data.

use crate::util::json::Json;
use crate::util::stats::{fmt_secs, summarize, Summary};
use std::io::Write;
use std::time::Instant;

pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_secs: f64,
    results: Vec<(String, Summary, Json)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // Keep budgets small: single-core machine, real numeric workloads.
        Bench {
            name: name.to_string(),
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 50,
            target_secs: 2.0,
            results: vec![],
        }
    }

    /// Measure `f` (one call = one iteration). Returns the summary.
    pub fn measure<F: FnMut()>(&mut self, label: &str, mut f: F) -> Summary {
        self.measure_meta(label, Json::Null, &mut f)
    }

    /// Measure with attached metadata (written to the JSONL record).
    pub fn measure_meta<F: FnMut()>(&mut self, label: &str, meta: Json, f: &mut F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = vec![];
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.target_secs
                && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        println!(
            "{:<44} {:>10} ± {:>9}  (p50 {:>10}, n={})",
            format!("{}/{}", self.name, label),
            fmt_secs(s.mean),
            fmt_secs(s.std),
            fmt_secs(s.p50),
            s.n
        );
        self.results.push((label.to_string(), s.clone(), meta));
        s
    }

    /// Record an externally-measured duration series under this bench.
    pub fn record(&mut self, label: &str, samples: &[f64], meta: Json) -> Summary {
        let s = summarize(samples);
        println!(
            "{:<44} {:>10} (recorded, n={})",
            format!("{}/{}", self.name, label),
            fmt_secs(s.mean),
            s.n
        );
        self.results.push((label.to_string(), s.clone(), meta));
        s
    }

    /// Write all results as JSON lines (append) and return them.
    pub fn finish(&self) -> anyhow::Result<()> {
        let path = std::path::Path::new("target").join("plora-bench.jsonl");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        for (label, s, meta) in &self.results {
            let rec = Json::obj(vec![
                ("bench", Json::str(self.name.clone())),
                ("label", Json::str(label.clone())),
                ("mean_s", Json::num(s.mean)),
                ("std_s", Json::num(s.std)),
                ("p50_s", Json::num(s.p50)),
                ("n", Json::num(s.n as f64)),
                ("meta", meta.clone()),
            ]);
            writeln!(f, "{rec}")?;
        }
        Ok(())
    }
}

/// Prevent the optimizer from discarding a value (std::hint based).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Where a bench binary should write its `BENCH_*.json` summary.
///
/// Resolution order: a `--out <path>` argument (reachable via
/// `cargo bench --bench <name> -- --out <path>`), then the
/// `PLORA_BENCH_OUT` env var as a *directory* for `name`, then the
/// historical default `<manifest_dir>/target/<name>`. The perf-budget
/// harness relies on the first two: CI writes to a stable path and gates
/// it against the committed `bench/history/` snapshot.
pub fn out_path(manifest_dir: &str, name: &str) -> std::path::PathBuf {
    let args = crate::util::cli::Args::parse();
    if let Some(p) = args.get("out") {
        return std::path::PathBuf::from(p);
    }
    if let Ok(dir) = std::env::var("PLORA_BENCH_OUT") {
        if !dir.is_empty() {
            return std::path::PathBuf::from(dir).join(name);
        }
    }
    std::path::Path::new(manifest_dir).join("target").join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_summarizes() {
        let mut b = Bench::new("unit");
        b.warmup_iters = 0;
        b.min_iters = 3;
        b.max_iters = 3;
        let s = b.measure("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn record_external_series() {
        let mut b = Bench::new("unit");
        let s = b.record("ext", &[1.0, 2.0, 3.0], Json::Null);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }
}
