//! Hardware pool abstraction for the execution engine: a set of devices
//! with memory capacity, allocation/release, and blocking acquisition —
//! the **Resource Monitor** of Figure 3.
//!
//! In live mode the "devices" are capacity slots over the shared CPU PJRT
//! backend (cpu-sim profile): the engine's packing decisions and job
//! lifecycle are identical to a real pool; only the duration model differs
//! (documented in DESIGN.md §7).

use std::collections::BTreeSet;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::config::GpuProfile;

/// One device.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub mem_bytes: f64,
}

/// A granted allocation; returned to the pool via [`ResourceMonitor::release`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub devices: Vec<usize>,
}

impl Allocation {
    pub fn d(&self) -> usize {
        self.devices.len()
    }

    /// A pool-less allocation of devices `0..d` — what standalone drivers
    /// (`run_pack`, benches, tests) execute on when no [`ResourceMonitor`]
    /// granted one. `d` is clamped to ≥ 1.
    pub fn local(d: usize) -> Allocation {
        Allocation { devices: (0..d.max(1)).collect() }
    }
}

#[derive(Debug)]
struct PoolState {
    free: BTreeSet<usize>,
    total: usize,
}

/// Thread-safe device pool with blocking acquisition (condvar-based —
/// worker threads park until enough devices free up).
#[derive(Clone)]
pub struct ResourceMonitor {
    profile: GpuProfile,
    state: Arc<(Mutex<PoolState>, Condvar)>,
}

impl ResourceMonitor {
    pub fn new(profile: &GpuProfile, count: usize) -> ResourceMonitor {
        ResourceMonitor {
            profile: profile.clone(),
            state: Arc::new((
                Mutex::new(PoolState { free: (0..count).collect(), total: count }),
                Condvar::new(),
            )),
        }
    }

    pub fn profile(&self) -> &GpuProfile {
        &self.profile
    }

    pub fn total(&self) -> usize {
        self.state.0.lock().unwrap().total
    }

    pub fn available(&self) -> usize {
        self.state.0.lock().unwrap().free.len()
    }

    /// Try to allocate `d` devices without blocking.
    pub fn try_acquire(&self, d: usize) -> Option<Allocation> {
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap();
        if st.free.len() < d {
            return None;
        }
        let devices: Vec<usize> = st.free.iter().take(d).copied().collect();
        for id in &devices {
            st.free.remove(id);
        }
        Some(Allocation { devices })
    }

    /// Block until `d` devices are free, then allocate them. Errors if the
    /// request can never be satisfied (d > pool size).
    pub fn acquire(&self, d: usize) -> Result<Allocation> {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        if d > st.total {
            bail!("requested {d} devices from a pool of {}", st.total);
        }
        while st.free.len() < d {
            st = cv.wait(st).unwrap();
        }
        let devices: Vec<usize> = st.free.iter().take(d).copied().collect();
        for id in &devices {
            st.free.remove(id);
        }
        Ok(Allocation { devices })
    }

    /// Return an allocation to the pool and wake waiters.
    pub fn release(&self, alloc: Allocation) {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        for id in alloc.devices {
            assert!(st.free.insert(id), "double release of device {id}");
        }
        cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pool::CPU_SIM;
    use std::time::Duration;

    #[test]
    fn try_acquire_and_release() {
        let m = ResourceMonitor::new(&CPU_SIM, 4);
        assert_eq!(m.available(), 4);
        let a = m.try_acquire(3).unwrap();
        assert_eq!(a.d(), 3);
        assert_eq!(m.available(), 1);
        assert!(m.try_acquire(2).is_none());
        m.release(a);
        assert_eq!(m.available(), 4);
    }

    #[test]
    fn acquire_blocks_until_release() {
        let m = ResourceMonitor::new(&CPU_SIM, 2);
        let a = m.try_acquire(2).unwrap();
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let alloc = m2.acquire(1).unwrap();
            m2.release(alloc);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "acquire must block while pool is empty");
        m.release(a);
        t.join().unwrap();
    }

    #[test]
    fn oversized_request_errors() {
        let m = ResourceMonitor::new(&CPU_SIM, 2);
        assert!(m.acquire(3).is_err());
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let m = ResourceMonitor::new(&CPU_SIM, 2);
        let a = m.try_acquire(1).unwrap();
        m.release(a.clone());
        m.release(a);
    }
}
