//! Model geometries.
//!
//! Two families:
//! - **TinyLM** sizes (nano/tiny/small/base) — the models this repo actually
//!   trains end-to-end via the AOT artifacts;
//! - **paper-scale** shapes (Qwen-2.5 3B/7B/14B/32B, LLaMa-3.2-3B,
//!   LLaMa-3.1-8B) — used by the cost model + discrete-event simulator to
//!   regenerate the paper's figures at their original scale.

/// Transformer geometry — everything the Appendix-A memory/FLOP model needs.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelGeom {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub vocab: usize,
    /// Training sequence length (paper §7.1 uses 1024).
    pub seq: usize,
    /// Bytes per parameter of the frozen base (2 = bf16, 0.5 = QLoRA 4-bit).
    pub base_bytes: f64,
    /// Bytes per LoRA/optimizer element (4 = f32 master weights).
    pub lora_bytes: f64,
}

impl ModelGeom {
    /// Total base parameters (embedding + blocks; unquantized count).
    pub fn params(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let v = self.vocab as f64;
        let per_layer = 4.0 * d * d + 3.0 * d * f + 2.0 * d;
        v * d + self.n_layers as f64 * per_layer + d
    }

    /// LoRA parameters for one adapter at rank `r` on all 7 projections
    /// (Appendix A Eq. 20: Q,K,V,O + up,gate,down).
    pub fn lora_params(&self, r: usize) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let r = r as f64;
        let per_layer =
            4.0 * (d * r + r * d) + 2.0 * (d * r + r * f) + (f * r + r * d);
        self.n_layers as f64 * per_layer
    }

    /// FLOPs of one training step for the *base* path over `tokens` tokens.
    /// Frozen base: fwd (2P) + activation-grad bwd (2P); no dW pass.
    pub fn base_step_flops(&self, tokens: f64) -> f64 {
        4.0 * self.params() * tokens
    }

    /// FLOPs of one training step for a single LoRA adapter of rank `r`
    /// over `tokens` tokens: fwd + full bwd (dW and dX) = 6 x params.
    pub fn lora_step_flops(&self, r: usize, tokens: f64) -> f64 {
        6.0 * self.lora_params(r) * tokens
    }

    /// Activation memory of the base path for `bs` sequences (Appendix A):
    /// embeddings + attention + MLP intermediates per layer, f32.
    pub fn base_act_bytes(&self, bs: f64) -> f64 {
        let s = self.seq as f64;
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        let per_layer = s * (2.0 * d + 2.0 * f) + (self.n_heads as f64) * s * s;
        bs * 4.0 * (s * d + self.n_layers as f64 * per_layer)
    }

    pub fn scaled(&self, name: &'static str, base_bytes: f64) -> ModelGeom {
        ModelGeom { name, base_bytes, ..self.clone() }
    }
}

/// Paper-scale geometries (public model-card shapes) plus the TinyLM sizes
/// this repo trains live — so `plan`/`sim` accept both families.
pub const GEOMS: &[ModelGeom] = &[
    ModelGeom {
        name: "qwen2.5-3b",
        n_layers: 36,
        d_model: 2048,
        d_ff: 11008,
        n_heads: 16,
        vocab: 151_936,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "qwen2.5-7b",
        n_layers: 28,
        d_model: 3584,
        d_ff: 18944,
        n_heads: 28,
        vocab: 152_064,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "qwen2.5-14b",
        n_layers: 48,
        d_model: 5120,
        d_ff: 13824,
        n_heads: 40,
        vocab: 152_064,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "qwen2.5-32b",
        n_layers: 64,
        d_model: 5120,
        d_ff: 27648,
        n_heads: 40,
        vocab: 152_064,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "llama3.2-3b",
        n_layers: 28,
        d_model: 3072,
        d_ff: 8192,
        n_heads: 24,
        vocab: 128_256,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "llama3.1-8b",
        n_layers: 32,
        d_model: 4096,
        d_ff: 14336,
        n_heads: 32,
        vocab: 128_256,
        seq: 1024,
        base_bytes: 2.0,
        lora_bytes: 4.0,
    },
    // TinyLM sizes (model.py::MODELS; f32 base — the live runtime's models).
    ModelGeom {
        name: "nano",
        n_layers: 2,
        d_model: 64,
        d_ff: 256,
        n_heads: 2,
        vocab: 256,
        seq: 32,
        base_bytes: 4.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "tiny",
        n_layers: 4,
        d_model: 128,
        d_ff: 512,
        n_heads: 4,
        vocab: 512,
        seq: 64,
        base_bytes: 4.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "small",
        n_layers: 6,
        d_model: 256,
        d_ff: 1024,
        n_heads: 8,
        vocab: 1024,
        seq: 64,
        base_bytes: 4.0,
        lora_bytes: 4.0,
    },
    ModelGeom {
        name: "base",
        n_layers: 8,
        d_model: 512,
        d_ff: 2048,
        n_heads: 8,
        vocab: 4096,
        seq: 128,
        base_bytes: 4.0,
        lora_bytes: 4.0,
    },
];

pub fn geom(name: &str) -> Option<&'static ModelGeom> {
    GEOMS.iter().find(|g| g.name == name)
}

/// Build a TinyLM geometry from manifest fields (runtime models).
pub fn tiny_geom(
    name: &'static str,
    n_layers: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    vocab: usize,
    seq: usize,
) -> ModelGeom {
    ModelGeom {
        name,
        n_layers,
        d_model,
        d_ff,
        n_heads,
        vocab,
        seq,
        base_bytes: 4.0,
        lora_bytes: 4.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_param_counts_are_plausible() {
        // Sanity: our analytic counts should land within ~25% of the
        // advertised sizes (we ignore GQA/bias details on purpose).
        let within = |name: &str, b: f64| {
            let p = geom(name).unwrap().params();
            assert!(
                (p / b - 1.0).abs() < 0.35,
                "{name}: {p:.2e} vs advertised {b:.2e}"
            );
        };
        within("qwen2.5-7b", 7.6e9);
        within("llama3.1-8b", 8.0e9);
        within("qwen2.5-32b", 32.8e9);
    }

    #[test]
    fn lora_fraction_matches_paper_claim() {
        // Paper §2.1: rank-64 adapter on Qwen-2.5-7B updates ~3.4% of params.
        let g = geom("qwen2.5-7b").unwrap();
        let frac = g.lora_params(64) / g.params();
        assert!(frac > 0.015 && frac < 0.05, "fraction {frac}");
    }

    #[test]
    fn lora_flops_linear_in_rank() {
        // §2.1: "additional FLOPs incurred by LoRA is linear to its rank".
        let g = geom("qwen2.5-3b").unwrap();
        let f8 = g.lora_step_flops(8, 1024.0);
        let f64_ = g.lora_step_flops(64, 1024.0);
        assert!((f64_ / f8 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn activation_memory_scales_with_batch() {
        let g = geom("qwen2.5-7b").unwrap();
        assert!((g.base_act_bytes(8.0) / g.base_act_bytes(1.0) - 8.0).abs() < 1e-9);
    }
}
