//! LoRA hyperparameter configurations and the tuning search space (Table 1).

/// One point in the search space: the four knobs of paper Table 1 plus the
/// downstream task it fine-tunes.
#[derive(Debug, Clone, PartialEq)]
pub struct LoraConfig {
    pub id: usize,
    /// Learning rate (paper range 2e-5 .. 4e-4).
    pub lr: f64,
    /// Per-adapter batch size (paper range 1 .. 32; Obs. 4: small wins).
    pub batch: usize,
    /// LoRA rank (paper range 8 .. 128).
    pub rank: usize,
    /// LoRA alpha as the *ratio* alpha/r (paper range r/4 .. 4r, i.e. 0.25..4).
    pub alpha_ratio: f64,
    /// Downstream task name (one of manifest `tasks`).
    pub task: String,
}

impl LoraConfig {
    /// Effective forward scaling s = alpha / r applied to the delta.
    pub fn scale(&self) -> f64 {
        self.alpha_ratio
    }

    /// The id-less spec of this configuration.
    pub fn spec(&self) -> AdapterSpec {
        AdapterSpec {
            lr: self.lr,
            batch: self.batch,
            rank: self.rank,
            alpha_ratio: self.alpha_ratio,
            task: self.task.clone(),
        }
    }
}

/// A LoRA configuration *before* an adapter id exists — what callers hand
/// to `Session::submit` (and what `search::default_config` returns). Ids
/// are allocated by the session at submit time, or explicitly via
/// [`AdapterSpec::with_id`]; there is no sentinel value to leak into the
/// checkpoint pool.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterSpec {
    pub lr: f64,
    pub batch: usize,
    pub rank: usize,
    pub alpha_ratio: f64,
    pub task: String,
}

impl AdapterSpec {
    pub fn new(task: &str) -> AdapterSpec {
        AdapterSpec { lr: 2e-4, batch: 2, rank: 16, alpha_ratio: 1.0, task: task.to_string() }
    }

    /// Bind an adapter id, producing a full [`LoraConfig`].
    pub fn with_id(self, id: usize) -> LoraConfig {
        LoraConfig {
            id,
            lr: self.lr,
            batch: self.batch,
            rank: self.rank,
            alpha_ratio: self.alpha_ratio,
            task: self.task,
        }
    }
}

/// The hyperparameter search space. `grid()` builds the paper's 120-point
/// grid; `sample()` draws random-search points (PLoRA is agnostic to the
/// tuning algorithm — §8 Related Work).
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub lrs: Vec<f64>,
    pub batches: Vec<usize>,
    pub ranks: Vec<usize>,
    pub alpha_ratios: Vec<f64>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        // 5 LR x 3 BS x 4 rank x 2 alpha = 120 configurations (§7.1).
        SearchSpace {
            lrs: vec![2e-5, 6e-5, 1e-4, 2e-4, 4e-4],
            batches: vec![1, 2, 4],
            ranks: vec![8, 16, 32, 64],
            alpha_ratios: vec![0.25, 1.0],
        }
    }
}

impl SearchSpace {
    /// Early-stopping-oriented space: LR-dense, rank-narrow. "Learning
    /// Rate Matters" (PAPERS.md) shows LR dominates rank for LoRA
    /// quality, so a successive-halving tuner gets the most signal per
    /// trial from many LRs at few ranks — most of the grid is
    /// predictably-bad LRs that rung demotion kills after the first
    /// budget fraction.
    pub fn lr_dense() -> SearchSpace {
        SearchSpace {
            lrs: vec![1e-4, 3e-4, 5e-4, 1e-3, 2e-3, 3e-3, 5e-3, 8e-3],
            batches: vec![1, 2],
            ranks: vec![8],
            alpha_ratios: vec![1.0],
        }
    }

    pub fn grid(&self, task: &str) -> Vec<LoraConfig> {
        let mut out = vec![];
        let mut id = 0;
        for &lr in &self.lrs {
            for &batch in &self.batches {
                for &rank in &self.ranks {
                    for &alpha_ratio in &self.alpha_ratios {
                        out.push(LoraConfig {
                            id,
                            lr,
                            batch,
                            rank,
                            alpha_ratio,
                            task: task.to_string(),
                        });
                        id += 1;
                    }
                }
            }
        }
        out
    }

    /// Random search: `n` i.i.d. draws (log-uniform LR, uniform in lists).
    pub fn sample(&self, task: &str, n: usize, rng: &mut crate::util::rng::Rng) -> Vec<LoraConfig> {
        let (lo, hi) = (
            self.lrs.iter().cloned().fold(f64::MAX, f64::min),
            self.lrs.iter().cloned().fold(0.0, f64::max),
        );
        (0..n)
            .map(|id| LoraConfig {
                id,
                lr: (lo.ln() + (hi.ln() - lo.ln()) * rng.f64()).exp(),
                batch: *rng.choice(&self.batches),
                rank: *rng.choice(&self.ranks),
                alpha_ratio: *rng.choice(&self.alpha_ratios),
                task: task.to_string(),
            })
            .collect()
    }

    pub fn size(&self) -> usize {
        self.lrs.len() * self.batches.len() * self.ranks.len() * self.alpha_ratios.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_is_120() {
        let g = SearchSpace::default().grid("gsm8k");
        assert_eq!(g.len(), 120);
        assert_eq!(g.len(), SearchSpace::default().size());
        // ids unique
        let mut ids: Vec<_> = g.iter().map(|c| c.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 120);
    }

    #[test]
    fn sample_respects_bounds() {
        let s = SearchSpace::default();
        let mut rng = crate::util::rng::Rng::new(4);
        for c in s.sample("copy", 200, &mut rng) {
            assert!(c.lr >= 2e-5 * 0.999 && c.lr <= 4e-4 * 1.001);
            assert!(s.batches.contains(&c.batch));
            assert!(s.ranks.contains(&c.rank));
        }
    }
}
