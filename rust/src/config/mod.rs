//! Configuration: LoRA search space (Table 1), model geometries (TinyLM and
//! the paper-scale Qwen/LLaMa shapes used by the simulator), GPU profiles,
//! and hardware pools.

pub mod geometry;
pub mod lora;
pub mod pool;

pub use geometry::{ModelGeom, GEOMS};
pub use lora::{AdapterSpec, LoraConfig, SearchSpace};
pub use pool::{GpuProfile, HardwarePool, PROFILES};
