//! GPU profiles and hardware pools.
//!
//! The planner and simulator only observe (memory capacity, peak FLOPs,
//! per-launch overhead, utilization curve). Profiles for the paper's
//! testbeds (A100-40G P4d, A10-24G G5) drive the simulator; the `cpu-sim`
//! profile describes this machine for live runs.

/// Hardware profile of one accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    pub mem_bytes: f64,
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s) — bounds low-arithmetic-intensity kernels.
    pub mem_bw: f64,
    /// Fixed overhead per kernel launch (s). Sequential per-adapter LoRA
    /// compute pays this per adapter per projection — the §3.1/§5.1
    /// underutilization effect.
    pub launch_overhead: f64,
    /// Tokens at which the base-GEMM utilization curve reaches half of its
    /// maximum (small batches underutilize SMs: §3.1 "SM occupancy 16.7%").
    pub tokens_half_util: f64,
    /// Maximum achievable fraction of peak for the big base GEMMs.
    pub max_eff: f64,
    /// Per-hop tensor-parallel efficiency (all-reduce cost): t(d) =
    /// t(1) / (d * tp_eff^log2(d)).
    pub tp_eff: f64,
}

pub const A100_40G: GpuProfile = GpuProfile {
    name: "a100-40g",
    mem_bytes: 40.0e9,
    peak_flops: 312.0e12,
    mem_bw: 1.555e12,
    launch_overhead: 8.0e-6,
    tokens_half_util: 4096.0,
    max_eff: 0.55,
    tp_eff: 0.88,
};

pub const A10_24G: GpuProfile = GpuProfile {
    name: "a10-24g",
    mem_bytes: 24.0e9,
    peak_flops: 125.0e12,
    mem_bw: 0.6e12,
    launch_overhead: 10.0e-6,
    tokens_half_util: 2048.0,
    max_eff: 0.50,
    tp_eff: 0.80, // PCIe Gen4, no NVLink (§7.1)
};

/// This machine, for live-engine accounting: a single CPU core behind the
/// PJRT CPU client. Memory capacity is what matters for packing decisions;
/// speed constants are calibrated by `costmodel::calibrate`.
pub const CPU_SIM: GpuProfile = GpuProfile {
    name: "cpu-sim",
    mem_bytes: 4.0e9,
    peak_flops: 5.0e9,
    mem_bw: 2.0e10,
    launch_overhead: 50.0e-6,
    tokens_half_util: 256.0,
    max_eff: 0.9,
    tp_eff: 1.0,
};

pub const PROFILES: &[&GpuProfile] = &[&A100_40G, &A10_24G, &CPU_SIM];

pub fn profile(name: &str) -> Option<&'static GpuProfile> {
    PROFILES.iter().copied().find(|p| p.name == name)
}

/// A homogeneous pool of `count` devices (paper testbed: 8 per instance).
#[derive(Debug, Clone)]
pub struct HardwarePool {
    pub profile: GpuProfile,
    pub count: usize,
}

impl HardwarePool {
    pub fn new(profile: &GpuProfile, count: usize) -> Self {
        HardwarePool { profile: profile.clone(), count }
    }

    pub fn p4d() -> Self {
        Self::new(&A100_40G, 8)
    }
    pub fn g5() -> Self {
        Self::new(&A10_24G, 8)
    }

    pub fn total_mem(&self) -> f64 {
        self.profile.mem_bytes * self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_profiles() {
        assert_eq!(profile("a100-40g").unwrap().name, "a100-40g");
        assert_eq!(profile("a10-24g").unwrap().mem_bytes, 24.0e9);
        assert!(profile("h100").is_none());
    }

    #[test]
    fn pools() {
        let p = HardwarePool::p4d();
        assert_eq!(p.count, 8);
        assert!((p.total_mem() - 320.0e9).abs() < 1.0);
    }
}
