//! Appendix-A memory model: per-device bytes of a packed LoRA fine-tuning
//! job under TP/PP/FSDP-ZeRO sharding, and the feasibility constraint
//! Eq. (14)/(19): `M_base + Σ_k M_lora,k ≤ C · M_gpu · d`.
//!
//! Calibration targets pinned by tests (paper §3.2, Qwen-2.5-7B on A100-40G):
//! one rank-64 adapter ⇒ ≈18.2 GB, two ⇒ ≈20.4 GB, ≈10 adapters fit.

use crate::config::{GpuProfile, LoraConfig, ModelGeom};
use crate::costmodel::Pack;

/// FSDP/ZeRO stage (Appendix A.1.1). `None` keeps every replica whole.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Zero {
    None,
    /// Optimizer state sharded.
    Zero1,
    /// Optimizer state + gradients sharded.
    Zero2,
    /// Optimizer state + gradients + parameters sharded.
    Zero3,
}

/// Parallelization of one fine-tuning job (Appendix A.1.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sharding {
    pub tp: usize,
    pub pp: usize,
    pub fsdp: usize,
    pub zero: Zero,
}

impl Sharding {
    /// Pure tensor parallelism over `d` devices — the paper's evaluated
    /// setting (§7.1); `d_j` in Eq. (14)–(16).
    pub fn tp(d: usize) -> Sharding {
        Sharding { tp: d.max(1), pp: 1, fsdp: 1, zero: Zero::None }
    }

    pub fn devices(&self) -> usize {
        self.tp * self.pp * self.fsdp
    }

    /// Model-weight shard factor: TP and PP split parameters (App. A:
    /// `M / (d_tp · d_pp)`), ZeRO-3 additionally splits them over FSDP.
    fn param_div(&self) -> f64 {
        let base = (self.tp * self.pp) as f64;
        match self.zero {
            Zero::Zero3 => base * self.fsdp as f64,
            _ => base,
        }
    }

    fn grad_div(&self) -> f64 {
        let base = (self.tp * self.pp) as f64;
        match self.zero {
            Zero::Zero2 | Zero::Zero3 => base * self.fsdp as f64,
            _ => base,
        }
    }

    fn opt_div(&self) -> f64 {
        let base = (self.tp * self.pp) as f64;
        match self.zero {
            Zero::None => base,
            _ => base * self.fsdp as f64,
        }
    }
}

/// The Appendix-A memory model for one (geometry, profile) pair.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub geom: ModelGeom,
    /// AdamW stores momentum + velocity (2 optimizer tensors per param).
    pub c_opt: f64,
    /// One gradient tensor per param during the step.
    pub c_grad: f64,
    /// Fragmentation / workspace multiplier on activations.
    pub c_act: f64,
}

impl MemoryModel {
    pub fn new(geom: &ModelGeom) -> MemoryModel {
        MemoryModel { geom: geom.clone(), c_opt: 2.0, c_grad: 1.0, c_act: 1.2 }
    }

    // -- base model -------------------------------------------------------

    /// Frozen base weights (bytes, unsharded).
    pub fn base_weight_bytes(&self) -> f64 {
        self.geom.params() * self.geom.base_bytes
    }

    /// Base-path activation bytes for `bs` concurrent sequences with
    /// activation checkpointing: layer-boundary residuals are stored, the
    /// interior is recomputed (standard LoRA fine-tuning practice; without
    /// it a 7B at seq 1024 would not fit 40 GB with any adapter).
    pub fn base_act_bytes(&self, bs: f64) -> f64 {
        let g = &self.geom;
        let s = g.seq as f64;
        let d = g.d_model as f64;
        // stored: embedding output + one residual per layer + final LN +
        // the live layer's interior (attention scores + MLP intermediates).
        let boundaries = (g.n_layers as f64 + 2.0) * s * d;
        let live = s * (2.0 * d + 2.0 * g.d_ff as f64)
            + g.n_heads as f64 * s * s;
        bs * 4.0 * (boundaries + live) * self.c_act
    }

    /// Per-device base-model bytes for a job running `total_bs` sequences.
    pub fn base_bytes(&self, total_bs: f64, sh: Sharding) -> f64 {
        self.base_weight_bytes() / sh.param_div()
            + self.base_act_bytes(total_bs) / (sh.tp * sh.pp) as f64
    }

    // -- LoRA adapters ----------------------------------------------------

    /// Trainable parameter bytes of one adapter at rank `r` (f32 masters).
    pub fn lora_param_bytes(&self, r: usize) -> f64 {
        self.geom.lora_params(r) * self.geom.lora_bytes
    }

    /// LoRA activation bytes: Eq. (A) `b · s · r` per LoRA-able projection
    /// per layer — the rank-r intermediate `x A` kept for the backward pass.
    pub fn lora_act_bytes(&self, c: &LoraConfig) -> f64 {
        let g = &self.geom;
        (c.batch * g.seq * c.rank) as f64 * 4.0 * (g.n_layers * 7) as f64
    }

    /// Per-device bytes of fine-tuning one adapter (Eq. 21 + A.1.1).
    pub fn lora_bytes(&self, c: &LoraConfig, sh: Sharding) -> f64 {
        let p = self.lora_param_bytes(c.rank);
        p / sh.param_div()
            + self.c_grad * p / sh.grad_div()
            + self.c_opt * p / sh.opt_div()
            + self.lora_act_bytes(c) / (sh.tp * sh.pp) as f64
    }

    // -- jobs -------------------------------------------------------------

    /// Per-device bytes of a packed job. With `charge_padding`, adapters are
    /// charged at the pack's static-shape buckets (`r_pad`, `bs_pad`) —
    /// what the AOT live path actually allocates; the paper-scale simulator
    /// charges true shapes (CUDA kernels handle heterogeneity natively).
    pub fn job_bytes(&self, pack: &Pack, sh: Sharding, charge_padding: bool) -> f64 {
        if pack.n() == 0 {
            return 0.0;
        }
        let (total_bs, lora): (f64, f64) = if charge_padding {
            let r = pack.r_pad();
            let b = pack.bs_pad();
            let padded: Vec<LoraConfig> = pack
                .configs
                .iter()
                .map(|c| LoraConfig { rank: r, batch: b, ..c.clone() })
                .collect();
            (
                (pack.n() * b) as f64,
                padded.iter().map(|c| self.lora_bytes(c, sh)).sum(),
            )
        } else {
            (
                pack.total_bs() as f64,
                pack.configs.iter().map(|c| self.lora_bytes(c, sh)).sum(),
            )
        };
        self.base_bytes(total_bs, sh) + lora
    }

    /// Eq. (14)/(19): does the pack fit on `d` TP devices at load factor `c`?
    pub fn fits(
        &self,
        pack: &Pack,
        d: usize,
        prof: &GpuProfile,
        c_load: f64,
        charge_padding: bool,
    ) -> bool {
        self.job_bytes(pack, Sharding::tp(d), charge_padding) <= c_load * prof.mem_bytes
    }

    /// Minimum TP degree (power of two, ≤ `gmax`) whose per-device memory
    /// admits even a single adapter of config `c`; `None` if none does.
    pub fn min_tp(
        &self,
        c: &LoraConfig,
        prof: &GpuProfile,
        c_load: f64,
        gmax: usize,
    ) -> Option<usize> {
        let pack = Pack::new(vec![c.clone()]);
        let mut d = 1;
        while d <= gmax {
            if self.fits(&pack, d, prof, c_load, false) {
                return Some(d);
            }
            d *= 2;
        }
        None
    }

    /// Largest number of homogeneous `(r, bs)` adapters that fit on `d`
    /// devices (the §3.2 "up to 10 concurrent adapters" computation).
    pub fn max_adapters(
        &self,
        r: usize,
        bs: usize,
        d: usize,
        prof: &GpuProfile,
        c_load: f64,
    ) -> usize {
        let proto = LoraConfig {
            id: 0,
            lr: 1e-4,
            batch: bs,
            rank: r,
            alpha_ratio: 1.0,
            task: String::new(),
        };
        let mut n = 0;
        loop {
            let pack = Pack::new(vec![proto.clone(); n + 1]);
            if !self.fits(&pack, d, prof, c_load, false) {
                return n;
            }
            n += 1;
            if n > 4096 {
                return n; // defensive cap; never hit with real geometries
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;

    fn cfg(r: usize, bs: usize) -> LoraConfig {
        LoraConfig { id: 0, lr: 1e-4, batch: bs, rank: r, alpha_ratio: 1.0, task: "t".into() }
    }

    /// Paper §3.2: Qwen-2.5-7B + one rank-64 adapter ≈ 18.2 GB on A100.
    #[test]
    fn qwen7b_single_adapter_memory_matches_paper() {
        let m = MemoryModel::new(geom("qwen2.5-7b").unwrap());
        let pack = Pack::new(vec![cfg(64, 1)]);
        let gb = m.job_bytes(&pack, Sharding::tp(1), false) / 1e9;
        assert!((15.0..21.0).contains(&gb), "got {gb:.1} GB, paper 18.2");
    }

    /// Paper §3.2: the second adapter adds ≈2.2 GB (20.4 − 18.2).
    #[test]
    fn qwen7b_second_adapter_increment_matches_paper() {
        let m = MemoryModel::new(geom("qwen2.5-7b").unwrap());
        let one = m.job_bytes(&Pack::new(vec![cfg(64, 1)]), Sharding::tp(1), false);
        let two = m.job_bytes(&Pack::new(vec![cfg(64, 1); 2]), Sharding::tp(1), false);
        let inc = (two - one) / 1e9;
        // We land ~3.7 GB vs the paper's 2.2: we ignore GQA (full-width K/V
        // projections) and charge checkpointed activations at max seq — a
        // deliberate overestimate (OOM-safe packing, Appendix A).
        assert!((1.2..4.2).contains(&inc), "increment {inc:.2} GB, paper ≈2.2");
    }

    /// Paper §3.2: ≈10 rank-64 adapters fit a 40 GB A100 without OOM.
    #[test]
    fn qwen7b_packs_about_ten_adapters() {
        let m = MemoryModel::new(geom("qwen2.5-7b").unwrap());
        let n = m.max_adapters(64, 1, 1, &A100_40G, 1.0);
        assert!((6..=14).contains(&n), "got {n}, paper ≈10");
    }

    /// TP over d devices increases pack capacity (§3.2 last sentence).
    #[test]
    fn tp_increases_capacity() {
        let m = MemoryModel::new(geom("qwen2.5-14b").unwrap());
        let n1 = m.max_adapters(64, 1, 2, &A100_40G, 0.9);
        let n2 = m.max_adapters(64, 1, 4, &A100_40G, 0.9);
        assert!(n2 > n1, "d=4 ({n2}) should pack more than d=2 ({n1})");
    }

    /// 14B needs 2 A100s, 32B needs 4 (paper §7.2.1 Min GPU setting).
    #[test]
    fn min_tp_matches_paper_testbed() {
        let c = cfg(32, 1);
        let m3 = MemoryModel::new(geom("qwen2.5-3b").unwrap());
        let m14 = MemoryModel::new(geom("qwen2.5-14b").unwrap());
        let m32 = MemoryModel::new(geom("qwen2.5-32b").unwrap());
        assert_eq!(m3.min_tp(&c, &A100_40G, 0.9, 8), Some(1));
        assert_eq!(m14.min_tp(&c, &A100_40G, 0.9, 8), Some(2));
        assert_eq!(m32.min_tp(&c, &A100_40G, 0.9, 8), Some(4));
    }

    /// ZeRO stages are monotone: higher stages never use more memory.
    #[test]
    fn zero_stages_monotone() {
        let m = MemoryModel::new(geom("qwen2.5-7b").unwrap());
        let c = cfg(64, 2);
        let mk = |zero| Sharding { tp: 1, pp: 1, fsdp: 4, zero };
        let none = m.lora_bytes(&c, mk(Zero::None));
        let z1 = m.lora_bytes(&c, mk(Zero::Zero1));
        let z2 = m.lora_bytes(&c, mk(Zero::Zero2));
        let z3 = m.lora_bytes(&c, mk(Zero::Zero3));
        assert!(none >= z1 && z1 >= z2 && z2 >= z3);
        assert!(z3 < none);
    }

    /// Padding charge is an upper bound on the true charge.
    #[test]
    fn padded_charge_dominates_true_charge() {
        let m = MemoryModel::new(geom("qwen2.5-3b").unwrap());
        let pack = Pack::new(vec![cfg(8, 1), cfg(64, 4), cfg(16, 2)]);
        let sh = Sharding::tp(1);
        assert!(m.job_bytes(&pack, sh, true) >= m.job_bytes(&pack, sh, false));
    }

    /// QLoRA (4-bit base) frees memory for more adapters (§7.5).
    #[test]
    fn qlora_packs_more_adapters() {
        let g = geom("qwen2.5-7b").unwrap();
        let m16 = MemoryModel::new(g);
        let mq = MemoryModel::new(&g.scaled("qwen2.5-7b-q4", 0.5));
        let a10 = crate::config::pool::A10_24G;
        let n16 = m16.max_adapters(32, 1, 1, &a10, 0.9);
        let nq = mq.max_adapters(32, 1, 1, &a10, 0.9);
        assert!(nq > n16, "QLoRA {nq} vs bf16 {n16}");
    }
}
