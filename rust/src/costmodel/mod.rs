//! Cost model: memory (paper Appendix A) and step-time T(H, d) (§4, §6).
//!
//! The planner never touches real hardware — it sees this model, exactly as
//! the paper's planner sees its profiled cost model ("profiling data from
//! the first few iterations"). Two calibrations feed it:
//!
//! - **paper-scale**: constants in `config::pool` are set so the model
//!   reproduces the paper's published measurements — §5.1 "+10% iteration
//!   time from batch 1 to 8", "naive 8-adapter packing is 3.6x worse",
//!   Table 7 "near-linear packed-kernel speedup", §3.2 "Qwen-7B + 1 adapter
//!   = 18.2 GB, + 2 adapters = 20.4 GB". Unit tests pin each of these.
//! - **live**: `calibrate()` fits the same functional form to measured PJRT
//!   step times of the TinyLM artifacts on this machine.

pub mod memory;
pub mod throughput;

pub use memory::MemoryModel;
pub use throughput::{CostModel, DpStat, ExecMode, JobPhase, SwitchCost};

use crate::config::LoraConfig;

/// A pack: the set of LoRA configurations fine-tuned by one job (H_{j,k}).
#[derive(Debug, Clone, Default)]
pub struct Pack {
    pub configs: Vec<LoraConfig>,
}

impl Pack {
    pub fn new(configs: Vec<LoraConfig>) -> Self {
        Pack { configs }
    }
    pub fn n(&self) -> usize {
        self.configs.len()
    }
    /// Static-shape rank bucket: every adapter zero-padded to the max rank.
    pub fn r_pad(&self) -> usize {
        self.configs.iter().map(|c| c.rank).max().unwrap_or(0)
    }
    /// Static-shape batch bucket: batches padded to the pack max.
    pub fn bs_pad(&self) -> usize {
        self.configs.iter().map(|c| c.batch).max().unwrap_or(0)
    }
    /// Total *real* sequences per step across adapters (activation memory).
    pub fn total_bs(&self) -> usize {
        self.configs.iter().map(|c| c.batch).sum()
    }
    /// Sum of ranks — the numerator of the DTM objective (Eq. 13 uses
    /// sum of r_k by the FLOP-linear-in-rank property).
    pub fn rank_sum(&self) -> usize {
        self.configs.iter().map(|c| c.rank).sum()
    }
}

/// Fine-tuning length of one configuration: epochs over a fixed-size task
/// dataset; small batches take proportionally more steps (paper §7:
/// each configuration fine-tunes the same data budget).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainBudget {
    pub dataset: usize,
    pub epochs: usize,
}

impl Default for TrainBudget {
    fn default() -> Self {
        TrainBudget { dataset: 256, epochs: 3 }
    }
}

impl TrainBudget {
    pub fn steps(&self, batch: usize) -> usize {
        let total = self.dataset * self.epochs;
        total.div_ceil(batch.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchSpace;

    #[test]
    fn pack_buckets() {
        let cfgs = SearchSpace::default().grid("t");
        let p = Pack::new(cfgs[..6].to_vec());
        assert_eq!(p.n(), 6);
        assert!(p.r_pad() >= p.configs.iter().map(|c| c.rank).max().unwrap());
        assert_eq!(p.total_bs(), p.configs.iter().map(|c| c.batch).sum());
    }

    #[test]
    fn budget_steps_inverse_in_batch() {
        let b = TrainBudget::default();
        assert_eq!(b.steps(1), 768);
        assert_eq!(b.steps(2), 384);
        assert_eq!(b.steps(4), 192);
        assert_eq!(b.steps(3), 256);
    }
}
