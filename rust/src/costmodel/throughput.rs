//! Step-time model `T(H_j, d_j)` (§4, §6) — the function the planner
//! optimizes over and the discrete-event simulator advances time with.
//!
//! Functional form (one packed fine-tuning step):
//!
//! ```text
//! t_step = t_base(tokens, d) + t_lora(pack, mode) + step_overhead
//! t_base = max( weight-IO time , GEMM FLOP time ) / tp_eff(d)
//! ```
//!
//! Why a roofline `max`: the paper profiles LoRA fine-tuning at SM occupancy
//! 16.7% with iteration time growing only ~10% from batch 1 → 8 (§3.1,
//! §5.1). That is the signature of *weight-IO-bound* GEMMs: downstream-task
//! samples are short (tens of real tokens), so `(tokens × d) · (d × d)`
//! GEMMs sit left of the roofline crossover and the frozen base weights are
//! re-read every step regardless of batch. The LoRA adapter term is
//! *launch-bound*: per-adapter kernels are too small to fill the GPU, so a
//! naive pack of n adapters pays n × (kernel count × per-kernel wall time)
//! (§5.1's 3.6× blow-up), while the packed kernels (§5.2) batch all
//! adapters into one launch per (projection, case) and regain near-linear
//! scaling (Table 7).
//!
//! Every paper-published ratio this model is calibrated against is pinned by
//! a unit test at the bottom of this file.

use crate::config::{GpuProfile, LoraConfig, ModelGeom};
use crate::costmodel::{MemoryModel, Pack, TrainBudget};

/// How the adapters of a job execute (§5.1 vs §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// PLoRA packed kernels: one fused launch per (projection, grad-case).
    Packed,
    /// Naive per-adapter loop: every adapter pays its own kernel launches.
    Sequential,
}

/// Workload/efficiency constants of the step-time model. Defaults are
/// calibrated so the paper's published measurements hold (tests below);
/// [`Calib::fit_live`] re-fits the same form to measured PJRT step times.
#[derive(Debug, Clone)]
pub struct Calib {
    /// Mean *real* (non-pad) tokens per sample. GLUE-class tasks are short;
    /// frameworks trim batches to the max sample length, so compute scales
    /// with real tokens even though `seq` is 1024 (§7.1).
    pub tokens_per_sample: f64,
    /// Base-weight reads per step (fwd + activation-grad bwd + recompute).
    pub weight_passes: f64,
    /// Achieved HBM-bandwidth fraction at 16.7% SM occupancy.
    pub bw_eff: f64,
    /// Achieved peak-FLOP fraction for base GEMMs once they are large.
    pub flop_eff: f64,
    /// Fixed per-step overhead (host launch queue, optimizer epilogue).
    pub step_overhead: f64,
    /// Wall time of one tiny LoRA kernel at `lora_kernel_ref_dim` hidden
    /// size: launch + low-occupancy execution (§3.1: adapter GEMMs lack the
    /// arithmetic intensity to fill SMs). Scales ∝ d_model (wider models
    /// stream wider A/B slices) down to `lora_kernel_floor`.
    pub lora_kernel_time: f64,
    /// Hidden dimension at which `lora_kernel_time` is quoted.
    pub lora_kernel_ref_dim: f64,
    /// Pure launch-latency floor for one kernel.
    pub lora_kernel_floor: f64,
    /// Marginal cost of one extra adapter inside a *packed* kernel, as a
    /// fraction of `lora_kernel_time` at the reference rank. Sets the
    /// sublinearity of Table 7 (32 adapters → ~29×, not 32×).
    pub packed_marginal: f64,
    /// Rank at which `packed_marginal` is quoted.
    pub ref_rank: f64,
    /// Per-TP-hop multiplier on the adapter path. LoRA kernels are
    /// launch-latency-bound: sharding a rank-r GEMM over d devices does not
    /// shrink its wall time, while every projection now rides a per-layer
    /// all-reduce with a fixed latency floor — TP makes the adapter path
    /// *slower*. This is what keeps the planner at the minimum feasible TP
    /// degree for models that fit one GPU (paper §7.2.1 job sizing).
    pub lora_tp_penalty: f64,
    /// LoRA kernels per adapter per step: layers × 7 projections ×
    /// (fwd + 4 bwd cases) + optimizer updates.
    pub kernels_per_adapter_per_layer: f64,
    /// Wall cost of one bucket switch (checkpoint the pack state, repack
    /// params + moments onto the new bucket, re-derive the workspace arena
    /// and batch tensors, swap executables). The elastic planner
    /// (`planner::rebalance::retarget_bucket`) only moves a running pack
    /// when the modeled phase-time saving beats this term. Defaults to 0
    /// (switches modeled free — the pre-elastic behavior); live sessions
    /// calibrate it from measured switch times ([`SwitchCost`],
    /// `Event::CalibUpdated`).
    pub bucket_switch_cost: f64,
    /// Measured data-parallel efficiency: the Amdahl fit `(a, b)` of
    /// per-sample step time `t(d) ≈ a + b/d` over the session's executed
    /// shard counts (`a` = serial per-sample seconds — scatter, fixed-order
    /// reduction, the single AdamW; `b` = the parallel forward/backward
    /// share). `None` until live calibration publishes one ([`DpStat`],
    /// `Event::CalibUpdated`); the model then falls back to the profile's
    /// static per-hop TP curve — the modeled-only behavior every
    /// paper-scale test pins.
    pub dp_fit: Option<(f64, f64)>,
    /// Wall cost of one device retarget (rebuild the shard set — scatter
    /// buffers, per-device workers, per-shard arenas — at a new device
    /// count). The session's boundary device offers only grow a running
    /// pack when the modeled phase-time saving beats this term; defaults
    /// to 0 and is calibrated live from measured rebuild times.
    pub device_switch_cost: f64,
    /// Per-device-class Amdahl fits keyed by speed tier (`"a100"`,
    /// `"a10"`, …): the same `(a, b)` decomposition as [`Calib::dp_fit`],
    /// but measured per class of host so a mixed fast/slow fleet gets one
    /// efficiency curve per tier. [`Calib::dp_fit_for`] consults this map
    /// first and falls back to the class-less fit. Fed from per-class
    /// [`DpStat`] records (`DpStat::record_class`).
    pub dp_fit_class: std::collections::BTreeMap<String, (f64, f64)>,
    /// Fractional per-boundary cost of the stage pipeline: each extra
    /// stage adds one activation/grad handoff per microbatch, charged as
    /// this fraction of the step on top of the GPipe bubble (see
    /// [`CostModel::pipeline_speedup`]). Calibrated so shallow pipelines
    /// on few microbatches never look free.
    pub stage_boundary_cost: f64,
    /// Wall cost of one pipeline retarget (rebuild the per-stage worker
    /// set and handoff channels at a new depth `s`). The session's
    /// boundary stage offers only deepen a running pack when the modeled
    /// phase-time saving beats this term; defaults to 0 and is calibrated
    /// live from measured rebuild times.
    pub stage_switch_cost: f64,
}

impl Default for Calib {
    fn default() -> Calib {
        Calib {
            tokens_per_sample: 64.0,
            weight_passes: 3.0,
            bw_eff: 0.42,
            flop_eff: 0.72,
            step_overhead: 2.0e-3,
            lora_kernel_time: 55.0e-6,
            lora_kernel_ref_dim: 3584.0,
            lora_kernel_floor: 25.0e-6,
            packed_marginal: 0.0033,
            ref_rank: 32.0,
            lora_tp_penalty: 0.8,
            kernels_per_adapter_per_layer: 7.0 * 5.0 + 4.0,
            bucket_switch_cost: 0.0,
            dp_fit: None,
            device_switch_cost: 0.0,
            dp_fit_class: Default::default(),
            stage_boundary_cost: 0.02,
            stage_switch_cost: 0.0,
        }
    }
}

impl Calib {
    /// The Amdahl fit for one device class: the class-keyed entry when
    /// per-class calibration recorded one, the class-less [`Calib::dp_fit`]
    /// otherwise. An unknown class therefore degrades gracefully to the
    /// fleet-wide curve instead of the static TP fallback.
    pub fn dp_fit_for(&self, class: &str) -> Option<(f64, f64)> {
        self.dp_fit_class.get(class).copied().or(self.dp_fit)
    }
}

/// Shared live estimator of the bucket-switch overhead: the phased driver
/// records the measured wall time of every switch it performs (checkpoint
/// + repack + arena re-derive), and every retarget decision reads the
/// running mean. Clonable handle — one estimator is shared by all jobs of
/// a session, so early jobs calibrate the term for later ones (§4
/// "profiling data from the first iterations", applied to orchestration).
#[derive(Clone, Default)]
pub struct SwitchCost {
    inner: std::sync::Arc<std::sync::Mutex<(f64, usize)>>,
    /// Estimate returned before any switch has been measured.
    pub default: f64,
}

impl SwitchCost {
    pub fn new(default: f64) -> SwitchCost {
        SwitchCost { inner: Default::default(), default }
    }

    /// Record one measured switch wall time.
    pub fn record(&self, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.0 += secs;
        g.1 += 1;
    }

    /// Running mean of the measured switch times (the `default` until the
    /// first sample arrives).
    pub fn estimate(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.1 == 0 {
            self.default
        } else {
            g.0 / g.1 as f64
        }
    }

    /// Number of switches measured so far.
    pub fn samples(&self) -> usize {
        self.inner.lock().unwrap().1
    }
}

/// Shared live estimator of data-parallel step efficiency: every executed
/// step records `(shard count d, padded samples, wall seconds)`, and
/// [`DpStat::fit`] regresses the per-sample step time on `1/d` — the
/// Amdahl decomposition `t(d) = a + b/d` the cost model's
/// [`Calib::dp_fit`] consumes. Clonable handle shared by all jobs of a
/// session, so steps executed at different device counts calibrate the
/// efficiency term for every later retarget decision (§4 "profiling data
/// from the first iterations", applied to the device axis).
#[derive(Clone, Default)]
pub struct DpStat {
    /// Per-d accumulator: d -> (sum of per-sample seconds, steps).
    inner: std::sync::Arc<std::sync::Mutex<std::collections::BTreeMap<usize, (f64, usize)>>>,
    /// Per-device-class accumulator: class -> (d -> (sum, steps)). Steps
    /// recorded with [`DpStat::record_class`] land here *and* in the
    /// class-less accumulator, so the fleet-wide fit keeps improving.
    #[allow(clippy::type_complexity)]
    by_class: std::sync::Arc<
        std::sync::Mutex<
            std::collections::BTreeMap<String, std::collections::BTreeMap<usize, (f64, usize)>>,
        >,
    >,
}

/// Least-squares `(a, b)` of mean per-sample time on `1/d` over the
/// distinct shard counts of one accumulator (needs at least two), clamped
/// to the physically meaningful quadrant (`a, b ≥ 0`).
fn amdahl_fit(g: &std::collections::BTreeMap<usize, (f64, usize)>) -> Option<(f64, f64)> {
    if g.len() < 2 {
        return None;
    }
    let pts: Vec<(f64, f64)> =
        g.iter().map(|(&d, &(sum, cnt))| (1.0 / d as f64, sum / cnt.max(1) as f64)).collect();
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let den = n * sxx - sx * sx;
    if den.abs() < 1e-18 {
        return None;
    }
    let b = (n * sxy - sx * sy) / den;
    let a = (sy - b * sx) / n;
    let (a, b) = (a.max(0.0), b.max(0.0));
    if a + b <= 0.0 {
        return None;
    }
    Some((a, b))
}

impl DpStat {
    pub fn new() -> DpStat {
        DpStat::default()
    }

    /// Record one executed step: `d` shards over `samples` padded
    /// sequences taking `secs` wall seconds.
    pub fn record(&self, d: usize, samples: f64, secs: f64) {
        if samples <= 0.0 || secs <= 0.0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(d.max(1)).or_insert((0.0, 0));
        e.0 += secs / samples;
        e.1 += 1;
    }

    /// [`DpStat::record`] tagged with the executing host's device class
    /// (speed tier). The sample feeds both the per-class accumulator —
    /// whose fit [`Calib::dp_fit_for`] prefers — and the class-less one.
    pub fn record_class(&self, class: &str, d: usize, samples: f64, secs: f64) {
        if samples <= 0.0 || secs <= 0.0 {
            return;
        }
        self.record(d, samples, secs);
        let mut g = self.by_class.lock().unwrap();
        let e = g.entry(class.to_string()).or_default().entry(d.max(1)).or_insert((0.0, 0));
        e.0 += secs / samples;
        e.1 += 1;
    }

    /// Total recorded steps.
    pub fn samples(&self) -> usize {
        self.inner.lock().unwrap().values().map(|v| v.1).sum()
    }

    /// Least-squares `(a, b)` of mean per-sample time on `1/d` over the
    /// distinct shard counts seen so far (needs at least two), clamped to
    /// the physically meaningful quadrant (`a, b ≥ 0`). `None` until the
    /// session has executed at more than one device count.
    pub fn fit(&self) -> Option<(f64, f64)> {
        amdahl_fit(&self.inner.lock().unwrap())
    }

    /// The per-class Amdahl fit for one device class (`None` until that
    /// class has executed steps at two or more distinct shard counts).
    pub fn fit_class(&self, class: &str) -> Option<(f64, f64)> {
        self.by_class.lock().unwrap().get(class).and_then(amdahl_fit)
    }

    /// Every class with a publishable fit, for bulk export into
    /// [`Calib::dp_fit_class`].
    pub fn class_fits(&self) -> std::collections::BTreeMap<String, (f64, f64)> {
        self.by_class
            .lock()
            .unwrap()
            .iter()
            .filter_map(|(c, g)| amdahl_fit(g).map(|f| (c.clone(), f)))
            .collect()
    }
}

impl Calib {
    /// Fit `(step_overhead, per-token, per-adapter)` to measured live step
    /// times `(tokens, n_adapters, seconds)` by least squares on the model
    /// `t = a + b·tokens + c·n`. Used by the engine to calibrate the
    /// `cpu-sim` profile from the first profiled iterations (§4: "using
    /// profiling data from the first few iterations").
    pub fn fit_live(samples: &[(f64, f64, f64)]) -> (f64, f64, f64) {
        // Normal equations for 3 unknowns; tiny and well-conditioned here.
        let n = samples.len() as f64;
        if samples.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let mut xtx = [[0.0f64; 3]; 3];
        let mut xty = [0.0f64; 3];
        for &(tok, na, t) in samples {
            let row = [1.0, tok, na];
            for i in 0..3 {
                for j in 0..3 {
                    xtx[i][j] += row[i] * row[j];
                }
                xty[i] += row[i] * t;
            }
        }
        // Ridge for degenerate designs (all-equal tokens etc.).
        for (i, r) in xtx.iter_mut().enumerate() {
            r[i] += 1e-9 * n.max(1.0);
        }
        solve3(xtx, xty)
    }
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> (f64, f64, f64) {
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let p = a[col][col];
        if p.abs() < 1e-30 {
            continue;
        }
        for row in col + 1..3 {
            let f = a[row][col] / p;
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 3];
    for row in (0..3).rev() {
        let mut s = b[row];
        for k in row + 1..3 {
            s -= a[row][k] * x[k];
        }
        x[row] = if a[row][row].abs() < 1e-30 { 0.0 } else { s / a[row][row] };
    }
    (x[0], x[1], x[2])
}

/// One phase of a packed job between adapter-completion boundaries
/// (see [`CostModel::job_phases`]).
#[derive(Debug, Clone)]
pub struct JobPhase {
    /// Noise-free seconds this phase runs.
    pub dur: f64,
    /// Training steps this phase executes (the per-member progress unit
    /// the simulator's elastic paths subtract at each boundary).
    pub steps: usize,
    /// Config ids finishing at the phase's end.
    pub finished: Vec<usize>,
    /// Surviving pack shape `(n, r_pad, bs_pad)` after the boundary
    /// (all zeros once the job is done).
    pub survivors: (usize, usize, usize),
}

/// The cost model: step time, job duration, throughput, and memory
/// feasibility for one (geometry, profile) pair.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub geom: ModelGeom,
    pub profile: GpuProfile,
    pub memory: MemoryModel,
    pub calib: Calib,
    /// Charge padded static shapes (live AOT path) or true shapes (paper
    /// CUDA kernels / simulator).
    pub charge_padding: bool,
    /// Memory load factor `C` of Eq. (14) (fragmentation headroom).
    pub c_load: f64,
    /// Live-mode static-shape bucket grid `(n, r, bs)` from the artifact
    /// manifest: a pack is only feasible if some bucket dominates its
    /// `(n, r_pad, bs_pad)`. `None` (paper scale / CUDA kernels) means
    /// shapes are unconstrained.
    pub buckets: Option<Vec<(usize, usize, usize)>>,
}

impl CostModel {
    pub fn new(geom: &ModelGeom, profile: &GpuProfile) -> CostModel {
        CostModel {
            geom: geom.clone(),
            profile: profile.clone(),
            memory: MemoryModel::new(geom),
            calib: Calib::default(),
            charge_padding: false,
            c_load: 0.94,
            buckets: None,
        }
    }

    /// Effective parallel speedup of `d`-way TP: each halving step costs an
    /// all-reduce (`tp_eff` per hop). `d` must be a power of two (Eq. 16).
    pub fn tp_speedup(&self, d: usize) -> f64 {
        let hops = (d.max(1) as f64).log2();
        d as f64 * self.profile.tp_eff.powf(hops)
    }

    /// Effective speedup of running one job's rows over `d` devices —
    /// the term the base step time divides by. With a live dp fit
    /// ([`Calib::dp_fit`], calibrated from step times measured at each
    /// executed shard count)
    /// this is the Amdahl ratio `t(1)/t(d) = (a + b) / (a + b/d)`;
    /// before calibration it falls back to the profile's static per-hop
    /// TP curve ([`CostModel::tp_speedup`]) — the modeled-only behavior
    /// the paper-scale tests pin.
    pub fn parallel_speedup(&self, d: usize) -> f64 {
        match self.calib.dp_fit {
            Some((a, b)) if a + b > 0.0 => {
                let d = d.max(1) as f64;
                (a + b) / (a + b / d).max(1e-18)
            }
            _ => self.tp_speedup(d),
        }
    }

    /// [`CostModel::parallel_speedup`] under one device class's own
    /// Amdahl fit ([`Calib::dp_fit_for`]): a slow tier with a more
    /// serial-dominated fit sees a smaller modeled speedup than the fast
    /// tier at the same `d`. Falls back to the class-less behavior when
    /// the class has no fit.
    pub fn parallel_speedup_for(&self, class: &str, d: usize) -> f64 {
        match self.calib.dp_fit_for(class) {
            Some((a, b)) if a + b > 0.0 => {
                let d = d.max(1) as f64;
                (a + b) / (a + b / d).max(1e-18)
            }
            _ => self.tp_speedup(d),
        }
    }

    /// Modeled speedup of streaming `microbatches` through an `s`-stage
    /// pipeline (GPipe schedule, DESIGN.md §15): ideal utilization is
    /// `s·M / (M + s − 1)` (the fill/drain bubble), discounted by
    /// [`Calib::stage_boundary_cost`] per extra stage boundary (one
    /// activation/grad handoff per microbatch each). `s = 1` is exactly
    /// 1; one microbatch through a deep pipeline is pure overhead (< 1).
    pub fn pipeline_speedup(&self, s: usize, microbatches: usize) -> f64 {
        let s = s.max(1);
        if s == 1 {
            return 1.0;
        }
        let sf = s as f64;
        let m = microbatches.max(1) as f64;
        let fill = sf * m / (m + sf - 1.0);
        fill / (1.0 + self.calib.stage_boundary_cost * (sf - 1.0))
    }

    /// Real tokens processed per step by a job running `samples` sequences.
    pub fn step_tokens(&self, samples: f64) -> f64 {
        samples * self.calib.tokens_per_sample.min(self.geom.seq as f64)
    }

    /// Base-model (frozen) fwd+bwd time for `samples` sequences on `d` TP
    /// devices — the roofline `max(weight-IO, FLOP)`.
    pub fn base_step_time(&self, samples: f64, d: usize) -> f64 {
        let tokens = self.step_tokens(samples);
        let speed = self.parallel_speedup(d);
        let io = self.calib.weight_passes * self.memory.base_weight_bytes()
            / (speed * self.profile.mem_bw * self.calib.bw_eff);
        let flops = self.geom.base_step_flops(tokens);
        let ft = flops / (speed * self.profile.peak_flops * self.calib.flop_eff);
        io.max(ft)
    }

    /// Kernel launches per adapter per step (all layers).
    fn kernels_per_adapter(&self) -> f64 {
        self.calib.kernels_per_adapter_per_layer * self.geom.n_layers as f64
    }

    /// Adapter-side time of one step under `mode` on `d` TP devices
    /// (launch-bound; §5.1/§5.2 — see [`Calib::lora_tp_penalty`]).
    pub fn lora_step_time(&self, pack: &Pack, d: usize, mode: ExecMode) -> f64 {
        let r_unit = if self.charge_padding {
            (pack.n() * pack.r_pad()) as f64
        } else {
            pack.rank_sum() as f64
        };
        self.lora_time_units(pack.n(), r_unit, d, mode)
    }

    /// Core of [`CostModel::lora_step_time`]: `n` adapters carrying
    /// `r_unit` rank-units of LoRA work.
    fn lora_time_units(&self, n: usize, r_unit: f64, d: usize, mode: ExecMode) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let hops = (d.max(1) as f64).log2();
        let per_kernel = (self.calib.lora_kernel_time * self.geom.d_model as f64
            / self.calib.lora_kernel_ref_dim)
            .max(self.calib.lora_kernel_floor);
        let k = self.kernels_per_adapter()
            * per_kernel
            * (1.0 + self.calib.lora_tp_penalty).powf(hops);
        match mode {
            // Every adapter pays its own full set of launches.
            ExecMode::Sequential => n as f64 * k,
            // One fused launch set; extra adapters cost only marginal FLOPs,
            // scaled by the rank they add (FLOP linear in rank, §2.1).
            ExecMode::Packed => {
                let extra = (r_unit / self.calib.ref_rank - 1.0).max(0.0);
                k * (1.0 + self.calib.packed_marginal * extra)
            }
        }
    }

    /// One fine-tuning step of `pack` *as executed on a concrete
    /// `(n, r, bs)` bucket*: the full padded bucket shape is charged
    /// regardless of [`CostModel::charge_padding`] — a static-shape
    /// artifact computes every padded row and rank column it was compiled
    /// for. This is the score `planner::rebalance::retarget_bucket`
    /// compares candidate buckets with.
    pub fn bucket_step_time(
        &self,
        bucket: (usize, usize, usize),
        d: usize,
        mode: ExecMode,
    ) -> f64 {
        let (bn, br, bbs) = bucket;
        let samples = (bn * bbs) as f64;
        if self.calib.dp_fit.is_some() {
            // Live dp calibration measures *whole* steps, so the Amdahl
            // ratio scales the whole step (the TP-specific adapter
            // penalty does not apply to the data-parallel axis). Sharded
            // execution splits at slot granularity, so devices beyond the
            // bucket's slot count sit idle — clamp the modeled width the
            // same way `ShardedState` clamps the executed one.
            let t1 = self.base_step_time(samples, 1)
                + self.lora_time_units(bn, (bn * br) as f64, 1, mode)
                + self.calib.step_overhead;
            return t1 / self.parallel_speedup(d.min(bn.max(1)));
        }
        self.base_step_time(samples, d)
            + self.lora_time_units(bn, (bn * br) as f64, d, mode)
            + self.calib.step_overhead
    }

    /// [`CostModel::bucket_step_time`] composed with an `s`-stage
    /// pipeline: the executed microbatch is one bucket slot, so `s`
    /// stages stream `bn` microbatches per step and the whole step
    /// divides by [`CostModel::pipeline_speedup`]. `s` clamps to the
    /// layer stack exactly as `ShardedState` clamps the executed depth;
    /// `s = 1` reproduces `bucket_step_time` bit-for-bit.
    pub fn bucket_step_time_ds(
        &self,
        bucket: (usize, usize, usize),
        d: usize,
        s: usize,
        mode: ExecMode,
    ) -> f64 {
        let t = self.bucket_step_time(bucket, d, mode);
        let s = s.clamp(1, self.geom.n_layers.max(1));
        if s <= 1 {
            return t;
        }
        t / self.pipeline_speedup(s, bucket.0.max(1))
    }

    /// One fine-tuning step of `pack` on `d` devices under `mode`.
    pub fn step_time(&self, pack: &Pack, d: usize, mode: ExecMode) -> f64 {
        let samples = if self.charge_padding {
            (pack.n() * pack.bs_pad()) as f64
        } else {
            pack.total_bs() as f64
        };
        if self.calib.dp_fit.is_some() {
            // See `bucket_step_time`: the Amdahl fit covers the full step
            // and the width clamps to the pack's slot count.
            let t1 = self.base_step_time(samples, 1)
                + self.lora_step_time(pack, 1, mode)
                + self.calib.step_overhead;
            return t1 / self.parallel_speedup(d.min(pack.n().max(1)));
        }
        self.base_step_time(samples, d)
            + self.lora_step_time(pack, d, mode)
            + self.calib.step_overhead
    }

    /// Steps a packed job runs: every adapter must complete its own budget;
    /// smaller batches need more steps (the job rides until the slowest
    /// adapter finishes).
    pub fn job_steps(&self, pack: &Pack, budget: &TrainBudget) -> usize {
        pack.configs.iter().map(|c| budget.steps(c.batch)).max().unwrap_or(0)
    }

    /// Phase decomposition behind [`CostModel::job_time`]: adapters that
    /// complete their budget *leave* the pack at each boundary (the live
    /// session re-buckets onto a smaller artifact there). Phases are the
    /// distinct per-adapter step counts in ascending boundary order; the
    /// simulator turns them into `AdapterFinished`/`Rebucketed` events.
    pub fn job_phases(
        &self,
        pack: &Pack,
        d: usize,
        mode: ExecMode,
        budget: &TrainBudget,
    ) -> Vec<JobPhase> {
        let members: Vec<(LoraConfig, usize)> =
            pack.configs.iter().map(|c| (c.clone(), budget.steps(c.batch))).collect();
        self.phases_from_remaining(&members, d, mode)
    }

    /// The general form behind [`CostModel::job_phases`]: phase
    /// decomposition from explicit per-member `(config, remaining steps)`
    /// state. The simulator's elastic paths (mid-job admission, device
    /// growth, preemption of grown runs) rebuild running timelines with
    /// it; members with zero remaining steps contribute nothing.
    pub fn phases_from_remaining(
        &self,
        members: &[(LoraConfig, usize)],
        d: usize,
        mode: ExecMode,
    ) -> Vec<JobPhase> {
        let mut order: Vec<(usize, &LoraConfig)> =
            members.iter().filter(|m| m.1 > 0).map(|m| (m.1, &m.0)).collect();
        // Descending by remaining steps: the alive set is always a prefix.
        order.sort_by(|a, b| b.0.cmp(&a.0));
        let mut phases = vec![];
        let mut prev_boundary = 0usize; // steps already accounted for
        // Walk boundaries from the *shortest-lived* member upwards.
        let mut i = order.len();
        while i > 0 {
            let steps_here = order[i - 1].0;
            if steps_here == prev_boundary {
                i -= 1;
                continue;
            }
            let alive = Pack::new(order[..i].iter().map(|(_, c)| (*c).clone()).collect());
            let steps = steps_here - prev_boundary;
            let dur = steps as f64 * self.step_time(&alive, d, mode);
            // Everything sitting exactly at this boundary finishes now.
            let mut j = i;
            while j > 0 && order[j - 1].0 == steps_here {
                j -= 1;
            }
            let finished: Vec<usize> = order[j..i].iter().map(|(_, c)| c.id).collect();
            let survivors = if j == 0 {
                (0, 0, 0)
            } else {
                let surv = Pack::new(order[..j].iter().map(|(_, c)| (*c).clone()).collect());
                (surv.n(), surv.r_pad(), surv.bs_pad())
            };
            phases.push(JobPhase { dur, steps, finished, survivors });
            prev_boundary = steps_here;
            i = j;
        }
        phases
    }

    /// The cross-`d` admission gate shared by the live session and the
    /// simulator: absorbing a queued job (own padded shape `own`,
    /// requested width `own_d`, longest member `steps`) into a host
    /// running bucket `host` at `host_d` trades the job's requested
    /// parallelism for starting *now*. Allowed when the per-step penalty
    /// of the host's width, summed over the job's steps, stays under the
    /// lower bound on what waiting would cost — the host's longest
    /// remaining member holds its devices at least `host_remaining`
    /// steps — plus the calibrated device-retarget budget.
    #[allow(clippy::too_many_arguments)]
    pub fn cross_d_admit(
        &self,
        host: (usize, usize, usize),
        host_d: usize,
        host_remaining: usize,
        own: (usize, usize, usize),
        own_d: usize,
        steps: usize,
        mode: ExecMode,
        device_switch_cost: f64,
    ) -> bool {
        let t_host = self.bucket_step_time(host, host_d, mode);
        let t_own = self.bucket_step_time(own, own_d, mode);
        steps as f64 * (t_host - t_own) <= host_remaining as f64 * t_host + device_switch_cost
    }

    /// `T(H_j, d_j)`: wall time of the whole job (Eq. 13/18 denominator) —
    /// the sum over its [`CostModel::job_phases`], so a large-batch config
    /// riding in a small-batch pack only costs its own steps.
    pub fn job_time(&self, pack: &Pack, d: usize, mode: ExecMode, budget: &TrainBudget) -> f64 {
        self.job_phases(pack, d, mode, budget).iter().map(|p| p.dur).sum()
    }

    /// Rung-aware [`CostModel::job_time`]: wall time of a job from
    /// explicit per-member `(config, remaining steps)` state — what a
    /// successive-halving tuner's SJF priorities price, where a promoted
    /// trial only runs the *increment* between its current rung's budget
    /// and the next one's.
    pub fn job_time_remaining(
        &self,
        members: &[(LoraConfig, usize)],
        d: usize,
        mode: ExecMode,
    ) -> f64 {
        self.phases_from_remaining(members, d, mode).iter().map(|p| p.dur).sum()
    }

    /// DTM objective (Eq. 18): LoRA rank-units per second of the job.
    pub fn throughput(&self, pack: &Pack, d: usize, mode: ExecMode, budget: &TrainBudget) -> f64 {
        let t = self.job_time(pack, d, mode, budget);
        if t <= 0.0 {
            return 0.0;
        }
        pack.rank_sum() as f64 / t
    }

    /// Eq. (14)/(19) feasibility of `pack` on `d` devices, plus (live mode)
    /// the static-shape bucket constraint.
    pub fn fits(&self, pack: &Pack, d: usize) -> bool {
        if let Some(buckets) = &self.buckets {
            if pack.n() > 0 {
                let (n, r, bs) = (pack.n(), pack.r_pad(), pack.bs_pad());
                if !buckets.iter().any(|&(bn, br, bb)| bn >= n && br >= r && bb >= bs) {
                    return false;
                }
            }
        }
        self.memory.fits(pack, d, &self.profile, self.c_load, self.charge_padding)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::{A100_40G, A10_24G};
    use crate::config::LoraConfig;

    fn cm() -> CostModel {
        CostModel::new(geom("qwen2.5-7b").unwrap(), &A100_40G)
    }

    fn cfg(r: usize, bs: usize) -> LoraConfig {
        LoraConfig { id: 0, lr: 1e-4, batch: bs, rank: r, alpha_ratio: 1.0, task: "t".into() }
    }

    /// §5.1: "iteration time increases by 10% when the batch size is
    /// increased from 1 to 8" (single adapter).
    #[test]
    fn batch_1_to_8_costs_about_ten_percent() {
        let m = cm();
        let p1 = Pack::new(vec![cfg(32, 1)]);
        let p8 = Pack::new(vec![cfg(32, 8)]);
        let r = m.step_time(&p8, 1, ExecMode::Sequential)
            / m.step_time(&p1, 1, ExecMode::Sequential);
        assert!((1.0..=1.25).contains(&r), "ratio {r:.3}, paper ≈1.10");
    }

    /// §5.1: naive 8-adapter packing is ≈3.6× slower than a single adapter.
    #[test]
    fn naive_eight_pack_is_about_3_6x_worse() {
        let m = cm();
        let p1 = Pack::new(vec![cfg(32, 1)]);
        let p8 = Pack::new(vec![cfg(32, 1); 8]);
        let r = m.step_time(&p8, 1, ExecMode::Sequential)
            / m.step_time(&p1, 1, ExecMode::Sequential);
        assert!((3.0..=4.2).contains(&r), "ratio {r:.2}, paper ≈3.6");
    }

    /// Table 7: packed kernels reach near-linear speedup over the
    /// sequential adapter loop — ≥25× at n=32, ≥7× at n=8, ≈2× at n=2.
    #[test]
    fn packed_kernel_speedup_is_near_linear() {
        let m = cm();
        for (n, lo, hi) in [(2usize, 1.8, 2.05), (8, 6.8, 8.05), (32, 24.0, 32.05)] {
            let pack = Pack::new(vec![cfg(32, 1); n]);
            let s = m.lora_step_time(&pack, 1, ExecMode::Sequential)
                / m.lora_step_time(&pack, 1, ExecMode::Packed);
            assert!((lo..=hi).contains(&s), "n={n}: speedup {s:.1}");
        }
    }

    /// Fig. 5 shape: a full packed job beats the single-adapter job by a
    /// large factor at batch size 1, and the gain shrinks as batch grows.
    #[test]
    fn job_throughput_gain_large_at_bs1_and_shrinks_with_bs() {
        let m = cm();
        let budget = TrainBudget::default();
        let gain = |bs: usize| {
            let nmax = m.memory.max_adapters(32, bs, 1, &m.profile, m.c_load);
            let packed = Pack::new(vec![cfg(32, bs); nmax.max(1)]);
            let single = Pack::new(vec![cfg(32, bs)]);
            m.throughput(&packed, 1, ExecMode::Packed, &budget)
                / m.throughput(&single, 1, ExecMode::Sequential, &budget)
        };
        let g1 = gain(1);
        let g4 = gain(4);
        assert!(g1 > 5.0, "bs1 gain {g1:.1} (paper up to 12.8×)");
        assert!(g4 < g1, "gain should shrink with batch: bs1 {g1:.1} vs bs4 {g4:.1}");
        assert!(g4 > 1.5, "bs4 still a significant win (paper Fig. 5)");
    }

    /// Max GPU (TP=8 for everything) is worse than Min GPU in aggregate
    /// pool throughput (Fig. 4: "Max GPU is much worse").
    #[test]
    fn max_gpu_underperforms_min_gpu() {
        let m = cm();
        let budget = TrainBudget::default();
        let single = Pack::new(vec![cfg(32, 1)]);
        // Min GPU: 8 concurrent single-adapter jobs, one per device.
        let min_gpu = 8.0 * m.throughput(&single, 1, ExecMode::Sequential, &budget);
        // Max GPU: one job over all 8 devices.
        let max_gpu = m.throughput(&single, 8, ExecMode::Sequential, &budget);
        assert!(min_gpu > 2.0 * max_gpu, "min {min_gpu:.1} vs max {max_gpu:.1}");
    }

    /// A10 gains are smaller than A100 gains (Fig. 7: less memory packs
    /// fewer adapters — 2.56× on 7B vs 6.52× on A100).
    #[test]
    fn a10_gain_smaller_than_a100() {
        let budget = TrainBudget::default();
        let gain = |prof: &GpuProfile| {
            let m = CostModel::new(geom("qwen2.5-7b").unwrap(), prof);
            let nmax = m.memory.max_adapters(32, 1, 1, prof, m.c_load).max(1);
            let packed = Pack::new(vec![cfg(32, 1); nmax]);
            let single = Pack::new(vec![cfg(32, 1)]);
            m.throughput(&packed, 1, ExecMode::Packed, &budget)
                / m.throughput(&single, 1, ExecMode::Sequential, &budget)
        };
        let a100 = gain(&A100_40G);
        let a10 = gain(&A10_24G);
        assert!(a10 < a100, "a10 {a10:.1} should trail a100 {a100:.1}");
        assert!(a10 > 1.3, "a10 gain {a10:.1} still > 1 (paper 2.56×)");
    }

    /// The adapter path gets slower with TP (launch-bound kernels + fixed
    /// all-reduce latency) — what keeps packed jobs at minimum TP.
    #[test]
    fn lora_time_grows_with_tp() {
        let m = cm();
        let pack = Pack::new(vec![cfg(32, 1); 8]);
        let t1 = m.lora_step_time(&pack, 1, ExecMode::Packed);
        let t8 = m.lora_step_time(&pack, 8, ExecMode::Packed);
        assert!(t8 > t1 * 2.0, "d=8 adapter path {t8:.4} vs d=1 {t1:.4}");
    }

    /// Per-GPU packed throughput at d=1 beats d=2 and d=8 for a model that
    /// fits one GPU — DTM therefore keeps 7B jobs at d=1 (§7.2.1).
    #[test]
    fn per_gpu_throughput_peaks_at_min_tp() {
        let m = cm();
        let budget = TrainBudget::default();
        let per_gpu = |d: usize| {
            let nmax = m.memory.max_adapters(32, 1, d, &m.profile, m.c_load).max(1);
            let pack = Pack::new(vec![cfg(32, 1); nmax]);
            m.throughput(&pack, d, ExecMode::Packed, &budget) / d as f64
        };
        let (g1, g2, g8) = (per_gpu(1), per_gpu(2), per_gpu(8));
        assert!(g1 > g2 && g2 > g8, "per-GPU thr d1={g1:.1} d2={g2:.1} d8={g8:.1}");
    }

    /// Phase-wise job time: a finished adapter leaves the pack, so a
    /// mixed-batch pack costs less than charging the full pack for the
    /// longest adapter's steps, but at least the uniform-long-pack time of
    /// its longest member alone.
    #[test]
    fn job_time_is_phase_wise() {
        let m = cm();
        let b = TrainBudget::default(); // bs1 -> 768 steps, bs4 -> 192
        let mixed = Pack::new(vec![cfg(32, 1), cfg(32, 4)]);
        let t_mixed = m.job_time(&mixed, 1, ExecMode::Packed, &b);
        // Upper bound: both adapters alive for all 768 steps.
        let t_upper = 768.0 * m.step_time(&mixed, 1, ExecMode::Packed);
        // Lower bound: the bs1 adapter alone for 768 steps.
        let solo = Pack::new(vec![cfg(32, 1)]);
        let t_lower = 768.0 * m.step_time(&solo, 1, ExecMode::Packed);
        assert!(t_mixed < t_upper, "{t_mixed} !< {t_upper}");
        assert!(t_mixed > t_lower, "{t_mixed} !> {t_lower}");
        // Exact: 192 steps together + 576 steps solo.
        let want = 192.0 * m.step_time(&mixed, 1, ExecMode::Packed)
            + 576.0 * m.step_time(&solo, 1, ExecMode::Packed);
        assert!((t_mixed - want).abs() < 1e-9);
    }

    /// `job_phases` decomposes exactly what `job_time` sums, with the
    /// right finishers and survivor shapes at each boundary.
    #[test]
    fn job_phases_decompose_job_time() {
        let m = cm();
        let b = TrainBudget::default(); // bs1 -> 768 steps, bs4 -> 192
        let mut c1 = cfg(32, 1);
        c1.id = 10;
        let mut c4 = cfg(16, 4);
        c4.id = 20;
        let mixed = Pack::new(vec![c1, c4]);
        let phases = m.job_phases(&mixed, 1, ExecMode::Packed, &b);
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].finished, vec![20], "bs4 config leaves first");
        assert_eq!(phases[0].survivors, (1, 32, 1));
        assert_eq!(phases[1].finished, vec![10]);
        assert_eq!(phases[1].survivors, (0, 0, 0));
        let total: f64 = phases.iter().map(|p| p.dur).sum();
        assert!((total - m.job_time(&mixed, 1, ExecMode::Packed, &b)).abs() < 1e-12);
        // Homogeneous pack: a single phase, everyone finishes together.
        let flat = Pack::new(vec![cfg(32, 1); 3]);
        let phases = m.job_phases(&flat, 1, ExecMode::Packed, &b);
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].finished.len(), 3);
        assert!(m.job_phases(&Pack::new(vec![]), 1, ExecMode::Packed, &b).is_empty());
    }

    /// Fig. 6 shape: base-model amortization alone (Sequential mode packs)
    /// is worth roughly 1.5–2.7x per adapter (paper: ~1.8x).
    #[test]
    fn sequential_packing_amortizes_base() {
        for model in ["qwen2.5-3b", "qwen2.5-7b"] {
            let m = CostModel::new(geom(model).unwrap(), &A100_40G);
            let n = 8;
            let single = Pack::new(vec![cfg(32, 1)]);
            let packed = Pack::new(vec![cfg(32, 1); n]);
            // Per-adapter gain: n adapters share one base pass.
            let gain = n as f64 * m.step_time(&single, 1, ExecMode::Sequential)
                / m.step_time(&packed, 1, ExecMode::Sequential);
            assert!(
                (1.3..2.8).contains(&gain),
                "{model}: sequential amortization {gain:.2} (paper ~1.8)"
            );
        }
    }

    /// TP speedup is sublinear and monotone.
    #[test]
    fn tp_speedup_monotone_sublinear() {
        let m = cm();
        let mut prev = 0.0;
        for d in [1usize, 2, 4, 8] {
            let s = m.tp_speedup(d);
            assert!(s > prev && s <= d as f64);
            prev = s;
        }
    }

    /// Padding charge makes heterogeneous packs more expensive, never less.
    #[test]
    fn padded_step_time_dominates() {
        let mut m = cm();
        let pack = Pack::new(vec![cfg(8, 1), cfg(64, 4)]);
        let t_true = m.step_time(&pack, 1, ExecMode::Packed);
        m.charge_padding = true;
        let t_pad = m.step_time(&pack, 1, ExecMode::Packed);
        assert!(t_pad >= t_true);
    }

    /// Bucket-shape-charged step time grows monotonically with every
    /// bucket dimension (a bigger artifact always computes more), and the
    /// live switch-cost estimator averages its samples. Uses the
    /// flop-bound cpu-sim profile — on the weight-IO-bound A100 profile
    /// small-batch base time is sample-independent by design (§3.1).
    #[test]
    fn bucket_step_time_monotone_and_switch_cost_averages() {
        use crate::config::pool::CPU_SIM;
        let m = CostModel::new(geom("qwen2.5-7b").unwrap(), &CPU_SIM);
        let t = |b| m.bucket_step_time(b, 1, ExecMode::Packed);
        assert!(t((1, 8, 1)) < t((2, 8, 1)));
        assert!(t((2, 8, 1)) < t((2, 8, 2)));
        assert!(t((2, 8, 2)) <= t((2, 32, 2)));
        let sc = SwitchCost::new(0.5);
        assert_eq!(sc.estimate(), 0.5, "default before any sample");
        assert_eq!(sc.samples(), 0);
        sc.record(1.0);
        sc.record(3.0);
        assert_eq!(sc.samples(), 2);
        assert!((sc.estimate() - 2.0).abs() < 1e-12);
        // Clones share the underlying estimator.
        let other = sc.clone();
        other.record(2.0);
        assert_eq!(sc.samples(), 3);
    }

    /// The dp-efficiency term: uncalibrated it reproduces the static TP
    /// curve exactly; a live Amdahl fit replaces it, `DpStat` recovers
    /// planted `(a, b)` from noiseless per-step records, and a fit with
    /// no parallel share pins the speedup at 1 (growing never pays).
    #[test]
    fn dp_fit_replaces_static_curve_and_dpstat_recovers() {
        let mut m = cm();
        for d in [1usize, 2, 4, 8] {
            assert_eq!(m.parallel_speedup(d), m.tp_speedup(d), "uncalibrated fallback at d={d}");
        }
        // Perfect parallel fit: speedup(d) = d.
        m.calib.dp_fit = Some((0.0, 1e-3));
        assert!((m.parallel_speedup(4) - 4.0).abs() < 1e-9);
        // Half-serial fit: speedup(2) = 1/(0.5 + 0.25) ... = 4/3.
        m.calib.dp_fit = Some((1e-3, 1e-3));
        assert!((m.parallel_speedup(2) - 4.0 / 3.0).abs() < 1e-9);
        // All-serial: more devices never help.
        m.calib.dp_fit = Some((1e-3, 0.0));
        assert_eq!(m.parallel_speedup(8), 1.0);
        // Calibrated speedup feeds the base step time.
        m.calib.dp_fit = Some((0.0, 1e-3));
        let t1 = m.base_step_time(8.0, 1);
        let t4 = m.base_step_time(8.0, 4);
        assert!((t1 / t4 - 4.0).abs() < 1e-6, "base time must scale by the dp fit");

        let (a, b) = (2.0e-4, 8.0e-4);
        let st = DpStat::new();
        assert!(st.fit().is_none(), "no fit before any record");
        st.record(1, 4.0, (a + b) * 4.0);
        assert!(st.fit().is_none(), "one distinct d cannot separate a from b");
        for d in [2usize, 4] {
            // Two steps per d; per-sample time a + b/d.
            st.record(d, 4.0, (a + b / d as f64) * 4.0);
            st.record(d, 8.0, (a + b / d as f64) * 8.0);
        }
        let (fa, fb) = st.fit().unwrap();
        assert!((fa - a).abs() < 1e-9 && (fb - b).abs() < 1e-9, "fit ({fa:.2e}, {fb:.2e})");
        assert_eq!(st.samples(), 5);
        // Clones share the estimator; degenerate records are ignored.
        let other = st.clone();
        other.record(8, 0.0, 1.0);
        assert_eq!(st.samples(), 5);
    }

    /// `fit_live` recovers planted coefficients from noiseless samples.
    #[test]
    fn fit_live_recovers_coefficients() {
        let (a, b, c) = (3.0e-3, 1.5e-6, 4.0e-4);
        let mut samples = vec![];
        for tok in [64.0, 128.0, 512.0, 1024.0] {
            for n in [1.0, 2.0, 4.0, 8.0] {
                samples.push((tok, n, a + b * tok + c * n));
            }
        }
        let (fa, fb, fc) = Calib::fit_live(&samples);
        assert!((fa - a).abs() < 1e-6 && (fb - b).abs() < 1e-9 && (fc - c).abs() < 1e-7,
            "fit ({fa:.2e},{fb:.2e},{fc:.2e})");
    }

    /// Pipeline speedup: identity at s=1, bounded by min(s, M), pure
    /// overhead for one microbatch, and monotone in the microbatch count;
    /// the `(d, s)` bucket time reproduces `bucket_step_time` at s=1 and
    /// strictly beats it when many microbatches stream a deep pipeline.
    #[test]
    fn pipeline_speedup_shapes_and_ds_step_time() {
        let m = cm();
        assert_eq!(m.pipeline_speedup(1, 8), 1.0);
        for s in [2usize, 4] {
            for mb in [2usize, 8, 32] {
                let v = m.pipeline_speedup(s, mb);
                assert!(v <= (s.min(mb)) as f64 + 1e-12, "s={s} mb={mb}: {v}");
            }
            assert!(m.pipeline_speedup(s, 1) < 1.0, "one microbatch is pure bubble");
            assert!(m.pipeline_speedup(s, 32) > m.pipeline_speedup(s, 2));
        }
        // Deep pipeline over many microbatches approaches s (minus the
        // boundary discount): comfortably > 1.5 at s=2, M=32.
        assert!(m.pipeline_speedup(2, 32) > 1.5);
        let b = (8usize, 32usize, 1usize);
        assert_eq!(
            m.bucket_step_time_ds(b, 1, 1, ExecMode::Packed).to_bits(),
            m.bucket_step_time(b, 1, ExecMode::Packed).to_bits(),
            "s=1 must be the identity"
        );
        let t1 = m.bucket_step_time_ds(b, 1, 1, ExecMode::Packed);
        let t2 = m.bucket_step_time_ds(b, 1, 2, ExecMode::Packed);
        assert!(t2 < t1, "pipelining 8 microbatches must pay: {t2} !< {t1}");
        // Depth clamps to the layer stack: beyond n_layers nothing changes.
        let deep = m.bucket_step_time_ds(b, 1, 10_000, ExecMode::Packed);
        let clamp = m.bucket_step_time_ds(b, 1, m.geom.n_layers, ExecMode::Packed);
        assert_eq!(deep.to_bits(), clamp.to_bits());
    }

    /// Per-device-class calibration: class records feed both accumulators,
    /// `fit_class`/`dp_fit_for` recover the planted per-tier curves, and
    /// `parallel_speedup_for` ranks the fast tier above the slow one while
    /// unknown classes fall back to the fleet-wide behavior.
    #[test]
    fn per_class_dp_fit_recovers_and_ranks_tiers() {
        let st = DpStat::new();
        // Fast tier: near-perfect scaling. Slow tier: serial-dominated.
        let (fa, fb) = (1.0e-4, 9.0e-4);
        let (sa, sb) = (8.0e-4, 2.0e-4);
        for d in [1usize, 2, 4] {
            st.record_class("fast", d, 4.0, (fa + fb / d as f64) * 4.0);
            st.record_class("slow", d, 4.0, (sa + sb / d as f64) * 4.0);
        }
        let (ga, gb) = st.fit_class("fast").unwrap();
        assert!((ga - fa).abs() < 1e-9 && (gb - fb).abs() < 1e-9, "fast fit ({ga:.2e},{gb:.2e})");
        assert!(st.fit_class("slow").is_some());
        assert!(st.fit_class("unknown").is_none());
        // Class records also feed the class-less accumulator.
        assert!(st.fit().is_some());
        assert_eq!(st.class_fits().len(), 2);

        let mut m = cm();
        m.calib.dp_fit_class = st.class_fits();
        assert_eq!(m.calib.dp_fit_for("fast"), st.fit_class("fast"));
        // Unknown class falls back to the class-less fit.
        m.calib.dp_fit = Some((1e-3, 1e-3));
        assert_eq!(m.calib.dp_fit_for("unknown"), Some((1e-3, 1e-3)));
        let fast = m.parallel_speedup_for("fast", 4);
        let slow = m.parallel_speedup_for("slow", 4);
        assert!(fast > slow, "fast tier must out-scale slow: {fast:.2} !> {slow:.2}");
        // No fits anywhere: static TP curve, same as the class-less path.
        m.calib.dp_fit = None;
        m.calib.dp_fit_class.clear();
        assert_eq!(m.parallel_speedup_for("fast", 4), m.tp_speedup(4));
    }
}
