//! Minimal dependency-free **HTTP/1.1 + JSON** transport for the daemon's
//! localhost control plane, plus the matching client used by the `submit`
//! / `status` / `cancel` subcommands and the tests.
//!
//! Deliberately small: loopback only, `Connection: close` per request,
//! `Content-Length` framing, JSON bodies. One thread per connection —
//! handlers are allowed to block (the event long-poll does), and the
//! accept loop polls a stop flag so shutdown never hangs on `accept`.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Largest request (head + body) the server will read, and the largest
/// response the client will buffer. Control-plane payloads are tiny; the
/// cap exists so a misbehaving peer cannot balloon memory.
const MAX_MESSAGE: usize = 4 << 20;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/v1/jobs/3/cancel`.
    pub path: String,
    pub query: BTreeMap<String, String>,
    /// Parsed JSON body, if the request carried one.
    pub body: Option<Json>,
}

/// One response: status code + JSON body.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: Json,
}

impl Response {
    pub fn ok(body: Json) -> Response {
        Response { status: 200, body }
    }

    pub fn err(status: u16, msg: impl Into<String>) -> Response {
        Response { status, body: Json::obj(vec![("error", Json::str(msg.into()))]) }
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A bound (but not yet serving) control-plane listener.
pub struct Server {
    pub addr: SocketAddr,
    listener: TcpListener,
}

impl Server {
    /// Bind to `127.0.0.1:port`; port 0 picks an ephemeral port (the
    /// daemon publishes the resolved `addr` in its `daemon.addr` file).
    pub fn bind(port: u16) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        Ok(Server { addr, listener })
    }

    /// Accept-and-dispatch until `stop` is set. Each connection gets its
    /// own thread so a blocking handler (long-poll) never stalls accepts.
    pub fn serve(self, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
        self.listener.set_nonblocking(true).context("set_nonblocking")?;
        loop {
            if stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let h = Arc::clone(&handler);
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, &h);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => bail!("accept: {e}"),
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, handler: &Handler) -> Result<()> {
    stream.set_nonblocking(false).ok();
    // Generous ceilings so a stuck peer cannot pin the thread forever;
    // long-poll handlers bound their own waits far below this.
    stream.set_read_timeout(Some(Duration::from_secs(300))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(60))).ok();
    let resp = match read_request(&mut stream) {
        Ok(req) => handler(&req),
        Err(e) => Response::err(400, format!("bad request: {e}")),
    };
    write_response(&mut stream, &resp)
}

/// Read one HTTP/1.1 request off the stream.
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    // Read until the blank line that ends the header block.
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_MESSAGE {
            bail!("request head too large");
        }
        let n = stream.read(&mut chunk).context("read head")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("head not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow!("no request target"))?;
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > MAX_MESSAGE {
        bail!("request body too large");
    }
    let body_start = head_end + 4; // past "\r\n\r\n"
    let mut body = buf[body_start.min(buf.len())..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("read body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let (path, query) = split_target(target);
    let body = if body.is_empty() {
        None
    } else {
        let text = std::str::from_utf8(&body).context("body not UTF-8")?;
        Some(Json::parse(text).map_err(|e| anyhow!("body not JSON: {e}"))?)
    };
    Ok(Request { method, path, query, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    let (path, qs) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in qs.split('&').filter(|p| !p.is_empty()) {
        match pair.split_once('=') {
            Some((k, v)) => query.insert(k.to_string(), v.to_string()),
            None => query.insert(pair.to_string(), String::new()),
        };
    }
    (path.to_string(), query)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let mut body = String::new();
    resp.body.write(&mut body);
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        reason(resp.status),
        body.len()
    );
    stream.write_all(head.as_bytes()).context("write head")?;
    stream.write_all(body.as_bytes()).context("write body")?;
    stream.flush().context("flush")
}

/// Fixed-capacity ring buffer backing the `/v1/events` long-poll log,
/// with a **monotone cursor**: event `i` keeps index `i` forever, whether
/// or not it is still buffered. A long-lived daemon emits events without
/// bound, so the old unbounded `Vec` grew monotonically; the ring caps
/// memory at `cap` events and evicts from the front. Clients that fall off
/// the tail (cursor older than the oldest buffered event) get whatever is
/// still buffered plus an explicit `truncated` marker instead of a silent
/// gap — they can re-sync from `/v1/jobs` state.
#[derive(Debug)]
pub struct EventRing {
    cap: usize,
    /// Monotone index of the oldest buffered event == how many events have
    /// been evicted so far.
    start: usize,
    buf: VecDeque<Json>,
}

impl EventRing {
    /// `cap` is clamped to ≥ 1 (a zero-capacity log would make every
    /// long-poll spin).
    pub fn new(cap: usize) -> EventRing {
        let cap = cap.max(1);
        EventRing { cap, start: 0, buf: VecDeque::with_capacity(cap) }
    }

    /// Append one event, evicting the oldest if the ring is full.
    pub fn push(&mut self, ev: Json) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.start += 1;
        }
        self.buf.push_back(ev);
    }

    /// One past the newest event's monotone index — the `next` cursor a
    /// caught-up client polls with.
    pub fn end(&self) -> usize {
        self.start + self.buf.len()
    }

    /// Every buffered event at monotone index ≥ `cursor`, plus whether the
    /// cursor fell off the tail (events `[cursor, start)` were evicted).
    /// A cursor at or past `end()` returns empty, not truncated.
    pub fn since(&self, cursor: usize) -> (Vec<Json>, bool) {
        let truncated = cursor < self.start;
        let from = cursor.max(self.start).min(self.end());
        (self.buf.iter().skip(from - self.start).cloned().collect(), truncated)
    }
}

/// Blocking JSON-over-HTTP client call; returns `(status, body)`. An
/// empty response body parses as `Json::Null`. The read timeout is long
/// enough to sit through a server-side event long-poll.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(180))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(30))).ok();
    let payload = body
        .map(|b| {
            let mut s = String::new();
            b.write(&mut s);
            s
        })
        .unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes()).context("write request")?;
    stream.write_all(payload.as_bytes()).context("write request body")?;
    stream.flush().ok();
    let mut raw = Vec::new();
    stream.take(MAX_MESSAGE as u64).read_to_end(&mut raw).context("read response")?;
    let head_end = find_head_end(&raw).ok_or_else(|| anyhow!("malformed response"))?;
    let head = std::str::from_utf8(&raw[..head_end]).context("response head not UTF-8")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("no status in response: {head}"))?;
    let body_bytes = &raw[head_end + 4..];
    let body = if body_bytes.is_empty() {
        Json::Null
    } else {
        let text = std::str::from_utf8(body_bytes).context("response body not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow!("response not JSON: {e}"))?
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_routing() {
        let server = Server::bind(0).unwrap();
        let addr = server.addr.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Handler = Arc::new(|req: &Request| match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/echo") => Response::ok(Json::obj(vec![
                ("got", req.body.clone().unwrap_or(Json::Null)),
                (
                    "q",
                    Json::str(req.query.get("tag").cloned().unwrap_or_default()),
                ),
            ])),
            ("GET", "/ping") => Response::ok(Json::obj(vec![("pong", Json::Bool(true))])),
            _ => Response::err(404, "no such route"),
        });
        let t = std::thread::spawn(move || server.serve(handler, stop2));

        let (st, body) = request(&addr, "GET", "/ping", None).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body.field("pong").unwrap().as_bool(), Some(true));

        let payload = Json::obj(vec![("x", Json::num(42.0))]);
        let (st, body) = request(&addr, "POST", "/echo?tag=abc", Some(&payload)).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body.field("got").unwrap().field("x").unwrap().as_f64(), Some(42.0));
        assert_eq!(body.field("q").unwrap().as_str(), Some("abc"));

        let (st, body) = request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(st, 404);
        assert!(body.field("error").unwrap().as_str().unwrap().contains("route"));

        stop.store(true, Ordering::SeqCst);
        t.join().unwrap().unwrap();
    }

    #[test]
    fn event_ring_wraps_with_monotone_cursor() {
        let mut ring = EventRing::new(4);
        assert_eq!(ring.end(), 0);
        let (evs, truncated) = ring.since(0);
        assert!(evs.is_empty() && !truncated, "empty ring: nothing, not truncated");

        // Below capacity: behaves exactly like the old Vec.
        for i in 0..3 {
            ring.push(Json::num(i as f64));
        }
        let (evs, truncated) = ring.since(0);
        assert_eq!(evs.len(), 3);
        assert!(!truncated);
        assert_eq!(ring.end(), 3);
        let (evs, truncated) = ring.since(2);
        assert_eq!(evs, vec![Json::num(2.0)]);
        assert!(!truncated);

        // Wrap: events 0..6 pushed into cap 4 evicts 0 and 1.
        for i in 3..6 {
            ring.push(Json::num(i as f64));
        }
        assert_eq!(ring.end(), 6);
        let (evs, truncated) = ring.since(0);
        assert!(truncated, "cursor 0 fell off the tail");
        let got: Vec<f64> = evs.iter().filter_map(Json::as_f64).collect();
        assert_eq!(got, vec![2.0, 3.0, 4.0, 5.0], "oldest evicted, order kept");
        // Cursor exactly at the oldest buffered event: not truncated.
        let (evs, truncated) = ring.since(2);
        assert_eq!(evs.len(), 4);
        assert!(!truncated);
        // Caught-up and future cursors: empty, never truncated.
        for cursor in [6usize, 7, 100] {
            let (evs, truncated) = ring.since(cursor);
            assert!(evs.is_empty() && !truncated, "cursor {cursor}");
        }

        // Capacity clamps to 1 and still rotates.
        let mut tiny = EventRing::new(0);
        tiny.push(Json::num(0.0));
        tiny.push(Json::num(1.0));
        assert_eq!(tiny.end(), 2);
        let (evs, truncated) = tiny.since(0);
        assert_eq!(evs, vec![Json::num(1.0)]);
        assert!(truncated);
    }
}
