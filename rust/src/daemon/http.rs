//! Minimal dependency-free **HTTP/1.1 + JSON** transport for the daemon's
//! localhost control plane, plus the matching client used by the `submit`
//! / `status` / `cancel` subcommands and the tests.
//!
//! Deliberately small: loopback only, `Connection: close` per request,
//! `Content-Length` framing, JSON bodies. One thread per connection —
//! handlers are allowed to block (the event long-poll does), and the
//! accept loop polls a stop flag so shutdown never hangs on `accept`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Largest request (head + body) the server will read, and the largest
/// response the client will buffer. Control-plane payloads are tiny; the
/// cap exists so a misbehaving peer cannot balloon memory.
const MAX_MESSAGE: usize = 4 << 20;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/v1/jobs/3/cancel`.
    pub path: String,
    pub query: BTreeMap<String, String>,
    /// Parsed JSON body, if the request carried one.
    pub body: Option<Json>,
}

/// One response: status code + JSON body.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: Json,
}

impl Response {
    pub fn ok(body: Json) -> Response {
        Response { status: 200, body }
    }

    pub fn err(status: u16, msg: impl Into<String>) -> Response {
        Response { status, body: Json::obj(vec![("error", Json::str(msg.into()))]) }
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A bound (but not yet serving) control-plane listener.
pub struct Server {
    pub addr: SocketAddr,
    listener: TcpListener,
}

impl Server {
    /// Bind to `127.0.0.1:port`; port 0 picks an ephemeral port (the
    /// daemon publishes the resolved `addr` in its `daemon.addr` file).
    pub fn bind(port: u16) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        Ok(Server { addr, listener })
    }

    /// Accept-and-dispatch until `stop` is set. Each connection gets its
    /// own thread so a blocking handler (long-poll) never stalls accepts.
    pub fn serve(self, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
        self.listener.set_nonblocking(true).context("set_nonblocking")?;
        loop {
            if stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let h = Arc::clone(&handler);
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, &h);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => bail!("accept: {e}"),
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, handler: &Handler) -> Result<()> {
    stream.set_nonblocking(false).ok();
    // Generous ceilings so a stuck peer cannot pin the thread forever;
    // long-poll handlers bound their own waits far below this.
    stream.set_read_timeout(Some(Duration::from_secs(300))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(60))).ok();
    let resp = match read_request(&mut stream) {
        Ok(req) => handler(&req),
        Err(e) => Response::err(400, format!("bad request: {e}")),
    };
    write_response(&mut stream, &resp)
}

/// Read one HTTP/1.1 request off the stream.
fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    // Read until the blank line that ends the header block.
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_MESSAGE {
            bail!("request head too large");
        }
        let n = stream.read(&mut chunk).context("read head")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("head not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow!("no request target"))?;
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > MAX_MESSAGE {
        bail!("request body too large");
    }
    let body_start = head_end + 4; // past "\r\n\r\n"
    let mut body = buf[body_start.min(buf.len())..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("read body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let (path, query) = split_target(target);
    let body = if body.is_empty() {
        None
    } else {
        let text = std::str::from_utf8(&body).context("body not UTF-8")?;
        Some(Json::parse(text).map_err(|e| anyhow!("body not JSON: {e}"))?)
    };
    Ok(Request { method, path, query, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    let (path, qs) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in qs.split('&').filter(|p| !p.is_empty()) {
        match pair.split_once('=') {
            Some((k, v)) => query.insert(k.to_string(), v.to_string()),
            None => query.insert(pair.to_string(), String::new()),
        };
    }
    (path.to_string(), query)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let mut body = String::new();
    resp.body.write(&mut body);
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        reason(resp.status),
        body.len()
    );
    stream.write_all(head.as_bytes()).context("write head")?;
    stream.write_all(body.as_bytes()).context("write body")?;
    stream.flush().context("flush")
}

/// Blocking JSON-over-HTTP client call; returns `(status, body)`. An
/// empty response body parses as `Json::Null`. The read timeout is long
/// enough to sit through a server-side event long-poll.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(180))).ok();
    stream.set_write_timeout(Some(Duration::from_secs(30))).ok();
    let payload = body
        .map(|b| {
            let mut s = String::new();
            b.write(&mut s);
            s
        })
        .unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes()).context("write request")?;
    stream.write_all(payload.as_bytes()).context("write request body")?;
    stream.flush().ok();
    let mut raw = Vec::new();
    stream.take(MAX_MESSAGE as u64).read_to_end(&mut raw).context("read response")?;
    let head_end = find_head_end(&raw).ok_or_else(|| anyhow!("malformed response"))?;
    let head = std::str::from_utf8(&raw[..head_end]).context("response head not UTF-8")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("no status in response: {head}"))?;
    let body_bytes = &raw[head_end + 4..];
    let body = if body_bytes.is_empty() {
        Json::Null
    } else {
        let text = std::str::from_utf8(body_bytes).context("response body not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow!("response not JSON: {e}"))?
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_routing() {
        let server = Server::bind(0).unwrap();
        let addr = server.addr.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Handler = Arc::new(|req: &Request| match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/echo") => Response::ok(Json::obj(vec![
                ("got", req.body.clone().unwrap_or(Json::Null)),
                (
                    "q",
                    Json::str(req.query.get("tag").cloned().unwrap_or_default()),
                ),
            ])),
            ("GET", "/ping") => Response::ok(Json::obj(vec![("pong", Json::Bool(true))])),
            _ => Response::err(404, "no such route"),
        });
        let t = std::thread::spawn(move || server.serve(handler, stop2));

        let (st, body) = request(&addr, "GET", "/ping", None).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body.field("pong").unwrap().as_bool(), Some(true));

        let payload = Json::obj(vec![("x", Json::num(42.0))]);
        let (st, body) = request(&addr, "POST", "/echo?tag=abc", Some(&payload)).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body.field("got").unwrap().field("x").unwrap().as_f64(), Some(42.0));
        assert_eq!(body.field("q").unwrap().as_str(), Some("abc"));

        let (st, body) = request(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(st, 404);
        assert!(body.field("error").unwrap().as_str().unwrap().contains("route"));

        stop.store(true, Ordering::SeqCst);
        t.join().unwrap().unwrap();
    }
}
