//! The daemon's **durable job store**: an append-only journal of
//! submissions and state transitions, fsync'd at every admission boundary.
//!
//! One record per line, `{fnv1a_checksum_hex}\t{json}\n`. The checksum is
//! FNV-1a 64 over the JSON text, so a torn write at the tail (power loss,
//! `kill -9` mid-append) is detected and dropped instead of misread.
//! Record kinds (`"rec"` discriminant):
//!
//! - `meta` — the daemon's digest-load-bearing settings (model, training
//!   options), written once on first start and validated on every restart.
//! - `submit` — one admitted job: idempotency token, tenant + weight,
//!   session job id, fair-share priority, `d`, exec mode, and the full
//!   adapter configs. Written (and fsync'd) *before* the session sees the
//!   job, so a crash in between re-submits on recovery rather than losing
//!   the admission.
//! - `adapter_done` — the [`AdapterDigest`] of one finished adapter. The
//!   tensors already live in the checkpoint pool; the digest is what makes
//!   post-crash accounting bit-exact.
//! - `job_done` / `job_failed` / `cancelled` — job closure.
//! - `drain` — clean shutdown marker (every running pack checkpointed).
//!
//! Recovery policy ([`recover`]): a corrupt or truncated **trailing**
//! record is dropped with a warning (the crash interrupted that append —
//! by the write protocol nothing after it can exist); corruption anywhere
//! earlier is a hard error (the file was tampered with or the disk is
//! bad); an unknown record kind is a hard error (the journal came from a
//! newer schema — resuming would silently drop state); a duplicate submit
//! token keeps the first record and warns (re-acked admission); a missing
//! or empty journal is a fresh start.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::LoraConfig;
use crate::costmodel::ExecMode;
use crate::session::Policy;
use crate::trace::{
    config_from_json, config_to_json, mode_name, mode_parse, options_from_json,
    options_to_json, policy_name, AdapterDigest,
};
use crate::train::TrainOptions;
use crate::util::hash::fnv1a;
use crate::util::json::Json;

/// On-disk journal schema version; [`recover`] refuses other versions.
pub const JOURNAL_SCHEMA: u64 = 1;

/// The daemon settings a journal was recorded under. `model` and
/// `options` are digest-load-bearing (they seed every trajectory);
/// changing them under an existing journal is refused. The rest is
/// timing-only provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Meta {
    pub model: String,
    pub gpus: usize,
    pub policy: Policy,
    pub elastic: bool,
    pub rebucket: bool,
    pub options: TrainOptions,
}

/// One admitted job as journaled at its admission boundary.
#[derive(Debug, Clone)]
pub struct Submission {
    /// Client idempotency token; a re-sent token re-acks instead of
    /// double-admitting.
    pub token: String,
    pub tenant: String,
    pub weight: f64,
    /// Session job id (daemon-assigned, dense).
    pub job: usize,
    /// Fair-share priority the job was enqueued at.
    pub priority: i32,
    pub d: usize,
    pub mode: ExecMode,
    pub configs: Vec<LoraConfig>,
}

/// Append-side handle. Every append is checksummed and fsync'd before it
/// returns, so anything the daemon acknowledged is on disk.
pub struct Journal {
    path: PathBuf,
    file: File,
}

impl Journal {
    pub fn open(path: &Path) -> Result<Journal> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir {}", dir.display()))?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        Ok(Journal { path: path.to_path_buf(), file })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&mut self, rec: &Json) -> Result<()> {
        let mut text = String::new();
        rec.write(&mut text);
        let line = seal(&text);
        self.file
            .write_all(line.as_bytes())
            .and_then(|_| self.file.sync_all())
            .with_context(|| format!("append journal {}", self.path.display()))
    }

    pub fn meta(&mut self, m: &Meta) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("meta")),
            ("schema", Json::num(JOURNAL_SCHEMA as f64)),
            ("model", Json::str(m.model.as_str())),
            ("gpus", Json::num(m.gpus as f64)),
            ("policy", Json::str(policy_name(m.policy))),
            ("elastic", Json::Bool(m.elastic)),
            ("rebucket", Json::Bool(m.rebucket)),
            ("options", options_to_json(&m.options)),
        ]))
    }

    pub fn submit(&mut self, s: &Submission) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("submit")),
            ("token", Json::str(s.token.as_str())),
            ("tenant", Json::str(s.tenant.as_str())),
            ("weight", Json::num(s.weight)),
            ("job", Json::num(s.job as f64)),
            ("priority", Json::num(s.priority as f64)),
            ("d", Json::num(s.d as f64)),
            ("mode", Json::str(mode_name(s.mode))),
            ("adapters", Json::arr(s.configs.iter().map(config_to_json))),
        ]))
    }

    pub fn adapter_done(&mut self, job: usize, adapter: usize, d: &AdapterDigest) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("adapter_done")),
            ("job", Json::num(job as f64)),
            ("adapter", Json::num(adapter as f64)),
            ("digest", d.to_json()),
        ]))
    }

    pub fn job_done(&mut self, job: usize) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("job_done")),
            ("job", Json::num(job as f64)),
        ]))
    }

    pub fn job_failed(&mut self, job: usize, error: &str) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("job_failed")),
            ("job", Json::num(job as f64)),
            ("error", Json::str(error)),
        ]))
    }

    pub fn cancelled(&mut self, job: usize) -> Result<()> {
        self.append(&Json::obj(vec![
            ("rec", Json::str("cancelled")),
            ("job", Json::num(job as f64)),
        ]))
    }

    pub fn drain(&mut self) -> Result<()> {
        self.append(&Json::obj(vec![("rec", Json::str("drain"))]))
    }
}

/// Checksum-prefix one serialized record into its on-disk line.
fn seal(json_text: &str) -> String {
    format!("{:016x}\t{json_text}\n", fnv1a(json_text.as_bytes()))
}

/// Everything [`recover`] reconstructs from a journal.
#[derive(Debug, Default)]
pub struct Recovered {
    pub meta: Option<Meta>,
    /// Admitted jobs in journal (= admission) order, deduped by token.
    pub submissions: Vec<Submission>,
    /// Finished adapters: id → journaled digest.
    pub digests: BTreeMap<usize, AdapterDigest>,
    /// Finished adapters: id → host job.
    pub adapter_jobs: BTreeMap<usize, usize>,
    pub done: BTreeSet<usize>,
    pub failed: BTreeMap<usize, String>,
    pub cancelled: BTreeSet<usize>,
    /// A `drain` record was the journal's logical tail: the previous
    /// process shut down cleanly with every running pack checkpointed.
    pub drained: bool,
    /// Non-fatal recovery notes (torn tail dropped, duplicate token).
    pub warnings: Vec<String>,
}

impl Recovered {
    /// Journal-derived floor for the daemon's next job id.
    pub fn next_job_id(&self) -> usize {
        self.submissions.iter().map(|s| s.job + 1).max().unwrap_or(0)
    }

    /// Journal-derived floor for the daemon's next adapter id.
    pub fn next_adapter_id(&self) -> usize {
        self.submissions
            .iter()
            .flat_map(|s| s.configs.iter().map(|c| c.id + 1))
            .max()
            .unwrap_or(0)
    }
}

/// Replay a journal into a [`Recovered`] state (see module docs for the
/// corruption policy). A missing file is a fresh start, not an error.
pub fn recover(path: &Path) -> Result<Recovered> {
    let mut out = Recovered::default();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(anyhow!("read journal {}: {e}", path.display())),
    };
    let lines: Vec<&str> = text.lines().collect();
    let mut tokens: BTreeSet<String> = BTreeSet::new();
    for (i, line) in lines.iter().enumerate() {
        if line.is_empty() {
            continue;
        }
        let last = i + 1 == lines.len();
        let rec = match parse_line(line) {
            Ok(r) => r,
            Err(e) if last => {
                // Torn tail: the crash interrupted this append. Nothing
                // after it can exist (appends are sequential + fsync'd),
                // so dropping it loses at most the un-acked record.
                out.warnings
                    .push(format!("journal line {}: dropped torn record ({e})", i + 1));
                break;
            }
            Err(e) => bail!("journal {} line {}: {e}", path.display(), i + 1),
        };
        let kind = rec
            .field("rec")
            .ok()
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow!("journal line {}: record without 'rec' kind", i + 1))?
            .to_string();
        match kind.as_str() {
            "meta" => {
                let schema = rec
                    .field("schema")?
                    .as_u64()
                    .ok_or_else(|| anyhow!("meta record: bad schema"))?;
                if schema != JOURNAL_SCHEMA {
                    bail!(
                        "journal {} is schema v{schema}; this build reads v{JOURNAL_SCHEMA}",
                        path.display()
                    );
                }
                let policy = rec
                    .field("policy")?
                    .as_str()
                    .and_then(Policy::parse)
                    .ok_or_else(|| anyhow!("meta record: bad policy"))?;
                out.meta = Some(Meta {
                    model: jstr(&rec, "model")?,
                    gpus: jusize(&rec, "gpus")?,
                    policy,
                    elastic: jbool(&rec, "elastic")?,
                    rebucket: jbool(&rec, "rebucket")?,
                    options: options_from_json(rec.field("options")?)?,
                });
            }
            "submit" => {
                let token = jstr(&rec, "token")?;
                if !tokens.insert(token.clone()) {
                    out.warnings.push(format!(
                        "journal line {}: duplicate submit token '{token}' — \
                         keeping the first admission",
                        i + 1
                    ));
                    continue;
                }
                let configs = rec
                    .field("adapters")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("submit record: 'adapters' not an array"))?
                    .iter()
                    .map(config_from_json)
                    .collect::<Result<Vec<_>>>()?;
                out.submissions.push(Submission {
                    token,
                    tenant: jstr(&rec, "tenant")?,
                    weight: jf64(&rec, "weight")?,
                    job: jusize(&rec, "job")?,
                    priority: jf64(&rec, "priority")? as i32,
                    d: jusize(&rec, "d")?,
                    mode: mode_parse(&jstr(&rec, "mode")?)?,
                    configs,
                });
            }
            "adapter_done" => {
                let adapter = jusize(&rec, "adapter")?;
                out.adapter_jobs.insert(adapter, jusize(&rec, "job")?);
                out.digests
                    .insert(adapter, AdapterDigest::from_json(rec.field("digest")?)?);
            }
            "job_done" => {
                out.done.insert(jusize(&rec, "job")?);
            }
            "job_failed" => {
                out.failed.insert(jusize(&rec, "job")?, jstr(&rec, "error")?);
            }
            "cancelled" => {
                out.cancelled.insert(jusize(&rec, "job")?);
            }
            "drain" => {
                out.drained = true;
            }
            other => bail!(
                "journal {} line {}: unknown record kind '{other}' — written by a \
                 newer schema; refusing to resume from a partially understood journal",
                path.display(),
                i + 1
            ),
        }
        // Any record after a drain marker means the daemon restarted and
        // worked further; the drain no longer describes the tail state.
        if kind != "drain" {
            out.drained = false;
        }
    }
    Ok(out)
}

/// Checksum-verify and parse one journal line.
fn parse_line(line: &str) -> Result<Json> {
    let (sum, body) = line
        .split_once('\t')
        .ok_or_else(|| anyhow!("no checksum separator"))?;
    let stored =
        u64::from_str_radix(sum, 16).map_err(|_| anyhow!("bad checksum '{sum}'"))?;
    let actual = fnv1a(body.as_bytes());
    if stored != actual {
        bail!("checksum mismatch (stored {stored:016x}, computed {actual:016x})");
    }
    Json::parse(body).map_err(|e| anyhow!("bad JSON: {e}"))
}

fn jstr(v: &Json, k: &str) -> Result<String> {
    Ok(v.field(k)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{k}': expected string"))?
        .to_string())
}

fn jusize(v: &Json, k: &str) -> Result<usize> {
    v.field(k)?.as_usize().ok_or_else(|| anyhow!("field '{k}': expected integer"))
}

fn jf64(v: &Json, k: &str) -> Result<f64> {
    v.field(k)?.as_f64().ok_or_else(|| anyhow!("field '{k}': expected number"))
}

fn jbool(v: &Json, k: &str) -> Result<bool> {
    v.field(k)?.as_bool().ok_or_else(|| anyhow!("field '{k}': expected bool"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::TrainBudget;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("plora-journal-{name}"));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn meta_fixture() -> Meta {
        Meta {
            model: "nano".into(),
            gpus: 2,
            policy: Policy::Priority,
            elastic: false,
            rebucket: true,
            options: TrainOptions {
                budget: TrainBudget { dataset: 32, epochs: 1 },
                eval_batches: 2,
                seed: 17,
                log_every: 0,
            },
        }
    }

    fn sub_fixture(token: &str, job: usize) -> Submission {
        Submission {
            token: token.into(),
            tenant: "alice".into(),
            weight: 2.0,
            job,
            priority: -125,
            d: 1,
            mode: ExecMode::Packed,
            configs: vec![LoraConfig {
                id: job * 10,
                lr: 2e-3,
                batch: 1,
                rank: 8,
                alpha_ratio: 1.0,
                task: "modadd".into(),
            }],
        }
    }

    fn digest_fixture() -> AdapterDigest {
        AdapterDigest {
            task: "modadd".into(),
            rank: 8,
            batch: 1,
            lr_bits: 2e-3f64.to_bits(),
            steps: 32,
            first_loss: 1.5f32.to_bits(),
            final_loss: 0.25f32.to_bits(),
            base_loss: 1.75f32.to_bits(),
            base_acc: 0.5f32.to_bits(),
            eval_loss: 0.3f32.to_bits(),
            eval_acc: 0.875f32.to_bits(),
            param_hash: 0x1234_5678_9abc_def0,
            curve: vec![(0, 1.5f32.to_bits())],
        }
    }

    #[test]
    fn roundtrip_all_record_kinds() {
        let path = tmp("roundtrip");
        let mut j = Journal::open(&path).unwrap();
        j.meta(&meta_fixture()).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        j.submit(&sub_fixture("t2", 1)).unwrap();
        j.adapter_done(0, 0, &digest_fixture()).unwrap();
        j.job_done(0).unwrap();
        j.job_failed(1, "boom \"quoted\"").unwrap();
        j.cancelled(2).unwrap();
        j.drain().unwrap();
        let r = recover(&path).unwrap();
        assert!(r.warnings.is_empty(), "{:?}", r.warnings);
        assert_eq!(r.meta, Some(meta_fixture()));
        assert_eq!(r.submissions.len(), 2);
        assert_eq!(r.submissions[0].token, "t1");
        assert_eq!(r.submissions[1].job, 1);
        assert_eq!(r.submissions[0].configs[0].task, "modadd");
        assert_eq!(r.digests.get(&0), Some(&digest_fixture()));
        assert_eq!(r.adapter_jobs.get(&0), Some(&0));
        assert!(r.done.contains(&0));
        assert_eq!(r.failed.get(&1).unwrap(), "boom \"quoted\"");
        assert!(r.cancelled.contains(&2));
        assert!(r.drained, "drain was the journal tail");
        assert_eq!(r.next_job_id(), 2);
        assert_eq!(r.next_adapter_id(), 11);
    }

    #[test]
    fn empty_and_missing_journals_are_fresh_starts() {
        let missing = tmp("missing");
        let r = recover(&missing).unwrap();
        assert!(r.meta.is_none() && r.submissions.is_empty() && r.warnings.is_empty());
        let empty = tmp("empty");
        std::fs::write(&empty, "").unwrap();
        let r = recover(&empty).unwrap();
        assert!(r.meta.is_none() && r.submissions.is_empty() && r.warnings.is_empty());
    }

    /// A torn trailing record (crash mid-append) is dropped with a
    /// warning; everything before it survives.
    #[test]
    fn truncated_tail_is_dropped_with_warning() {
        let path = tmp("torn");
        let mut j = Journal::open(&path).unwrap();
        j.meta(&meta_fixture()).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        j.job_done(0).unwrap();
        // Simulate a torn append: half a line, no trailing newline.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("{text}0123456789abcdef\t{{\"rec\":\"sub")).unwrap();
        let r = recover(&path).unwrap();
        assert_eq!(r.submissions.len(), 1);
        assert!(r.done.contains(&0));
        assert_eq!(r.warnings.len(), 1);
        assert!(r.warnings[0].contains("torn"), "{}", r.warnings[0]);
    }

    /// The same torn bytes anywhere but the tail are a hard error.
    #[test]
    fn corruption_mid_file_is_fatal() {
        let path = tmp("midcorrupt");
        let mut j = Journal::open(&path).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        j.submit(&sub_fixture("t2", 1)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        // Flip one byte inside the first record's JSON body.
        lines[0] = lines[0].replace("alice", "malice");
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let err = recover(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    /// A duplicate submit token re-acks (first admission wins) instead of
    /// double-admitting, with a warning.
    #[test]
    fn duplicate_submit_token_dedupes() {
        let path = tmp("dup");
        let mut j = Journal::open(&path).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        j.submit(&sub_fixture("t1", 1)).unwrap();
        let r = recover(&path).unwrap();
        assert_eq!(r.submissions.len(), 1);
        assert_eq!(r.submissions[0].job, 0, "first admission wins");
        assert_eq!(r.warnings.len(), 1);
        assert!(r.warnings[0].contains("duplicate"), "{}", r.warnings[0]);
    }

    /// Well-formed records of an unknown kind mean a newer schema wrote
    /// the journal: refuse rather than silently dropping state.
    #[test]
    fn unknown_record_kind_is_fatal() {
        let path = tmp("unknown");
        let mut j = Journal::open(&path).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        let body = "{\"rec\":\"flux_capacitor\",\"job\":0}";
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str(&seal(body));
        // Append a valid record after it so the unknown kind is not in
        // torn-tail position.
        text.push_str(&seal("{\"rec\":\"job_done\",\"job\":0}"));
        std::fs::write(&path, text).unwrap();
        let err = recover(&path).unwrap_err().to_string();
        assert!(err.contains("flux_capacitor"), "{err}");
    }

    /// An unknown kind in tail position is still fatal — the record is
    /// intact (checksum passes), so this is schema skew, not a torn write.
    #[test]
    fn unknown_record_kind_at_tail_is_fatal() {
        let path = tmp("unknown-tail");
        let mut j = Journal::open(&path).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str(&seal("{\"rec\":\"flux_capacitor\",\"job\":0}"));
        std::fs::write(&path, text).unwrap();
        assert!(recover(&path).is_err());
    }

    #[test]
    fn restart_after_drain_clears_the_drained_flag() {
        let path = tmp("redrain");
        let mut j = Journal::open(&path).unwrap();
        j.submit(&sub_fixture("t1", 0)).unwrap();
        j.drain().unwrap();
        j.submit(&sub_fixture("t2", 1)).unwrap();
        let r = recover(&path).unwrap();
        assert!(!r.drained, "work after a drain marker voids it");
    }
}
