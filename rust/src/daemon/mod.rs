//! **Tuning-as-a-service**: a durable multi-tenant daemon wrapping one
//! [`Session`] with an HTTP control plane and crash-exact recovery
//! (DESIGN.md §13).
//!
//! Three pieces compose it:
//!
//! - [`journal`] — the append-only durable job store. Every admission is
//!   fsync'd *before* the session sees the job (a crash in the gap
//!   re-submits; the reverse order would lose an acknowledged job), and
//!   every finished adapter's [`AdapterDigest`] is journaled *after* its
//!   checkpoint-pool write (a crash in that gap deterministically re-runs
//!   the adapter to the same bits).
//! - [`http`] — a dependency-free localhost HTTP/1.1 + JSON control plane:
//!   submit / status / cancel / list / long-poll events / digest. The
//!   event wire format is the session's own [`Event`] vocabulary,
//!   serialized verbatim by [`crate::trace::event_to_json`].
//! - [`tenant`] — weighted fair-share (SFQ) admission, mapped onto the
//!   session's priority scheduler.
//!
//! **Shutdown vs crash.** `SIGTERM`/`SIGINT` (or `POST /v1/shutdown`)
//! drain gracefully: the control plane stops, the session suspends —
//! running packs checkpoint their members through the pool and requeue —
//! and a `drain` marker seals the journal. `SIGKILL` gets no courtesy,
//! and needs none: on restart, recovery replays the journal, closes jobs
//! whose every adapter has a journaled digest, and re-submits the rest —
//! resuming mid-budget from preemption checkpoints where they exist and
//! from step 0 where they don't. Both paths land on bit-identical
//! trajectories (the repo-wide determinism invariant), so the combined
//! digest after a crash equals the uninterrupted run's.

pub mod http;
pub mod journal;
pub mod tenant;

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::ResourceMonitor;
use crate::config::{pool, LoraConfig};
use crate::costmodel::{ExecMode, Pack};
use crate::engine::CheckpointPool;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::session::{Event, Policy, Session};
use crate::trace::{event_to_json, AdapterDigest, SessionDigest};
use crate::train::{AdapterReport, MemberResume, TrainOptions};
use crate::util::json::Json;

use http::{EventRing, Handler, Request, Response, Server};
use journal::{Journal, Meta, Submission};
use tenant::FairShare;

/// Daemon launch configuration (`plora serve --daemon`).
#[derive(Debug, Clone)]
pub struct DaemonOpts {
    pub model: String,
    pub gpus: usize,
    /// State directory: journal, checkpoint pool, `daemon.addr`.
    pub dir: PathBuf,
    /// Control-plane port on 127.0.0.1; 0 picks an ephemeral port.
    pub port: u16,
    pub options: TrainOptions,
    pub policy: Policy,
    pub elastic: bool,
    pub rebucket: bool,
}

/// Lifecycle of one submitted job as the control plane reports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

fn state_name(s: JobState) -> &'static str {
    match s {
        JobState::Queued => "queued",
        JobState::Running => "running",
        JobState::Done => "done",
        JobState::Failed => "failed",
        JobState::Cancelled => "cancelled",
    }
}

/// Control-plane view of one job.
#[derive(Debug, Clone)]
struct JobView {
    job: usize,
    token: String,
    tenant: String,
    state: JobState,
    error: Option<String>,
    priority: i32,
    /// Adapter (config) ids this job owns.
    adapters: Vec<usize>,
    /// Adapter ids with a journaled digest.
    finished: BTreeSet<usize>,
}

/// Everything guarded by the daemon's primary lock. The [`Session`] lives
/// under its own separate mutex; the two are never held simultaneously
/// (admission journals under `Inner`, *then* submits under the session
/// lock — see the durability ordering in the module docs).
struct Inner {
    journal: Journal,
    fair: FairShare,
    jobs: BTreeMap<usize, JobView>,
    /// Idempotency token → job id.
    tokens: BTreeMap<String, usize>,
    /// Adapter id → owning job id (adapters can *finish* under a different
    /// session job when elastic admission absorbs them into a running pack).
    owner: BTreeMap<usize, usize>,
    /// Job id → fair-share start tag (feeds [`FairShare::complete`]).
    tags: BTreeMap<usize, f64>,
    next_job: usize,
    next_adapter: usize,
}

struct Daemon {
    inner: Mutex<Inner>,
    session: Mutex<Session>,
    /// Serialized session events, in emission order — the long-poll log.
    /// A fixed-capacity ring with a monotone cursor ([`EVENT_LOG_CAP`]):
    /// memory stays bounded on a long-lived daemon, and clients that fall
    /// off the tail see an explicit `truncated` marker.
    events: Mutex<EventRing>,
    events_cv: Condvar,
    /// Journaled digests of every finished adapter (the crash-exact oracle).
    digests: Mutex<BTreeMap<usize, AdapterDigest>>,
    options: TrainOptions,
    stop: Arc<AtomicBool>,
}

/// SIGTERM/SIGINT latch. Only an atomic store happens in the handler.
static TERM: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_sig: i32) {
    TERM.store(true, Ordering::SeqCst);
}

extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
}

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

/// Long-poll event-log capacity. Generous for any poll cadence a client
/// uses (the CI suites emit a few hundred events per session), small
/// enough that a daemon emitting events for weeks stays at constant
/// memory; laggards past the cap see `truncated: true` and re-sync.
const EVENT_LOG_CAP: usize = 8192;

/// Run the daemon until SIGTERM/SIGINT or `POST /v1/shutdown`. Returns
/// after a clean drain (journal sealed, every running pack checkpointed).
pub fn run(rt: Arc<Runtime>, opts: DaemonOpts) -> Result<()> {
    std::fs::create_dir_all(&opts.dir)
        .with_context(|| format!("mkdir {}", opts.dir.display()))?;
    let journal_path = opts.dir.join("journal.log");
    let recovered = journal::recover(&journal_path)?;
    for w in &recovered.warnings {
        eprintln!("daemon: recovery: {w}");
    }
    let meta = Meta {
        model: opts.model.clone(),
        gpus: opts.gpus,
        policy: opts.policy,
        elastic: opts.elastic,
        rebucket: opts.rebucket,
        options: opts.options.clone(),
    };
    if let Some(m) = &recovered.meta {
        // Model and training options seed every trajectory; silently
        // changing them under an existing journal would make "recovered"
        // digests incomparable to the originals.
        if m.model != meta.model {
            bail!(
                "journal {} was recorded for model '{}', daemon started with '{}'",
                journal_path.display(),
                m.model,
                meta.model
            );
        }
        if m.options != meta.options {
            bail!(
                "journal {} was recorded under different training options; \
                 refusing to mix trajectories (use a fresh --dir)",
                journal_path.display()
            );
        }
        for (name, old, new) in [
            ("gpus", m.gpus.to_string(), meta.gpus.to_string()),
            ("policy", format!("{:?}", m.policy), format!("{:?}", meta.policy)),
            ("elastic", m.elastic.to_string(), meta.elastic.to_string()),
            ("rebucket", m.rebucket.to_string(), meta.rebucket.to_string()),
        ] {
            if old != new {
                eprintln!(
                    "daemon: {name} changed ({old} -> {new}); results are \
                     schedule-invariant, timing will differ"
                );
            }
        }
    }

    let ckpt = CheckpointPool::new(&opts.dir.join("ckpt"), rt.clone())?;
    let mut session =
        Session::new(rt, ResourceMonitor::new(&pool::CPU_SIM, opts.gpus), &opts.model);
    session.options = opts.options.clone();
    session.rebucket = opts.rebucket;
    session.set_policy(opts.policy);
    session.set_elastic(opts.elastic);
    session.checkpoints = Some(ckpt.clone());
    // Subscribe before any submission so recovery-resubmitted jobs stream
    // their events like fresh ones.
    let ev_rx = session.subscribe();
    let rep_rx = session.subscribe_reports();

    let mut journal = Journal::open(&journal_path)?;
    if recovered.meta.is_none() {
        journal.meta(&meta)?;
    }

    // Rebuild fair-share state and job views from the journal.
    let mut inner = Inner {
        journal,
        fair: FairShare::new(),
        jobs: BTreeMap::new(),
        tokens: BTreeMap::new(),
        owner: BTreeMap::new(),
        tags: BTreeMap::new(),
        next_job: recovered.next_job_id(),
        next_adapter: recovered.next_adapter_id(),
    };
    for sub in &recovered.submissions {
        inner.fair.set_weight(&sub.tenant, sub.weight);
        let tag = inner.fair.admit(&sub.tenant, job_cost(&opts.options, &sub.configs));
        inner.tags.insert(sub.job, tag);
        let state = if recovered.cancelled.contains(&sub.job) {
            JobState::Cancelled
        } else if recovered.failed.contains_key(&sub.job) {
            JobState::Failed
        } else if recovered.done.contains(&sub.job) {
            JobState::Done
        } else {
            JobState::Queued
        };
        let adapters: Vec<usize> = sub.configs.iter().map(|c| c.id).collect();
        let finished: BTreeSet<usize> = adapters
            .iter()
            .copied()
            .filter(|id| recovered.digests.contains_key(id))
            .collect();
        for &id in &adapters {
            inner.owner.insert(id, sub.job);
        }
        inner.tokens.insert(sub.token.clone(), sub.job);
        inner.jobs.insert(
            sub.job,
            JobView {
                job: sub.job,
                token: sub.token.clone(),
                tenant: sub.tenant.clone(),
                state,
                error: recovered.failed.get(&sub.job).cloned(),
                priority: sub.priority,
                adapters,
                finished,
            },
        );
    }
    // Served work advances the virtual clock (order-independent: max).
    for job in recovered.done.iter().chain(recovered.failed.keys()) {
        if let Some(&tag) = inner.tags.get(job) {
            inner.fair.complete(tag);
        }
    }

    // Re-submit unfinished jobs: only the adapters without a journaled
    // digest, resuming mid-budget where a preemption checkpoint exists.
    let mut resubmitted = 0usize;
    let mut resumed = 0usize;
    for sub in &recovered.submissions {
        let view_state = inner.jobs[&sub.job].state;
        if view_state != JobState::Queued {
            continue;
        }
        let remaining: Vec<LoraConfig> = sub
            .configs
            .iter()
            .filter(|c| !recovered.digests.contains_key(&c.id))
            .cloned()
            .collect();
        if remaining.is_empty() {
            // Every adapter finished but the crash beat the job_done
            // record; close it now.
            inner.journal.job_done(sub.job)?;
            inner.jobs.get_mut(&sub.job).unwrap().state = JobState::Done;
            continue;
        }
        let mut resume: Vec<(usize, MemberResume)> = vec![];
        for c in &remaining {
            if ckpt.has_resume(&opts.model, c.id) {
                resume.push((c.id, ckpt.load_resume(&opts.model, c.id)?));
            }
        }
        resumed += resume.len();
        let job = PlannedJob {
            id: sub.job,
            pack: Pack::new(remaining),
            d: sub.d,
            s: 0, // depth inherits PLORA_STAGES; digests are depth-invariant
            mode: sub.mode,
        };
        session.submit_planned_resume(job, sub.priority, resume)?;
        resubmitted += 1;
    }
    if !recovered.submissions.is_empty() {
        println!(
            "daemon: recovered {} jobs from {} ({} finished, {} resubmitted, \
             {} adapters resuming mid-budget)",
            recovered.submissions.len(),
            journal_path.display(),
            recovered.done.len(),
            resubmitted,
            resumed,
        );
    }

    let daemon = Arc::new(Daemon {
        inner: Mutex::new(inner),
        session: Mutex::new(session),
        events: Mutex::new(EventRing::new(EVENT_LOG_CAP)),
        events_cv: Condvar::new(),
        digests: Mutex::new(recovered.digests),
        options: opts.options.clone(),
        stop: Arc::new(AtomicBool::new(false)),
    });

    spawn_event_pump(Arc::clone(&daemon), ev_rx);
    spawn_report_pump(Arc::clone(&daemon), rep_rx);

    unsafe {
        signal(SIGTERM, on_term as usize);
        signal(SIGINT, on_term as usize);
    }

    let server = Server::bind(opts.port)?;
    let addr = server.addr;
    let addr_file = opts.dir.join("daemon.addr");
    std::fs::write(&addr_file, addr.to_string())
        .with_context(|| format!("write {}", addr_file.display()))?;
    println!("daemon: listening on http://{addr} (state in {})", opts.dir.display());
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    let handler_daemon = Arc::clone(&daemon);
    let handler: Handler = Arc::new(move |req: &Request| handler_daemon.route(req));
    let http_stop = Arc::clone(&daemon.stop);
    let http_thread = std::thread::spawn(move || server.serve(handler, http_stop));

    while !TERM.load(Ordering::SeqCst) && !daemon.stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }

    // Graceful drain: stop admitting, checkpoint every running pack
    // through the pool, seal the journal.
    println!("daemon: draining (checkpointing running packs)");
    daemon.stop.store(true, Ordering::SeqCst);
    {
        let mut session = daemon.session.lock().unwrap();
        session.suspend();
        session.wait_quiesced();
    }
    daemon.inner.lock().unwrap().journal.drain()?;
    let _ = std::fs::remove_file(&addr_file);
    match http_thread.join() {
        Ok(r) => r?,
        Err(_) => eprintln!("daemon: control-plane thread panicked"),
    }
    println!("daemon: drained cleanly");
    Ok(())
}

/// One job's admission cost for fair share: its total training steps.
fn job_cost(options: &TrainOptions, configs: &[LoraConfig]) -> f64 {
    configs.iter().map(|c| options.budget.steps(c.batch)).sum::<usize>() as f64
}

fn spawn_event_pump(d: Arc<Daemon>, rx: mpsc::Receiver<Event>) {
    std::thread::spawn(move || {
        for ev in rx {
            d.on_event(&ev);
        }
    });
}

fn spawn_report_pump(d: Arc<Daemon>, rx: mpsc::Receiver<(usize, AdapterReport)>) {
    std::thread::spawn(move || {
        for (host_job, report) in rx {
            d.on_report(host_job, &report);
        }
    });
}

impl Daemon {
    /// Append a session event to the long-poll log and fold job lifecycle
    /// transitions into the control-plane views. Terminal states
    /// (`Cancelled`, `Failed`, `Done`) are never overridden — a cancel
    /// that races the final `JobFinished` stays a cancel.
    fn on_event(&self, ev: &Event) {
        {
            let mut log = self.events.lock().unwrap();
            log.push(event_to_json(ev));
            self.events_cv.notify_all();
        }
        match ev {
            Event::JobStarted { job, .. } => {
                let mut inner = self.inner.lock().unwrap();
                if let Some(v) = inner.jobs.get_mut(job) {
                    if v.state == JobState::Queued {
                        v.state = JobState::Running;
                    }
                }
            }
            Event::JobFinished { job, .. } => {
                // `JobFinished` alone does not close the view: an
                // elastically absorbed job emits a zero-adapter finish
                // while its adapters ride another pack. Closure requires
                // every owned adapter's digest (checked in maybe_close).
                let mut inner = self.inner.lock().unwrap();
                maybe_close(&mut inner, *job);
            }
            Event::JobFailed { job, error, .. } => {
                let mut inner = self.inner.lock().unwrap();
                let Some(v) = inner.jobs.get_mut(job) else { return };
                if matches!(v.state, JobState::Cancelled | JobState::Done | JobState::Failed)
                {
                    return;
                }
                v.state = JobState::Failed;
                v.error = Some(error.clone());
                if let Err(e) = inner.journal.job_failed(*job, error) {
                    eprintln!("daemon: journal job_failed({job}): {e}");
                }
                // A failed job consumed service; advance the vclock.
                if let Some(&tag) = inner.tags.get(job) {
                    inner.fair.complete(tag);
                }
            }
            _ => {}
        }
    }

    /// A finished adapter's report arrived (its checkpoint-pool write
    /// already happened, session-side): journal its digest, then fold it
    /// into its *owning* job's view — `host_job` is where it ran, which
    /// differs from where it was submitted after elastic absorption.
    fn on_report(&self, host_job: usize, report: &AdapterReport) {
        let id = report.config.id;
        let digest = AdapterDigest::of_report(report);
        self.digests.lock().unwrap().insert(id, digest.clone());
        let mut inner = self.inner.lock().unwrap();
        let owner = inner.owner.get(&id).copied().unwrap_or(host_job);
        if let Err(e) = inner.journal.adapter_done(owner, id, &digest) {
            eprintln!("daemon: journal adapter_done({owner}, {id}): {e}");
        }
        if let Some(v) = inner.jobs.get_mut(&owner) {
            v.finished.insert(id);
        }
        maybe_close(&mut inner, owner);
    }

    /// Control-plane router.
    fn route(&self, req: &Request) -> Response {
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["v1", "health"]) => self.health(),
            ("POST", ["v1", "jobs"]) => self.submit(req),
            ("GET", ["v1", "jobs"]) => self.list(),
            ("GET", ["v1", "jobs", id]) => match id.parse::<usize>() {
                Ok(id) => self.status(id),
                Err(_) => Response::err(400, format!("bad job id '{id}'")),
            },
            ("POST", ["v1", "jobs", id, "cancel"]) => match id.parse::<usize>() {
                Ok(id) => self.cancel(id),
                Err(_) => Response::err(400, format!("bad job id '{id}'")),
            },
            ("GET", ["v1", "events"]) => self.events(req),
            ("GET", ["v1", "digest"]) => self.digest(),
            ("POST", ["v1", "shutdown"]) => {
                self.stop.store(true, Ordering::SeqCst);
                Response::ok(Json::obj(vec![("stopping", Json::Bool(true))]))
            }
            (m, _) if m != "GET" && m != "POST" => {
                Response::err(405, format!("method {m} not allowed"))
            }
            _ => Response::err(404, format!("no route {} {}", req.method, req.path)),
        }
    }

    fn health(&self) -> Response {
        let inner = self.inner.lock().unwrap();
        let queued =
            inner.jobs.values().filter(|v| v.state == JobState::Queued).count();
        let running =
            inner.jobs.values().filter(|v| v.state == JobState::Running).count();
        Response::ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("jobs", Json::num(inner.jobs.len() as f64)),
            ("queued", Json::num(queued as f64)),
            ("running", Json::num(running as f64)),
        ]))
    }

    /// `POST /v1/jobs`: admit one job. Body:
    /// `{tenant?, weight?, token?, d?, mode?, adapters: [{task, rank?,
    /// batch?, lr?, alpha_ratio?}]}`. The journal record is fsync'd before
    /// the session sees the job (durable admission), and a re-sent
    /// idempotency token re-acks the original admission.
    fn submit(&self, req: &Request) -> Response {
        let Some(body) = &req.body else {
            return Response::err(400, "submit: JSON body required");
        };
        let tenant = body
            .field("tenant")
            .ok()
            .and_then(|t| t.as_str())
            .unwrap_or("default")
            .to_string();
        let weight =
            body.field("weight").ok().and_then(|w| w.as_f64()).unwrap_or(1.0);
        let d = body.field("d").ok().and_then(|v| v.as_usize()).unwrap_or(1);
        let mode = match body.field("mode").ok().and_then(|m| m.as_str()) {
            None | Some("packed") => ExecMode::Packed,
            Some("sequential") => ExecMode::Sequential,
            Some(other) => {
                return Response::err(400, format!("submit: unknown mode '{other}'"))
            }
        };
        let Some(specs) = body.field("adapters").ok().and_then(|a| a.as_arr()) else {
            return Response::err(400, "submit: 'adapters' array required");
        };
        if specs.is_empty() {
            return Response::err(400, "submit: empty adapter list");
        }

        let (planned, priority, view_json) = {
            let mut inner = self.inner.lock().unwrap();
            // Idempotent re-submit: same token re-acks the original job.
            if let Some(token) = body.field("token").ok().and_then(|t| t.as_str()) {
                if let Some(&job) = inner.tokens.get(token) {
                    let v = &inner.jobs[&job];
                    let mut fields = view_fields(v);
                    fields.push(("deduped", Json::Bool(true)));
                    return Response::ok(Json::obj(fields));
                }
            }
            let job_id = inner.next_job;
            let mut configs = vec![];
            for (i, s) in specs.iter().enumerate() {
                let Some(task) = s.field("task").ok().and_then(|t| t.as_str()) else {
                    return Response::err(400, format!("submit: adapter {i}: 'task' required"));
                };
                configs.push(LoraConfig {
                    id: inner.next_adapter + i,
                    task: task.to_string(),
                    rank: s.field("rank").ok().and_then(|v| v.as_usize()).unwrap_or(8),
                    batch: s.field("batch").ok().and_then(|v| v.as_usize()).unwrap_or(1),
                    lr: s.field("lr").ok().and_then(|v| v.as_f64()).unwrap_or(2e-3),
                    alpha_ratio: s
                        .field("alpha_ratio")
                        .ok()
                        .and_then(|v| v.as_f64())
                        .unwrap_or(1.0),
                });
            }
            let token = body
                .field("token")
                .ok()
                .and_then(|t| t.as_str())
                .map(str::to_string)
                .unwrap_or_else(|| format!("job-{job_id}"));
            inner.fair.set_weight(&tenant, weight);
            let tag = inner.fair.admit(&tenant, job_cost(&self.options, &configs));
            let priority = FairShare::priority(tag);
            let sub = Submission {
                token: token.clone(),
                tenant: tenant.clone(),
                weight,
                job: job_id,
                priority,
                d,
                mode,
                configs: configs.clone(),
            };
            // Durable admission: fsync the submit record BEFORE the
            // session sees the job. Crash in the gap => recovery
            // re-submits. The reverse order could run (and even finish)
            // a job that no journal remembers.
            if let Err(e) = inner.journal.submit(&sub) {
                return Response::err(500, format!("journal: {e}"));
            }
            inner.next_job = job_id + 1;
            inner.next_adapter += configs.len();
            inner.tags.insert(job_id, tag);
            inner.tokens.insert(token.clone(), job_id);
            let adapters: Vec<usize> = configs.iter().map(|c| c.id).collect();
            for &id in &adapters {
                inner.owner.insert(id, job_id);
            }
            let view = JobView {
                job: job_id,
                token,
                tenant: tenant.clone(),
                state: JobState::Queued,
                error: None,
                priority,
                adapters,
                finished: BTreeSet::new(),
            };
            let vj = Json::obj(view_fields(&view));
            inner.jobs.insert(job_id, view);
            let planned =
                PlannedJob { id: job_id, pack: Pack::new(configs), d, s: 0, mode };
            (planned, priority, vj)
        };

        let job_id = planned.id;
        let submitted = self.session.lock().unwrap().submit_planned_at(planned, priority);
        if let Err(e) = submitted {
            let mut inner = self.inner.lock().unwrap();
            let msg = e.to_string();
            if let Err(je) = inner.journal.job_failed(job_id, &msg) {
                eprintln!("daemon: journal job_failed({job_id}): {je}");
            }
            if let Some(v) = inner.jobs.get_mut(&job_id) {
                v.state = JobState::Failed;
                v.error = Some(msg.clone());
            }
            return Response::err(400, format!("submit: {msg}"));
        }
        Response::ok(view_json)
    }

    fn list(&self) -> Response {
        let inner = self.inner.lock().unwrap();
        Response::ok(Json::obj(vec![(
            "jobs",
            Json::arr(inner.jobs.values().map(|v| Json::obj(view_fields(v)))),
        )]))
    }

    fn status(&self, job: usize) -> Response {
        let inner = self.inner.lock().unwrap();
        match inner.jobs.get(&job) {
            Some(v) => Response::ok(Json::obj(view_fields(v))),
            None => Response::err(404, format!("no job {job}")),
        }
    }

    /// `POST /v1/jobs/{id}/cancel`. The view flips to `Cancelled` (and the
    /// journal records it) *before* the session is told — the session's
    /// follow-up `JobFinished` event then cannot overwrite the state.
    fn cancel(&self, job: usize) -> Response {
        {
            let mut inner = self.inner.lock().unwrap();
            let Some(v) = inner.jobs.get_mut(&job) else {
                return Response::err(404, format!("no job {job}"));
            };
            if !matches!(v.state, JobState::Queued | JobState::Running) {
                return Response::err(
                    409,
                    format!("job {job} is already {}", state_name(v.state)),
                );
            }
            v.state = JobState::Cancelled;
            if let Err(e) = inner.journal.cancelled(job) {
                eprintln!("daemon: journal cancelled({job}): {e}");
            }
        }
        let found = self.session.lock().unwrap().cancel(job);
        Response::ok(Json::obj(vec![
            ("job", Json::num(job as f64)),
            ("cancelled", Json::Bool(true)),
            // False when the job slipped to completion in the race window;
            // finished adapters keep their digests either way.
            ("interrupted", Json::Bool(found)),
        ]))
    }

    /// `GET /v1/events?since=N&wait=MS`: the session event stream as
    /// recorded JSON (the same vocabulary traces use). Long-polls up to
    /// `wait` ms for events past `since`, then returns what exists.
    /// Cursors are monotone across the bounded ring: `next` always equals
    /// the total emission count, and a `since` that fell off the ring's
    /// tail returns the surviving suffix with `truncated: true`.
    fn events(&self, req: &Request) -> Response {
        let since = req
            .query
            .get("since")
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0);
        let wait_ms = req
            .query
            .get("wait")
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(0)
            .min(60_000);
        let deadline = Instant::now() + Duration::from_millis(wait_ms);
        let mut log = self.events.lock().unwrap();
        while log.end() <= since {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            log = self.events_cv.wait_timeout(log, left).unwrap().0;
        }
        let (events, truncated) = log.since(since);
        Response::ok(Json::obj(vec![
            ("next", Json::num(log.end() as f64)),
            ("events", Json::Arr(events)),
            ("truncated", Json::Bool(truncated)),
        ]))
    }

    /// `GET /v1/digest`: the combined [`SessionDigest`] over every
    /// finished adapter — the bit-exact oracle crash-recovery tests
    /// compare across kill/restart boundaries.
    fn digest(&self) -> Response {
        let adapters = self.digests.lock().unwrap().clone();
        Response::ok(SessionDigest { adapters }.to_json())
    }
}

/// Close a job's view once every adapter it owns has a digest. Called on
/// `JobFinished` *and* after each adapter report: an elastically absorbed
/// job has no own `JobFinished` with adapters — its last report closes it.
fn maybe_close(inner: &mut Inner, job: usize) {
    let Some(v) = inner.jobs.get(&job) else { return };
    if !matches!(v.state, JobState::Queued | JobState::Running) {
        return;
    }
    if !v.adapters.iter().all(|a| v.finished.contains(a)) {
        return;
    }
    if let Err(e) = inner.journal.job_done(job) {
        eprintln!("daemon: journal job_done({job}): {e}");
    }
    inner.jobs.get_mut(&job).unwrap().state = JobState::Done;
    if let Some(&tag) = inner.tags.get(&job) {
        inner.fair.complete(tag);
    }
}

fn view_fields(v: &JobView) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("job", Json::num(v.job as f64)),
        ("token", Json::str(v.token.as_str())),
        ("tenant", Json::str(v.tenant.as_str())),
        ("state", Json::str(state_name(v.state))),
        ("priority", Json::num(v.priority as f64)),
        ("adapters", Json::arr(v.adapters.iter().map(|&a| Json::num(a as f64)))),
        ("finished", Json::arr(v.finished.iter().map(|&a| Json::num(a as f64)))),
    ];
    if let Some(e) = &v.error {
        fields.push(("error", Json::str(e.as_str())));
    }
    fields
}
