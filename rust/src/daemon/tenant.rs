//! Multi-tenant **weighted fair-share** admission, mapped onto the
//! session's priority scheduler.
//!
//! Classic start-time fair queuing (SFQ): each tenant carries a virtual
//! time that advances by `cost / weight` per admitted job, and a job's
//! *start tag* is `max(global_vclock, tenant_vtime)`. Lower tag = earlier
//! virtual start = runs first, so a weight-3 tenant's tags advance a third
//! as fast as a weight-1 tenant's and it gets ~3× the throughput — while
//! the weight-1 tenant's tags stay finite, so it always completes
//! (no starvation: a heavy tenant's tags strictly increase past any fixed
//! light-tenant tag).
//!
//! The global vclock advances only when a job is **served**
//! ([`FairShare::complete`] with the job's own start tag) — never at
//! admission. Advancing it at admission would let one tenant's far-future
//! backlog tag drag every other tenant's next tag up to it, erasing the
//! weighting. Serving-time advancement is what SFQ prescribes: the vclock
//! tracks the virtual start of the work the server has actually reached,
//! so a tenant that joins (or returns from idle) enters *there* — it
//! neither banks credit for past idleness nor pays for other tenants'
//! queued-but-unserved backlog.
//!
//! Tags are a pure function of the admit/complete call sequence (no wall
//! clocks), so the daemon can reconstruct fair-share state from its
//! journal on restart. Recovery replays admissions in journal order and
//! then applies the completions; the reconstructed tags steer
//! *scheduling* only — trajectories and digests are bitwise invariant to
//! execution order, so fairness state never touches crash-exactness.

use std::collections::BTreeMap;

/// Start-time fair-queuing state across tenants.
#[derive(Debug, Default)]
pub struct FairShare {
    /// Virtual time the server has reached: the largest start tag among
    /// jobs served so far.
    vclock: f64,
    tenants: BTreeMap<String, Tenant>,
}

#[derive(Debug)]
struct Tenant {
    weight: f64,
    /// This tenant's virtual finish time: where its next job's tag starts.
    vtime: f64,
}

impl FairShare {
    pub fn new() -> FairShare {
        FairShare::default()
    }

    /// Set a tenant's weight (share of throughput relative to other
    /// tenants). Applies to jobs admitted from now on; clamped away from
    /// zero so `cost / weight` stays finite.
    pub fn set_weight(&mut self, tenant: &str, weight: f64) {
        let w = if weight.is_finite() && weight > 1e-6 { weight } else { 1e-6 };
        self.tenants
            .entry(tenant.to_string())
            .and_modify(|t| t.weight = w)
            .or_insert(Tenant { weight: w, vtime: 0.0 });
    }

    /// Admit one job of `cost` (total training steps) for `tenant` and
    /// return its start tag. An idle tenant re-enters at the current
    /// vclock (no banked credit), a busy one queues behind its own
    /// backlog.
    pub fn admit(&mut self, tenant: &str, cost: f64) -> f64 {
        let t = self
            .tenants
            .entry(tenant.to_string())
            .or_insert(Tenant { weight: 1.0, vtime: 0.0 });
        let tag = if self.vclock > t.vtime { self.vclock } else { t.vtime };
        t.vtime = tag + cost.max(0.0) / t.weight;
        tag
    }

    /// A job with start tag `tag` was served (finished or failed after
    /// running): advance the vclock to it. Cancelled-while-queued jobs are
    /// *not* reported here — the server never reached them.
    pub fn complete(&mut self, tag: f64) {
        if tag > self.vclock {
            self.vclock = tag;
        }
    }

    /// Map a start tag onto the session's `i32` priority scale (higher
    /// runs first): negate so earlier virtual starts win, scale by 1000 so
    /// fractional tag gaps survive the rounding.
    pub fn priority(tag: f64) -> i32 {
        (-(tag * 1000.0)).round().clamp(i32::MIN as f64, i32::MAX as f64) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Weights 3:1, equal-cost jobs admitted interleaved while none have
    /// been served: the heavy tenant gets exactly three tags strictly
    /// below the light tenant's second tag.
    #[test]
    fn weighted_interleave_is_three_to_one() {
        let mut f = FairShare::new();
        f.set_weight("heavy", 3.0);
        f.set_weight("light", 1.0);
        let mut h = vec![];
        let mut l = vec![];
        for _ in 0..4 {
            h.push(f.admit("heavy", 3.0));
            l.push(f.admit("light", 3.0));
        }
        assert_eq!(h, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(l, vec![0.0, 3.0, 6.0, 9.0]);
        let heavy_before = h.iter().filter(|&&t| t < l[1]).count();
        assert_eq!(heavy_before, 3, "3× the throughput between light's jobs");
    }

    /// A tenant spamming jobs cannot starve another: its tags strictly
    /// increase, so only finitely many outrank any fixed tag.
    #[test]
    fn no_starvation() {
        let mut f = FairShare::new();
        f.set_weight("spammer", 100.0);
        f.set_weight("victim", 1.0);
        let victim_tag = f.admit("victim", 10.0);
        let mut last = -1.0;
        let mut outranking = 0;
        for _ in 0..10_000 {
            let t = f.admit("spammer", 10.0);
            assert!(t > last, "spammer tags must strictly increase");
            last = t;
            if t <= victim_tag {
                outranking += 1;
            }
        }
        assert!(outranking <= 1, "only the tied first job may share the victim's tag");
        assert!(last > victim_tag, "spammer eventually queues behind the victim");
    }

    /// Tags are a pure function of the admit/complete sequence — the
    /// property journal-based recovery depends on.
    #[test]
    fn tags_replay_deterministically() {
        let run = || {
            let mut f = FairShare::new();
            let mut tags = vec![];
            for (tenant, w, cost) in
                [("a", 2.0, 32.0), ("b", 1.0, 64.0), ("a", 2.0, 32.0), ("b", 1.0, 16.0)]
            {
                f.set_weight(tenant, w);
                let t = f.admit(tenant, cost);
                f.complete(t);
                tags.push(t.to_bits());
            }
            tags
        };
        assert_eq!(run(), run());
    }

    /// An idle tenant re-enters at the served vclock, not at 0 — idleness
    /// is not banked as a priority monopoly over busy tenants.
    #[test]
    fn idle_tenant_reenters_at_vclock() {
        let mut f = FairShare::new();
        f.set_weight("busy", 1.0);
        f.set_weight("idle", 1.0);
        for _ in 0..5 {
            let t = f.admit("busy", 10.0);
            f.complete(t);
        }
        let tag = f.admit("idle", 10.0);
        assert_eq!(tag, 40.0, "re-enter at the served vclock (busy's last tag)");
    }

    /// Queued-but-unserved backlog must NOT drag other tenants' tags up —
    /// the regression the admission-time-vclock design would cause.
    #[test]
    fn unserved_backlog_does_not_inflate_other_tenants() {
        let mut f = FairShare::new();
        f.set_weight("a", 1.0);
        f.set_weight("b", 1.0);
        let _big = f.admit("b", 1000.0); // tag 0, b.vtime = 1000, unserved
        let a1 = f.admit("a", 10.0);
        assert_eq!(a1, 0.0, "b's backlog is queued, not served; a starts at 0");
    }

    #[test]
    fn priority_orders_lower_tags_first() {
        let hi = FairShare::priority(0.5);
        let lo = FairShare::priority(2.0);
        assert!(hi > lo, "earlier virtual start must map to higher priority");
        // Extreme tags saturate instead of wrapping.
        assert_eq!(FairShare::priority(1e300), i32::MIN);
        assert_eq!(FairShare::priority(-1e300), i32::MAX);
    }
}
