//! The **Checkpoint Pool** (§4, Figure 3): every adapter of a finished
//! packed job is saved — at its *true* rank, sliced out of the padded pack
//! tensors — together with a JSON sidecar of its configuration and metrics.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::planner::PlannedJob;
use crate::runtime::tensor_file;
use crate::runtime::{HostTensor, MemberState, Runtime, TrainState, LORA_ORDER};
use crate::train::{AdapterReport, JobReport, MemberResume};
use crate::util::json::Json;

/// Directory of finished-adapter checkpoints.
#[derive(Clone)]
pub struct CheckpointPool {
    pub dir: PathBuf,
    runtime: Arc<Runtime>,
}

impl CheckpointPool {
    pub fn new(dir: &Path, runtime: Arc<Runtime>) -> Result<CheckpointPool> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        Ok(CheckpointPool { dir: dir.to_path_buf(), runtime })
    }

    fn paths(&self, model: &str, config_id: usize) -> (PathBuf, PathBuf) {
        let stem = self.dir.join(format!("{model}_cfg{config_id}"));
        (stem.with_extension("bin"), stem.with_extension("json"))
    }

    /// Save one finished adapter's metadata sidecar (the session calls
    /// this at the adapter's completion boundary — possibly mid-job, right
    /// before a re-bucket drops its slot). Pair with
    /// [`CheckpointPool::save_state`] for the tensor checkpoint.
    pub fn save_adapter(&self, model: &str, job_id: usize, adapter: &AdapterReport) -> Result<()> {
        let (_bin, meta) = self.paths(model, adapter.config.id);
        let c = &adapter.config;
        let j = Json::obj(vec![
            ("model", Json::str(model)),
            ("job_id", Json::num(job_id as f64)),
            ("config_id", Json::num(c.id as f64)),
            ("task", Json::str(c.task.clone())),
            ("lr", Json::num(c.lr)),
            ("batch", Json::num(c.batch as f64)),
            ("rank", Json::num(c.rank as f64)),
            ("alpha_ratio", Json::num(c.alpha_ratio)),
            ("steps", Json::num(adapter.steps as f64)),
            ("final_loss", Json::num(adapter.final_loss as f64)),
            ("eval_loss", Json::num(adapter.eval_loss as f64)),
            ("eval_acc", Json::num(adapter.eval_acc as f64)),
            ("base_acc", Json::num(adapter.base_acc as f64)),
        ]);
        let mut s = String::new();
        j.write(&mut s);
        std::fs::write(&meta, s).with_context(|| format!("write {}", meta.display()))
    }

    /// Save every adapter of a finished job (metadata sidecars). For full
    /// tensor checkpoints use [`CheckpointPool::save_state`] from call
    /// sites that still hold the `TrainState`.
    pub fn save_job(&self, model: &str, job: &PlannedJob, report: &JobReport) -> Result<()> {
        for adapter in &report.adapters {
            self.save_adapter(model, job.id, adapter)?;
        }
        Ok(())
    }

    /// Save adapter tensors from a live `TrainState` (true-rank slices).
    pub fn save_state(
        &self,
        model: &str,
        state: &TrainState,
        slots: &[(usize, usize, usize)], // (slot, config_id, true_rank)
    ) -> Result<()> {
        for &(slot, config_id, rank) in slots {
            let tensors: Vec<(String, HostTensor)> = state.extract_adapter(slot, rank)?;
            let (bin, _) = self.paths(model, config_id);
            tensor_file::write_tensors(&bin, &tensors)?;
        }
        Ok(())
    }

    fn resume_paths(&self, model: &str, config_id: usize) -> (PathBuf, PathBuf) {
        let stem = self.dir.join(format!("{model}_cfg{config_id}_resume"));
        (stem.with_extension("bin"), stem.with_extension("json"))
    }

    /// Save a **preemption checkpoint**: the adapter's full training state
    /// (params + AdamW moments at true rank, per-adapter step counter) and
    /// the driver-side resume bookkeeping (steps done, base metrics, loss
    /// curve so far), so a preempted adapter can re-enter a pack — any
    /// pack — bit-identically (§4, DESIGN.md §10). Metrics not yet
    /// measured (a job preempted before its first step has no
    /// `first_loss`) are stored as JSON `null`, never `NaN`.
    pub fn save_resume(&self, model: &str, config_id: usize, r: &MemberResume) -> Result<()> {
        let (bin, meta) = self.resume_paths(model, config_id);
        let mut tensors: Vec<(String, HostTensor)> = vec![];
        for (name, t) in LORA_ORDER.iter().zip(&r.state.lora) {
            tensors.push((name.to_string(), t.clone()));
        }
        for (name, t) in LORA_ORDER.iter().zip(&r.state.m) {
            tensors.push((format!("m_{name}"), t.clone()));
        }
        for (name, t) in LORA_ORDER.iter().zip(&r.state.v) {
            tensors.push((format!("v_{name}"), t.clone()));
        }
        // The loss-curve samples ride as a (len, 2) tensor: (step, loss).
        let mut curve = Vec::with_capacity(r.curve.len() * 2);
        for &(step, loss) in &r.curve {
            curve.push(step as f32);
            curve.push(loss);
        }
        tensors.push(("curve".to_string(), HostTensor::f32(vec![r.curve.len(), 2], curve)?));
        tensor_file::write_tensors(&bin, &tensors)?;
        let opt = |x: f32| if x.is_finite() { Json::num(x as f64) } else { Json::Null };
        let j = Json::obj(vec![
            ("model", Json::str(model)),
            ("config_id", Json::num(config_id as f64)),
            ("rank", Json::num(r.state.rank as f64)),
            ("t", Json::num(r.state.t as f64)),
            ("steps_done", Json::num(r.steps_done as f64)),
            ("first_loss", opt(r.first_loss)),
            ("base_loss", opt(r.base_loss)),
            ("base_acc", opt(r.base_acc)),
        ]);
        let mut s = String::new();
        j.write(&mut s);
        std::fs::write(&meta, s).with_context(|| format!("write {}", meta.display()))
    }

    /// Whether a complete preemption checkpoint (tensors + sidecar) exists
    /// for this adapter — the probe `replay --from-checkpoint` and the
    /// daemon's crash recovery use to decide between resuming mid-budget
    /// and restarting from step 0 (both are bit-identical; resuming just
    /// skips the already-executed steps).
    pub fn has_resume(&self, model: &str, config_id: usize) -> bool {
        let (bin, meta) = self.resume_paths(model, config_id);
        bin.is_file() && meta.is_file()
    }

    /// Load a preemption checkpoint written by
    /// [`CheckpointPool::save_resume`].
    pub fn load_resume(&self, model: &str, config_id: usize) -> Result<MemberResume> {
        let (bin, meta) = self.resume_paths(model, config_id);
        let mut map = tensor_file::read_tensors(&bin)?;
        let curve_t = map.remove("curve");
        let mut take = |prefix: &str| -> Result<Vec<HostTensor>> {
            LORA_ORDER
                .iter()
                .map(|name| {
                    map.remove(&format!("{prefix}{name}")).ok_or_else(|| {
                        anyhow::anyhow!("{}: missing tensor {prefix}{name}", bin.display())
                    })
                })
                .collect()
        };
        let lora = take("")?;
        let m = take("m_")?;
        let v = take("v_")?;
        let mut curve = vec![];
        if let Some(t) = curve_t {
            let flat = t.as_f32()?;
            for pair in flat.chunks(2) {
                curve.push((pair[0] as usize, pair[1]));
            }
        }
        let s = std::fs::read_to_string(&meta)?;
        let j = Json::parse(&s).map_err(|e| anyhow::anyhow!("{}: {e:?}", meta.display()))?;
        let num = |k: &str| -> Result<f64> {
            j.field(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{}: '{k}' is not a number", meta.display()))
        };
        // Metrics stored as null (not yet measured) come back as NaN — the
        // driver's "unset" sentinel.
        let opt = |k: &str| -> f32 {
            j.field(k).ok().and_then(|f| f.as_f64()).map(|x| x as f32).unwrap_or(f32::NAN)
        };
        Ok(MemberResume {
            state: MemberState {
                rank: num("rank")? as usize,
                lora,
                m,
                v,
                t: num("t")? as f32,
            },
            steps_done: num("steps_done")? as usize,
            first_loss: opt("first_loss"),
            base_loss: opt("base_loss"),
            base_acc: opt("base_acc"),
            curve,
        })
    }

    /// Load a saved adapter's tensors.
    pub fn load(&self, model: &str, config_id: usize) -> Result<Vec<(String, HostTensor)>> {
        let (bin, _) = self.paths(model, config_id);
        let map = tensor_file::read_tensors(&bin)?;
        Ok(map.into_iter().collect())
    }

    /// Load a saved adapter's metadata JSON.
    pub fn load_meta(&self, model: &str, config_id: usize) -> Result<Json> {
        let (_, meta) = self.paths(model, config_id);
        let s = std::fs::read_to_string(&meta)?;
        Json::parse(&s).map_err(|e| anyhow::anyhow!("{}: {e:?}", meta.display()))
    }

    /// All saved checkpoints for a model (config ids).
    pub fn list(&self, model: &str) -> Vec<usize> {
        let prefix = format!("{model}_cfg");
        let mut out = vec![];
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                if let Some(rest) = name.strip_prefix(&prefix) {
                    if let Some(id) = rest.strip_suffix(".json").and_then(|s| s.parse().ok()) {
                        out.push(id);
                    }
                }
            }
        }
        out.sort();
        out
    }

    /// The runtime the pool belongs to (for adapter reloads).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelInfo;

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Arc::new(Runtime::load(&dir).unwrap()))
    }

    #[test]
    fn save_and_load_state_slices() {
        let Some(rt) = runtime() else { return };
        let dir = std::env::temp_dir().join("plora_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let pool = CheckpointPool::new(&dir, rt).unwrap();
        let mi = ModelInfo {
            name: "t".into(),
            vocab: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq: 8,
            params: 0,
            weights: String::new(),
        };
        let state = TrainState::init(&mi, 2, 4, 9);
        pool.save_state("t", &state, &[(0, 10, 2), (1, 11, 4)]).unwrap();
        let t10 = pool.load("t", 10).unwrap();
        assert_eq!(t10.len(), 14);
        let aq = t10.iter().find(|(n, _)| n == "a_q").unwrap();
        assert_eq!(aq.1.shape, vec![2, 8, 2]); // true rank 2
        let t11 = pool.load("t", 11).unwrap();
        let aq = t11.iter().find(|(n, _)| n == "a_q").unwrap();
        assert_eq!(aq.1.shape, vec![2, 8, 4]);
    }
}
