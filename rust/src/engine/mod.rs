//! The **LoRA Execution Engine** (§4, Figure 3): dequeues planned jobs
//! from the LoRA Job Queue, acquires devices from the Resource Monitor,
//! launches packed fine-tuning jobs concurrently on worker threads, and
//! saves every finished adapter into the Checkpoint Pool.
//!
//! Live mode runs real PJRT training (the AOT artifacts); the degree of
//! parallelism `d_j` is honored as a capacity allocation on the simulated
//! pool — on this machine all jobs share one CPU backend, so wall time
//! measures end-to-end composition, not hardware scaling (DESIGN.md §7).

pub mod checkpoint;

pub use checkpoint::CheckpointPool;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::ResourceMonitor;
use crate::costmodel::throughput::Calib;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::train::{run_pack_full, JobReport, TrainOptions};
use crate::util::threadpool::ThreadPool;

/// One finished job with its engine-side timeline.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: usize,
    pub devices: Vec<usize>,
    /// Seconds after engine start when the job launched / finished.
    pub start: f64,
    pub end: f64,
    pub report: JobReport,
}

/// Engine run summary.
#[derive(Debug)]
pub struct EngineReport {
    pub outcomes: Vec<JobOutcome>,
    pub makespan: f64,
    /// Live cost-model fit `(a, b, c)` of `t = a + b·tokens + c·n` over all
    /// profiled steps (§4: calibration from the first iterations).
    pub calib_fit: (f64, f64, f64),
}

impl EngineReport {
    pub fn total_adapters(&self) -> usize {
        self.outcomes.iter().map(|o| o.report.adapters.len()).sum()
    }
}

/// The execution engine.
pub struct Engine {
    pub runtime: Arc<Runtime>,
    pub monitor: ResourceMonitor,
    pub checkpoints: Option<CheckpointPool>,
    pub options: TrainOptions,
    /// Worker threads (≥ the max number of concurrent jobs).
    pub workers: usize,
}

impl Engine {
    pub fn new(runtime: Arc<Runtime>, monitor: ResourceMonitor) -> Engine {
        Engine {
            runtime,
            monitor,
            checkpoints: None,
            options: TrainOptions::default(),
            workers: 4,
        }
    }

    /// Run a queue of planned jobs to completion, FIFO with blocking device
    /// acquisition (jobs launch concurrently whenever capacity allows —
    /// "PLoRA will deploy multiple fine-tuning jobs concurrently, as long
    /// as the hardware pool has sufficient resources", §4).
    pub fn run(&self, model: &str, queue: &[PlannedJob]) -> Result<EngineReport> {
        let t0 = Instant::now();
        let pool = ThreadPool::new(self.workers.max(1));
        let (tx, rx) = mpsc::channel::<Result<JobOutcome>>();
        let errors = Arc::new(AtomicUsize::new(0));
        let outcomes = Arc::new(Mutex::new(Vec::<JobOutcome>::new()));

        for job in queue.iter().cloned() {
            // Acquire devices *before* spawning: preserves the queue order
            // (FIFO semantics of the LoRA Job Queue) and applies
            // backpressure when the pool is exhausted.
            let alloc = self.monitor.acquire(job.d)?;
            let start = t0.elapsed().as_secs_f64();
            let rt = self.runtime.clone();
            let monitor = self.monitor.clone();
            let ckpt = self.checkpoints.clone();
            let opts = self.options.clone();
            let model = model.to_string();
            let tx = tx.clone();
            let errors = errors.clone();
            let outcomes_ref = outcomes.clone();
            pool.spawn(move || {
                let result =
                    run_pack_full(&rt, &model, &job.pack.configs, &opts).and_then(|(report, state)| {
                        if let Some(ckpt) = &ckpt {
                            ckpt.save_job(&model, &job, &report)?;
                            let slots: Vec<(usize, usize, usize)> = job
                                .pack
                                .configs
                                .iter()
                                .enumerate()
                                .map(|(slot, c)| (slot, c.id, c.rank))
                                .collect();
                            ckpt.save_state(&model, &state, &slots)?;
                        }
                        Ok(JobOutcome {
                            job_id: job.id,
                            devices: alloc.devices.clone(),
                            start,
                            end: t0.elapsed().as_secs_f64(),
                            report,
                        })
                    });
                monitor.release(alloc);
                match result {
                    Ok(out) => outcomes_ref.lock().unwrap().push(out),
                    Err(e) => {
                        errors.fetch_add(1, Ordering::SeqCst);
                        let _ = tx.send(Err(e));
                    }
                }
            });
        }
        drop(tx);
        pool.join();

        if errors.load(Ordering::SeqCst) > 0 {
            let first = rx.into_iter().find_map(|r| r.err());
            return Err(first.unwrap_or_else(|| anyhow!("job failed")));
        }
        let mut outcomes = Arc::try_unwrap(outcomes)
            .map_err(|_| anyhow!("outcome collection still shared"))?
            .into_inner()
            .unwrap();
        outcomes.sort_by_key(|o| o.job_id);

        let makespan = outcomes.iter().map(|o| o.end).fold(0.0, f64::max);
        let samples: Vec<(f64, f64, f64)> =
            outcomes.iter().flat_map(|o| o.report.profile.iter().copied()).collect();
        let calib_fit = Calib::fit_live(&samples);
        Ok(EngineReport { outcomes, makespan, calib_fit })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pool::CPU_SIM;
    use crate::config::LoraConfig;
    use crate::costmodel::{ExecMode, Pack, TrainBudget};

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Arc::new(Runtime::load(&dir).unwrap()))
    }

    fn cfg(id: usize, task: &str) -> LoraConfig {
        LoraConfig { id, lr: 2e-3, batch: 1, rank: 8, alpha_ratio: 1.0, task: task.into() }
    }

    fn job(id: usize, d: usize, configs: Vec<LoraConfig>) -> PlannedJob {
        PlannedJob { id, pack: Pack::new(configs), d, mode: ExecMode::Packed }
    }

    /// Two jobs on a 2-slot pool run concurrently; a third waits its turn.
    #[test]
    fn engine_runs_queue_with_device_backpressure() {
        let Some(rt) = runtime() else { return };
        let mut engine = Engine::new(rt, ResourceMonitor::new(&CPU_SIM, 2));
        engine.options.budget = TrainBudget { dataset: 6, epochs: 1 };
        engine.options.eval_batches = 1;
        engine.options.log_every = 0;
        let queue = vec![
            job(0, 1, vec![cfg(0, "modadd")]),
            job(1, 1, vec![cfg(1, "parity")]),
            job(2, 2, vec![cfg(2, "copy"), cfg(3, "needle")]),
        ];
        let report = engine.run("nano", &queue).unwrap();
        assert_eq!(report.outcomes.len(), 3);
        assert_eq!(report.total_adapters(), 4);
        assert!(report.makespan > 0.0);
        // Job 2 needs both devices: it must start after one of job 0/1 ends.
        let j2 = &report.outcomes[2];
        let first_end = report.outcomes[..2].iter().map(|o| o.end).fold(f64::MAX, f64::min);
        assert!(
            j2.start >= first_end - 0.05,
            "job2 started at {:.3}s before capacity freed at {:.3}s",
            j2.start,
            first_end
        );
        assert_eq!(engine.monitor.available(), 2, "all devices returned");
    }

    /// Errors surface and the pool is not leaked.
    #[test]
    fn engine_propagates_job_errors_and_releases_devices() {
        let Some(rt) = runtime() else { return };
        let engine = Engine::new(rt, ResourceMonitor::new(&CPU_SIM, 2));
        // rank 99 has no artifact bucket -> run_pack fails.
        let bad = LoraConfig { id: 0, lr: 1e-3, batch: 1, rank: 99, alpha_ratio: 1.0, task: "copy".into() };
        let queue = vec![job(0, 1, vec![bad])];
        assert!(engine.run("nano", &queue).is_err());
        assert_eq!(engine.monitor.available(), 2);
    }
}
