//! The **LoRA Execution Engine** (§4, Figure 3) — now a thin compatibility
//! shim over [`crate::session::Session`]: `Engine::run` submits the whole
//! planned queue and drains it. The session supplies everything the old
//! batch engine had (FIFO admission with device backpressure, concurrent
//! packed jobs, checkpointing, live calibration) plus dynamic admission
//! and preemptive re-bucketing at adapter-completion boundaries; prefer it
//! directly for anything interactive.

pub mod checkpoint;

pub use checkpoint::CheckpointPool;
pub use crate::session::JobOutcome;

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::ResourceMonitor;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::session::{Policy, Session, SessionReport};
use crate::train::TrainOptions;

/// Engine run summary.
#[derive(Debug)]
pub struct EngineReport {
    pub outcomes: Vec<JobOutcome>,
    pub makespan: f64,
    /// Live cost-model fit `(a, b, c)` of `t = a + b·tokens + c·n` over all
    /// profiled steps (§4: calibration from the first iterations).
    pub calib_fit: (f64, f64, f64),
}

impl EngineReport {
    pub fn total_adapters(&self) -> usize {
        self.outcomes.iter().map(|o| o.report.adapters.len()).sum()
    }
}

/// The execution engine (batch shim over the session).
pub struct Engine {
    pub runtime: Arc<Runtime>,
    pub monitor: ResourceMonitor,
    pub checkpoints: Option<CheckpointPool>,
    pub options: TrainOptions,
    /// Preemptive re-bucketing at adapter-completion boundaries (on by
    /// default — the §4 behavior the cost model's `job_time` assumes).
    pub rebucket: bool,
    /// Queue policy the backing session dispatches under (default FIFO —
    /// the historical engine semantics).
    pub policy: Policy,
    /// Elastic mid-job admission of queued adapters (default off).
    pub elastic: bool,
}

impl Engine {
    pub fn new(runtime: Arc<Runtime>, monitor: ResourceMonitor) -> Engine {
        Engine {
            runtime,
            monitor,
            checkpoints: None,
            options: TrainOptions::default(),
            rebucket: true,
            policy: Policy::Fifo,
            elastic: false,
        }
    }

    /// Run a queue of planned jobs to completion: submit everything to a
    /// fresh session, drain, and repackage the report. Dispatch follows
    /// [`Engine::policy`] with device backpressure — "PLoRA will deploy
    /// multiple fine-tuning jobs concurrently, as long as the hardware
    /// pool has sufficient resources" (§4).
    pub fn run(&self, model: &str, queue: &[PlannedJob]) -> Result<EngineReport> {
        let report = self.run_session(model, queue)?;
        Ok(EngineReport {
            outcomes: report.outcomes,
            makespan: report.makespan,
            calib_fit: report.calib_fit,
        })
    }

    /// Like [`Engine::run`] but returns the session's full report (events,
    /// calibration detail) — what `--record` serializes into a trace.
    pub fn run_session(&self, model: &str, queue: &[PlannedJob]) -> Result<SessionReport> {
        let mut session = Session::new(self.runtime.clone(), self.monitor.clone(), model);
        session.options = self.options.clone();
        session.checkpoints = self.checkpoints.clone();
        session.rebucket = self.rebucket;
        session.set_policy(self.policy);
        session.set_elastic(self.elastic);
        for job in queue {
            session.submit_planned(job.clone())?;
        }
        session.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::pool::CPU_SIM;
    use crate::config::LoraConfig;
    use crate::costmodel::{ExecMode, Pack, TrainBudget};

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Arc::new(Runtime::load(&dir).unwrap()))
    }

    fn cfg(id: usize, task: &str) -> LoraConfig {
        LoraConfig { id, lr: 2e-3, batch: 1, rank: 8, alpha_ratio: 1.0, task: task.into() }
    }

    fn job(id: usize, d: usize, configs: Vec<LoraConfig>) -> PlannedJob {
        PlannedJob { id, pack: Pack::new(configs), d, s: 0, mode: ExecMode::Packed }
    }

    /// Two jobs on a 2-slot pool run concurrently; a third waits its turn.
    #[test]
    fn engine_runs_queue_with_device_backpressure() {
        let Some(rt) = runtime() else { return };
        let mut engine = Engine::new(rt, ResourceMonitor::new(&CPU_SIM, 2));
        engine.options.budget = TrainBudget { dataset: 6, epochs: 1 };
        engine.options.eval_batches = 1;
        engine.options.log_every = 0;
        let queue = vec![
            job(0, 1, vec![cfg(0, "modadd")]),
            job(1, 1, vec![cfg(1, "parity")]),
            job(2, 2, vec![cfg(2, "copy"), cfg(3, "needle")]),
        ];
        let report = engine.run("nano", &queue).unwrap();
        assert_eq!(report.outcomes.len(), 3);
        assert_eq!(report.total_adapters(), 4);
        assert!(report.makespan > 0.0);
        // Job 2 needs both devices: it must start after one of job 0/1 ends.
        let j2 = &report.outcomes[2];
        let first_end = report.outcomes[..2].iter().map(|o| o.end).fold(f64::MAX, f64::min);
        assert!(
            j2.start >= first_end - 0.05,
            "job2 started at {:.3}s before capacity freed at {:.3}s",
            j2.start,
            first_end
        );
        assert_eq!(engine.monitor.available(), 2, "all devices returned");
    }

    /// Errors surface and the pool is not leaked.
    #[test]
    fn engine_propagates_job_errors_and_releases_devices() {
        let Some(rt) = runtime() else { return };
        let engine = Engine::new(rt, ResourceMonitor::new(&CPU_SIM, 2));
        // rank 99 has no artifact bucket -> the job fails.
        let bad = LoraConfig {
            id: 0,
            lr: 1e-3,
            batch: 1,
            rank: 99,
            alpha_ratio: 1.0,
            task: "copy".into(),
        };
        let queue = vec![job(0, 1, vec![bad])];
        assert!(engine.run("nano", &queue).is_err());
        assert_eq!(engine.monitor.available(), 2);
    }
}
