//! # PLoRA — efficient LoRA hyperparameter tuning
//!
//! Reproduction of *"PLoRA: Efficient LoRA Hyperparameter Tuning for Large
//! Models"* (Yan, Wang, Jia, Venkataraman, Wang — cs.LG 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the coordinator — Appendix-A cost model, the
//!   ILP + DTM packing planner (§6), the live execution engine (§4), and a
//!   discrete-event simulator that regenerates the paper's figures at the
//!   original 8×A100 / 8×A10 scale.
//! - **L2/L1 (`python/compile/`)**: the packed multi-adapter TinyLM train
//!   step and the packed-LoRA Pallas kernels, AOT-lowered once to HLO text
//!   (`make artifacts`); Python is never on the request path.
//! - **Runtime**: [`runtime`] loads `artifacts/*.hlo.txt` via the PJRT CPU
//!   client (`xla` crate) and replays them from the Rust hot path.
//!
//! Entry points: [`planner::JobPlanner`] (Alg. 2), [`engine::Engine`]
//! (live packed fine-tuning), [`sim::Simulator`] (paper-scale makespan),
//! and the `plora` binary (`rust/src/main.rs`).

pub mod bench;
pub mod cluster;
pub mod engine;
pub mod runtime;
pub mod train;
pub mod config;
pub mod costmodel;
pub mod metrics;
pub mod planner;
pub mod search;
pub mod sim;
pub mod util;
