// Portable SIMD is still unstable; the `portable-simd` cargo feature
// (nightly-only) swaps the explicit-vector GEMM microkernel's lane type
// from the unrolled stable fallback to `std::simd::f32x8`. Results are
// bit-identical either way (DESIGN.md §14).
#![cfg_attr(feature = "portable-simd", feature(portable_simd))]

//! # PLoRA — efficient LoRA hyperparameter tuning
//!
//! Reproduction of *"PLoRA: Efficient LoRA Hyperparameter Tuning for Large
//! Models"* (Yan, Wang, Jia, Venkataraman, Wang — cs.LG 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the coordinator — Appendix-A cost model, the
//!   ILP + DTM packing planner (§6), the live execution engine (§4), and a
//!   discrete-event simulator that regenerates the paper's figures at the
//!   original 8×A100 / 8×A10 scale.
//! - **L2/L1 (`python/compile/`)**: the packed multi-adapter TinyLM train
//!   step and the packed-LoRA Pallas kernels, AOT-lowered once to HLO text
//!   (`make artifacts`, optional); Python is never on the request path.
//! - **Runtime**: [`runtime`] executes the artifact contract through a
//!   pluggable [`runtime::ExecutionBackend`]. The default **reference
//!   backend** interprets the packed-LoRA computations in pure Rust and
//!   synthesizes the manifest + base weights when `artifacts/` is absent,
//!   so everything runs end-to-end offline; with `--features pjrt` (and
//!   the `xla` crate available) the AOT `artifacts/*.hlo.txt` are replayed
//!   via the PJRT CPU client instead.
//!
//! Entry points: [`planner::JobPlanner`] (Alg. 2), [`session::Session`]
//! (the event-driven orchestrator: dynamic admission, adapter-completion
//! re-bucketing, streaming events), [`engine::Engine`] (compatibility shim
//! over the session), [`sim::Simulator`] (paper-scale makespan), and the
//! `plora` binary (`rust/src/main.rs`). Architecture and design rationale
//! live in `DESIGN.md`; user-facing docs in `README.md`.

pub mod bench;
pub mod cluster;
pub mod engine;
pub mod runtime;
pub mod train;
pub mod config;
pub mod costmodel;
pub mod daemon;
pub mod metrics;
pub mod planner;
pub mod search;
pub mod session;
pub mod sim;
pub mod trace;
pub mod util;
