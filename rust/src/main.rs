//! `plora` — the CLI leader process.
//!
//! Subcommands map onto the paper's workflow (Figure 3) and evaluation:
//!
//! ```text
//! plora plan     offline planning (Alg. 1+2): schedule a search space
//! plora sim      paper-scale makespan simulation (Figs. 4/6) per method
//! plora train    one live packed fine-tuning job on the PJRT runtime
//! plora sweep    live end-to-end sweep through planner + session
//! plora serve    session with a live event-stream progress renderer
//! plora replay   re-execute a recorded trace, assert bit-identical results
//! plora perf-budget  gate a BENCH_*.json against a committed snapshot
//! plora quality  quality tables (Tables 2/3/4/6 analogues)
//! plora kernels  packed-kernel micro-benchmarks, live (Tables 7/8)
//! plora calib    print the live cost-model fit for this machine
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use plora::cluster::ResourceMonitor;
use plora::config::{geometry, pool, LoraConfig, SearchSpace};
use plora::costmodel::{CostModel, TrainBudget};
use plora::engine::CheckpointPool;
use plora::metrics::{fmt_dur, fmt_x, Table};
use plora::planner::{max_gpu_plan, min_gpu_plan, sequential_plora_plan, JobPlanner};
use plora::runtime::{HostTensor, Runtime};
use plora::search;
use plora::session::{Event, Policy, Session};
use plora::sim::{SimOptions, Simulator};
use plora::trace::{perf, Trace, TraceRecorder};
use plora::train::{run_pack, TrainOptions};
use plora::util::cli::Args;
use plora::util::json::Json;

const USAGE: &str = "\
plora — efficient LoRA hyperparameter tuning (PLoRA reproduction)

USAGE: plora <subcommand> [flags]

  plan     --model <geom> --gpus N [--configs N] [--budget N]
  sim      --model <geom> --gpus N [--a10] [--qlora] [--noise S] [--policy P]
           [--elastic] [--grow-devices] [--tuner full|asha --eta N --rungs N]
  train    --model <tinylm> --task T [--rank R] [--lr X] [--batch B] [--steps N]
  sweep    --model <tinylm> --configs N [--gpus N] [--steps N] [--ckpt DIR]
           [--record PATH] [--tuner full|asha --eta N --rungs N]
           [--policy fifo|priority|preempt] [--elastic]
  serve    --model <tinylm> [--configs N] [--gpus N] [--steps N] [--no-rebucket]
           [--policy fifo|priority|preempt] [--elastic] [--record PATH]
           [--daemon --dir DIR --port P]  durable multi-tenant daemon mode
  submit   --task T [--task T2 ...] [--rank R] [--batch B] [--lr X] [--alpha A]
           [--tenant NAME --weight W] [--token TOK] [--d N] [--addr HOST:PORT]
  status   [job] [--digest] [--addr HOST:PORT]
  cancel   <job> [--addr HOST:PORT]
  replay   <trace.json> [--sim] [--from-checkpoint DIR]
  perf-budget  --current BENCH.json --baseline SNAPSHOT.json [--tolerance F]
           [--warn-only] [--update-baseline]
  quality  --model <tinylm> [--steps N] [--per-task N]
  kernels  [--ns 1,2,8,32] [--geoms attn,mlp] [--iters N]
  calib    --model <tinylm> [--steps N]

Geometries (plan/sim): qwen2.5-{3b,7b,14b,32b}, llama3.2-3b, llama3.1-8b,
or the TinyLM sizes nano/tiny/small/base. Live subcommands (train/sweep/
serve/quality/kernels/calib) take a TinyLM model and run on the default
pure-Rust reference backend. The PJRT/XLA runtime is opt-in: vendor the xla
crate, run `make artifacts`, build with --features pjrt (README 'Feature
matrix').";

fn main() {
    let args = Args::parse();
    let r = match args.subcommand.as_deref() {
        Some("plan") => cmd_plan(&args),
        Some("sim") => cmd_sim(&args),
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("status") => cmd_status(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("replay") => cmd_replay(&args),
        Some("perf-budget") => cmd_perf_budget(&args),
        Some("quality") => cmd_quality(&args),
        Some("kernels") => cmd_kernels(&args),
        Some("calib") => cmd_calib(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn geom_cm(args: &Args) -> Result<CostModel> {
    let model = args.get_or("model", "qwen2.5-7b");
    let g = geometry::geom(model).ok_or_else(|| anyhow!("unknown geometry '{model}'"))?;
    let prof = if args.flag("a10") { &pool::A10_24G } else { &pool::A100_40G };
    let mut g = g.clone();
    if args.flag("qlora") {
        g.base_bytes = 0.5; // 4-bit base (§7.5)
    }
    Ok(CostModel::new(&g, prof))
}

fn grid(args: &Args) -> Result<Vec<LoraConfig>> {
    let n = args.usize("configs", 120)?;
    let task = args.get_or("task", "modadd");
    let mut g = SearchSpace::default().grid(task);
    g.truncate(n);
    Ok(g)
}

fn budget(args: &Args) -> Result<TrainBudget> {
    Ok(TrainBudget { dataset: args.usize("budget", 256)?, epochs: args.usize("epochs", 3)? })
}

fn runtime() -> Result<Arc<Runtime>> {
    Ok(Arc::new(Runtime::load(&Runtime::default_dir())?))
}

/// Largest (rank, batch) any train bucket of `model` admits — live sweeps
/// must keep their sampled spaces inside the static bucket grid (nano tops
/// out at r=8, bs=2; tiny at r=32, bs=4).
fn bucket_caps(rt: &Runtime, model: &str) -> (usize, usize) {
    let buckets = rt.manifest.train_buckets(model);
    let max_r = buckets.iter().map(|b| b.1).max().unwrap_or(8);
    let max_bs = buckets.iter().map(|b| b.2).max().unwrap_or(1);
    (max_r, max_bs)
}

// ---------------------------------------------------------------------------

fn cmd_plan(args: &Args) -> Result<()> {
    let cm = geom_cm(args)?;
    let gpus = args.usize("gpus", 8)?;
    let configs = grid(args)?;
    let mut planner = JobPlanner::new(cm, gpus);
    planner.budget = budget(args)?;
    let plan = planner.plan(&configs)?;
    let profile = planner.cm.profile.name;
    let mut t = Table::new(
        &format!("PLoRA plan — {} configs on {gpus} x {profile}", configs.len()),
        &["job", "n", "r_pad", "d", "start", "end"],
    );
    for j in &plan.jobs {
        t.row(vec![
            j.job.id.to_string(),
            j.job.pack.n().to_string(),
            j.job.pack.r_pad().to_string(),
            j.job.d.to_string(),
            fmt_dur(j.start),
            fmt_dur(j.end),
        ]);
    }
    t.print();
    println!(
        "\nmakespan {}  AR bound {:.3}  occupancy {:.0}%  ilp calls {}  planned in {:.2}s",
        fmt_dur(plan.makespan),
        plan.ar_bound,
        plan.occupancy() * 100.0,
        plan.stats.ilp_calls,
        plan.plan_secs
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cm = geom_cm(args)?;
    let gpus = args.usize("gpus", 8)?;
    let configs = grid(args)?;
    let b = budget(args)?;
    let noise = args.f64("noise", 0.0)?;
    let sim = Simulator { cm: cm.clone(), budget: b, gpus };
    let opts = SimOptions {
        noise,
        seed: args.usize("seed", 42)? as u64,
        policy: args
            .get("policy")
            .and_then(Policy::parse)
            .unwrap_or(Policy::Fifo),
        elastic: args.flag("elastic"),
        grow_devices: args.flag("grow-devices"),
        tuner: match args.get("tuner") {
            Some("asha") => Some((args.usize("eta", 2)?, args.usize("rungs", 3)?)),
            Some("full") | None => None,
            Some(other) => bail!("unknown tuner '{other}' (full|asha)"),
        },
    };

    let run = |plan: &plora::planner::Plan| {
        let queue: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        sim.run_queue(&queue, &opts)
    };
    let min = run(&min_gpu_plan(&cm, &b, gpus, &configs)?);
    let max = run(&max_gpu_plan(&cm, &b, gpus, &configs)?);
    let seq = run(&sequential_plora_plan(&cm, &b, gpus, &configs)?);
    let mut planner = JobPlanner::new(cm.clone(), gpus);
    planner.budget = b;
    let plora_plan = planner.plan(&configs)?;
    let plora = run(&plora_plan);

    let mut t = Table::new(
        &format!(
            "Makespan — {} on {} x {} ({} configs)",
            cm.geom.name,
            gpus,
            cm.profile.name,
            configs.len()
        ),
        &["method", "makespan", "norm (MinGPU=1)", "speedup vs MinGPU", "pool util"],
    );
    for (name, r) in
        [("Min GPU", &min), ("Max GPU", &max), ("Sequential PLoRA", &seq), ("PLoRA", &plora)]
    {
        t.row(vec![
            name.to_string(),
            fmt_dur(r.makespan),
            format!("{:.2}", r.makespan / min.makespan),
            fmt_x(min.makespan / r.makespan),
            format!("{:.0}%", r.utilization() * 100.0),
        ]);
    }
    t.print();
    println!("\nPLoRA planner AR bound: {:.3}", plora_plan.ar_bound);
    if let Some((eta, rungs)) = opts.tuner {
        let asha = sim.run_asha(&configs, &opts)?;
        println!(
            "ASHA (eta {eta}, {rungs} rungs): predicted makespan {} — {:.2}x of the full \
             PLoRA sweep (synchronous-rung upper bound; live eager promotion does better)",
            fmt_dur(asha.makespan),
            asha.makespan / plora.makespan,
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let model = args.get_or("model", "nano").to_string();
    let task = args.get_or("task", "modadd").to_string();
    let config = LoraConfig {
        id: 0,
        lr: args.f64("lr", 2e-3)?,
        batch: args.usize("batch", 1)?,
        rank: args.usize("rank", 8)?,
        alpha_ratio: args.f64("alpha", 1.0)?,
        task,
    };
    let opts = TrainOptions {
        budget: TrainBudget { dataset: args.usize("steps", 64)?, epochs: 1 },
        eval_batches: args.usize("eval-batches", 4)?,
        seed: args.usize("seed", 17)? as u64,
        log_every: args.usize("log-every", 8)?,
    };
    let rep = run_pack(&rt, &model, &[config], &opts)?;
    let a = &rep.adapters[0];
    println!(
        "artifact {}  steps {}  wall {:.1}s  ({:.3}s/step, compile {:.1}s)",
        rep.artifact, rep.steps, rep.wall_secs, rep.step_secs, rep.compile_secs
    );
    println!(
        "task {}: base acc {:.3} -> eval acc {:.3} | loss {:.3} -> {:.3}",
        a.config.task, a.base_acc, a.eval_acc, a.base_loss, a.eval_loss
    );
    for (s, l) in &a.curve {
        println!("  step {s:>4}  loss {l:.4}");
    }
    Ok(())
}

/// Sampled live-scale configurations for sweep/serve, clamped to the
/// model's bucket grid.
fn sampled_configs(rt: &Runtime, model: &str, n: usize) -> Vec<LoraConfig> {
    let tasks = rt.manifest.tasks.clone();
    let (max_r, max_bs) = bucket_caps(rt, model);
    let space = SearchSpace {
        lrs: vec![5e-4, 2e-3, 5e-3],
        batches: vec![1, 2].into_iter().filter(|&b| b <= max_bs).collect(),
        ranks: vec![8, 16].into_iter().filter(|&r| r <= max_r).collect(),
        alpha_ratios: vec![0.5, 1.0],
    };
    let mut rng = plora::util::rng::Rng::new(7);
    let mut configs = vec![];
    for i in 0..n {
        let mut c = space.sample(&tasks[i % tasks.len()], 1, &mut rng).remove(0);
        c.id = i;
        c.task = tasks[i % tasks.len()].clone();
        configs.push(c);
    }
    configs
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let model = args.get_or("model", "nano").to_string();
    let gpus = args.usize("gpus", 4)?;
    let n = args.usize("configs", 8)?;
    let steps = args.usize("steps", 48)?;
    let tuner_name = args.get_or("tuner", "full");
    let tuner: Box<dyn search::Tuner> = match tuner_name {
        "full" => Box::new(search::FullSweep { ckpt_dir: args.get("ckpt").map(PathBuf::from) }),
        "asha" => Box::new(search::Asha {
            eta: args.usize("eta", 2)?,
            rungs: args.usize("rungs", 3)?,
            ckpt_dir: args.get("ckpt").map(PathBuf::from),
        }),
        other => bail!("unknown tuner '{other}' (full|asha)"),
    };

    // Plan against the live profile for a full-sweep makespan prediction
    // (the tuner replans internally — ASHA per rung).
    let configs = sampled_configs(&rt, &model, n);
    let opts = search::SweepOptions {
        budget: TrainBudget { dataset: steps, epochs: 1 },
        eval_batches: 2,
        seed: args.usize("seed", 17)? as u64,
        gpus,
        policy: args.get("policy").and_then(Policy::parse).unwrap_or(Policy::Fifo),
        elastic: args.flag("elastic"),
    };
    let mut planner = JobPlanner::new(search::live_cost_model(&rt, &model)?, gpus);
    planner.budget = opts.budget;
    let plan = planner.plan(&configs)?;
    println!(
        "plan: {} jobs, predicted full-sweep makespan {} (cost-model time), tuner {}",
        plan.jobs.len(),
        fmt_dur(plan.makespan),
        tuner.name(),
    );

    // The recorder snapshots the *full* final budget — under ASHA the
    // session's own options hold the current rung's budget, so the trace
    // is built here, not from the session.
    let full_options = TrainOptions {
        budget: opts.budget,
        eval_batches: opts.eval_batches,
        seed: opts.seed,
        log_every: 0,
    };
    let mut rec = args
        .get("record")
        .map(|_| TraceRecorder::new(&model, gpus, opts.policy, opts.elastic, true, &full_options));
    let out = tuner.run(&rt, &model, &configs, &opts, rec.as_mut())?;
    if let (Some(rec), Some(path)) = (rec.take(), args.get("record")) {
        rec.finish(&out.session).save(&PathBuf::from(path))?;
        println!("recorded trace -> {path}");
    }

    for r in &out.rungs {
        println!(
            "rung {}: dataset {:>4}, {} trial(s), {} promoted",
            r.rung, r.dataset, r.trials, r.promoted
        );
    }
    let mut t = Table::new(
        &format!("Live sweep — {} configs on {model} ({})", n, tuner.name()),
        &["config", "task", "rank", "bs", "lr", "steps", "base acc", "eval acc"],
    );
    for a in &out.reports {
        t.row(vec![
            a.config.id.to_string(),
            a.config.task.clone(),
            a.config.rank.to_string(),
            a.config.batch.to_string(),
            format!("{:.0e}", a.config.lr),
            a.steps.to_string(),
            format!("{:.3}", a.base_acc),
            format!("{:.3}", a.eval_acc),
        ]);
    }
    t.print();
    for (task, best) in search::best_per_task(&out.reports) {
        println!("best {task}: config {} at eval acc {:.3}", best.config.id, best.eval_acc);
    }
    let (a, b, c) = out.session.calib_fit;
    println!(
        "\nlive makespan {}  adapters {}  calib fit: t = {:.4} + {:.2e}*tokens + {:.2e}*n",
        fmt_dur(out.session.makespan),
        out.session.total_adapters(),
        a,
        b,
        c
    );
    Ok(())
}

/// `plora serve`: drive a session interactively — submit a planned queue
/// and render the live event stream (job starts, adapter completions,
/// re-buckets, calibration refreshes) as it happens, then the summary.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("daemon") {
        return cmd_daemon(args);
    }
    let rt = runtime()?;
    let model = args.get_or("model", "nano").to_string();
    let gpus = args.usize("gpus", 2)?;
    let n = args.usize("configs", 6)?;
    let steps = args.usize("steps", 32)?;

    let configs = sampled_configs(&rt, &model, n);
    let mut planner = JobPlanner::new(search::live_cost_model(&rt, &model)?, gpus);
    planner.budget = TrainBudget { dataset: steps, epochs: 1 };
    let plan = planner.plan(&configs)?;

    let mut session = Session::new(rt.clone(), ResourceMonitor::new(&pool::CPU_SIM, gpus), &model);
    session.options =
        TrainOptions { budget: planner.budget, eval_batches: 2, seed: 17, log_every: 0 };
    session.rebucket = !args.flag("no-rebucket");
    let policy = args.get("policy").and_then(Policy::parse).unwrap_or(Policy::Fifo);
    session.set_policy(policy);
    session.set_elastic(args.flag("elastic"));
    if let Some(dir) = args.get("ckpt") {
        session.checkpoints = Some(CheckpointPool::new(&PathBuf::from(dir), rt.clone())?);
    }
    let rx = session.subscribe();
    println!(
        "serve: {} configs in {} jobs on {gpus} slots of {model} (rebucket {}, {policy:?}{})",
        configs.len(),
        plan.jobs.len(),
        if session.rebucket { "on" } else { "off" },
        if session.elastic() { ", elastic" } else { "" }
    );
    // Priority policies: the caller gave no priorities, so derive
    // shortest-job-first ranks from modeled work (planner-side priority
    // assignment — short jobs clear the queue first).
    let jobs: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
    let prios = plora::planner::default_priorities(
        &planner.cm,
        &planner.budget,
        &jobs,
        policy != Policy::Fifo,
    );
    let mut recorder = args.get("record").map(|_| TraceRecorder::for_session(&session));
    let mut pending = 0usize;
    for (j, prio) in jobs.into_iter().zip(prios) {
        if let Some(rec) = recorder.as_mut() {
            rec.submit(&j, prio);
        }
        session.submit_planned_at(j, prio)?;
        pending += 1;
    }
    while pending > 0 {
        let Ok(ev) = rx.recv() else { break };
        render_event(&ev);
        if matches!(ev, Event::JobFinished { .. } | Event::JobFailed { .. }) {
            pending -= 1;
        }
    }
    let report = session.drain()?;
    if let (Some(rec), Some(path)) = (recorder.take(), args.get("record")) {
        rec.finish(&report).save(&PathBuf::from(path))?;
        println!("recorded trace -> {path}");
    }
    let (a, b, c) = report.calib_fit;
    println!(
        "\ndone: makespan {}  jobs {}  adapters {}  rebuckets {}  admissions {}  \
         preemptions {}  device-retargets {}  switch-cost {:.4}s  \
         device-switch {:.4}s  calib t = {a:.4} + {b:.2e}*tokens + {c:.2e}*n",
        fmt_dur(report.makespan),
        report.outcomes.len(),
        report.total_adapters(),
        report.rebuckets(),
        report.admissions(),
        report.preemptions(),
        report.device_retargets(),
        report.switch_cost,
        report.device_switch_cost,
    );
    Ok(())
}

/// `plora serve --daemon`: the durable multi-tenant tuning service
/// (DESIGN.md §13) — journal + checkpoint pool in `--dir`, HTTP control
/// plane on 127.0.0.1, crash-exact recovery on restart.
fn cmd_daemon(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let steps = args.usize("steps", 32)?;
    let opts = plora::daemon::DaemonOpts {
        model: args.get_or("model", "nano").to_string(),
        gpus: args.usize("gpus", 2)?,
        dir: PathBuf::from(args.get_or("dir", "plora-daemon")),
        port: args.usize("port", 7733)? as u16,
        options: TrainOptions {
            budget: TrainBudget { dataset: steps, epochs: 1 },
            eval_batches: 2,
            seed: 17,
            log_every: 0,
        },
        policy: args.get("policy").and_then(Policy::parse).unwrap_or(Policy::Priority),
        elastic: args.flag("elastic"),
        rebucket: !args.flag("no-rebucket"),
    };
    plora::daemon::run(rt, opts)
}

fn daemon_addr(args: &Args) -> String {
    args.get_or("addr", "127.0.0.1:7733").to_string()
}

fn print_json(v: &Json) {
    let mut s = String::new();
    v.write(&mut s);
    println!("{s}");
}

/// `plora submit`: POST one job to a running daemon. Repeat `--task` for
/// multi-adapter packs; `--tenant`/`--weight` drive fair share.
fn cmd_submit(args: &Args) -> Result<()> {
    let mut tasks = args.get_all("task");
    if tasks.is_empty() {
        tasks.push("modadd");
    }
    let rank = args.usize("rank", 8)?;
    let batch = args.usize("batch", 1)?;
    let lr = args.f64("lr", 2e-3)?;
    let alpha = args.f64("alpha", 1.0)?;
    let adapters = Json::arr(tasks.iter().map(|t| {
        Json::obj(vec![
            ("task", Json::str(*t)),
            ("rank", Json::num(rank as f64)),
            ("batch", Json::num(batch as f64)),
            ("lr", Json::num(lr)),
            ("alpha_ratio", Json::num(alpha)),
        ])
    }));
    let mut fields = vec![
        ("tenant", Json::str(args.get_or("tenant", "default"))),
        ("weight", Json::num(args.f64("weight", 1.0)?)),
        ("adapters", adapters),
        ("d", Json::num(args.usize("d", 1)? as f64)),
        ("mode", Json::str(args.get_or("mode", "packed"))),
    ];
    if let Some(token) = args.get("token") {
        fields.push(("token", Json::str(token)));
    }
    let body = Json::obj(fields);
    let (st, resp) =
        plora::daemon::http::request(&daemon_addr(args), "POST", "/v1/jobs", Some(&body))?;
    print_json(&resp);
    if st != 200 {
        bail!("submit failed (HTTP {st})");
    }
    Ok(())
}

/// `plora status [job]`: list jobs, show one job, or `--digest` for the
/// combined crash-exact session digest.
fn cmd_status(args: &Args) -> Result<()> {
    let addr = daemon_addr(args);
    let path = if args.flag("digest") {
        "/v1/digest".to_string()
    } else {
        match args.positional.first() {
            Some(id) => format!("/v1/jobs/{id}"),
            None => "/v1/jobs".to_string(),
        }
    };
    let (st, resp) = plora::daemon::http::request(&addr, "GET", &path, None)?;
    print_json(&resp);
    if st != 200 {
        bail!("status failed (HTTP {st})");
    }
    Ok(())
}

/// `plora cancel <job>`: cancel a queued or running job.
fn cmd_cancel(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: plora cancel <job> [--addr HOST:PORT]"))?;
    let (st, resp) = plora::daemon::http::request(
        &daemon_addr(args),
        "POST",
        &format!("/v1/jobs/{id}/cancel"),
        None,
    )?;
    print_json(&resp);
    if st != 200 {
        bail!("cancel failed (HTTP {st})");
    }
    Ok(())
}

/// `plora replay <trace.json>`: re-execute a recorded session and assert
/// the result is bit-identical to the recording; `--sim` instead rebuilds
/// the timeline through the simulator's cost model (no training);
/// `--from-checkpoint <dir>` seeds the replay from a checkpoint pool's
/// preemption midpoints (same bits, fewer re-executed steps).
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("trace"))
        .ok_or_else(|| anyhow!("usage: plora replay <trace.json> [--sim]"))?
        .to_string();
    let trace = Trace::load(&PathBuf::from(&path))?;
    println!(
        "trace: {} jobs / {} adapters of {} on {} devices ({:?}{}{}) — recorded makespan {}",
        trace.jobs.len(),
        trace.total_adapters(),
        trace.model,
        trace.gpus,
        trace.policy,
        if trace.elastic { ", elastic" } else { "" },
        if trace.rebucket { "" } else { ", no-rebucket" },
        fmt_dur(trace.makespan),
    );
    let rt = runtime()?;
    if args.flag("sim") {
        let cm = search::live_cost_model(&rt, &trace.model)?;
        let res = plora::trace::replay_timing(&cm, &trace);
        for ev in &res.log {
            render_event(ev);
        }
        println!(
            "\nmodeled makespan {} vs recorded {} (events {}, utilization {:.0}%)",
            fmt_dur(res.makespan),
            fmt_dur(trace.makespan),
            res.events,
            res.utilization() * 100.0,
        );
        return Ok(());
    }
    let out = match args.get("from-checkpoint") {
        Some(dir) => {
            let ckpt = CheckpointPool::new(&PathBuf::from(dir), rt.clone())?;
            plora::trace::replay_resume(rt, &trace, &ckpt)?
        }
        None => plora::trace::replay(rt, &trace)?,
    };
    if out.matches() {
        println!(
            "replay OK: {} adapters bit-identical to the recording (fingerprint {:016x}), \
             replayed makespan {}",
            out.digest.adapters.len(),
            out.digest.fingerprint(),
            fmt_dur(out.report.makespan),
        );
        Ok(())
    } else {
        eprintln!("{}", out.diff);
        bail!("replay diverged from the recording — determinism violation (see diff above)");
    }
}

/// `plora perf-budget`: evaluate a bench output against a committed
/// `bench/history/` snapshot. Exits non-zero on regression unless
/// `--warn-only` or `PLORA_PERF_OVERRIDE=1` (CI sets the latter from the
/// 'perf-budget-override' PR label).
fn cmd_perf_budget(args: &Args) -> Result<()> {
    let read = |flag: &str| -> Result<(String, Json)> {
        let p = args
            .get(flag)
            .ok_or_else(|| anyhow!("--{flag} <json> is required"))?
            .to_string();
        let text =
            std::fs::read_to_string(&p).map_err(|e| anyhow!("read {p}: {e}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{p}: {e}"))?;
        Ok((p, v))
    };
    let (_, current) = read("current")?;
    let (base_path, baseline) = read("baseline")?;
    let tolerance = args.f64("tolerance", 0.25)?;
    let checks = perf::perf_budget(&current, &baseline, tolerance)?;
    for c in &checks {
        println!("{}", c.render());
    }
    if args.flag("update-baseline") {
        let mut out = String::new();
        perf::update_snapshot(&baseline, &current).write(&mut out);
        out.push('\n');
        std::fs::write(&base_path, out).map_err(|e| anyhow!("write {base_path}: {e}"))?;
        println!("baseline record updated -> {base_path}");
    }
    let failed = checks.iter().filter(|c| !c.ok).count();
    if failed == 0 {
        println!("perf budget OK ({} checks, tolerance {tolerance})", checks.len());
        return Ok(());
    }
    let overridden = args.flag("warn-only")
        || std::env::var("PLORA_PERF_OVERRIDE").map(|v| v == "1").unwrap_or(false);
    if overridden {
        println!("{failed} perf check(s) over budget — overridden, not failing");
        return Ok(());
    }
    bail!(
        "{failed} perf check(s) over budget; if the regression is intentional, apply the \
         'perf-budget-override' PR label (or rerun with --warn-only) and refresh the \
         snapshot with --update-baseline"
    );
}

/// One line per session event, prefixed with the session timestamp.
fn render_event(ev: &Event) {
    let at = ev.at();
    match ev {
        Event::JobStarted { job, n_adapters, devices, .. } => {
            println!("[{at:7.2}s] job {job} started: {n_adapters} adapters on {devices:?}");
        }
        Event::AdapterFinished { job, adapter, task, steps, eval_loss, eval_acc, .. } => {
            println!(
                "[{at:7.2}s] job {job} adapter {adapter} ({task}) finished after {steps} \
                 steps: eval loss {eval_loss:.3}, acc {eval_acc:.3}"
            );
        }
        Event::AdapterAdmitted { job, adapter, task, from_job, .. } => {
            println!(
                "[{at:7.2}s] job {job} admitted adapter {adapter} ({task}) from queued \
                 job {from_job}"
            );
        }
        Event::Rebucketed { job, from, to, survivors, .. } => {
            println!(
                "[{at:7.2}s] job {job} re-bucketed {from:?} -> {to:?}, survivors {survivors:?}"
            );
        }
        Event::Preempted { job, adapters, .. } => {
            println!("[{at:7.2}s] job {job} PREEMPTED: adapters {adapters:?} back to queue");
        }
        Event::DeviceRetarget { job, from, to, .. } => {
            println!("[{at:7.2}s] job {job} device-retargeted: {from} -> {to} devices");
        }
        Event::StageRetarget { job, from, to, .. } => {
            println!("[{at:7.2}s] job {job} stage-retargeted: {from} -> {to} pipeline stages");
        }
        Event::JobFinished { job, adapters, wall, .. } => {
            if *adapters == 0 {
                println!("[{at:7.2}s] job {job} fully absorbed by running packs");
            } else {
                println!("[{at:7.2}s] job {job} finished: {adapters} adapters in {wall:.2}s");
            }
        }
        Event::JobFailed { job, error, .. } => {
            println!("[{at:7.2}s] job {job} FAILED: {error}");
        }
        Event::TrialPromoted { rung, adapter, .. } => {
            println!("[{at:7.2}s] tuner promoted adapter {adapter} out of rung {rung}");
        }
        Event::RungDecision { rung, task, survivors, demoted, .. } => {
            println!(
                "[{at:7.2}s] rung {rung} ({task}) complete: survivors {survivors:?}, \
                 demoted {demoted:?}"
            );
        }
        Event::CalibUpdated { fit: (a, b, c), samples, switch_cost, dp_fit, .. } => {
            let dp = match dp_fit {
                Some((da, db)) => format!(", dp t_row = {da:.2e} + {db:.2e}/d"),
                None => String::new(),
            };
            println!(
                "[{at:7.2}s] calib updated over {samples} steps: \
                 t = {a:.4} + {b:.2e}*tok + {c:.2e}*n, switch {switch_cost:.4}s{dp}"
            );
        }
    }
}

fn cmd_quality(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let model = args.get_or("model", "nano").to_string();
    let steps = args.usize("steps", 96)?;
    let per_task = args.usize("per-task", 12)?;

    let opts = search::SweepOptions {
        budget: TrainBudget { dataset: steps, epochs: 1 },
        eval_batches: 4,
        seed: 23,
        gpus: args.usize("gpus", 2)?,
        ..Default::default()
    };
    // Small grid per task around live-scale learning rates, restricted to
    // the shapes the model's bucket grid can execute.
    let (max_r, max_bs) = bucket_caps(&rt, &model);
    let space = SearchSpace {
        lrs: vec![5e-4, 2e-3, 8e-3],
        batches: vec![1, 2].into_iter().filter(|&b| b <= max_bs).collect(),
        ranks: vec![8, 16].into_iter().filter(|&r| r <= max_r).collect(),
        alpha_ratios: vec![0.5, 1.0],
    };
    let tasks = rt.manifest.tasks.clone();
    let mut all = vec![];
    let mut defaults = vec![];
    for task in &tasks {
        let mut g = space.grid(task);
        g.truncate(per_task);
        for (i, c) in g.iter_mut().enumerate() {
            c.id = i;
        }
        println!("[{model}/{task}] sweeping {} configs ...", g.len());
        all.extend(search::sweep(&rt, &model, &g, &opts)?);
        let mut d = search::default_config(task);
        d.lr = 2e-3; // live-scale default
        d.rank = d.rank.min(max_r);
        d.batch = d.batch.min(max_bs);
        let rep = run_pack(
            &rt,
            &model,
            &[d.with_id(9999)],
            &TrainOptions {
                budget: opts.budget,
                eval_batches: opts.eval_batches,
                seed: opts.seed,
                log_every: 0,
            },
        )?;
        defaults.extend(rep.adapters);
    }
    search::table2(&all).print();
    search::table3(&all).print();
    search::table4(&model, &all).print();
    search::table6(&model, &all, &defaults).print();
    Ok(())
}

fn cmd_kernels(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let ns = args.list_usize("ns", &[1, 2, 8, 32])?;
    let geoms: Vec<String> = args
        .get_or("geoms", "attn,mlp")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let iters = args.usize("iters", 10)?;

    let mut t = Table::new(
        "Packed-LoRA kernels — live speedup over sequential per-adapter launches \
         (Table 7 analogue)",
        &["geom", "n", "fwd", "bwd"],
    );
    for geom in &geoms {
        let (base_f, base_b) = kernel_time(&rt, geom, 1, iters)?;
        for &n in &ns {
            let (tf, tb) = kernel_time(&rt, geom, n, iters)?;
            // Sequential baseline: n separate n=1 launches.
            t.row(vec![
                geom.clone(),
                n.to_string(),
                fmt_x(n as f64 * base_f / tf),
                fmt_x(n as f64 * base_b / tb),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// Median wall time of the packed fwd/bwd kernel artifacts at pack size `n`.
fn kernel_time(rt: &Runtime, geom: &str, n: usize, iters: usize) -> Result<(f64, f64)> {
    let fwd = rt.executable(&format!("kfwd_{geom}_n{n}"))?;
    let bwd = rt.executable(&format!("kbwd_{geom}_n{n}"))?;
    let (d, k, r, m) = (
        fwd.info.meta_usize("d").unwrap(),
        fwd.info.meta_usize("k").unwrap(),
        fwd.info.meta_usize("r").unwrap(),
        fwd.info.meta_usize("m").unwrap(),
    );
    let x = HostTensor::f32(vec![n, m, d], vec![0.01; n * m * d])?;
    let a = HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r])?;
    let bt = HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k])?;
    let alpha = HostTensor::f32(vec![n], vec![1.0; n])?;
    let g = HostTensor::f32(vec![n, m, k], vec![0.05; n * m * k])?;

    let time = |exe: &plora::runtime::Executable, inputs: &[HostTensor]| -> Result<f64> {
        exe.run(inputs)?; // warmup
        let mut samples = vec![];
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            exe.run(inputs)?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|p, q| p.total_cmp(q));
        Ok(samples[samples.len() / 2])
    };
    let tf = time(&fwd, &[x.clone(), a.clone(), bt.clone(), alpha.clone()])?;
    let tb = time(&bwd, &[x, a, bt, alpha, g])?;
    Ok((tf, tb))
}

fn cmd_calib(args: &Args) -> Result<()> {
    let rt = runtime()?;
    let model = args.get_or("model", "nano").to_string();
    let steps = args.usize("steps", 16)?;
    let mut samples = vec![];
    for (n, bs) in [(1usize, 1usize), (2, 1), (4, 1), (2, 2)] {
        let configs: Vec<LoraConfig> = (0..n)
            .map(|i| LoraConfig {
                id: i,
                lr: 1e-3,
                batch: bs,
                rank: 8,
                alpha_ratio: 1.0,
                task: "modadd".into(),
            })
            .collect();
        let opts = TrainOptions {
            budget: TrainBudget { dataset: steps * bs, epochs: 1 },
            eval_batches: 1,
            seed: 3,
            log_every: 0,
        };
        match run_pack(&rt, &model, &configs, &opts) {
            Ok(rep) => {
                println!("n={n} bs={bs}: {:.4}s/step", rep.step_secs);
                samples.extend(rep.profile);
            }
            Err(e) => println!("n={n} bs={bs}: skipped ({e})"),
        }
    }
    if samples.is_empty() {
        bail!("no profile samples collected");
    }
    let (a, b, c) = plora::costmodel::throughput::Calib::fit_live(&samples);
    println!(
        "\nlive fit over {} steps: t = {:.4} + {:.3e}*tokens + {:.3e}*n_adapters",
        samples.len(),
        a,
        b,
        c
    );
    println!("(feed these into CostModel::calib for cpu-sim planning)");
    Ok(())
}
