//! Result tables and run reports: the uniform way benches, examples, and
//! the CLI emit paper-style tables (markdown for EXPERIMENTS.md, CSV for
//! downstream plotting, JSON lines for machine consumption).

use std::path::Path;

use crate::util::json::Json;

/// A rows × columns table with a title — one paper table/figure series.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row<S: ToString>(&mut self, cells: Vec<S>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.into_iter().map(|c| c.to_string()).collect());
        self
    }

    /// GitHub-flavored markdown rendering.
    pub fn markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        s.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("headers", Json::arr(self.headers.iter().map(|h| Json::str(h.clone())))),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c.clone())))),
                ),
            ),
        ])
    }

    /// Print to stdout (aligned plain text).
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Append a table (as markdown) to a report file, creating it if needed.
pub fn append_markdown(path: &Path, table: &Table) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", table.markdown())
}

/// Format a speedup/slowdown factor the way the paper does (`6.51x`).
pub fn fmt_x(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format seconds compactly (`42s`, `3.2m`, `1.4h`).
pub fn fmt_dur(secs: f64) -> String {
    if secs < 120.0 {
        format!("{secs:.0}s")
    } else if secs < 7200.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_shapes() {
        let mut t = Table::new("Makespan", &["method", "makespan", "speedup"]);
        t.row(vec!["Min GPU", "100", "1.00x"]);
        t.row(vec!["PLoRA", "14", "7.08x"]);
        let md = t.markdown();
        assert!(md.contains("### Makespan"));
        assert!(md.lines().count() >= 5);
        assert!(md.contains("| PLoRA | 14 | 7.08x |"));
        let csv = t.csv();
        assert_eq!(csv.lines().next().unwrap(), "method,makespan,speedup");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["hello, world"]);
        assert!(t.csv().contains("\"hello, world\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_x(6.513), "6.51x");
        assert_eq!(fmt_dur(42.0), "42s");
        assert_eq!(fmt_dur(300.0), "5.0m");
        assert_eq!(fmt_dur(10000.0), "2.78h");
    }
}
