//! Evaluation baselines (§7.1) as planners producing the same [`Plan`]
//! shape as PLoRA's job planner, so the simulator and benches compare
//! like-for-like:
//!
//! - **Min GPU**: one configuration per job, each at the *minimum* TP
//!   degree that fits its memory; jobs fill all GPUs concurrently.
//! - **Max GPU**: one configuration per job at TP = G (one job at a time).
//! - **Sequential PLoRA** (Fig. 6 ablation): PLoRA's packing planner, but
//!   jobs execute with the naive sequential per-adapter loop (§5.1) —
//!   isolates planner gains from kernel gains.

use anyhow::{bail, Result};

use crate::config::LoraConfig;
use crate::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use crate::planner::job_planner::{Plan, ScheduledJob};
use crate::planner::{DtmStats, JobPlanner, PlannedJob};

/// Greedy event-driven placement of fixed single-config jobs (shared by the
/// Min/Max GPU baselines): schedule each job as soon as `d` GPUs free up.
fn place_fixed_jobs(
    cm: &CostModel,
    budget: &TrainBudget,
    gpus: usize,
    jobs: Vec<(Pack, usize)>,
) -> Plan {
    let t_wall = std::time::Instant::now();
    let mut queue: Vec<ScheduledJob> = vec![];
    let mut running: Vec<(f64, usize)> = vec![]; // (end, d)
    let mut g_avail = gpus;
    let mut now = 0.0f64;
    let mut pending: std::collections::VecDeque<(Pack, usize)> = jobs.into();
    let mut next_id = 0usize;

    while !pending.is_empty() {
        // Launch everything that fits right now (FIFO, like a cluster queue).
        while let Some((_pack, d)) = pending.front() {
            if *d <= g_avail {
                let (pack, d) = pending.pop_front().unwrap();
                let dur = cm.job_time(&pack, d, ExecMode::Sequential, budget);
                g_avail -= d;
                running.push((now + dur, d));
                queue.push(ScheduledJob {
                    job: PlannedJob { id: next_id, pack, d, s: 0, mode: ExecMode::Sequential },
                    start: now,
                    end: now + dur,
                });
                next_id += 1;
            } else {
                break;
            }
        }
        if pending.is_empty() {
            break;
        }
        // Advance to the next completion.
        let (idx, _) = running
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
            .expect("pending jobs but nothing running");
        let (end, d) = running.swap_remove(idx);
        now = end.max(now);
        g_avail += d;
    }

    let makespan = queue.iter().map(|j| j.end).fold(0.0, f64::max);
    Plan {
        jobs: queue,
        makespan,
        ar_bound: f64::NAN, // Theorem 6.1 applies to the PLoRA planner only
        lb_makespan: f64::NAN,
        gpus,
        stats: DtmStats::default(),
        plan_secs: t_wall.elapsed().as_secs_f64(),
    }
}

/// **Min GPU**: every config is its own sequential job at the model's
/// minimum TP degree. As in §7.2.1 the degree is *per model*, uniform over
/// the space (the minimum set of hardware that satisfies the memory
/// constraint for every job: 3B/7B → 1, 14B → 2, 32B → 4).
pub fn min_gpu_plan(
    cm: &CostModel,
    budget: &TrainBudget,
    gpus: usize,
    configs: &[LoraConfig],
) -> Result<Plan> {
    let mut d_model = 1usize;
    for c in configs {
        let Some(d) = cm.memory.min_tp(c, &cm.profile, cm.c_load, gpus) else {
            bail!("config {} does not fit the pool", c.id);
        };
        d_model = d_model.max(d);
    }
    let jobs = configs.iter().map(|c| (Pack::new(vec![c.clone()]), d_model)).collect();
    Ok(place_fixed_jobs(cm, budget, gpus, jobs))
}

/// **Max GPU**: every config is its own sequential job at TP = G (§7.1) —
/// one job occupies the whole instance at a time.
pub fn max_gpu_plan(
    cm: &CostModel,
    budget: &TrainBudget,
    gpus: usize,
    configs: &[LoraConfig],
) -> Result<Plan> {
    let jobs = configs.iter().map(|c| (Pack::new(vec![c.clone()]), gpus)).collect();
    Ok(place_fixed_jobs(cm, budget, gpus, jobs))
}

/// **Sequential PLoRA** (Fig. 6): PLoRA's packing plan, executed with the
/// naive per-adapter kernel loop instead of the packed kernels.
pub fn sequential_plora_plan(
    cm: &CostModel,
    budget: &TrainBudget,
    gpus: usize,
    configs: &[LoraConfig],
) -> Result<Plan> {
    let mut planner = JobPlanner::new(cm.clone(), gpus);
    planner.budget = *budget;
    planner.mode = ExecMode::Sequential;
    planner.plan(configs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::SearchSpace;

    fn cm(model: &str) -> CostModel {
        CostModel::new(geom(model).unwrap(), &A100_40G)
    }

    #[test]
    fn min_gpu_runs_eight_concurrent_jobs_for_7b() {
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&m, &b, 8, &grid).unwrap();
        assert_eq!(plan.total_configs(), 120);
        // At t=0+, exactly 8 jobs should be running (one per GPU).
        let at0 = plan.jobs.iter().filter(|j| j.start == 0.0).count();
        assert_eq!(at0, 8);
        assert!(plan.jobs.iter().all(|j| j.job.d == 1));
    }

    #[test]
    fn min_gpu_uses_tp2_for_14b() {
        let m = cm("qwen2.5-14b");
        let b = TrainBudget::default();
        let grid = SearchSpace::default().grid("t");
        let plan = min_gpu_plan(&m, &b, 8, &grid[..16]).unwrap();
        assert!(plan.jobs.iter().all(|j| j.job.d == 2));
        let at0 = plan.jobs.iter().filter(|j| j.start == 0.0).count();
        assert_eq!(at0, 4, "four concurrent 2-GPU jobs");
    }

    #[test]
    fn max_gpu_serializes_everything() {
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let grid = SearchSpace::default().grid("t");
        let plan = max_gpu_plan(&m, &b, 8, &grid[..10]).unwrap();
        assert!(plan.jobs.iter().all(|j| j.job.d == 8));
        // Strictly serialized: starts are non-decreasing, no overlap.
        for w in plan.jobs.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-9);
        }
    }

    /// Fig. 4 ordering: PLoRA < Sequential-PLoRA < Min GPU < Max GPU.
    #[test]
    fn makespan_ordering_matches_figure_4() {
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let grid = SearchSpace::default().grid("t");
        let min = min_gpu_plan(&m, &b, 8, &grid).unwrap().makespan;
        let max = max_gpu_plan(&m, &b, 8, &grid).unwrap().makespan;
        let seq = sequential_plora_plan(&m, &b, 8, &grid).unwrap().makespan;
        let plora = JobPlanner::new(m.clone(), 8).plan(&grid).unwrap().makespan;
        assert!(max > min, "Max GPU ({max:.0}s) must trail Min GPU ({min:.0}s)");
        assert!(seq < min, "Sequential PLoRA ({seq:.0}s) must beat Min GPU ({min:.0}s)");
        assert!(plora < seq, "PLoRA ({plora:.0}s) must beat Sequential PLoRA ({seq:.0}s)");
        let speedup = min / plora;
        assert!(
            (3.0..12.0).contains(&speedup),
            "PLoRA speedup over Min GPU {speedup:.2} (paper: 6.5-7.5x)"
        );
    }
}
