//! Algorithm 1 — **Decomposed Throughput Maximization (DTM)**.
//!
//! Given `g` free GPUs and the remaining configuration set `K`, enumerate
//! parallelism degrees (powers of two, Eq. 16), solve the per-job packing
//! ILP `F(d, K)` for each, and recurse on the leftover GPUs; return the set
//! of concurrent jobs maximizing instantaneous throughput (Eq. 13).
//!
//! Degrees are explored non-increasingly along a policy — the
//! monotonicity condition used by the Theorem-6.1 proof — which also
//! de-duplicates permutations of the same partition.

use crate::config::LoraConfig;
use crate::costmodel::{CostModel, ExecMode, TrainBudget};
use crate::planner::ilp::PackProblem;
use crate::planner::PlannedJob;

/// One DTM invocation (the paper's `DTM(G, K)`).
pub struct Dtm<'a> {
    pub cm: &'a CostModel,
    pub budget: &'a TrainBudget,
    pub mode: ExecMode,
    /// Cap on ILP invocations (paper: 286 calls for 8 GPUs; this guards
    /// adversarial pool sizes).
    pub max_ilp_calls: usize,
}

/// Statistics of one DTM run (observability + §6.2 "computation time").
#[derive(Debug, Clone, Default)]
pub struct DtmStats {
    pub ilp_calls: usize,
    pub policies: usize,
    pub nodes: usize,
}

impl<'a> Dtm<'a> {
    pub fn new(cm: &'a CostModel, budget: &'a TrainBudget, mode: ExecMode) -> Self {
        Dtm { cm, budget, mode, max_ilp_calls: 4096 }
    }

    /// `DTM(g, K)`: the best set of concurrent jobs for `g` free GPUs.
    /// Jobs in the result use disjoint configs; configs that fit nowhere
    /// are left unscheduled (the caller retries when more GPUs free up).
    pub fn plan(&self, g: usize, configs: &[LoraConfig]) -> (Vec<PlannedJob>, DtmStats) {
        let mut stats = DtmStats::default();
        let mut best: Option<(f64, Vec<PlannedJob>)> = None;
        let mut current = vec![];
        self.helper(g, usize::MAX, configs.to_vec(), &mut current, &mut best, &mut stats);
        (best.map(|(_, jobs)| jobs).unwrap_or_default(), stats)
    }

    /// `DTMHelper(g, P_tmp, K, P)` with non-increasing degree `d ≤ d_max`.
    fn helper(
        &self,
        g: usize,
        d_max: usize,
        remaining: Vec<LoraConfig>,
        current: &mut Vec<PlannedJob>,
        best: &mut Option<(f64, Vec<PlannedJob>)>,
        stats: &mut DtmStats,
    ) {
        // Terminal: no GPUs left, no configs left, or ILP budget exhausted.
        if g == 0 || remaining.is_empty() || stats.ilp_calls >= self.max_ilp_calls {
            self.offer(current, best, stats);
            return;
        }
        // d ∈ {g', g'/2, …, 1} with g' = 2^⌊log2 g⌋ (Alg. 1 line 4–5).
        let mut gp = 1usize;
        while gp * 2 <= g {
            gp *= 2;
        }
        // Ensure d ≤ d_max (non-increasing policies).
        let mut d = gp;
        while d > d_max {
            d /= 2;
        }
        let mut any_child = false;
        while d >= 1 {
            stats.ilp_calls += 1;
            let prob = PackProblem::new(self.cm, d, self.mode, self.budget);
            if let Some(sol) = prob.solve(&remaining) {
                stats.nodes += sol.nodes;
                if sol.pack.n() > 0 {
                    any_child = true;
                    let used: Vec<usize> = sol.pack.configs.iter().map(|c| c.id).collect();
                    let rest: Vec<LoraConfig> =
                        remaining.iter().filter(|c| !used.contains(&c.id)).cloned().collect();
                    current.push(PlannedJob {
                        id: 0, // assigned by the job planner
                        pack: sol.pack,
                        d,
                        s: 0, // depth chosen later (JobPlanner::choose_stages)
                        mode: self.mode,
                    });
                    self.helper(g - d, d, rest, current, best, stats);
                    current.pop();
                }
            }
            if d == 1 {
                break;
            }
            d /= 2;
        }
        if !any_child {
            // Nothing fits on any degree ≤ g: close this policy as-is.
            self.offer(current, best, stats);
        }
    }

    /// Score a complete policy — Alg. 1 line 11 (`arg min T(p)`), adapted
    /// for policies that schedule different amounts of work: **round
    /// effective throughput** = total scheduled rank / longest job time.
    /// At equal work this is exactly min-makespan selection.
    ///
    /// A plain Σ_j (rank_j / T_j) sum (the literal Eq. 13 reading) is
    /// degenerate here: it rewards dumping all slow configurations into one
    /// sacrificial long job so the remaining jobs look fast — which
    /// *maximizes* the makespan the outer problem (Eq. 12) minimizes.
    fn offer(
        &self,
        current: &[PlannedJob],
        best: &mut Option<(f64, Vec<PlannedJob>)>,
        stats: &mut DtmStats,
    ) {
        stats.policies += 1;
        let work: f64 = current.iter().map(|j| j.pack.rank_sum() as f64).sum();
        let t = self.longest(current);
        let score = if t > 0.0 { work / t } else { 0.0 };
        if std::env::var("PLORA_DTM_DEBUG").is_ok() {
            let ds: Vec<usize> = current.iter().map(|j| j.d).collect();
            let ns: Vec<usize> = current.iter().map(|j| j.pack.n()).collect();
            eprintln!("policy d={ds:?} n={ns:?} score={score:.3} T={t:.0}");
        }
        let better = match best {
            None => true,
            Some((b, _)) => score > *b * (1.0 + 1e-12),
        };
        if better && !current.is_empty() {
            *best = Some((score, current.to_vec()));
        } else if best.is_none() {
            *best = Some((0.0, vec![]));
        }
    }

    fn longest(&self, jobs: &[PlannedJob]) -> f64 {
        jobs.iter()
            .map(|j| self.cm.job_time(&j.pack, j.d, j.mode, self.budget))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::SearchSpace;

    fn cm(model: &str) -> CostModel {
        CostModel::new(geom(model).unwrap(), &A100_40G)
    }

    #[test]
    fn dtm_schedules_disjoint_configs() {
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let dtm = Dtm::new(&m, &b, ExecMode::Packed);
        let configs = SearchSpace::default().grid("t");
        let (jobs, stats) = dtm.plan(8, &configs);
        assert!(!jobs.is_empty());
        assert!(stats.ilp_calls >= 1);
        let total_d: usize = jobs.iter().map(|j| j.d).sum();
        assert!(total_d <= 8, "jobs use {total_d} GPUs > 8");
        let mut seen = std::collections::BTreeSet::new();
        for j in &jobs {
            for c in &j.pack.configs {
                assert!(seen.insert(c.id), "config {} scheduled twice", c.id);
            }
            assert!(m.fits(&j.pack, j.d), "infeasible pack returned");
        }
    }

    #[test]
    fn degrees_are_powers_of_two_within_pool() {
        let m = cm("qwen2.5-14b"); // needs d >= 2
        let b = TrainBudget::default();
        let dtm = Dtm::new(&m, &b, ExecMode::Packed);
        let configs = SearchSpace::default().grid("t");
        let (jobs, _) = dtm.plan(8, &configs);
        assert!(!jobs.is_empty());
        for j in &jobs {
            assert!(j.d.is_power_of_two() && j.d <= 8);
            assert!(j.d >= 2, "14B cannot fit a single GPU");
        }
    }

    #[test]
    fn dtm_prefers_packing_over_spreading() {
        // With packing available, one 7B job per GPU packed full beats any
        // TP spreading: expect 8 single-GPU jobs over the big grid.
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let dtm = Dtm::new(&m, &b, ExecMode::Packed);
        let configs = SearchSpace::default().grid("t");
        let (jobs, _) = dtm.plan(8, &configs);
        assert!(jobs.iter().all(|j| j.d == 1), "7B packs best at d=1");
        assert_eq!(jobs.len(), 8);
        // Every job should pack several adapters.
        assert!(jobs.iter().all(|j| j.pack.n() >= 2));
    }

    #[test]
    fn empty_config_set_yields_empty_plan() {
        let m = cm("qwen2.5-7b");
        let b = TrainBudget::default();
        let dtm = Dtm::new(&m, &b, ExecMode::Packed);
        let (jobs, _) = dtm.plan(8, &[]);
        assert!(jobs.is_empty());
    }

    #[test]
    fn nothing_fits_yields_empty_plan_not_hang() {
        let m = cm("qwen2.5-32b"); // ~69 GB of weights: never fits one A100
        let b = TrainBudget::default();
        let dtm = Dtm::new(&m, &b, ExecMode::Packed);
        let configs = SearchSpace::default().grid("t");
        let (jobs, _) = dtm.plan(1, &configs); // only 1 free
        assert!(jobs.is_empty());
    }
}
