//! Heterogeneous-device-aware job placement (DESIGN.md §15): assign
//! planned jobs to a mixed fast/slow fleet using per-device-class speed
//! tiers instead of pretending every host is identical.
//!
//! The fleet is a list of [`Host`]s, each carrying a relative speed (1.0
//! = the reference tier; a host at 0.5 runs every job twice as long).
//! Speeds come from the per-device-class calibration
//! ([`crate::costmodel::throughput::Calib::dp_fit_for`], fed from
//! measured per-class step times via `DpStat::record_class`) through
//! [`hosts_from_fits`]. Placement is greedy LPT — longest job first onto
//! the host with the earliest *believed* finish time — where "believed"
//! is the distinction under test:
//!
//! - **hetero-aware** ([`place_jobs`] with `aware = true`): the planner
//!   believes the calibrated speeds, so a long job lands on a fast host
//!   even when a slow one is idler.
//! - **identical-device baseline** (`aware = false`): the planner
//!   believes every host runs at speed 1 (the pre-calibration behavior)
//!   and balances raw load only.
//!
//! Both placements are *evaluated* under the true speeds, so on a skewed
//! fleet the identical-device baseline pays for parking long jobs on
//! slow hosts — the makespan gap the skewed-fleet bench gate pins.

use crate::costmodel::throughput::Calib;

/// One host of a (possibly mixed) fleet.
#[derive(Debug, Clone)]
pub struct Host {
    pub id: usize,
    /// Device-class tag (speed tier) the host belongs to.
    pub class: String,
    /// Relative throughput: reference tier = 1.0; a job of baseline
    /// duration `t` takes `t / speed` wall seconds here.
    pub speed: f64,
}

impl Host {
    /// A uniform fleet of `n` reference-speed hosts.
    pub fn uniform(n: usize) -> Vec<Host> {
        (0..n).map(|id| Host { id, class: "ref".into(), speed: 1.0 }).collect()
    }
}

/// A placement of jobs onto hosts, evaluated under the fleet's true
/// speeds.
#[derive(Debug, Clone, Default)]
pub struct HostPlacement {
    /// `(job index, host id)` in placement order.
    pub assignments: Vec<(usize, usize)>,
    /// Per-host finish time (true speeds), indexed like the host slice.
    pub finish: Vec<f64>,
    /// Max over [`HostPlacement::finish`].
    pub makespan: f64,
}

/// Build a fleet from per-class Amdahl fits: each `(class, count)` entry
/// contributes `count` hosts whose speed is the class's modeled
/// per-sample rate `1 / (a + b/d)` at width `d`, normalized so the
/// fastest tier sits at 1.0. Classes without a fit (and without a
/// class-less fallback) are treated as reference speed — calibration
/// that never ran must not invent a skew.
pub fn hosts_from_fits(calib: &Calib, classes: &[(String, usize)], d: usize) -> Vec<Host> {
    let rate = |class: &str| -> f64 {
        match calib.dp_fit_for(class) {
            Some((a, b)) if a + b > 0.0 => 1.0 / (a + b / d.max(1) as f64).max(1e-18),
            _ => 1.0,
        }
    };
    let rates: Vec<f64> = classes.iter().map(|(c, _)| rate(c)).collect();
    let top = rates.iter().fold(0.0f64, |m, &r| m.max(r)).max(1e-18);
    let mut hosts = vec![];
    let mut id = 0usize;
    for ((class, count), r) in classes.iter().zip(rates) {
        for _ in 0..*count {
            hosts.push(Host { id, class: class.clone(), speed: r / top });
            id += 1;
        }
    }
    hosts
}

/// Greedy LPT placement of jobs (given by their reference-speed
/// durations) onto `hosts`. With `aware` the planner schedules against
/// the hosts' calibrated speeds; without it every host is believed to
/// run at speed 1 (identical-device baseline). Either way the returned
/// finish times and makespan are computed under the *true* speeds.
pub fn place_jobs(durs: &[f64], hosts: &[Host], aware: bool) -> HostPlacement {
    if hosts.is_empty() {
        return HostPlacement::default();
    }
    let mut order: Vec<usize> = (0..durs.len()).collect();
    // Longest first; ties keep input order for determinism.
    order.sort_by(|&a, &b| durs[b].total_cmp(&durs[a]).then(a.cmp(&b)));
    let mut believed = vec![0.0f64; hosts.len()];
    let mut finish = vec![0.0f64; hosts.len()];
    let mut assignments = vec![];
    for j in order {
        let dur = durs[j].max(0.0);
        let (h, _) = hosts
            .iter()
            .enumerate()
            .map(|(h, host)| {
                let speed = if aware { host.speed.max(1e-18) } else { 1.0 };
                (h, believed[h] + dur / speed)
            })
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
            .unwrap();
        let speed = if aware { hosts[h].speed.max(1e-18) } else { 1.0 };
        believed[h] += dur / speed;
        finish[h] += dur / hosts[h].speed.max(1e-18);
        assignments.push((j, hosts[h].id));
    }
    let makespan = finish.iter().fold(0.0f64, |m, &f| m.max(f));
    HostPlacement { assignments, finish, makespan }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed() -> Vec<Host> {
        let mut hosts = vec![Host { id: 0, class: "fast".into(), speed: 1.0 }];
        for id in 1..4 {
            hosts.push(Host { id, class: "slow".into(), speed: 0.25 });
        }
        hosts
    }

    /// Every job is assigned exactly once and the makespan matches the
    /// per-host finish times.
    #[test]
    fn placement_is_a_partition() {
        let durs = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        let p = place_jobs(&durs, &skewed(), true);
        let mut seen: Vec<usize> = p.assignments.iter().map(|&(j, _)| j).collect();
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        let top = p.finish.iter().fold(0.0f64, f64::max);
        assert_eq!(p.makespan, top);
        assert!(place_jobs(&durs, &[], true).assignments.is_empty());
    }

    /// On a uniform fleet the two believed-speed models coincide — being
    /// speed-aware can never hurt when there is no skew.
    #[test]
    fn uniform_fleet_is_aware_invariant() {
        let durs = vec![4.0, 3.0, 2.0, 2.0, 1.0, 1.0];
        let hosts = Host::uniform(3);
        let aware = place_jobs(&durs, &hosts, true);
        let blind = place_jobs(&durs, &hosts, false);
        assert_eq!(aware.makespan, blind.makespan);
        assert_eq!(aware.assignments, blind.assignments);
    }

    /// The gate the skewed-fleet bench pins: on a mixed fast/slow fleet,
    /// believing the calibrated speeds strictly beats believing every
    /// host is identical (both evaluated under the true speeds).
    #[test]
    fn hetero_aware_beats_identical_on_skewed_fleet() {
        let durs: Vec<f64> = (0..12).map(|i| 1.0 + (i % 4) as f64).collect();
        let hosts = skewed();
        let aware = place_jobs(&durs, &hosts, true);
        let blind = place_jobs(&durs, &hosts, false);
        assert!(
            aware.makespan < blind.makespan,
            "aware {:.2} !< identical {:.2}",
            aware.makespan,
            blind.makespan
        );
    }

    /// Fleet construction from per-class fits: the faster tier normalizes
    /// to 1.0, the slower tier lands strictly below it, and classes
    /// without calibration default to reference speed.
    #[test]
    fn hosts_from_fits_rank_tiers() {
        let mut calib = Calib::default();
        calib.dp_fit_class.insert("fast".into(), (1.0e-4, 4.0e-4));
        calib.dp_fit_class.insert("slow".into(), (8.0e-4, 8.0e-4));
        let classes =
            vec![("fast".to_string(), 1usize), ("slow".to_string(), 2), ("mystery".to_string(), 1)];
        let hosts = hosts_from_fits(&calib, &classes, 2);
        assert_eq!(hosts.len(), 4);
        let speed =
            |c: &str| hosts.iter().find(|h| h.class == c).map(|h| h.speed).unwrap();
        assert!((speed("fast") - 1.0).abs() < 1e-12, "fastest tier normalizes to 1");
        assert!(speed("slow") < speed("fast"));
        assert!(speed("slow") > 0.0);
        // Uncalibrated class: raw rate 1.0, normalized against the top.
        assert!(speed("mystery") <= 1.0 && speed("mystery") > 0.0);
        assert_eq!(hosts.iter().map(|h| h.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
