//! Exact solver for the per-job packing problem `F(D, K)` (Eq. 18–19):
//! choose the subset of LoRA configurations that maximizes
//! `Σ_k H_k · r_k / T(H, D)` under the Eq.-(19) memory constraint.
//!
//! The paper hands this to Gurobi; the offline crate set has no solver, so
//! we built one: **branch & bound over inclusion decisions** with a
//! fractional-knapsack upper bound. The bound is valid because
//! `T(S, D)` is monotone in `S` (adding an adapter never makes a step
//! faster), so for any superset `S' ⊇ S`:
//! `f(S') ≤ (r(S) + fracknap(remaining)) / T(S, D)`.
//!
//! Instances here are small (≤ 120 items, capacity admits ~10–40), and the
//! include-first dive in density order *is* the greedy solution, so an
//! incumbent exists immediately; a node cap keeps worst cases bounded
//! (the paper reports < 1 s per Gurobi instance — same contract).

use crate::config::LoraConfig;
use crate::costmodel::{CostModel, ExecMode, Pack, TrainBudget};

/// One `F(D, K)` instance.
pub struct PackProblem<'a> {
    pub cm: &'a CostModel,
    /// Parallelism degree `D` of the job being formed.
    pub d: usize,
    pub mode: ExecMode,
    pub budget: &'a TrainBudget,
    /// Node budget for branch & bound; on exhaustion the incumbent (≥ the
    /// greedy solution) is returned.
    pub max_nodes: usize,
}

/// Solver outcome: the selected pack and its objective value.
#[derive(Debug, Clone)]
pub struct PackSolution {
    pub pack: Pack,
    /// `Σ r_k / T(H, D)` — rank-units per second.
    pub throughput: f64,
    /// Nodes explored (observability; planner stats).
    pub nodes: usize,
    /// True iff the node cap was hit (solution may be suboptimal).
    pub truncated: bool,
}

struct Item {
    cfg: LoraConfig,
    rank: f64,
    mem: f64,
}

struct Search<'a> {
    prob: &'a PackProblem<'a>,
    items: Vec<Item>,
    best_val: f64,
    best_set: Vec<usize>,
    nodes: usize,
    truncated: bool,
    /// Per-device memory is additive per item when charging true shapes —
    /// include-feasibility then runs on scalars (the ILP hot path).
    additive_mem: bool,
}

impl<'a> PackProblem<'a> {
    pub fn new(cm: &'a CostModel, d: usize, mode: ExecMode, budget: &'a TrainBudget) -> Self {
        PackProblem { cm, d, mode, budget, max_nodes: 200_000 }
    }

    /// Solve `F(D, K)` over `configs`. Returns `None` if not even a single
    /// configuration fits on `d` devices.
    pub fn solve(&self, configs: &[LoraConfig]) -> Option<PackSolution> {
        let sh = crate::costmodel::memory::Sharding::tp(self.d);
        let mut items: Vec<Item> = configs
            .iter()
            .filter(|c| self.cm.fits(&Pack::new(vec![(*c).clone()]), self.d))
            .map(|c| Item {
                cfg: c.clone(),
                rank: c.rank as f64,
                // Additive per-device cost: adapter state + the base-path
                // activation its samples add (both linear in the item).
                mem: self.cm.memory.lora_bytes(c, sh)
                    + self.cm.memory.base_act_bytes(c.batch as f64)
                        / (sh.tp * sh.pp) as f64,
            })
            .collect();
        if items.is_empty() {
            return None;
        }
        // Density order (rank per byte): both the dive order and the
        // fractional-bound order.
        items.sort_by(|a, b| (b.rank / b.mem).total_cmp(&(a.rank / a.mem)));

        let mut s = Search {
            prob: self,
            items,
            best_val: 0.0,
            best_set: vec![],
            nodes: 0,
            truncated: false,
            additive_mem: !self.cm.charge_padding,
        };
        s.branch(&mut vec![]);
        let pack = Pack::new(s.best_set.iter().map(|&i| s.items[i].cfg.clone()).collect());
        let throughput = self.objective(&pack);
        Some(PackSolution { pack, throughput, nodes: s.nodes, truncated: s.truncated })
    }

    /// The Eq.-(18) objective for a candidate pack.
    pub fn objective(&self, pack: &Pack) -> f64 {
        if pack.n() == 0 {
            return 0.0;
        }
        self.cm.throughput(pack, self.d, self.mode, self.budget)
    }
}

impl Search<'_> {
    fn pack_of(&self, chosen: &[usize]) -> Pack {
        Pack::new(chosen.iter().map(|&i| self.items[i].cfg.clone()).collect())
    }

    /// Per-device bytes the pack occupies beyond the frozen base — additive
    /// per item when shapes are true (sim mode), so include-feasibility and
    /// the knapsack bound run on scalars instead of rebuilding packs.
    fn mem_cap(&self) -> f64 {
        let sh = crate::costmodel::memory::Sharding::tp(self.prob.d);
        self.prob.cm.c_load * self.prob.cm.profile.mem_bytes
            - self.prob.cm.memory.base_bytes(0.0, sh)
    }

    /// Upper bound for any completion of `chosen` using items `>= next`:
    /// numerator by fractional knapsack on memory headroom; denominator by
    /// monotonicity of `T` — `T(S') >= T(S)`, and `T(S) = rank(S)/obj(S)`
    /// which the caller already computed (no job_time re-evaluation).
    fn upper_bound(&self, rank_sum: f64, obj: f64, mem_used: f64, next: usize) -> f64 {
        let mut headroom = (self.mem_cap() - mem_used).max(0.0);
        let mut num = rank_sum;
        for it in &self.items[next..] {
            if it.mem <= headroom {
                headroom -= it.mem;
                num += it.rank;
            } else {
                if headroom > 0.0 {
                    num += it.rank * headroom / it.mem;
                }
                break;
            }
        }
        if rank_sum <= 0.0 {
            // Empty prefix: bound by the best single-item throughput times
            // the knapsack numerator over that item's rank (coarse but
            // valid: T of any pack >= T of its cheapest member alone).
            let t_min = self
                .items[next..]
                .iter()
                .map(|it| {
                    self.prob.cm.job_time(
                        &Pack::new(vec![it.cfg.clone()]),
                        self.prob.d,
                        self.prob.mode,
                        self.prob.budget,
                    )
                })
                .fold(f64::INFINITY, f64::min);
            return if t_min.is_finite() { num / t_min } else { f64::INFINITY };
        }
        num * obj / rank_sum // = num / T(S)
    }

    fn branch(&mut self, chosen: &mut Vec<usize>) {
        self.branch_from(chosen, 0, 0.0, 0.0, 0.0);
    }

    /// `rank_sum`, `obj`, `mem_used` describe `chosen` (incremental state).
    fn branch_from(
        &mut self,
        chosen: &mut Vec<usize>,
        next: usize,
        rank_sum: f64,
        obj: f64,
        mem_used: f64,
    ) {
        self.nodes += 1;
        if self.nodes > self.prob.max_nodes {
            self.truncated = true;
            return;
        }
        if next >= self.items.len() {
            return;
        }
        if self.upper_bound(rank_sum, obj, mem_used, next) <= self.best_val {
            return; // prune: no completion can beat the incumbent
        }
        // Include item `next` if it fits (dive first: greedy incumbent).
        let it_mem = self.items[next].mem;
        let fits = if self.additive_mem {
            mem_used + it_mem <= self.mem_cap() && self.bucket_ok(chosen, next)
        } else {
            chosen.push(next);
            let ok = self.prob.cm.fits(&self.pack_of(chosen), self.prob.d);
            chosen.pop();
            ok
        };
        if fits {
            chosen.push(next);
            let pack = self.pack_of(chosen);
            let v = self.prob.objective(&pack);
            let r2 = rank_sum + self.items[next].rank;
            if v > self.best_val {
                self.best_val = v;
                self.best_set = chosen.clone();
            }
            self.branch_from(chosen, next + 1, r2, v, mem_used + it_mem);
            chosen.pop();
        }
        // Exclude item `next`.
        self.branch_from(chosen, next + 1, rank_sum, obj, mem_used);
    }

    /// Static-bucket feasibility of `chosen + {next}` (live mode only).
    fn bucket_ok(&self, chosen: &[usize], next: usize) -> bool {
        let Some(buckets) = &self.prob.cm.buckets else { return true };
        let n = chosen.len() + 1;
        let r = chosen
            .iter()
            .chain(std::iter::once(&next))
            .map(|&i| self.items[i].cfg.rank)
            .max()
            .unwrap_or(0);
        let bs = chosen
            .iter()
            .chain(std::iter::once(&next))
            .map(|&i| self.items[i].cfg.batch)
            .max()
            .unwrap_or(0);
        buckets.iter().any(|&(bn, br, bb)| bn >= n && br >= r && bb >= bs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::SearchSpace;

    fn cm() -> CostModel {
        CostModel::new(geom("qwen2.5-7b").unwrap(), &A100_40G)
    }

    fn cfg(id: usize, r: usize, bs: usize) -> LoraConfig {
        LoraConfig { id, lr: 1e-4, batch: bs, rank: r, alpha_ratio: 1.0, task: "t".into() }
    }

    #[test]
    fn picks_everything_when_it_all_fits() {
        let m = cm();
        let b = TrainBudget::default();
        let p = PackProblem::new(&m, 1, ExecMode::Packed, &b);
        let configs: Vec<_> = (0..4).map(|i| cfg(i, 16, 1)).collect();
        let sol = p.solve(&configs).unwrap();
        assert_eq!(sol.pack.n(), 4, "4 rank-16 adapters easily fit an A100");
        assert!(!sol.truncated);
    }

    #[test]
    fn respects_memory_capacity() {
        let m = cm();
        let b = TrainBudget::default();
        let p = PackProblem::new(&m, 1, ExecMode::Packed, &b);
        let configs: Vec<_> = (0..64).map(|i| cfg(i, 128, 4)).collect();
        let sol = p.solve(&configs).unwrap();
        assert!(sol.pack.n() < 64, "64 rank-128 bs-4 adapters cannot fit");
        assert!(m.fits(&sol.pack, 1), "returned pack must be feasible");
        assert!(sol.pack.n() >= 1);
    }

    #[test]
    fn returns_none_when_nothing_fits() {
        let m = CostModel::new(geom("qwen2.5-32b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let p = PackProblem::new(&m, 1, ExecMode::Packed, &b); // 32B needs 4 GPUs
        assert!(p.solve(&[cfg(0, 8, 1)]).is_none());
        let p4 = PackProblem::new(&m, 4, ExecMode::Packed, &b);
        assert!(p4.solve(&[cfg(0, 8, 1)]).is_some());
    }

    #[test]
    fn beats_or_matches_greedy_density_packing() {
        let m = cm();
        let b = TrainBudget::default();
        let p = PackProblem::new(&m, 1, ExecMode::Packed, &b);
        let configs = SearchSpace::default().grid("t");
        let sol = p.solve(&configs).unwrap();
        // Greedy-by-density baseline.
        let sh = crate::costmodel::memory::Sharding::tp(1);
        let mut sorted = configs.clone();
        sorted.sort_by(|a, b2| {
            let da = a.rank as f64 / m.memory.lora_bytes(a, sh);
            let db = b2.rank as f64 / m.memory.lora_bytes(b2, sh);
            db.total_cmp(&da)
        });
        let mut greedy = Pack::default();
        for c in sorted {
            let mut cand = greedy.clone();
            cand.configs.push(c);
            if m.fits(&cand, 1) {
                greedy = cand;
            }
        }
        let g = p.objective(&greedy);
        assert!(
            sol.throughput >= g * 0.999,
            "B&B {:.3} must be >= greedy {:.3}",
            sol.throughput,
            g
        );
    }

    #[test]
    fn solution_improves_with_more_devices() {
        let m = CostModel::new(geom("qwen2.5-14b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let configs: Vec<_> = (0..32).map(|i| cfg(i, 64, 2)).collect();
        let p2 = PackProblem::new(&m, 2, ExecMode::Packed, &b);
        let p4 = PackProblem::new(&m, 4, ExecMode::Packed, &b);
        let s2 = p2.solve(&configs).unwrap();
        let s4 = p4.solve(&configs).unwrap();
        assert!(s4.pack.n() >= s2.pack.n(), "more devices pack at least as many");
    }
}
