//! Algorithm 2 — **The Job Planner**: schedule every configuration in the
//! search space by repeatedly invoking DTM on the currently-free GPUs,
//! predicting the next job-completion event with the cost model, and
//! enqueueing the resulting jobs in the LoRA Job Queue.
//!
//! Also computes the Theorem-6.1 approximation-ratio bound
//! `AR ≤ F / (F − T_last · (G − D)/G)` for the produced schedule
//! (the paper reports AR ∈ [1.05, 1.14] on its testbed).

use anyhow::{bail, Result};

use crate::config::LoraConfig;
use crate::costmodel::{CostModel, ExecMode, TrainBudget};
use crate::planner::dtm::{Dtm, DtmStats};
use crate::planner::PlannedJob;

/// A planned job with its predicted timeline.
#[derive(Debug, Clone)]
pub struct ScheduledJob {
    pub job: PlannedJob,
    /// Predicted start/end (cost-model time, seconds).
    pub start: f64,
    pub end: f64,
}

/// The planner output: the LoRA Job Queue plus predictions and the
/// Theorem-6.1 bound.
#[derive(Debug, Clone)]
pub struct Plan {
    pub jobs: Vec<ScheduledJob>,
    /// Predicted makespan `F`.
    pub makespan: f64,
    /// Theorem 6.1 upper bound on the approximation ratio.
    pub ar_bound: f64,
    /// Certified makespan lower bound for *this packing*:
    /// `max(total device-seconds / G, longest single job)`. No schedule of
    /// these jobs can beat it, so `makespan / lb_makespan` certifies how
    /// close the greedy Alg.-2 ordering is to optimal (the quantity the
    /// paper's AR∈[1.05, 1.14] speaks to; Thm 6.1's bound is loose when
    /// one job spans most of the makespan).
    pub lb_makespan: f64,
    /// Pool size `G` the plan was computed for.
    pub gpus: usize,
    pub stats: DtmStats,
    /// Planner wall time.
    pub plan_secs: f64,
}

impl Plan {
    pub fn total_configs(&self) -> usize {
        self.jobs.iter().map(|j| j.job.pack.n()).sum()
    }

    /// Empirical optimality ratio of the schedule: makespan / lower bound.
    pub fn empirical_ratio(&self) -> f64 {
        if self.lb_makespan <= 0.0 {
            return 1.0;
        }
        self.makespan / self.lb_makespan
    }

    /// Average GPU occupancy of the predicted schedule (device-seconds used
    /// over `G × makespan`) — the utilization the paper's packing recovers.
    pub fn occupancy(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        let used: f64 = self.jobs.iter().map(|j| (j.end - j.start) * j.job.d as f64).sum();
        used / (self.gpus as f64 * self.makespan)
    }
}

/// Algorithm 2 driver.
pub struct JobPlanner {
    pub cm: CostModel,
    pub budget: TrainBudget,
    pub mode: ExecMode,
    /// Pool size `G`.
    pub gpus: usize,
    /// Choose a stage-pipeline depth `s` per job (the second parallelism
    /// axis, DESIGN.md §15). Off by default: the pre-pipeline plans (and
    /// the paper-pinned prediction tests) are reproduced exactly; when
    /// on, every planned job gets the depth minimizing its modeled
    /// duration under [`CostModel::pipeline_speedup`], and predicted
    /// timelines account for it. Pipelining shares the job's devices
    /// (stages are workers on the same allocation), so `s` never
    /// consumes pool capacity.
    pub stages: bool,
}

impl JobPlanner {
    pub fn new(cm: CostModel, gpus: usize) -> JobPlanner {
        JobPlanner {
            cm,
            budget: TrainBudget::default(),
            mode: ExecMode::Packed,
            gpus,
            stages: false,
        }
    }

    /// Plan the full search space. Errors if some configuration cannot fit
    /// the pool at any parallelism degree (it would loop forever in Alg. 2).
    pub fn plan(&self, configs: &[LoraConfig]) -> Result<Plan> {
        let t_wall = std::time::Instant::now();
        for c in configs {
            if self
                .cm
                .memory
                .min_tp(c, &self.cm.profile, self.cm.c_load, self.gpus)
                .is_none()
            {
                bail!(
                    "config {} (r={}, bs={}) does not fit {} x {} at any TP degree",
                    c.id,
                    c.rank,
                    c.batch,
                    self.gpus,
                    self.cm.profile.name
                );
            }
        }

        let mut remaining: Vec<LoraConfig> = configs.to_vec();
        let mut queue: Vec<ScheduledJob> = vec![];
        let mut stats = DtmStats::default();
        // Running jobs as (end_time, gpus) — the predicted completion
        // events of Alg. 2 line 9.
        let mut running: Vec<(f64, usize)> = vec![];
        let mut g_avail = self.gpus;
        let mut now = 0.0f64;
        let mut next_id = 0usize;

        while !remaining.is_empty() {
            if g_avail > 0 {
                let dtm = Dtm::new(&self.cm, &self.budget, self.mode);
                let (mut jobs, s) = dtm.plan(g_avail, &remaining);
                stats.ilp_calls += s.ilp_calls;
                stats.policies += s.policies;
                stats.nodes += s.nodes;
                // Balance the round: the sequential per-job ILP hoards long
                // configurations in the first pack (see planner/rebalance).
                crate::planner::rebalance::rebalance_round(
                    &self.cm,
                    &self.budget,
                    &mut jobs,
                    4 * remaining.len().max(8),
                );
                let mut jobs = crate::planner::rebalance::drop_empty(jobs);
                for job in &jobs {
                    let used: Vec<usize> = job.pack.configs.iter().map(|c| c.id).collect();
                    remaining.retain(|c| !used.contains(&c.id));
                }
                // Device-count-aware `d`: once the whole space is
                // scheduled, leftover devices would idle for the rest of
                // the round — widen the longest jobs while the modeled
                // parallel speedup strictly shortens them.
                if remaining.is_empty() {
                    let spare = g_avail - jobs.iter().map(|j| j.d).sum::<usize>();
                    self.widen_jobs(&mut jobs, spare);
                }
                if self.stages {
                    for job in &mut jobs {
                        job.s = self.choose_stages(&job.pack);
                    }
                }
                for mut job in jobs {
                    job.id = next_id;
                    next_id += 1;
                    let dur = self.job_dur(&job);
                    g_avail -= job.d;
                    running.push((now + dur, job.d));
                    queue.push(ScheduledJob { job, start: now, end: now + dur });
                }
            }
            if remaining.is_empty() {
                break;
            }
            // Advance to the next completion event (Alg. 2 line 9).
            if running.is_empty() {
                // No job running and nothing scheduled ⇒ DTM couldn't place
                // anything on g_avail GPUs; with the min_tp pre-check this
                // can only mean a bug — fail loudly instead of spinning.
                bail!("planner stalled with {} configs remaining", remaining.len());
            }
            let (idx, _) = running
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0))
                .unwrap();
            let (end, d) = running.swap_remove(idx);
            now = end.max(now);
            g_avail += d;
        }

        let makespan = queue.iter().map(|j| j.end).fold(0.0, f64::max);
        let ar_bound = ar_bound(&queue, self.gpus, makespan);
        let work: f64 = queue.iter().map(|j| (j.end - j.start) * j.job.d as f64).sum();
        let longest = queue.iter().map(|j| j.end - j.start).fold(0.0, f64::max);
        let lb_makespan = (work / self.gpus as f64).max(longest);
        Ok(Plan {
            jobs: queue,
            makespan,
            ar_bound,
            lb_makespan,
            gpus: self.gpus,
            stats,
            plan_secs: t_wall.elapsed().as_secs_f64(),
        })
    }
}

impl JobPlanner {
    /// Device-count-aware widening: the planner chooses each job's `d`
    /// instead of taking it from the caller. With the search space fully
    /// scheduled, `spare` devices would idle until the round drains, so
    /// the longest job's parallelism doubles while (a) the devices exist,
    /// (b) memory stays feasible at the wider degree, and (c) the modeled
    /// job time *strictly* shrinks under [`CostModel::parallel_speedup`]
    /// — the live-calibrated dp-efficiency term when a session published
    /// one, the static TP curve otherwise. A calibration showing no
    /// data-parallel benefit (serial-dominated fit) therefore pins every
    /// job at its minimal degree.
    fn widen_jobs(&self, jobs: &mut [PlannedJob], mut spare: usize) -> usize {
        let mut grew = 0usize;
        // Jobs proven unwidenable (memory, spare, or no strict speedup)
        // are frozen rather than ending the pass — a shorter job may
        // still profitably take the spare devices.
        let mut frozen = vec![false; jobs.len()];
        loop {
            let Some((i, dur)) = jobs
                .iter()
                .enumerate()
                .filter(|(i, _)| !frozen[*i])
                .map(|(i, j)| (i, self.cm.job_time(&j.pack, j.d, j.mode, &self.budget)))
                .max_by(|a, b| a.1.total_cmp(&b.1))
            else {
                break;
            };
            let extra = jobs[i].d; // double the degree (power-of-two, Eq. 16)
            if extra == 0 || extra > spare || !self.cm.fits(&jobs[i].pack, jobs[i].d * 2) {
                frozen[i] = true;
                continue;
            }
            let t2 = self.cm.job_time(&jobs[i].pack, jobs[i].d * 2, jobs[i].mode, &self.budget);
            if t2 >= dur * (1.0 - 1e-9) {
                frozen[i] = true; // wider is not strictly faster here
                continue;
            }
            jobs[i].d *= 2;
            spare -= extra;
            grew += extra;
        }
        grew
    }

    /// Modeled wall time of one planned job at its chosen `(d, s)`: the
    /// phase-wise [`CostModel::job_time`] divided by the pipeline
    /// utilization at depth `s` over the pack's slot count (the executed
    /// microbatch is one slot). `s ≤ 1` reproduces `job_time` exactly.
    pub fn job_dur(&self, job: &PlannedJob) -> f64 {
        let t = self.cm.job_time(&job.pack, job.d, job.mode, &self.budget);
        let s = job.stages().min(self.cm.geom.n_layers.max(1));
        if s <= 1 {
            return t;
        }
        t / self.cm.pipeline_speedup(s, job.pack.n().max(1))
    }

    /// The `s` half of the `(d, s)` choice: the power-of-two depth (≤ the
    /// layer stack) maximizing the modeled pipeline speedup for this
    /// pack's microbatch count. Depth 1 wins whenever no deeper pipeline
    /// is *strictly* faster — a single-slot pack, or a boundary cost that
    /// eats the bubble gain — so enabling stage planning can never slow a
    /// modeled plan down.
    pub fn choose_stages(&self, pack: &crate::costmodel::Pack) -> usize {
        let m = pack.n().max(1);
        let cap = self.cm.geom.n_layers.max(1);
        let mut best = (1usize, 1.0f64);
        let mut s = 2usize;
        while s <= cap {
            let sp = self.cm.pipeline_speedup(s, m);
            if sp > best.1 * (1.0 + 1e-9) {
                best = (s, sp);
            }
            s *= 2;
        }
        best.0
    }
}

/// Planner-side priority assignment: shortest-job-first ranks from
/// modeled work ([`CostModel::job_time`]) for callers that submit without
/// explicit priorities. Shorter modeled jobs get strictly higher ranks
/// (SJF minimizes mean completion time on a shared pool); ties keep
/// input order. Returns one rank per entry of `jobs`, aligned by index —
/// feed them to `Session::submit_planned_at` under a priority policy.
pub fn sjf_priorities(
    cm: &crate::costmodel::CostModel,
    budget: &TrainBudget,
    jobs: &[PlannedJob],
) -> Vec<i32> {
    let times: Vec<f64> =
        jobs.iter().map(|j| cm.job_time(&j.pack, j.d, j.mode, budget)).collect();
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| times[a].total_cmp(&times[b]).then(a.cmp(&b)));
    let mut prios = vec![0i32; jobs.len()];
    for (rank, &i) in order.iter().enumerate() {
        prios[i] = jobs.len() as i32 - rank as i32;
    }
    prios
}

/// Priorities for a queue whose caller supplied none: zero ranks when
/// `sjf` is off (FIFO — submission order already encodes the queue),
/// [`sjf_priorities`] otherwise. The one entry point `search::sweep` and
/// `plora serve` share.
pub fn default_priorities(
    cm: &crate::costmodel::CostModel,
    budget: &TrainBudget,
    jobs: &[PlannedJob],
    sjf: bool,
) -> Vec<i32> {
    if sjf {
        sjf_priorities(cm, budget, jobs)
    } else {
        vec![0; jobs.len()]
    }
}

/// Theorem 6.1: `AR ≤ F / (F − T_last · (G − D)/G)` where the "last job"
/// is the one finishing at the makespan.
fn ar_bound(queue: &[ScheduledJob], gpus: usize, makespan: f64) -> f64 {
    let Some(last) = queue.iter().max_by(|a, b| a.end.total_cmp(&b.end)) else {
        return 1.0;
    };
    let t_last = last.end - last.start;
    let d = last.job.d as f64;
    let g = gpus as f64;
    let denom = makespan - t_last * (g - d) / g;
    if denom <= 0.0 {
        f64::INFINITY
    } else {
        makespan / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::SearchSpace;

    fn planner(model: &str) -> JobPlanner {
        JobPlanner::new(CostModel::new(geom(model).unwrap(), &A100_40G), 8)
    }

    #[test]
    fn plans_the_full_120_grid() {
        let p = planner("qwen2.5-7b");
        let grid = SearchSpace::default().grid("gsm8k");
        let plan = p.plan(&grid).unwrap();
        assert_eq!(plan.total_configs(), 120, "every configuration scheduled");
        // Each config exactly once.
        let mut ids: Vec<usize> =
            plan.jobs.iter().flat_map(|j| j.job.pack.configs.iter().map(|c| c.id)).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 120);
        assert!(plan.makespan > 0.0);
    }

    #[test]
    fn schedule_is_feasible_no_gpu_oversubscription() {
        let p = planner("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = p.plan(&grid).unwrap();
        // Sweep event points: at any time, Σ d of active jobs ≤ G.
        let mut points: Vec<f64> = plan.jobs.iter().flat_map(|j| [j.start, j.end]).collect();
        points.sort_by(|a, b| a.total_cmp(b));
        for &t in &points {
            let active: usize = plan
                .jobs
                .iter()
                .filter(|j| j.start <= t + 1e-9 && t + 1e-9 < j.end)
                .map(|j| j.job.d)
                .sum();
            assert!(active <= 8, "oversubscribed at t={t}: {active} GPUs");
        }
    }

    #[test]
    fn ar_bound_in_papers_range() {
        // Paper §6: "AR between 1.05 and 1.14" on their testbed; we assert
        // the bound is finite, ≥ 1, and not wildly loose.
        let p = planner("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = p.plan(&grid).unwrap();
        assert!(plan.ar_bound >= 1.0);
        // Thm 6.1's bound is loose when one job spans most of the makespan
        // (our compressed schedules); the certified empirical ratio is the
        // tight statement and should sit in the paper's reported range.
        let r = plan.empirical_ratio();
        assert!((1.0..1.35).contains(&r), "empirical ratio {r:.3} (paper 1.05-1.14)");
    }

    #[test]
    fn occupancy_is_high_for_homogeneous_grid() {
        let p = planner("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let plan = p.plan(&grid).unwrap();
        let occ = plan.occupancy();
        assert!(occ > 0.6, "schedule occupancy {occ:.2} too low");
    }

    #[test]
    fn rejects_impossible_configs() {
        let mut p = planner("qwen2.5-32b");
        p.gpus = 1; // 32B needs 4 GPUs
        let grid = SearchSpace::default().grid("t");
        assert!(p.plan(&grid[..4]).is_err());
    }

    #[test]
    fn multi_gpu_models_schedule_cleanly() {
        let p = planner("qwen2.5-14b");
        let grid = SearchSpace::default().grid("t");
        let plan = p.plan(&grid[..40]).unwrap();
        assert_eq!(plan.total_configs(), 40);
        assert!(plan.jobs.iter().all(|j| j.job.d >= 2));
    }

    /// Device-count-aware widening: with spare devices and a modeled
    /// speedup, the longest job's `d` doubles; a serial-dominated dp
    /// calibration pins everything at the minimal degree instead.
    #[test]
    fn widen_jobs_grows_longest_only_when_speedup_is_real() {
        use crate::costmodel::{ExecMode, Pack};
        let mut p = planner("qwen2.5-7b");
        let cfg = |id: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: 1,
            rank: 32,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let mk = || {
            vec![
                PlannedJob {
                    id: 0,
                    pack: Pack::new(vec![cfg(0), cfg(1), cfg(2)]),
                    d: 1,
                    s: 0,
                    mode: ExecMode::Packed,
                },
                PlannedJob {
                    id: 1,
                    pack: Pack::new(vec![cfg(3)]),
                    d: 1,
                    s: 0,
                    mode: ExecMode::Packed,
                },
            ]
        };
        // Perfectly parallel dp fit: widening pays and takes the spare.
        p.cm.calib.dp_fit = Some((0.0, 1e-3));
        let mut jobs = mk();
        let grew = p.widen_jobs(&mut jobs, 2);
        assert!(grew >= 1, "spare devices must be soaked when speedup is real");
        assert!(jobs.iter().any(|j| j.d >= 2));
        assert!(jobs.iter().map(|j| j.d).sum::<usize>() <= 4);
        // Serial-dominated fit: speedup(2) ≈ 1, widening never fires.
        p.cm.calib.dp_fit = Some((1e-3, 0.0));
        let mut jobs = mk();
        assert_eq!(p.widen_jobs(&mut jobs, 2), 0);
        assert!(jobs.iter().all(|j| j.d == 1));
    }

    /// Shortest-job-first priorities: the shortest modeled job outranks
    /// everything, ranks are a permutation, and ties keep input order.
    #[test]
    fn sjf_priorities_rank_short_jobs_highest() {
        use crate::costmodel::{ExecMode, Pack};
        let p = planner("qwen2.5-7b");
        let cfg = |id: usize, bs: usize| LoraConfig {
            id,
            lr: 1e-4,
            batch: bs,
            rank: 32,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        // bs 1 -> many steps (long); bs 4 -> few steps (short).
        let jobs = vec![
            PlannedJob {
                id: 0,
                pack: Pack::new(vec![cfg(0, 1)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            PlannedJob {
                id: 1,
                pack: Pack::new(vec![cfg(1, 4)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
            PlannedJob {
                id: 2,
                pack: Pack::new(vec![cfg(2, 4)]),
                d: 1,
                s: 0,
                mode: ExecMode::Packed,
            },
        ];
        let prios = sjf_priorities(&p.cm, &p.budget, &jobs);
        assert_eq!(prios.len(), 3);
        assert!(prios[1] > prios[0], "short job must outrank the long one");
        assert!(prios[1] > prios[2], "ties resolve by input order");
        let mut sorted = prios.clone();
        sorted.sort();
        assert_eq!(sorted, vec![1, 2, 3], "ranks are a permutation of 1..=n");
    }

    /// The `(d, s)` chooser: with stage planning off every job keeps
    /// `s = 0` (pre-pipeline plans are bit-stable); with it on, multi-slot
    /// jobs get the modeled-fastest power-of-two depth, predicted
    /// durations account for it, and the planned makespan never grows.
    #[test]
    fn stage_planning_chooses_depth_and_never_slows_the_plan() {
        use crate::costmodel::Pack;
        let p = planner("qwen2.5-7b");
        let grid = SearchSpace::default().grid("t");
        let base = p.plan(&grid[..8]).unwrap();
        assert!(base.jobs.iter().all(|j| j.job.s == 0), "stages off: s stays unplanned");

        let mut ps = planner("qwen2.5-7b");
        ps.stages = true;
        let plan = ps.plan(&grid[..8]).unwrap();
        assert_eq!(plan.total_configs(), 8);
        assert!(plan.jobs.iter().all(|j| j.job.s >= 1), "stages on: every job planned a depth");
        assert!(
            plan.jobs.iter().all(|j| j.job.s.is_power_of_two()
                && j.job.s <= ps.cm.geom.n_layers.max(1)),
            "depths are power-of-two and bounded by the layer stack"
        );
        assert!(
            plan.jobs.iter().any(|j| j.job.pack.n() > 1 && j.job.s > 1),
            "a multi-slot pack must pipeline when the model says it pays"
        );
        assert!(
            plan.makespan <= base.makespan * (1.0 + 1e-9),
            "pipelined plan {:.3} must not exceed flat plan {:.3}",
            plan.makespan,
            base.makespan
        );
        // Per-job: the chosen depth's modeled duration is the argmin over
        // the candidate depths, and a single-slot pack never pipelines.
        let solo_cfg = LoraConfig {
            id: 9,
            lr: 1e-4,
            batch: 1,
            rank: 32,
            alpha_ratio: 1.0,
            task: "t".into(),
        };
        let solo = Pack::new(vec![solo_cfg]);
        assert_eq!(ps.choose_stages(&solo), 1, "one microbatch is pure bubble");
        for j in &plan.jobs {
            let chosen = ps.job_dur(&j.job);
            let mut probe = j.job.clone();
            for s in [1usize, 2, 4, 8] {
                probe.s = s;
                assert!(
                    chosen <= ps.job_dur(&probe) * (1.0 + 1e-9),
                    "job {}: s={} beats the chosen s={}",
                    j.job.id,
                    s,
                    j.job.s
                );
            }
        }
    }
}
