//! The PLoRA packing planner (§6): the per-job packing ILP (`ilp`), the
//! DTM enumeration over parallelism degrees (`dtm`, Alg. 1), the job
//! planner that emits the LoRA Job Queue (`job_planner`, Alg. 2 +
//! Theorem 6.1), and the evaluation baselines (`baselines`: Min GPU,
//! Max GPU, Sequential-PLoRA).

pub mod baselines;
pub mod dtm;
pub mod ilp;
pub mod job_planner;
pub mod rebalance;

pub use baselines::{max_gpu_plan, min_gpu_plan, sequential_plora_plan};
pub use dtm::{Dtm, DtmStats};
pub use ilp::{PackProblem, PackSolution};
pub use job_planner::{default_priorities, sjf_priorities, JobPlanner, Plan};
pub use rebalance::rebalance_round;

use crate::costmodel::{ExecMode, Pack};

/// One fine-tuning job produced by planning: a pack of LoRA configurations
/// plus the parallelism degree and kernel mode it will execute with.
#[derive(Debug, Clone)]
pub struct PlannedJob {
    pub id: usize,
    pub pack: Pack,
    /// Parallelism degree `d_j` (number of GPUs, power of two).
    pub d: usize,
    pub mode: ExecMode,
}

impl PlannedJob {
    /// Short human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "job{} [n={} r̄={} d={} {:?}]",
            self.id,
            self.pack.n(),
            self.pack.r_pad(),
            self.d,
            self.mode
        )
    }
}
