//! The PLoRA packing planner (§6): the per-job packing ILP (`ilp`), the
//! DTM enumeration over parallelism degrees (`dtm`, Alg. 1), the job
//! planner that emits the LoRA Job Queue (`job_planner`, Alg. 2 +
//! Theorem 6.1), and the evaluation baselines (`baselines`: Min GPU,
//! Max GPU, Sequential-PLoRA).

pub mod baselines;
pub mod dtm;
pub mod hetero;
pub mod ilp;
pub mod job_planner;
pub mod rebalance;

pub use baselines::{max_gpu_plan, min_gpu_plan, sequential_plora_plan};
pub use dtm::{Dtm, DtmStats};
pub use hetero::{hosts_from_fits, place_jobs, Host, HostPlacement};
pub use ilp::{PackProblem, PackSolution};
pub use job_planner::{default_priorities, sjf_priorities, JobPlanner, Plan};
pub use rebalance::rebalance_round;

use crate::costmodel::{ExecMode, Pack};

/// One fine-tuning job produced by planning: a pack of LoRA configurations
/// plus the parallelism degree, pipeline depth and kernel mode it will
/// execute with.
#[derive(Debug, Clone)]
pub struct PlannedJob {
    pub id: usize,
    pub pack: Pack,
    /// Parallelism degree `d_j` (number of GPUs, power of two).
    pub d: usize,
    /// Stage-pipeline depth `s_j` (contiguous layer stages streamed per
    /// microbatch). `0` means "unplanned" — execution inherits the
    /// `PLORA_STAGES` default; the planner's `(d, s)` chooser writes an
    /// explicit depth ≥ 1. Trajectories are depth-invariant (DESIGN.md
    /// §15), so `s` only moves the timeline.
    pub s: usize,
    pub mode: ExecMode,
}

impl PlannedJob {
    /// The pipeline depth execution should use: the planned `s`, or 1
    /// slot-for-slot with the pre-pipeline behavior when unplanned.
    pub fn stages(&self) -> usize {
        self.s.max(1)
    }

    /// Short human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "job{} [n={} r̄={} d={} s={} {:?}]",
            self.id,
            self.pack.n(),
            self.pack.r_pad(),
            self.d,
            self.stages(),
            self.mode
        )
    }
}
