//! Round load balancing: after DTM forms a round's jobs, durations can be
//! badly skewed — the sequential per-job ILP greedily builds one maximal
//! pack at a time, so the first job hoards the long (small-batch)
//! configurations and finishes long after the rest, idling GPUs.
//!
//! This pass moves configurations between the round's jobs while the
//! round's longest duration strictly decreases and memory stays feasible —
//! the scheduling-side "load balancing for heterogeneous adapters" the
//! paper applies inside its kernels (§5.2), applied at job granularity.

use crate::costmodel::{CostModel, Pack, TrainBudget};
use crate::planner::PlannedJob;

/// Balance a round of concurrent jobs in place. Returns the number of
/// configuration moves applied.
pub fn rebalance_round(
    cm: &CostModel,
    budget: &TrainBudget,
    jobs: &mut [PlannedJob],
    max_moves: usize,
) -> usize {
    if jobs.len() < 2 {
        return 0;
    }
    let dur = |j: &PlannedJob| cm.job_time(&j.pack, j.d, j.mode, budget);
    let mut moves = 0;
    while moves < max_moves {
        // Current longest / shortest jobs.
        let (hi, hi_t) = match jobs
            .iter()
            .enumerate()
            .map(|(i, j)| (i, dur(j)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
        {
            Some(x) => x,
            None => return moves,
        };
        let mut improved = false;
        // Try moving each config of the longest job to any shorter job,
        // best destination first.
        let mut dests: Vec<(usize, f64)> = jobs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != hi)
            .map(|(i, j)| (i, dur(j)))
            .collect();
        dests.sort_by(|a, b| a.1.total_cmp(&b.1));
        'outer: for ci in 0..jobs[hi].pack.n() {
            let c = jobs[hi].pack.configs[ci].clone();
            for &(di, dest_t) in &dests {
                if dest_t >= hi_t {
                    break;
                }
                // Candidate move.
                let mut new_dest = jobs[di].pack.clone();
                new_dest.configs.push(c.clone());
                if !cm.fits(&new_dest, jobs[di].d) {
                    continue;
                }
                let mut new_src = jobs[hi].pack.clone();
                new_src.configs.remove(ci);
                let t_src = if new_src.n() == 0 {
                    0.0
                } else {
                    cm.job_time(&new_src, jobs[hi].d, jobs[hi].mode, budget)
                };
                let t_dst = cm.job_time(&new_dest, jobs[di].d, jobs[di].mode, budget);
                if t_src.max(t_dst) < hi_t * (1.0 - 1e-6) {
                    jobs[hi].pack = new_src;
                    jobs[di].pack = new_dest;
                    moves += 1;
                    improved = true;
                    break 'outer;
                }
            }
        }
        if !improved {
            break;
        }
    }
    // Drop jobs that were emptied by the moves.
    moves
}

/// Remove jobs whose packs became empty after rebalancing.
pub fn drop_empty(jobs: Vec<PlannedJob>) -> Vec<PlannedJob> {
    jobs.into_iter().filter(|j| j.pack.n() > 0).collect()
}

/// Engine-side shrink at an adapter-completion boundary (§4): the smallest
/// `(n, r, bs)` bucket in `buckets` that admits the surviving pack, when it
/// is strictly smaller (by padded element count) than `current`. `None`
/// means "keep riding the current bucket" — either no bucket admits the
/// survivors or none is smaller. This is the planning decision the live
/// session consults when an adapter converges, so the cost model's
/// phase-wise `job_time` is realized instead of padding to job end.
pub fn shrink_bucket(
    buckets: &[(usize, usize, usize)],
    survivors: &Pack,
    current: (usize, usize, usize),
) -> Option<(usize, usize, usize)> {
    if survivors.n() == 0 {
        return None;
    }
    let (n, r, bs) = (survivors.n(), survivors.r_pad(), survivors.bs_pad());
    let best = buckets
        .iter()
        .copied()
        .filter(|&(bn, br, bb)| bn >= n && br >= r && bb >= bs)
        .min_by_key(|&(bn, br, bb)| bn * br * bb)?;
    let vol = |(a, b, c): (usize, usize, usize)| a * b * c;
    (vol(best) < vol(current)).then_some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::LoraConfig;
    use crate::costmodel::ExecMode;

    fn cfg(id: usize, r: usize, bs: usize) -> LoraConfig {
        LoraConfig { id, lr: 1e-4, batch: bs, rank: r, alpha_ratio: 1.0, task: "t".into() }
    }

    fn job(id: usize, configs: Vec<LoraConfig>) -> PlannedJob {
        PlannedJob { id, pack: Pack::new(configs), d: 1, mode: ExecMode::Sequential }
    }

    #[test]
    fn rebalance_reduces_round_makespan() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        // Skewed round: job 0 hoards 12 long (bs=1) configs; job 1 has two
        // short (bs=4) ones.
        let mut jobs = vec![
            job(0, (0..12).map(|i| cfg(i, 16, 1)).collect()),
            job(1, vec![cfg(100, 16, 4), cfg(101, 16, 4)]),
        ];
        let t_before: f64 = jobs
            .iter()
            .map(|j| cm.job_time(&j.pack, j.d, j.mode, &b))
            .fold(0.0, f64::max);
        let moves = rebalance_round(&cm, &b, &mut jobs, 100);
        assert!(moves > 0, "skewed round must trigger moves");
        let t_after: f64 = jobs
            .iter()
            .map(|j| cm.job_time(&j.pack, j.d, j.mode, &b))
            .fold(0.0, f64::max);
        assert!(t_after < t_before * 0.8, "round T {t_before:.0} -> {t_after:.0}");
        // No config lost or duplicated.
        let mut ids: Vec<usize> =
            jobs.iter().flat_map(|j| j.pack.configs.iter().map(|c| c.id)).collect();
        ids.sort();
        assert_eq!(ids.len(), 14);
        ids.dedup();
        assert_eq!(ids.len(), 14);
        // All packs still feasible.
        for j in &jobs {
            assert!(cm.fits(&j.pack, j.d));
        }
    }

    #[test]
    fn balanced_round_is_left_alone() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let mut jobs = vec![
            job(0, (0..4).map(|i| cfg(i, 16, 1)).collect()),
            job(1, (4..8).map(|i| cfg(i, 16, 1)).collect()),
        ];
        assert_eq!(rebalance_round(&cm, &b, &mut jobs, 100), 0);
    }

    #[test]
    fn single_job_round_noop() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let mut jobs = vec![job(0, vec![cfg(0, 8, 1)])];
        assert_eq!(rebalance_round(&cm, &b, &mut jobs, 100), 0);
    }

    /// Boundary shrink: survivors move to the smallest admitting bucket,
    /// and only when that is strictly smaller than the current one.
    #[test]
    fn shrink_bucket_picks_smallest_strictly_smaller() {
        // The nano-style grid plus a rank-32 tier.
        let grid = [(1, 8, 1), (2, 8, 1), (4, 8, 1), (2, 8, 2), (2, 32, 2)];
        let one = Pack::new(vec![cfg(0, 8, 1)]);
        assert_eq!(shrink_bucket(&grid, &one, (2, 8, 2)), Some((1, 8, 1)));
        // Already on the smallest admitting bucket: no move.
        assert_eq!(shrink_bucket(&grid, &one, (1, 8, 1)), None);
        // Rank shrink: a rank-8 survivor leaves the rank-32 bucket.
        let two = Pack::new(vec![cfg(0, 8, 1), cfg(1, 8, 2)]);
        assert_eq!(shrink_bucket(&grid, &two, (2, 32, 2)), Some((2, 8, 2)));
        // Nothing admits an oversized pack.
        let big = Pack::new(vec![cfg(0, 64, 1)]);
        assert_eq!(shrink_bucket(&grid, &big, (2, 32, 2)), None);
        // Empty survivor set never re-buckets.
        assert_eq!(shrink_bucket(&grid, &Pack::new(vec![]), (2, 8, 2)), None);
    }
}
