//! Round load balancing: after DTM forms a round's jobs, durations can be
//! badly skewed — the sequential per-job ILP greedily builds one maximal
//! pack at a time, so the first job hoards the long (small-batch)
//! configurations and finishes long after the rest, idling GPUs.
//!
//! This pass moves configurations between the round's jobs while the
//! round's longest duration strictly decreases and memory stays feasible —
//! the scheduling-side "load balancing for heterogeneous adapters" the
//! paper applies inside its kernels (§5.2), applied at job granularity.

use crate::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use crate::planner::PlannedJob;

/// Balance a round of concurrent jobs in place. Returns the number of
/// configuration moves applied.
pub fn rebalance_round(
    cm: &CostModel,
    budget: &TrainBudget,
    jobs: &mut [PlannedJob],
    max_moves: usize,
) -> usize {
    if jobs.len() < 2 {
        return 0;
    }
    let dur = |j: &PlannedJob| cm.job_time(&j.pack, j.d, j.mode, budget);
    let mut moves = 0;
    while moves < max_moves {
        // Current longest / shortest jobs.
        let (hi, hi_t) = match jobs
            .iter()
            .enumerate()
            .map(|(i, j)| (i, dur(j)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
        {
            Some(x) => x,
            None => return moves,
        };
        let mut improved = false;
        // Try moving each config of the longest job to any shorter job,
        // best destination first.
        let mut dests: Vec<(usize, f64)> = jobs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != hi)
            .map(|(i, j)| (i, dur(j)))
            .collect();
        dests.sort_by(|a, b| a.1.total_cmp(&b.1));
        'outer: for ci in 0..jobs[hi].pack.n() {
            let c = jobs[hi].pack.configs[ci].clone();
            for &(di, dest_t) in &dests {
                if dest_t >= hi_t {
                    break;
                }
                // Candidate move.
                let mut new_dest = jobs[di].pack.clone();
                new_dest.configs.push(c.clone());
                if !cm.fits(&new_dest, jobs[di].d) {
                    continue;
                }
                let mut new_src = jobs[hi].pack.clone();
                new_src.configs.remove(ci);
                let t_src = if new_src.n() == 0 {
                    0.0
                } else {
                    cm.job_time(&new_src, jobs[hi].d, jobs[hi].mode, budget)
                };
                let t_dst = cm.job_time(&new_dest, jobs[di].d, jobs[di].mode, budget);
                if t_src.max(t_dst) < hi_t * (1.0 - 1e-6) {
                    jobs[hi].pack = new_src;
                    jobs[di].pack = new_dest;
                    moves += 1;
                    improved = true;
                    break 'outer;
                }
            }
        }
        if !improved {
            break;
        }
    }
    // Drop jobs that were emptied by the moves.
    moves
}

/// Remove jobs whose packs became empty after rebalancing.
pub fn drop_empty(jobs: Vec<PlannedJob>) -> Vec<PlannedJob> {
    jobs.into_iter().filter(|j| j.pack.n() > 0).collect()
}

/// Does a static-shape `(n, r, bs)` bucket admit `pack`? (Every dimension
/// must dominate the pack's padded shape; the empty pack is admitted by
/// nothing — there is no job to run.)
pub fn admits(bucket: (usize, usize, usize), pack: &Pack) -> bool {
    let (bn, br, bb) = bucket;
    pack.n() > 0 && bn >= pack.n() && br >= pack.r_pad() && bb >= pack.bs_pad()
}

/// Elastic bucket retargeting at an adapter-completion boundary (§4): the
/// bucket the combined pack (`survivors` still training ∪ `joiners` being
/// admitted mid-job) should run its next phase on. Generalizes the old
/// one-way shrink — the move can *grow* the bucket when joiners need more
/// slots/rank/batch than the current artifact has.
///
/// Returns `Some(target)` only when switching is worth it:
///
/// - the target must admit the combined pack;
/// - if `current` cannot hold the combined pack (joiners force growth) the
///   cheapest admitting bucket is returned unconditionally — admission was
///   already decided by the caller, the only question is *which* bucket;
/// - otherwise the move must pay for itself: the modeled saving over the
///   next phase, `phase_steps × (t_step(current) − t_step(target))`, has
///   to exceed `switch_cost` (checkpoint + repack + re-derive — the
///   [`CostModel::bucket_switch_cost`][c] term, live-calibrated via
///   `CalibUpdated`). `None` means "keep riding the current bucket".
///
/// Step times charge the full padded bucket shape
/// ([`CostModel::bucket_step_time`]) at the pack's executed device count
/// `d` (the dp-efficiency term scales the base share); ties break toward
/// the smaller padded volume.
///
/// [c]: crate::costmodel::throughput::Calib::bucket_switch_cost
#[allow(clippy::too_many_arguments)]
pub fn retarget_bucket(
    buckets: &[(usize, usize, usize)],
    survivors: &Pack,
    joiners: &Pack,
    current: (usize, usize, usize),
    cm: &CostModel,
    d: usize,
    switch_cost: f64,
    phase_steps: usize,
) -> Option<(usize, usize, usize)> {
    let mut combined = survivors.clone();
    combined.configs.extend(joiners.configs.iter().cloned());
    if combined.n() == 0 {
        return None;
    }
    let vol = |(a, b, c): (usize, usize, usize)| a * b * c;
    let score = |b: (usize, usize, usize)| cm.bucket_step_time(b, d.max(1), ExecMode::Packed);
    let best = buckets
        .iter()
        .copied()
        .filter(|&b| admits(b, &combined))
        .min_by(|&x, &y| score(x).total_cmp(&score(y)).then(vol(x).cmp(&vol(y))))?;
    if best == current {
        return None;
    }
    if !admits(current, &combined) {
        // Forced move: the current artifact cannot hold the joiners.
        return Some(best);
    }
    let saving = phase_steps as f64 * (score(current) - score(best));
    (saving > switch_cost).then_some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::geometry::geom;
    use crate::config::pool::A100_40G;
    use crate::config::LoraConfig;
    use crate::costmodel::ExecMode;

    fn cfg(id: usize, r: usize, bs: usize) -> LoraConfig {
        LoraConfig { id, lr: 1e-4, batch: bs, rank: r, alpha_ratio: 1.0, task: "t".into() }
    }

    fn job(id: usize, configs: Vec<LoraConfig>) -> PlannedJob {
        PlannedJob { id, pack: Pack::new(configs), d: 1, s: 0, mode: ExecMode::Sequential }
    }

    #[test]
    fn rebalance_reduces_round_makespan() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        // Skewed round: job 0 hoards 12 long (bs=1) configs; job 1 has two
        // short (bs=4) ones.
        let mut jobs = vec![
            job(0, (0..12).map(|i| cfg(i, 16, 1)).collect()),
            job(1, vec![cfg(100, 16, 4), cfg(101, 16, 4)]),
        ];
        let t_before: f64 = jobs
            .iter()
            .map(|j| cm.job_time(&j.pack, j.d, j.mode, &b))
            .fold(0.0, f64::max);
        let moves = rebalance_round(&cm, &b, &mut jobs, 100);
        assert!(moves > 0, "skewed round must trigger moves");
        let t_after: f64 = jobs
            .iter()
            .map(|j| cm.job_time(&j.pack, j.d, j.mode, &b))
            .fold(0.0, f64::max);
        assert!(t_after < t_before * 0.8, "round T {t_before:.0} -> {t_after:.0}");
        // No config lost or duplicated.
        let mut ids: Vec<usize> =
            jobs.iter().flat_map(|j| j.pack.configs.iter().map(|c| c.id)).collect();
        ids.sort();
        assert_eq!(ids.len(), 14);
        ids.dedup();
        assert_eq!(ids.len(), 14);
        // All packs still feasible.
        for j in &jobs {
            assert!(cm.fits(&j.pack, j.d));
        }
    }

    #[test]
    fn balanced_round_is_left_alone() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let mut jobs = vec![
            job(0, (0..4).map(|i| cfg(i, 16, 1)).collect()),
            job(1, (4..8).map(|i| cfg(i, 16, 1)).collect()),
        ];
        assert_eq!(rebalance_round(&cm, &b, &mut jobs, 100), 0);
    }

    #[test]
    fn single_job_round_noop() {
        let cm = CostModel::new(geom("qwen2.5-3b").unwrap(), &A100_40G);
        let b = TrainBudget::default();
        let mut jobs = vec![job(0, vec![cfg(0, 8, 1)])];
        assert_eq!(rebalance_round(&cm, &b, &mut jobs, 100), 0);
    }

    /// Boundary retarget, shrink direction (no joiners): survivors move to
    /// the cheapest admitting bucket, and only when the modeled phase-time
    /// saving beats the switch cost.
    #[test]
    fn retarget_shrinks_to_cheapest_admitting_bucket() {
        // cpu-sim is FLOP-bound, so fewer padded samples = less modeled
        // time (on the IO-bound A100 profile small-batch step time is
        // sample-independent and only the rank term separates buckets).
        let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &crate::config::pool::CPU_SIM);
        let none = Pack::new(vec![]);
        // The nano-style grid plus a rank-32 tier.
        let grid = [(1, 8, 1), (2, 8, 1), (4, 8, 1), (2, 8, 2), (2, 32, 2)];
        let one = Pack::new(vec![cfg(0, 8, 1)]);
        let rt = |surv: &Pack, cur, sw| retarget_bucket(&grid, surv, &none, cur, &cm, 1, sw, 100);
        assert_eq!(rt(&one, (2, 8, 2), 0.0), Some((1, 8, 1)));
        // Already on the cheapest admitting bucket: no move.
        assert_eq!(rt(&one, (1, 8, 1), 0.0), None);
        // Rank shrink: a rank-8 survivor pack leaves the rank-32 bucket.
        let two = Pack::new(vec![cfg(0, 8, 1), cfg(1, 8, 2)]);
        assert_eq!(rt(&two, (2, 32, 2), 0.0), Some((2, 8, 2)));
        // Nothing admits an oversized pack.
        let big = Pack::new(vec![cfg(0, 64, 1)]);
        assert_eq!(rt(&big, (2, 32, 2), 0.0), None);
        // Empty survivor set never re-buckets.
        assert_eq!(rt(&none, (2, 8, 2), 0.0), None);
        // A prohibitive switch cost pins the pack to its current bucket.
        assert_eq!(rt(&one, (2, 8, 2), f64::MAX), None);
    }

    /// Joiners can force growth: when the current bucket cannot hold the
    /// combined pack, the cheapest admitting bucket is returned regardless
    /// of switch cost; when it can, admission stays in place unless the
    /// move pays for itself.
    #[test]
    fn retarget_grows_for_joiners() {
        let cm = CostModel::new(geom("qwen2.5-7b").unwrap(), &crate::config::pool::CPU_SIM);
        let grid = [(1, 8, 1), (2, 8, 1), (4, 8, 1), (2, 8, 2)];
        let surv = Pack::new(vec![cfg(0, 8, 1)]);
        let join = Pack::new(vec![cfg(1, 8, 1), cfg(2, 8, 1)]);
        // 3 combined adapters don't fit (1, 8, 1): forced move, even at
        // infinite switch cost.
        assert_eq!(
            retarget_bucket(&grid, &surv, &join, (1, 8, 1), &cm, 1, f64::MAX, 10),
            Some((4, 8, 1))
        );
        // Combined pack fits the current (4, 8, 1): no cheaper admitting
        // bucket exists, so stay.
        assert_eq!(retarget_bucket(&grid, &surv, &join, (4, 8, 1), &cm, 1, 0.0, 10), None);
        // One joiner into a bs-2 bucket: (2, 8, 1) admits and is cheaper;
        // taken only when the saving clears the switch cost.
        let one_join = Pack::new(vec![cfg(1, 8, 1)]);
        let got = retarget_bucket(&grid, &surv, &one_join, (2, 8, 2), &cm, 1, 0.0, 100);
        assert_eq!(got, Some((2, 8, 1)));
        let pinned = retarget_bucket(&grid, &surv, &one_join, (2, 8, 2), &cm, 1, f64::MAX, 100);
        assert_eq!(pinned, None);
        // The decision is d-aware: scores at d=2 shrink the base share
        // uniformly, so the *ordering* (and hence the chosen bucket) is
        // preserved while the absolute saving scales down.
        let got2 = retarget_bucket(&grid, &surv, &one_join, (2, 8, 2), &cm, 2, 0.0, 100);
        assert_eq!(got2, Some((2, 8, 1)));
    }
}
