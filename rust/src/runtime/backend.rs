//! The pluggable execution-backend interface.
//!
//! A backend turns manifest artifacts into runnable executables. The
//! engine, driver, benches and CLI only ever see [`crate::runtime::Runtime`]
//! / [`crate::runtime::Executable`]; which backend does the work is decided
//! once at `Runtime::load` time:
//!
//! - [`crate::runtime::reference::RefBackend`] — pure-Rust interpreter of
//!   the packed-LoRA computations (default; no native deps).
//! - `pjrt::PjrtBackend` (`pjrt` feature) — compiles the AOT HLO artifacts
//!   via the PJRT CPU client and replays them.

use anyhow::Result;

use crate::runtime::manifest::{ArtifactInfo, Manifest};
use crate::runtime::tensor::HostTensor;

/// A backend that can prepare manifest artifacts for execution.
///
/// Implementations must be thread-safe: the engine prepares and runs
/// executables from concurrent worker threads.
pub trait ExecutionBackend: Send + Sync {
    /// Identifier shown in logs/CLI (`ref-cpu`, `cpu` for PJRT, ...).
    fn platform(&self) -> String;

    /// Prepare one artifact. Called once per artifact (the runtime caches
    /// the result); may be expensive (e.g. XLA compilation).
    fn load(&self, manifest: &Manifest, info: &ArtifactInfo) -> Result<Box<dyn BackendExecutable>>;
}

/// A prepared artifact. Inputs are pre-validated against the manifest by
/// [`crate::runtime::Executable::run`], so implementations may rely on
/// arity, dtypes and shapes being exactly the manifest's.
pub trait BackendExecutable: Send + Sync {
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}
