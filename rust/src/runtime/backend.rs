//! The pluggable execution-backend interface.
//!
//! A backend turns manifest artifacts into runnable executables. The
//! engine, driver, benches and CLI only ever see [`crate::runtime::Runtime`]
//! / [`crate::runtime::Executable`]; which backend does the work is decided
//! once at `Runtime::load` time:
//!
//! - [`crate::runtime::reference::RefBackend`] — pure-Rust interpreter of
//!   the packed-LoRA computations (default; no native deps).
//! - `pjrt::PjrtBackend` (`pjrt` feature) — compiles the AOT HLO artifacts
//!   via the PJRT CPU client and replays them.

use std::any::Any;

use anyhow::Result;

use crate::runtime::manifest::{ArtifactInfo, Manifest};
use crate::runtime::tensor::HostTensor;

/// A backend that can prepare manifest artifacts for execution.
///
/// Implementations must be thread-safe: the engine prepares and runs
/// executables from concurrent worker threads.
pub trait ExecutionBackend: Send + Sync {
    /// Identifier shown in logs/CLI (`ref-cpu`, `cpu` for PJRT, ...).
    fn platform(&self) -> String;

    /// Prepare one artifact. Called once per artifact (the runtime caches
    /// the result); may be expensive (e.g. XLA compilation).
    fn load(&self, manifest: &Manifest, info: &ArtifactInfo) -> Result<Box<dyn BackendExecutable>>;

    /// Data-parallel split support: build an executor for the two halves
    /// of a train step — forward/backward gradients over an arbitrary
    /// `(n, r, bs)` sub-bucket of `model`, and the AdamW application from
    /// externally supplied gradients. This is the unit
    /// [`crate::runtime::shard::ShardedState`] runs per device. `None`
    /// (the default) means the backend only executes fused steps (e.g.
    /// AOT-compiled PJRT artifacts); the sharding layer then falls back to
    /// single-device execution.
    fn shard(
        &self,
        manifest: &Manifest,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
    ) -> Result<Option<Box<dyn ShardStepExec>>> {
        let _ = (manifest, model, n, r, bs);
        Ok(None)
    }

    /// Stage-pipeline split support: build one [`StageStepExec`] per
    /// contiguous layer range of `ranges` (which must partition
    /// `[0, n_layers)` in order). Each executor owns the forward/backward
    /// of its layers at the `(n, r, bs)` sub-bucket and accumulates its
    /// own slice of the LoRA gradients;
    /// [`crate::runtime::pipeline::PipelinedExec`] streams microbatches
    /// through them. `None` (the default) means the backend cannot split
    /// the layer stack; the pipelining layer then falls back to the fused
    /// or data-parallel path.
    fn stages(
        &self,
        manifest: &Manifest,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Option<Vec<Box<dyn StageStepExec>>>> {
        let _ = (manifest, model, n, r, bs, ranges);
        Ok(None)
    }
}

/// The gradient half of one train step: per-tensor LoRA gradients in
/// `LORA_ORDER` (shapes matching the packed `lora` inputs) plus the
/// per-adapter losses of the batch.
pub struct GradStep {
    pub grads: Vec<HostTensor>,
    pub per_loss: Vec<f32>,
}

/// The optimizer half of one train step: the updated parameter/moment set
/// and the advanced per-adapter step counters.
pub struct AdamOut {
    pub lora: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub t: Vec<f32>,
}

/// A train step split into its forward/backward and optimizer halves —
/// what one shard worker of [`crate::runtime::shard::ShardedState`] runs.
/// The fused-step contract (`BackendExecutable::run`) is exactly
/// `run_grads` followed by `run_adamw` on the same tensors, and both
/// halves preserve every output element's reduction order, so a sharded
/// step whose shards partition the pack at slot granularity is bitwise
/// identical to the fused step (DESIGN.md §11).
pub trait ShardStepExec: Send + Sync {
    /// Forward + backward over this shard's `(n, r, bs)` slice: `base` in
    /// `BASE_ORDER`, `lora` the 14 packed `LORA_ORDER` tensors at the
    /// shard shape, `tokens`/`targets` `(n, bs, seq)` i32, `mask`
    /// `(n, bs, seq)` f32, `scale` `(n,)`.
    #[allow(clippy::too_many_arguments)]
    fn run_grads(
        &self,
        base: &[HostTensor],
        lora: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<GradStep>;

    /// Logits-only eval over this shard's `(n, r, bs)` slice — the same
    /// input layout as [`ShardStepExec::run_grads`] — returning per-slot
    /// `(loss, acc)`. Eval is per-row independent (no cross-slot
    /// reduction at all), so a slot-partitioned sharded eval is bitwise
    /// identical to the fused eval executable. `None` (the default) means
    /// the backend cannot evaluate at shard granularity; the sharding
    /// layer then falls back to the fused eval path.
    #[allow(clippy::too_many_arguments)]
    fn run_eval(
        &self,
        base: &[HostTensor],
        lora: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let _ = (base, lora, tokens, targets, mask, scale, scratch);
        Ok(None)
    }

    /// One AdamW update of the full `(n, r)` state from externally
    /// reduced gradients (`grads` in `LORA_ORDER`, full-bucket shapes).
    /// `t` is the per-adapter step-counter vector *before* the update.
    #[allow(clippy::too_many_arguments)]
    fn run_adamw(
        &self,
        lora: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
        t: &[f32],
        grads: &[HostTensor],
        lr: &[f32],
        rmask: &HostTensor,
        scratch: &mut Scratch,
    ) -> Result<AdamOut>;
}

/// One pipeline stage of a train step: a contiguous layer range's
/// forward/backward at an `(n, r, bs)` sub-bucket, driven one *slot
/// window* (microbatch) at a time by
/// [`crate::runtime::pipeline::PipelinedExec`].
///
/// The contract mirrors the monolithic step exactly: every activation,
/// boundary tensor and gradient element is produced by exactly one
/// `(stage, microbatch)` call with the same reduction order the fused
/// step uses, so the pipelined step is bitwise identical to it
/// (DESIGN.md §15). `&mut self` because each stage owns its workspace
/// arena and gradient accumulators; one persistent worker drives each
/// stage, so no `Sync` is required.
pub trait StageStepExec: Send {
    /// The `[lo, hi)` layer range this stage owns.
    fn layer_range(&self) -> (usize, usize);

    /// Reset per-step state: size the arena and zero this stage's LoRA
    /// gradient accumulators. Called once per step before any microbatch.
    fn begin_step(&mut self) -> Result<()>;

    /// Forward slots `[slo, slo+nw)` through this stage's layers.
    /// `x_in` is the boundary activation from the previous stage
    /// (`None` on stage 0, which embeds `tokens` itself). Returns the
    /// boundary activation for the next stage; the final stage runs the
    /// head internally and returns an empty vec.
    #[allow(clippy::too_many_arguments)]
    fn run_fwd(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        lora: &[HostTensor],
        scale: &[f32],
        tokens: &HostTensor,
        x_in: Option<&[f32]>,
    ) -> Result<Vec<f32>>;

    /// Final stage only: per-slot losses of `[slo, slo+nw)` plus the
    /// backward seed (head + final-LN backward), kept internally for the
    /// stage's own `run_bwd`.
    fn run_loss(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        targets: &HostTensor,
        mask: &HostTensor,
    ) -> Result<Vec<f32>>;

    /// Backward slots `[slo, slo+nw)`. `dx_in` is the boundary gradient
    /// from the next stage (`None` on the final stage, whose seed was
    /// placed by [`StageStepExec::run_loss`]). Accumulates this window's
    /// LoRA gradients and returns the boundary gradient for the previous
    /// stage; stage 0 returns an empty vec (embeddings are frozen).
    fn run_bwd(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        lora: &[HostTensor],
        scale: &[f32],
        dx_in: Option<&[f32]>,
    ) -> Result<Vec<f32>>;

    /// The stage's accumulated LoRA gradients after a full step: 14 flat
    /// buffers in `LORA_ORDER`, each shaped `(hi-lo, n, d2, d3)` — this
    /// stage's layer slice of the full gradient tensors.
    fn stage_grads(&self) -> &[Vec<f32>];
}

/// A prepared artifact. Inputs are pre-validated against the manifest by
/// [`crate::runtime::Executable::run`], so implementations may rely on
/// arity, dtypes and shapes being exactly the manifest's.
///
/// Inputs are *borrowed* so callers with long-lived state (the train
/// driver) never deep-copy tensors into the call; `scratch` is the
/// caller's step-persistent scratch (see [`Scratch`]) — backends that need
/// none simply ignore it.
pub trait BackendExecutable: Send + Sync {
    fn run(&self, inputs: &[&HostTensor], scratch: &mut Scratch) -> Result<Vec<HostTensor>>;
}

/// Opaque per-job scratch carried across executable runs.
///
/// Owned by whoever owns the job state (`TrainState` holds one behind a
/// mutex); the backend decides what lives inside. Two compartments:
///
/// - an untyped **slot** the backend populates with its arena on first use
///   (the reference backend keeps its
///   [`crate::runtime::reference::workspace::Workspace`] here). Dropping
///   the `Scratch` — e.g. when `TrainState::repack` builds the
///   re-bucketed state — drops the arena, and the next run re-derives it
///   at the new shape.
/// - a **pool** of recycled f32 buffers any backend may take output
///   tensors from; callers return spent state buffers via
///   [`Scratch::recycle`], closing the allocation cycle so steady-state
///   steps allocate nothing.
#[derive(Default)]
pub struct Scratch {
    slot: Option<Box<dyn Any + Send>>,
    pool: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Borrow the backend arena and the buffer pool simultaneously,
    /// initializing the arena with `init` on first use (or after a
    /// [`Scratch::reset`]). If the slot holds a different type, it is
    /// replaced.
    pub fn parts<T, F>(&mut self, init: F) -> (&mut T, &mut Vec<Vec<f32>>)
    where
        T: Any + Send,
        F: FnOnce() -> T,
    {
        let fresh = match &mut self.slot {
            Some(b) => b.downcast_mut::<T>().is_none(),
            None => true,
        };
        if fresh {
            self.slot = Some(Box::new(init()));
        }
        let arena = self
            .slot
            .as_mut()
            .expect("slot populated above")
            .downcast_mut::<T>()
            .expect("slot type checked above");
        (arena, &mut self.pool)
    }

    /// Take a buffer of exactly `len` elements from the pool, or allocate
    /// one. Contents are **unspecified** (stale) — callers must write
    /// every element before reading any.
    pub fn take_buf(&mut self, len: usize) -> Vec<f32> {
        take_buf(&mut self.pool, len)
    }

    /// Borrow the recycled-buffer pool alone (no arena involvement) —
    /// for backend paths that only cycle output buffers.
    pub fn pool(&mut self) -> &mut Vec<Vec<f32>> {
        &mut self.pool
    }

    /// Return a spent f32 buffer to the pool for reuse by later runs.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        self.pool.push(buf);
    }

    /// Drop the arena and the pool (benches use this to model the
    /// pre-arena allocate-every-step behavior).
    pub fn reset(&mut self) {
        self.slot = None;
        self.pool.clear();
    }
}

/// Pool-take usable while the arena is borrowed via [`Scratch::parts`].
/// Same contract as [`Scratch::take_buf`]: contents are unspecified.
pub fn take_buf(pool: &mut Vec<Vec<f32>>, len: usize) -> Vec<f32> {
    match pool.iter().rposition(|v| v.len() == len) {
        Some(pos) => pool.swap_remove(pos),
        None => vec![0.0; len],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parts_initializes_once_and_persists() {
        let mut s = Scratch::new();
        let (v, _) = s.parts(|| vec![1u8, 2, 3]);
        v.push(4);
        let (v, _) = s.parts(Vec::<u8>::new);
        assert_eq!(v, &vec![1u8, 2, 3, 4], "arena persists across parts()");
        s.reset();
        let (v, _) = s.parts(Vec::<u8>::new);
        assert!(v.is_empty(), "reset drops the arena");
    }

    #[test]
    fn parts_replaces_on_type_change() {
        let mut s = Scratch::new();
        let (v, _) = s.parts(|| vec![7u8]);
        assert_eq!(v.len(), 1);
        let (x, _) = s.parts(|| 42u32);
        assert_eq!(*x, 42);
    }

    #[test]
    fn pool_recycles_exact_lengths() {
        let mut s = Scratch::new();
        s.recycle(vec![1.0; 8]);
        s.recycle(vec![2.0; 4]);
        let b = s.take_buf(8);
        assert_eq!(b.len(), 8);
        assert_eq!(b[0], 1.0, "recycled buffer (stale contents) preferred");
        let b = s.take_buf(8);
        assert_eq!(b.len(), 8);
        assert_eq!(b[0], 0.0, "pool miss allocates fresh");
        assert_eq!(s.take_buf(4)[0], 2.0);
    }
}
