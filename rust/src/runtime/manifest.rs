//! `artifacts/manifest.json` — the contract between the build-time Python
//! (aot.py) and the Rust runtime. Everything the coordinator knows about
//! the AOT executables (names, argument order, shapes, bucket grid, token
//! layout, pretrained-model metadata) comes from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::Json;

/// Shape+dtype of one executable argument or result.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.field("name")?.as_str().ok_or_else(|| anyhow!("spec name"))?.to_string(),
            dtype: DType::parse(j.field("dtype")?.as_str().unwrap_or(""))?,
            shape: j
                .field("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("spec shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("shape dim")))
                .collect::<Result<_>>()?,
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Fused packed train step (lora/opt state in, updated state out).
    Train,
    /// Per-adapter eval (loss, accuracy).
    Eval,
    /// Standalone packed-LoRA forward kernel (Table 7/8 benches).
    KernelFwd,
    /// Standalone packed-LoRA backward kernel (4 grad cases fused).
    KernelBwd,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "train" => ArtifactKind::Train,
            "eval" => ArtifactKind::Eval,
            "kernel_fwd" => ArtifactKind::KernelFwd,
            "kernel_bwd" => ArtifactKind::KernelBwd,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

/// One AOT-compiled executable.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: ArtifactKind,
    /// HLO text file, relative to the artifacts dir.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model/n/r/bs for train-eval; geom/d/k/r/m for
    /// kernels) — typed accessors below.
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactInfo {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|j| j.as_str())
    }

    pub fn input(&self, name: &str) -> Result<&TensorSpec> {
        self.inputs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no input '{name}'", self.name))
    }
}

/// Pretrained TinyLM metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub params: usize,
    /// Weight container file, relative to the artifacts dir.
    pub weights: String,
}

/// Token ids shared with the Python task generators.
#[derive(Debug, Clone, Copy)]
pub struct TokenLayout {
    pub pad: i32,
    pub bos: i32,
    pub sep: i32,
    pub eos: i32,
    pub alpha0: i32,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tokens: TokenLayout,
    pub tasks: Vec<String>,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e:?}", path.display()))?;

        let tl = j.field("token_layout")?;
        let tok = |k: &str| -> Result<i32> {
            Ok(tl.field(k)?.as_f64().ok_or_else(|| anyhow!("token {k}"))? as i32)
        };
        let tokens = TokenLayout {
            pad: tok("pad")?,
            bos: tok("bos")?,
            sep: tok("sep")?,
            eos: tok("eos")?,
            alpha0: tok("alpha0")?,
        };

        let tasks = j
            .field("tasks")?
            .as_arr()
            .ok_or_else(|| anyhow!("tasks"))?
            .iter()
            .filter_map(|t| t.as_str().map(|s| s.to_string()))
            .collect();

        let mut models = BTreeMap::new();
        for (name, m) in j.field("models")?.as_obj().ok_or_else(|| anyhow!("models"))? {
            let u = |k: &str| -> Result<usize> {
                m.field(k)?.as_usize().ok_or_else(|| anyhow!("model {name}.{k}"))
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    vocab: u("vocab")?,
                    d_model: u("d_model")?,
                    n_layers: u("n_layers")?,
                    n_heads: u("n_heads")?,
                    d_ff: u("d_ff")?,
                    seq: u("seq")?,
                    params: u("params")?,
                    weights: m
                        .field("weights")?
                        .as_str()
                        .ok_or_else(|| anyhow!("model {name}.weights"))?
                        .to_string(),
                },
            );
        }

        let mut artifacts = vec![];
        for a in j.field("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts"))? {
            let obj = a.as_obj().ok_or_else(|| anyhow!("artifact entry"))?;
            let get_str = |k: &str| -> Result<String> {
                a.field(k)?
                    .as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("artifact field {k}"))
            };
            let parse_specs = |k: &str| -> Result<Vec<TensorSpec>> {
                a.field(k)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("artifact {k}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            let known = ["name", "kind", "path", "inputs", "outputs"];
            let meta = obj
                .iter()
                .filter(|(k, _)| !known.contains(&k.as_str()))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            artifacts.push(ArtifactInfo {
                name: get_str("name")?,
                kind: ArtifactKind::parse(&get_str("kind")?)?,
                path: get_str("path")?,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                meta,
            });
        }

        Ok(Manifest { dir: dir.to_path_buf(), tokens, tasks, models, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Artifacts of one kind for one model.
    pub fn by_kind(&self, kind: ArtifactKind) -> impl Iterator<Item = &ArtifactInfo> + '_ {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }

    /// The static-shape **bucket grid** for a model: the smallest available
    /// `(n, r, bs)` train artifact that dominates the requested pack shape
    /// (n' ≥ n, r' ≥ r, bs' ≥ bs), minimizing padding waste by total padded
    /// element count `n'·r'·bs'`. Returns `None` if no bucket fits.
    pub fn train_bucket(
        &self,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
    ) -> Option<&ArtifactInfo> {
        self.by_kind(ArtifactKind::Train)
            .filter(|a| a.meta_str("model") == Some(model))
            .filter(|a| {
                a.meta_usize("n").unwrap_or(0) >= n
                    && a.meta_usize("r").unwrap_or(0) >= r
                    && a.meta_usize("bs").unwrap_or(0) >= bs
            })
            .min_by_key(|a| {
                a.meta_usize("n").unwrap_or(0)
                    * a.meta_usize("r").unwrap_or(0)
                    * a.meta_usize("bs").unwrap_or(0)
            })
    }

    /// The eval artifact matching a train bucket's `(model, n, r, bs)`.
    pub fn eval_for(&self, train: &ArtifactInfo) -> Result<&ArtifactInfo> {
        self.by_kind(ArtifactKind::Eval)
            .find(|a| {
                ["model", "n", "r", "bs"].iter().all(|k| {
                    let fmt = |m: &ArtifactInfo| m.meta.get(*k).map(|v| format!("{v:?}"));
                    fmt(a) == fmt(train)
                })
            })
            .ok_or_else(|| anyhow!("no eval artifact for {}", train.name))
    }

    /// All `(n, r, bs)` train buckets available for `model` — the
    /// static-shape grid the planner must respect in live mode
    /// (`CostModel::buckets`).
    pub fn train_buckets(&self, model: &str) -> Vec<(usize, usize, usize)> {
        self.by_kind(ArtifactKind::Train)
            .filter(|a| a.meta_str("model") == Some(model))
            .filter_map(|a| {
                Some((a.meta_usize("n")?, a.meta_usize("r")?, a.meta_usize("bs")?))
            })
            .collect()
    }

    /// Largest packed-adapter count available for a model's train buckets.
    pub fn max_bucket_n(&self, model: &str) -> usize {
        self.by_kind(ArtifactKind::Train)
            .filter(|a| a.meta_str("model") == Some(model))
            .filter_map(|a| a.meta_usize("n"))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn load() -> Option<Manifest> {
        let d = manifest_dir();
        d.join("manifest.json").exists().then(|| Manifest::load(&d).unwrap())
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = load() else { return };
        assert!(m.models.contains_key("nano"));
        assert!(m.tasks.iter().any(|t| t == "modadd"));
        assert_eq!(m.tokens.bos, 1);
        assert!(!m.artifacts.is_empty());
    }

    #[test]
    fn train_bucket_selection_dominates_and_minimizes() {
        let Some(m) = load() else { return };
        // tiny grid has n in {1,2,4,8}, r in {8,32}, bs in {1,4}.
        let b = m.train_bucket("tiny", 3, 8, 1).unwrap();
        assert_eq!(b.meta_usize("n"), Some(4));
        assert_eq!(b.meta_usize("r"), Some(8));
        assert_eq!(b.meta_usize("bs"), Some(1));
        // Exact hit.
        let b = m.train_bucket("tiny", 8, 32, 4).unwrap();
        assert_eq!(
            (b.meta_usize("n"), b.meta_usize("r"), b.meta_usize("bs")),
            (Some(8), Some(32), Some(4))
        );
        // Nothing dominates an oversized request.
        assert!(m.train_bucket("tiny", 9, 8, 1).is_none());
        assert!(m.train_bucket("tiny", 1, 256, 1).is_none());
    }

    #[test]
    fn eval_artifact_pairs_with_train() {
        let Some(m) = load() else { return };
        let t = m.train_bucket("nano", 1, 8, 1).unwrap();
        let e = m.eval_for(t).unwrap();
        assert_eq!(e.kind, ArtifactKind::Eval);
        assert_eq!(e.meta_usize("n"), t.meta_usize("n"));
    }

    #[test]
    fn train_signature_shape_sanity() {
        let Some(m) = load() else { return };
        let t = m.train_bucket("tiny", 2, 8, 1).unwrap();
        let tok = t.input("tokens").unwrap();
        assert_eq!(tok.dtype, DType::I32);
        let mi = m.model("tiny").unwrap();
        assert_eq!(tok.shape, vec![2, 1, mi.seq]);
        // outputs: 14 lora + 14 m + 14 v + t + per_loss
        assert_eq!(t.outputs.len(), 44);
    }
}
