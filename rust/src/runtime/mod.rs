//! Execution runtime: the manifest of packed-LoRA artifacts plus a
//! pluggable [`ExecutionBackend`] that runs them.
//!
//! Two backends exist:
//!
//! - **Reference** ([`reference::RefBackend`], the default): a pure-Rust
//!   interpreter of the manifest's packed-LoRA computations — the fused
//!   TinyLM train/eval steps and the standalone packed kernels (batched
//!   `y += α·(x·A)·B` forward/backward) — over [`HostTensor`]s. It needs no
//!   native libraries and no build-time artifacts: when `artifacts/` is
//!   absent it synthesizes the manifest (bucket grid, token layout, model
//!   geometry — the same tables `python/compile/aot.py` emits) and
//!   deterministic base weights, so the engine, the train driver, the
//!   benches and the examples all run end-to-end offline.
//! - **PJRT** (`pjrt` feature): loads the AOT artifacts (`make artifacts`,
//!   HLO text) via the PJRT CPU client (`xla` crate) and replays them from
//!   the Rust hot path. HLO **text** is the interchange format; jax ≥ 0.5
//!   serialized protos are rejected by xla_extension 0.5.1 (64-bit
//!   instruction ids).
//!
//! The artifact *contract* (argument order, shapes, bucket grid) is
//! identical for both backends — see [`manifest`] and DESIGN.md §2.

pub mod backend;
pub mod manifest;
pub mod pipeline;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod shard;
pub mod state;
pub mod tensor;
pub mod tensor_file;

pub use backend::{
    AdamOut, BackendExecutable, ExecutionBackend, GradStep, Scratch, ShardStepExec, StageStepExec,
};
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest, ModelInfo, TensorSpec};
pub use pipeline::{stage_ranges, PipelinedExec, PipelinedState};
pub use shard::ShardedState;
pub use state::{JoinSource, MemberState, TrainState};
pub use tensor::{DType, HostTensor, TensorData};

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

/// A prepared artifact bound to its manifest entry: validates inputs
/// against the manifest contract, then dispatches to the backend.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: Box<dyn BackendExecutable>,
    /// Wall time spent preparing/compiling (profiling/§Perf bookkeeping).
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with host tensors; validates dtypes/shapes against the
    /// manifest before dispatch (shape bugs surface as Rust errors here,
    /// not deep inside a backend). Convenience wrapper over
    /// [`Executable::run_scratch`] with a throwaway scratch — long-lived
    /// callers (the train driver via `TrainState`) hold a persistent
    /// [`Scratch`] instead so the backend's arena survives across steps.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_scratch(&refs, &mut Scratch::new())
    }

    /// Execute with borrowed inputs and a caller-owned step-persistent
    /// scratch (zero-copy, zero steady-state allocation on the reference
    /// backend's train path).
    pub fn run_scratch(
        &self,
        inputs: &[&HostTensor],
        scratch: &mut Scratch,
    ) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let outs = self
            .exe
            .run(inputs, scratch)
            .with_context(|| format!("{}: execute", self.info.name))?;
        if outs.len() != self.info.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, backend returned {}",
                self.info.name,
                self.info.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    fn check_inputs(&self, inputs: &[&HostTensor]) -> Result<()> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.info.inputs) {
            if t.shape != spec.shape || t.dtype() != spec.dtype {
                bail!(
                    "{}: input '{}' expects {:?} {:?}, got {:?} {:?}",
                    self.info.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape
                );
            }
        }
        Ok(())
    }
}

/// The runtime: one execution backend + the manifest + an executable cache
/// (shared across engine worker threads) + a base-weight cache.
pub struct Runtime {
    backend: Arc<dyn ExecutionBackend>,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
    weights: Mutex<BTreeMap<String, Arc<Vec<HostTensor>>>>,
}

impl Runtime {
    /// Load a runtime rooted at `artifacts_dir`.
    ///
    /// If `manifest.json` exists there, it is loaded (and, with the `pjrt`
    /// feature, executed via PJRT); otherwise the built-in manifest is
    /// synthesized and the pure-Rust reference backend is used, so the
    /// runtime always comes up on an offline machine.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let has_files = artifacts_dir.join("manifest.json").exists();
        let manifest = if has_files {
            Manifest::load(artifacts_dir)?
        } else {
            reference::builtin_manifest(artifacts_dir)
        };
        #[cfg(feature = "pjrt")]
        if has_files {
            let backend = pjrt::PjrtBackend::new().context("starting PJRT CPU client")?;
            return Ok(Runtime::with_backend(Arc::new(backend), manifest));
        }
        Ok(Runtime::with_backend(Arc::new(reference::RefBackend), manifest))
    }

    /// Build a runtime over an explicit backend (tests, embedding).
    pub fn with_backend(backend: Arc<dyn ExecutionBackend>, manifest: Manifest) -> Runtime {
        Runtime {
            backend,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            weights: Mutex::new(BTreeMap::new()),
        }
    }

    /// Default artifacts directory (crate-root `artifacts/`).
    pub fn default_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Backend identifier (`ref-cpu` for the reference interpreter, the
    /// PJRT platform name otherwise).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Prepare (or fetch from cache) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let exe = self
            .backend
            .load(&self.manifest, &info)
            .with_context(|| format!("prepare {name}"))?;
        let prepared =
            Arc::new(Executable { info, exe, compile_secs: t0.elapsed().as_secs_f64() });
        let mut cache = self.cache.lock().unwrap();
        // Benign race: if another thread prepared meanwhile, keep the first.
        Ok(cache.entry(name.to_string()).or_insert(prepared).clone())
    }

    /// Number of prepared executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Data-parallel split support: the backend's forward/backward and
    /// optimizer halves of a train step at an exact `(n, r, bs)`
    /// sub-bucket of `model`. `None` when the backend only executes fused
    /// steps — [`shard::ShardedState`] then falls back to single-device
    /// execution.
    pub fn shard_exec(
        &self,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
    ) -> Result<Option<Box<dyn ShardStepExec>>> {
        self.backend.shard(&self.manifest, model, n, r, bs)
    }

    /// Stage-pipeline split support: one executor per contiguous layer
    /// range at an exact `(n, r, bs)` sub-bucket of `model` — the units
    /// [`pipeline::PipelinedExec`] streams microbatches through. `None`
    /// when the backend cannot split the layer stack; the pipelining
    /// layer then falls back to the fused or data-parallel path.
    pub fn stage_exec(
        &self,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Option<Vec<Box<dyn StageStepExec>>>> {
        self.backend.stages(&self.manifest, model, n, r, bs, ranges)
    }

    /// A model's frozen base weights in `BASE_ORDER` (the train/eval
    /// artifact argument order), shared read-only across jobs. Reads the
    /// pretrained weight container when present; otherwise synthesizes
    /// deterministic weights with the same init distributions as
    /// `python/compile/model.py::init_base`.
    pub fn base_weights(&self, model: &str) -> Result<Arc<Vec<HostTensor>>> {
        if let Some(w) = self.weights.lock().unwrap().get(model) {
            return Ok(w.clone());
        }
        let mi = self.manifest.model(model)?.clone();
        let path = self.manifest.dir.join(&mi.weights);
        // Synthesize only when the whole manifest is synthetic (no
        // artifacts on disk). A real manifest promising a weights file
        // that is gone must fail loudly, not silently hand back a random
        // base with plausible-looking quality numbers.
        let real_manifest = self.manifest.dir.join("manifest.json").exists();
        let loaded: Vec<HostTensor> = if path.exists() || real_manifest {
            let mut by_name = tensor_file::read_tensors(&path)?;
            BASE_ORDER
                .iter()
                .map(|k| {
                    by_name.remove(*k).ok_or_else(|| {
                        anyhow::anyhow!("{}: missing base tensor '{k}'", mi.weights)
                    })
                })
                .collect::<Result<_>>()?
        } else {
            reference::synth_base_weights(&mi)
        };
        let arc = Arc::new(loaded);
        let mut cache = self.weights.lock().unwrap();
        Ok(cache.entry(model.to_string()).or_insert(arc).clone())
    }
}

/// Base-weight argument order — must match `model.py::BASE_ORDER`.
pub const BASE_ORDER: [&str; 12] = [
    "embed", "pos", "ln1", "ln2", "wq", "wk", "wv", "wo", "wup", "wgate", "wdown", "lnf",
];

/// LoRA tensor order — must match `model.py::LORA_ORDER`
/// (sorted `{a,b}_{proj}` names).
pub const LORA_ORDER: [&str; 14] = [
    "a_down", "a_gate", "a_k", "a_o", "a_q", "a_up", "a_v", "b_down", "b_gate", "b_k", "b_o",
    "b_q", "b_up", "b_v",
];

/// The seven LoRA-able projections (paper Appendix A).
pub const PROJS: [&str; 7] = ["q", "k", "v", "o", "up", "gate", "down"];

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        // Default dir has no committed artifacts: exercises the built-in
        // manifest + reference backend path.
        Runtime::load(&Runtime::default_dir()).unwrap()
    }

    #[test]
    fn lora_order_is_sorted_ab_projections() {
        let mut names: Vec<String> = PROJS
            .iter()
            .flat_map(|p| ["a", "b"].iter().map(move |t| format!("{t}_{p}")))
            .collect();
        names.sort();
        assert_eq!(names, LORA_ORDER.to_vec());
    }

    #[test]
    fn prepares_and_runs_kernel_artifact() {
        let rt = runtime();
        let exe = rt.executable("kfwd_attn_n1").unwrap();
        let info = rt.manifest.artifact("kfwd_attn_n1").unwrap();
        let (n, m, d, r, k) = (
            1,
            info.meta_usize("m").unwrap(),
            info.meta_usize("d").unwrap(),
            info.meta_usize("r").unwrap(),
            info.meta_usize("k").unwrap(),
        );
        let x = HostTensor::f32(vec![n, m, d], vec![0.01; n * m * d]).unwrap();
        let a = HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r]).unwrap();
        let b = HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k]).unwrap();
        let alpha = HostTensor::f32(vec![n], vec![2.0]).unwrap();
        let out = exe.run(&[x, a, b, alpha]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![n, m, k]);
        // y = alpha * x @ a @ b = 2 * (d * .01*.02) * (r * .03) per elem
        let want = 2.0 * (d as f32 * 0.01 * 0.02) * (r as f32 * 0.03);
        let got = out[0].as_f32().unwrap()[0];
        assert!((got - want).abs() < 1e-4, "got {got}, want {want}");
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let rt = runtime();
        let exe = rt.executable("kfwd_attn_n1").unwrap();
        let bad = vec![HostTensor::scalar_f32(0.0); 4];
        assert!(exe.run(&bad).is_err());
        assert!(exe.run(&[]).is_err());
    }

    #[test]
    fn prepare_cache_hits() {
        let rt = runtime();
        let a = rt.executable("kfwd_attn_n1").unwrap();
        let b = rt.executable("kfwd_attn_n1").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn base_weights_match_model_shapes() {
        let rt = runtime();
        let w = rt.base_weights("nano").unwrap();
        let mi = rt.manifest.model("nano").unwrap();
        assert_eq!(w.len(), 12);
        assert_eq!(w[0].shape, vec![mi.vocab, mi.d_model]); // embed
        assert_eq!(w[1].shape, vec![mi.seq, mi.d_model]); // pos

        // Deterministic and cached: a second call returns identical data.
        let w2 = rt.base_weights("nano").unwrap();
        assert_eq!(w[0].as_f32().unwrap(), w2[0].as_f32().unwrap());
    }
}
