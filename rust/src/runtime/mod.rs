//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path. Python is build-time only — after `make artifacts` the
//! coordinator talks exclusively to this module.
//!
//! Wiring (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO **text** is the interchange format; jax ≥ 0.5 serialized protos are
//! rejected by xla_extension 0.5.1 (64-bit instruction ids).

pub mod manifest;
pub mod state;
pub mod tensor;
pub mod tensor_file;

pub use manifest::{ArtifactInfo, ArtifactKind, Manifest, ModelInfo, TensorSpec};
pub use state::TrainState;
pub use tensor::{DType, HostTensor, TensorData};

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled artifact bound to its manifest entry.
///
/// # Thread safety
/// `xla::PjRtLoadedExecutable` holds raw pointers and is `!Send` by
/// default, but the underlying PJRT C API object is thread-safe (XLA
/// guarantees concurrent `Execute` calls); the engine executes jobs from
/// worker threads, so we assert Send+Sync here.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: PjRtLoadedExecutable,
    /// Wall time spent compiling (profiling/§Perf bookkeeping).
    pub compile_secs: f64,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host tensors; validates dtypes/shapes against the
    /// manifest before crossing the FFI boundary (shape bugs surface as
    /// Rust errors, not XLA aborts).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("{}: building literals", self.info.name))?;
        let outs = self.run_literals(&lits)?;
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with prebuilt literals, returning untupled output literals.
    pub fn run_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("{}: execute", self.info.name))?;
        // Single replica; jax lowers with return_tuple=True so the one
        // output buffer is a tuple literal — decompose it.
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("{}: fetch result", self.info.name))?;
        let parts = lit.to_tuple().with_context(|| format!("{}: untuple", self.info.name))?;
        if parts.len() != self.info.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.info.name,
                self.info.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.info.inputs) {
            if t.shape != spec.shape || t.dtype() != spec.dtype {
                bail!(
                    "{}: input '{}' expects {:?} {:?}, got {:?} {:?}",
                    self.info.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape
                );
            }
        }
        Ok(())
    }
}

/// The runtime: one PJRT CPU client + the manifest + a compile cache.
/// Compilation happens lazily on first use and is shared across threads.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

// PjRtClient is a thread-safe C++ object behind raw pointers (see
// `Executable` note).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest and start the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("PjRtClient::cpu()")?;
        Ok(Runtime { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Default artifacts directory (crate-root `artifacts/`).
    pub fn default_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&info.path);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let compiled =
            Arc::new(Executable { info, exe, compile_secs: t0.elapsed().as_secs_f64() });
        let mut cache = self.cache.lock().unwrap();
        // Benign race: if another thread compiled meanwhile, keep the first.
        Ok(cache.entry(name.to_string()).or_insert(compiled).clone())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Read a model's pretrained base weights in `BASE_ORDER`
    /// (the train/eval artifact argument order).
    pub fn base_weights(&self, model: &str) -> Result<Vec<HostTensor>> {
        let mi = self.manifest.model(model)?;
        let path = self.manifest.dir.join(&mi.weights);
        let mut by_name = tensor_file::read_tensors(&path)?;
        BASE_ORDER
            .iter()
            .map(|k| {
                by_name
                    .remove(*k)
                    .ok_or_else(|| anyhow::anyhow!("{}: missing base tensor '{k}'", mi.weights))
            })
            .collect()
    }
}

/// Base-weight argument order — must match `model.py::BASE_ORDER`.
pub const BASE_ORDER: [&str; 12] = [
    "embed", "pos", "ln1", "ln2", "wq", "wk", "wv", "wo", "wup", "wgate", "wdown", "lnf",
];

/// LoRA tensor order — must match `model.py::LORA_ORDER`
/// (sorted `{a,b}_{proj}` names).
pub const LORA_ORDER: [&str; 14] = [
    "a_down", "a_gate", "a_k", "a_o", "a_q", "a_up", "a_v", "b_down", "b_gate", "b_k", "b_o",
    "b_q", "b_up", "b_v",
];

/// The seven LoRA-able projections (paper Appendix A).
pub const PROJS: [&str; 7] = ["q", "k", "v", "o", "up", "gate", "down"];

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then(|| Runtime::load(&dir).unwrap())
    }

    #[test]
    fn lora_order_is_sorted_ab_projections() {
        let mut names: Vec<String> = PROJS
            .iter()
            .flat_map(|p| ["a", "b"].iter().map(move |t| format!("{t}_{p}")))
            .collect();
        names.sort();
        assert_eq!(names, LORA_ORDER.to_vec());
    }

    #[test]
    fn compiles_and_runs_kernel_artifact() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("kfwd_attn_n1").unwrap();
        let info = rt.manifest.artifact("kfwd_attn_n1").unwrap();
        let (n, m, d, r, k) = (
            1,
            info.meta_usize("m").unwrap(),
            info.meta_usize("d").unwrap(),
            info.meta_usize("r").unwrap(),
            info.meta_usize("k").unwrap(),
        );
        let x = HostTensor::f32(vec![n, m, d], vec![0.01; n * m * d]).unwrap();
        let a = HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r]).unwrap();
        let b = HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k]).unwrap();
        let alpha = HostTensor::f32(vec![n], vec![2.0]).unwrap();
        let out = exe.run(&[x, a, b, alpha]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![n, m, k]);
        // y = alpha * x @ a @ b = 2 * (d * .01*.02) * (r * .03) per elem
        let want = 2.0 * (d as f32 * 0.01 * 0.02) * (r as f32 * 0.03);
        let got = out[0].as_f32().unwrap()[0];
        assert!((got - want).abs() < 1e-4, "got {got}, want {want}");
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        let exe = rt.executable("kfwd_attn_n1").unwrap();
        let bad = vec![HostTensor::scalar_f32(0.0); 4];
        assert!(exe.run(&bad).is_err());
        assert!(exe.run(&[]).is_err());
    }

    #[test]
    fn compile_cache_hits() {
        let Some(rt) = runtime() else { return };
        let a = rt.executable("kfwd_attn_n1").unwrap();
        let b = rt.executable("kfwd_attn_n1").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn base_weights_match_model_shapes() {
        let Some(rt) = runtime() else { return };
        let w = rt.base_weights("nano").unwrap();
        let mi = rt.manifest.model("nano").unwrap();
        assert_eq!(w.len(), 12);
        assert_eq!(w[0].shape, vec![mi.vocab, mi.d_model]); // embed
        assert_eq!(w[1].shape, vec![mi.seq, mi.d_model]); // pos
    }
}
