//! **Stage-pipelined execution** (DESIGN.md §15): the second parallelism
//! axis next to the data-parallel shards of [`crate::runtime::shard`].
//!
//! A [`PipelinedExec`] splits the TinyLM layer stack into `s` contiguous
//! stages (one [`StageStepExec`] per stage, built by
//! [`crate::runtime::ExecutionBackend::stages`]) and streams the pack's
//! bucket slots through them as microbatches — one persistent worker per
//! stage, GPipe-style: every stage runs all `M` forward microbatches in
//! ascending slot order, then all `M` backward microbatches in the same
//! order. Stage boundaries hand activations (forward) and boundary
//! gradients (backward) to their neighbor over per-step channels in
//! **fixed microbatch order**, so the handoff schedule is deterministic
//! regardless of worker timing.
//!
//! Bitwise identity with the fused step holds by construction:
//!
//! - a microbatch is one bucket *slot*, so every per-adapter loss
//!   denominator and every `dA`/`dB` gradient element accumulates over
//!   exactly one microbatch's rows — the same contributions in the same
//!   order the fused step uses;
//! - each activation / boundary-tensor / gradient element is produced by
//!   exactly one `(stage, microbatch)` call into the very `tinylm`
//!   routines the monolithic forward/backward delegate to, windowed to
//!   `(slot, layer-range)` — no element's reduction tree changes;
//! - the final gradient tensors are assembled by installing each stage's
//!   layer slice into its own disjoint region (layer-major layout), a
//!   pure placement with no floating-point reassociation.
//!
//! So every adapter trajectory is bitwise identical at `s = 1, 2, 4`,
//! across uneven layer splits, and composed with the data-parallel axis
//! (`rust/tests/session.rs` pins this). [`PipelinedExec`] implements
//! [`ShardStepExec`], so a [`crate::runtime::shard::ShardedState`] shard
//! can transparently execute its slot slice pipelined — that is the
//! `d × s` composition. [`PipelinedState`] is the standalone sibling of
//! `ShardedState` for pure stage-parallel (`d = 1`) execution.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::runtime::backend::{AdamOut, GradStep, Scratch, ShardStepExec, StageStepExec};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{Runtime, TrainState};
use crate::util::threadpool::ThreadPool;

/// Split `layers` into at most `s` contiguous, non-empty stage ranges
/// covering `[0, layers)` in order. Earlier stages take the remainder
/// (`layers % s`) one extra layer each, mirroring the slot split of the
/// data-parallel shards. `s` is clamped to `[1, layers]`, so asking for
/// more stages than layers degrades gracefully.
pub fn stage_ranges(layers: usize, s: usize) -> Vec<(usize, usize)> {
    let s = s.clamp(1, layers.max(1));
    let base = layers / s;
    let rem = layers % s;
    let mut out = Vec::with_capacity(s);
    let mut lo = 0usize;
    for k in 0..s {
        let nw = base + usize::from(k < rem);
        out.push((lo, lo + nw));
        lo += nw;
    }
    out
}

/// Marker embedded in channel-closure errors so the reduction can prefer
/// the *originating* stage failure over the cascade it causes.
const PIPE_CLOSED: &str = "pipeline handoff channel closed";

/// The stage executors plus their worker pool, behind one lock:
/// [`StageStepExec`] is `&mut self` (each stage owns its arena), while
/// [`ShardStepExec::run_grads`] is `&self` — the mutex bridges the two.
/// Steps are serialized per job anyway, so the lock is uncontended.
struct PipeWork {
    stages: Vec<Box<dyn StageStepExec>>,
    pool: ThreadPool,
}

/// One step's channel endpoints for a single stage: forward activations
/// arrive from the previous stage and leave toward the next; backward
/// boundary gradients flow the other way. `None` marks the pipeline
/// ends (stage 0 embeds; the final stage runs head + loss).
struct StageIo {
    f_rx: Option<mpsc::Receiver<Vec<f32>>>,
    f_tx: Option<mpsc::Sender<Vec<f32>>>,
    b_rx: Option<mpsc::Receiver<Vec<f32>>>,
    b_tx: Option<mpsc::Sender<Vec<f32>>>,
}

/// Drive one stage through a full step: all `m` forward microbatches in
/// ascending slot order, then all `m` backward microbatches in the same
/// order (GPipe). Channels are unbounded, so the fixed schedule cannot
/// deadlock: a stage blocks only on data its neighbor has not produced
/// yet. `per` is the per-slot loss sink (final stage only).
#[allow(clippy::too_many_arguments)]
fn run_stage(
    st: &mut dyn StageStepExec,
    m: usize,
    base: &[HostTensor],
    lora: &[HostTensor],
    scale: &[f32],
    tokens: &HostTensor,
    targets: &HostTensor,
    mask: &HostTensor,
    io: StageIo,
    mut per: Option<&mut Vec<f32>>,
) -> Result<()> {
    let (lo, hi) = st.layer_range();
    let closed = |dir: &str| anyhow!("stage [{lo}, {hi}): {dir} {PIPE_CLOSED}");
    st.begin_step()?;
    for mb in 0..m {
        let x_in = match io.f_rx.as_ref() {
            Some(rx) => Some(rx.recv().map_err(|_| closed("forward"))?),
            None => None,
        };
        let x_out = st.run_fwd(mb, 1, base, lora, scale, tokens, x_in.as_deref())?;
        match (io.f_tx.as_ref(), per.as_deref_mut()) {
            (Some(tx), _) => tx.send(x_out).map_err(|_| closed("forward"))?,
            (None, Some(p)) => {
                let pl = st.run_loss(mb, 1, base, targets, mask)?;
                if pl.len() != 1 {
                    bail!("stage [{lo}, {hi}): {} losses for one microbatch", pl.len());
                }
                p[mb] = pl[0];
            }
            (None, None) => bail!("stage [{lo}, {hi}): final stage has no loss sink"),
        }
    }
    for mb in 0..m {
        let dx_in = match io.b_rx.as_ref() {
            Some(rx) => Some(rx.recv().map_err(|_| closed("backward"))?),
            None => None,
        };
        let dx_out = st.run_bwd(mb, 1, base, lora, scale, dx_in.as_deref())?;
        if let Some(tx) = io.b_tx.as_ref() {
            tx.send(dx_out).map_err(|_| closed("backward"))?;
        }
    }
    Ok(())
}

/// A train step's gradient half executed stage-pipelined (module docs).
/// Implements [`ShardStepExec`], so it drops into every slot of the
/// execution stack a fused shard executor fits: a [`PipelinedState`]'s
/// whole bucket, or one data-parallel shard of a
/// [`crate::runtime::shard::ShardedState`] (the `d × s` composition).
/// The optimizer half and eval delegate to the backend's fused shard
/// executor — both are layer-monolithic operations.
pub struct PipelinedExec {
    work: Mutex<PipeWork>,
    /// Fused full-range executor for the AdamW half and eval.
    inner: Box<dyn ShardStepExec>,
    /// Bucket slot count — also the microbatch count `M`.
    n: usize,
    ranges: Vec<(usize, usize)>,
}

impl PipelinedExec {
    /// Build a pipelined executor over `s` stages at the `(n, r, bs)`
    /// bucket shape, or `None` when pipelining cannot engage: `stages <=
    /// 1` after clamping to the layer count, or the backend cannot split
    /// the layer stack / the fused step. Callers fall back to the fused
    /// or data-parallel path on `None` — the `PLORA_STAGES=1` default
    /// never constructs one.
    pub fn build(
        rt: &Runtime,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
        stages: usize,
    ) -> Result<Option<PipelinedExec>> {
        let layers = rt.manifest.model(model)?.n_layers;
        if stages <= 1 || layers <= 1 {
            return Ok(None);
        }
        let ranges = stage_ranges(layers, stages);
        if ranges.len() <= 1 {
            return Ok(None);
        }
        let Some(stage_execs) = rt.stage_exec(model, n, r, bs, &ranges)? else {
            return Ok(None);
        };
        let Some(inner) = rt.shard_exec(model, n, r, bs)? else {
            return Ok(None);
        };
        // One persistent worker per stage (`scoped` runs the last stage
        // inline on the caller, so the pool is never oversubscribed).
        let pool = ThreadPool::new(ranges.len());
        Ok(Some(PipelinedExec {
            work: Mutex::new(PipeWork { stages: stage_execs, pool }),
            inner,
            n,
            ranges,
        }))
    }

    /// Effective stage count (after clamping to the layer count).
    pub fn stages(&self) -> usize {
        self.ranges.len()
    }

    /// The contiguous layer ranges, in stage order.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }
}

impl ShardStepExec for PipelinedExec {
    fn run_grads(
        &self,
        base: &[HostTensor],
        lora: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<GradStep> {
        let m = self.n;
        if scale.len() != m {
            bail!("pipelined run_grads: {} scale entries for bucket of {m}", scale.len());
        }
        let mut guard = self.work.lock().map_err(|_| anyhow!("pipeline stage panicked"))?;
        let PipeWork { stages, pool } = &mut *guard;
        let s_count = stages.len();
        if s_count < 2 {
            bail!("pipelined run_grads: {s_count} stages built");
        }

        // Per-step boundary channels: stage k hands forward activations
        // to k+1 and backward gradients to k-1. Unbounded, so the fixed
        // GPipe schedule never blocks a producer.
        let mut ios: Vec<StageIo> = (0..s_count)
            .map(|_| StageIo { f_rx: None, f_tx: None, b_rx: None, b_tx: None })
            .collect();
        for k in 0..s_count - 1 {
            let (ftx, frx) = mpsc::channel();
            ios[k].f_tx = Some(ftx);
            ios[k + 1].f_rx = Some(frx);
            let (btx, brx) = mpsc::channel();
            ios[k + 1].b_tx = Some(btx);
            ios[k].b_rx = Some(brx);
        }

        let mut per = vec![0.0f32; m];
        let mut outs: Vec<Option<Result<()>>> = (0..s_count).map(|_| None).collect();
        {
            let mut per_slot = Some(&mut per);
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(s_count);
            for (k, ((st, io), out)) in
                stages.iter_mut().zip(ios).zip(outs.iter_mut()).enumerate()
            {
                let p = if k + 1 == s_count { per_slot.take() } else { None };
                tasks.push(Box::new(move || {
                    *out = Some(run_stage(
                        &mut **st,
                        m,
                        base,
                        lora,
                        scale,
                        tokens,
                        targets,
                        mask,
                        io,
                        p,
                    ));
                }));
            }
            pool.scoped(tasks);
        }

        // A failing stage drops its channel ends, cascading "closed"
        // errors through its neighbors — report the origin, not the wave.
        let mut origin: Option<anyhow::Error> = None;
        let mut cascade: Option<anyhow::Error> = None;
        for (k, out) in outs.into_iter().enumerate() {
            match out {
                Some(Ok(())) => {}
                Some(Err(e)) => {
                    if !e.to_string().contains(PIPE_CLOSED) {
                        origin.get_or_insert(e);
                    } else {
                        cascade.get_or_insert(e);
                    }
                }
                None => {
                    cascade.get_or_insert(anyhow!("pipeline stage {k} did not run"));
                }
            }
        }
        if let Some(e) = origin.or(cascade) {
            return Err(e);
        }

        // Assemble the full gradient tensors: each stage's accumulators
        // are its layer slice `(hi-lo, n, d2, d3)` of the layer-major
        // `(L, n, d2, d3)` layout — one contiguous memcpy per stage per
        // tensor, every element written by exactly one stage.
        let mut grads = Vec::with_capacity(lora.len());
        for (t_idx, full) in lora.iter().enumerate() {
            let shape = full.shape.clone();
            if shape.len() != 4 || shape[1] != m {
                bail!("pipelined run_grads: lora[{t_idx}] shape {shape:?} for bucket of {m}");
            }
            let panel = shape[2] * shape[3];
            let count: usize = shape.iter().product();
            let mut buf = scratch.take_buf(count);
            for st in stages.iter() {
                let (lo, hi) = st.layer_range();
                let sg = st.stage_grads();
                if sg.len() != lora.len() {
                    bail!("pipelined run_grads: stage produced {} grad tensors", sg.len());
                }
                let seg = &sg[t_idx];
                if seg.len() != (hi - lo) * m * panel {
                    bail!(
                        "pipelined run_grads: stage [{lo}, {hi}) grad len {} != {}",
                        seg.len(),
                        (hi - lo) * m * panel
                    );
                }
                buf[lo * m * panel..hi * m * panel].copy_from_slice(seg);
            }
            grads.push(HostTensor::f32(shape, buf)?);
        }
        Ok(GradStep { grads, per_loss: per })
    }

    fn run_eval(
        &self,
        base: &[HostTensor],
        lora: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        // Eval is a logits-only forward — no stage state to keep, so the
        // fused shard executor runs it (bitwise identical by DESIGN §11).
        self.inner.run_eval(base, lora, tokens, targets, mask, scale, scratch)
    }

    fn run_adamw(
        &self,
        lora: &[HostTensor],
        m: &[HostTensor],
        v: &[HostTensor],
        t: &[f32],
        grads: &[HostTensor],
        lr: &[f32],
        rmask: &HostTensor,
        scratch: &mut Scratch,
    ) -> Result<AdamOut> {
        self.inner.run_adamw(lora, m, v, t, grads, lr, rmask, scratch)
    }
}

/// A [`TrainState`] executing stage-pipelined on one device-set slot —
/// the stage-parallel sibling of [`crate::runtime::shard::ShardedState`].
/// Where `ShardedState` splits the bucket's *slots* across devices, this
/// splits the *layer stack* across stage workers; the trajectory is
/// bitwise identical to the fused path either way (module docs).
pub struct PipelinedState {
    inner: TrainState,
    exe: PipelinedExec,
    scratch: Scratch,
    bs: usize,
}

impl PipelinedState {
    /// Wrap `inner` for `stages`-way pipelined execution. Unlike
    /// [`crate::runtime::shard::ShardedState::new`] this does not fall
    /// back silently: callers decide the fallback (the driver composes
    /// pipelining through `ShardedState`, which does degrade to fused),
    /// so an un-pipelinable request here is an error.
    pub fn new(
        rt: &Runtime,
        model: &str,
        inner: TrainState,
        bs: usize,
        stages: usize,
    ) -> Result<PipelinedState> {
        match PipelinedExec::build(rt, model, inner.n, inner.r, bs, stages)? {
            Some(exe) => Ok(PipelinedState { inner, exe, scratch: Scratch::new(), bs }),
            None => bail!("pipelined state: cannot split '{model}' into {stages} stages"),
        }
    }

    /// The wrapped single-bucket training state.
    pub fn inner(&self) -> &TrainState {
        &self.inner
    }

    /// Unwrap (checkpointing and repack run on the plain state).
    pub fn into_inner(self) -> TrainState {
        self.inner
    }

    /// Effective pipeline depth (after clamping to the layer count).
    pub fn stages(&self) -> usize {
        self.exe.stages()
    }

    /// See [`TrainState::rank_mask`].
    pub fn rank_mask(&self, ranks: &[usize]) -> Result<HostTensor> {
        self.inner.rank_mask(ranks)
    }

    /// One training step — the same contract as [`TrainState::step`]:
    /// pipelined gradient half, then one fused AdamW update.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
        lr: &[f32],
        rmask: &HostTensor,
    ) -> Result<Vec<f32>> {
        let n = self.inner.n;
        if tokens.shape != [n, self.bs, self.inner.model.seq] {
            bail!(
                "pipelined step: batch tensors {:?} do not match the built ({n}, {}, {}) layout",
                tokens.shape,
                self.bs,
                self.inner.model.seq
            );
        }
        if scale.len() != n || lr.len() != n {
            bail!(
                "pipelined step: {} scale / {} lr entries for pack of {n}",
                scale.len(),
                lr.len()
            );
        }
        let GradStep { grads, per_loss } = self.exe.run_grads(
            base,
            &self.inner.lora,
            tokens,
            targets,
            loss_mask,
            scale,
            &mut self.scratch,
        )?;
        let out = self.exe.run_adamw(
            &self.inner.lora,
            &self.inner.m,
            &self.inner.v,
            &self.inner.t,
            &grads,
            lr,
            rmask,
            &mut self.scratch,
        )?;
        let old_l = std::mem::replace(&mut self.inner.lora, out.lora);
        let old_m = std::mem::replace(&mut self.inner.m, out.m);
        let old_v = std::mem::replace(&mut self.inner.v, out.v);
        self.inner.t = out.t;
        for spent in old_l.into_iter().chain(old_m).chain(old_v).chain(grads) {
            if let Some(buf) = spent.into_f32_vec() {
                self.scratch.recycle(buf);
            }
        }
        Ok(per_loss)
    }

    /// See [`TrainState::eval`]. Eval is layer-monolithic (logits-only
    /// forward), so it runs on the fused shard executor — bitwise
    /// identical to the fused eval executable.
    pub fn eval(
        &mut self,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.inner.n;
        if tokens.shape != [n, self.bs, self.inner.model.seq] {
            bail!(
                "pipelined eval: batch tensors {:?} do not match the built ({n}, {}, {}) layout",
                tokens.shape,
                self.bs,
                self.inner.model.seq
            );
        }
        if scale.len() != n {
            bail!("pipelined eval: {} scale entries for pack of {n}", scale.len());
        }
        match self.exe.run_eval(
            base,
            &self.inner.lora,
            tokens,
            targets,
            loss_mask,
            scale,
            &mut self.scratch,
        )? {
            Some(out) => Ok(out),
            None => bail!("pipelined eval: backend cannot eval at bucket granularity"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn runtime() -> Runtime {
        Runtime::load(&std::env::temp_dir().join("plora-pipeline-tests")).unwrap()
    }

    #[test]
    fn stage_ranges_partition_the_stack() {
        assert_eq!(stage_ranges(4, 2), vec![(0, 2), (2, 4)]);
        assert_eq!(stage_ranges(4, 3), vec![(0, 2), (2, 3), (3, 4)]);
        assert_eq!(stage_ranges(5, 2), vec![(0, 3), (3, 5)]);
        assert_eq!(stage_ranges(3, 1), vec![(0, 3)]);
        // More stages than layers: clamped, never an empty stage.
        assert_eq!(stage_ranges(2, 4), vec![(0, 1), (1, 2)]);
        // Every split covers [0, L) contiguously.
        for layers in 1..9usize {
            for s in 1..9usize {
                let r = stage_ranges(layers, s);
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, layers);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                    assert!(w[0].1 > w[0].0);
                }
            }
        }
    }

    /// The tentpole invariant at the runtime layer: the same pack stepped
    /// fused and stage-pipelined at s = 2 (and s = 4, clamped to nano's
    /// two layers) produces bitwise-identical params, moments, step
    /// counters and per-adapter losses.
    #[test]
    fn pipelined_steps_are_bitwise_identical_to_fused() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 4, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;
        let seeds = [3u64, 5, 7, 9];
        let ranks = [8usize, 4, 8, 6];
        let scale = [1.0f32, 0.5, 1.0, 0.8];
        let lrs = [2e-3f32, 1e-3, 2e-3, 1e-3];

        let batch = |rng: &mut Rng| {
            let tokens: Vec<i32> =
                (0..4 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
            let mut targets = tokens.clone();
            targets.rotate_left(1);
            let tok = HostTensor::i32(vec![4, 1, seq], tokens).unwrap();
            let tgt = HostTensor::i32(vec![4, 1, seq], targets).unwrap();
            let msk = HostTensor::f32(vec![4, 1, seq], vec![1.0; 4 * seq]).unwrap();
            (tok, tgt, msk)
        };
        let snap = |st: &TrainState| -> (Vec<Vec<f32>>, Vec<f32>, Vec<Vec<f32>>) {
            (
                st.lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect(),
                st.t.clone(),
                st.m.iter().map(|t| t.as_f32().unwrap().to_vec()).collect(),
            )
        };

        // Fused baseline.
        let (want, want_per) = {
            let mut st = TrainState::init_per_adapter(&mi, 4, 8, &seeds, &ranks).unwrap();
            let rmask = st.rank_mask(&ranks).unwrap();
            let mut rng = Rng::new(41);
            let mut losses = vec![];
            for _ in 0..3 {
                let (tok, tgt, msk) = batch(&mut rng);
                losses.push(
                    st.step(&exe, &base, &tok, &tgt, &msk, &scale, &lrs, &rmask).unwrap(),
                );
            }
            (snap(&st), losses)
        };
        assert_eq!(want.1, vec![3.0; 4]);
        assert!(want_per.iter().flatten().all(|l| l.is_finite()));

        for s in [2usize, 4] {
            let inner = TrainState::init_per_adapter(&mi, 4, 8, &seeds, &ranks).unwrap();
            let mut st = PipelinedState::new(&rt, "nano", inner, 1, s).unwrap();
            assert_eq!(st.stages(), s.min(mi.n_layers), "stage count clamps to the stack");
            let rmask = st.rank_mask(&ranks).unwrap();
            let mut rng = Rng::new(41);
            let mut losses = vec![];
            for _ in 0..3 {
                let (tok, tgt, msk) = batch(&mut rng);
                losses.push(st.step(&base, &tok, &tgt, &msk, &scale, &lrs, &rmask).unwrap());
            }
            let got = snap(st.inner());
            assert_eq!(want_per, losses, "per-adapter losses diverged at s={s}");
            assert_eq!(want.1, got.1, "step counters diverged at s={s}");
            for (k, (a, b)) in want.0.iter().zip(&got.0).enumerate() {
                assert_eq!(a, b, "lora[{k}] diverged at s={s}");
            }
            for (k, (a, b)) in want.2.iter().zip(&got.2).enumerate() {
                assert_eq!(a, b, "m[{k}] diverged at s={s}");
            }
        }
    }

    /// Eval through a pipelined state matches the fused eval bitwise —
    /// including mid-trajectory, after params have moved.
    #[test]
    fn pipelined_eval_matches_fused() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 2, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let eval_exe = rt.executable(&rt.manifest.eval_for(&info).unwrap().name.clone()).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;
        let scale = [1.0f32, 0.5];
        let lrs = [2e-3f32, 1e-3];

        let batch = |rng: &mut Rng| {
            let tokens: Vec<i32> =
                (0..2 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
            let mut targets = tokens.clone();
            targets.rotate_left(1);
            let tok = HostTensor::i32(vec![2, 1, seq], tokens).unwrap();
            let tgt = HostTensor::i32(vec![2, 1, seq], targets).unwrap();
            let msk = HostTensor::f32(vec![2, 1, seq], vec![1.0; 2 * seq]).unwrap();
            (tok, tgt, msk)
        };

        let mut fused = TrainState::init_per_adapter(&mi, 2, 8, &[5, 9], &[8, 4]).unwrap();
        let inner = TrainState::init_per_adapter(&mi, 2, 8, &[5, 9], &[8, 4]).unwrap();
        let mut piped = PipelinedState::new(&rt, "nano", inner, 1, 2).unwrap();
        let rmask = fused.rank_mask(&[8, 4]).unwrap();
        let mut rng = Rng::new(17);
        for _ in 0..2 {
            let (tok, tgt, msk) = batch(&mut rng);
            let (fl, fa) = fused.eval(&eval_exe, &base, &tok, &tgt, &msk, &scale).unwrap();
            let (pl, pa) = piped.eval(&base, &tok, &tgt, &msk, &scale).unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&fl), bits(&pl), "eval losses diverged");
            assert_eq!(bits(&fa), bits(&pa), "eval accs diverged");
            fused.step(&exe, &base, &tok, &tgt, &msk, &scale, &lrs, &rmask).unwrap();
            piped.step(&base, &tok, &tgt, &msk, &scale, &lrs, &rmask).unwrap();
        }
    }
}
