//! PJRT execution backend (`pjrt` feature): compiles the AOT HLO-text
//! artifacts (`make artifacts`) via the PJRT CPU client and replays them.
//!
//! Wiring (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO **text** is the interchange format; jax ≥ 0.5 serialized protos are
//! rejected by xla_extension 0.5.1 (64-bit instruction ids).

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::backend::{BackendExecutable, ExecutionBackend, Scratch};
use crate::runtime::manifest::{ArtifactInfo, Manifest};
use crate::runtime::tensor::HostTensor;

/// One PJRT CPU client shared by every executable it loads.
pub struct PjrtBackend {
    client: PjRtClient,
}

// PjRtClient is a thread-safe C++ object behind raw pointers; XLA
// guarantees concurrent compile/execute calls.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        Ok(PjrtBackend { client: PjRtClient::cpu().context("PjRtClient::cpu()")? })
    }
}

impl ExecutionBackend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load(&self, manifest: &Manifest, info: &ArtifactInfo) -> Result<Box<dyn BackendExecutable>> {
        let path = manifest.dir.join(&info.path);
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).with_context(|| format!("compile {}", info.name))?;
        Ok(Box::new(PjrtExec { name: info.name.clone(), exe }))
    }
}

/// A compiled HLO module. Output arity against the manifest is enforced by
/// `Executable::run`, which wraps every backend call.
struct PjrtExec {
    name: String,
    exe: PjRtLoadedExecutable,
}

// See `PjrtBackend` note: the underlying PJRT object is thread-safe.
unsafe impl Send for PjrtExec {}
unsafe impl Sync for PjrtExec {}

impl BackendExecutable for PjrtExec {
    // PJRT owns its device buffers; the host-side scratch is unused.
    fn run(&self, inputs: &[&HostTensor], _scratch: &mut Scratch) -> Result<Vec<HostTensor>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("{}: building literals", self.name))?;
        let result = self
            .exe
            .execute::<Literal>(&lits)
            .with_context(|| format!("{}: execute", self.name))?;
        // Single replica; jax lowers with return_tuple=True so the one
        // output buffer is a tuple literal — decompose it.
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("{}: fetch result", self.name))?;
        let parts = lit.to_tuple().with_context(|| format!("{}: untuple", self.name))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}
