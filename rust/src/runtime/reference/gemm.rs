//! Register-blocked, cache-tiled GEMM kernels for the reference backend's
//! hot path, plus the runtime knobs that select between them.
//!
//! Three accumulate-into-`out` primitives cover every matmul the TinyLM
//! interpreter performs (see [`super::tinylm`]): `out += α·A·B`
//! ([`mm_acc`]), `out += α·A·Bᵀ` ([`mm_nt_acc`]) and `out += α·Aᵀ·B`
//! ([`mm_tn_acc`]). Each exists in three implementations:
//!
//! - [`naive`] — the straight triple loops the backend shipped with. They
//!   stay compiled as the ground truth the property tests and the
//!   `train_step` bench compare against.
//! - [`tiled`] — the default. Output tiles are walked with fixed-width
//!   register accumulator blocks and the reduction dimension is processed
//!   in cache-sized panels.
//! - [`simd`] — `PLORA_GEMM=simd`. The tiled panel structure with an
//!   explicit 8-lane vector inner microkernel; lanes always span output
//!   columns, never the reduction (DESIGN.md §14's lane-reduction-order
//!   contract), so it is bit-identical to the other two.
//!
//! **Bit-exactness invariant.** For every output element, all
//! implementations perform the *identical sequence of f32 operations*: the
//! k-accumulation runs in ascending k order, partial dot products are
//! rounded exactly where the naive code rounds them, and the `f == 0.0`
//! skip fires on exactly the same terms. Tiling and vectorization only
//! reorder work *across* output elements, never within one, so switching
//! implementations (or thread counts) can never perturb a training
//! trajectory — the solo-vs-packed-vs-rebucketed guarantees pinned in
//! `rust/tests/session.rs` hold under any `Mode`/`PLORA_THREADS` setting.
//! `rust/tests/properties.rs` re-verifies the equivalence on randomized
//! shapes every run.
//!
//! **Threading.** [`mm_acc_par`] / [`mm_nt_acc_par`] / [`mm_tn_acc_par`]
//! split the *output rows* across the persistent
//! [`crate::util::threadpool::global`] workers (no per-region thread
//! spawns). A row's reduction is entirely sequential inside one worker and
//! no two workers share an output element, so the result is bitwise
//! identical at any worker count. The worker count comes from the
//! `PLORA_THREADS` env var (default 1, i.e. serial), and can be overridden
//! programmatically with [`set_threads`] (benches).
//!
//! **Batching.** [`batched`] runs `nb` independent same-shape `Aᵀ·B`
//! problems (the packed bucket's per-adapter `dA`/`dB` reductions) through
//! one entry point whose `_par` driver splits the combined `nb·m` output
//! rows at *row* granularity instead of adapter granularity. Interleaving
//! adapters never touches any single element's reduction chain, so the
//! fused path is bit-identical to the per-adapter loop it replaces
//! (`PLORA_FUSED=0` restores that loop for A/B benchmarking).

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// Kernel implementation selector (`PLORA_GEMM`, default `tiled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Tiled,
    Naive,
    Simd,
}

const MODE_TILED: u8 = 0;
const MODE_NAIVE: u8 = 1;
const MODE_SIMD: u8 = 2;
const MODE_UNSET: u8 = 3;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);
static THREADS: AtomicUsize = AtomicUsize::new(0); // 0 = not yet resolved

const FUSED_ON: u8 = 0;
const FUSED_OFF: u8 = 1;
const FUSED_UNSET: u8 = 2;

static FUSED: AtomicU8 = AtomicU8::new(FUSED_UNSET);

/// Active kernel implementation; first call reads `PLORA_GEMM`
/// (`naive`/`tiled`/`simd`). All produce bit-identical results — the knob
/// exists for the bench baseline and for bisecting perf regressions.
pub fn mode() -> Mode {
    match MODE.load(Ordering::Relaxed) {
        MODE_TILED => Mode::Tiled,
        MODE_NAIVE => Mode::Naive,
        MODE_SIMD => Mode::Simd,
        _ => {
            let m = match std::env::var("PLORA_GEMM").as_deref() {
                Ok("naive") => Mode::Naive,
                Ok("simd") => Mode::Simd,
                _ => Mode::Tiled,
            };
            set_mode(m);
            m
        }
    }
}

/// Override the kernel implementation (benches/tests).
pub fn set_mode(m: Mode) {
    let v = match m {
        Mode::Tiled => MODE_TILED,
        Mode::Naive => MODE_NAIVE,
        Mode::Simd => MODE_SIMD,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// Whether the packed projection fuses work across adapter boundaries
/// (the [`batched`] `dA`/`dB` path plus the hoisted shared-base GEMMs in
/// `tinylm`); first call reads `PLORA_FUSED` (default on; `0`/`off`
/// restores the per-adapter loops). Both settings are bit-identical — the
/// knob exists for the bench baseline and for bisecting.
pub fn fused() -> bool {
    match FUSED.load(Ordering::Relaxed) {
        FUSED_ON => true,
        FUSED_OFF => false,
        _ => {
            let f = !matches!(std::env::var("PLORA_FUSED").as_deref(), Ok("0") | Ok("off"));
            set_fused(f);
            f
        }
    }
}

/// Override the adapter-fusion knob (benches/tests).
pub fn set_fused(f: bool) {
    FUSED.store(if f { FUSED_ON } else { FUSED_OFF }, Ordering::Relaxed);
}

/// Intra-step worker count; first call reads `PLORA_THREADS` (default 1).
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => {
            let t = std::env::var("PLORA_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&t| t >= 1)
                .unwrap_or(1);
            THREADS.store(t, Ordering::Relaxed);
            t
        }
        t => t,
    }
}

/// Override the intra-step worker count (benches/tests). Clamped to ≥ 1.
pub fn set_threads(t: usize) {
    THREADS.store(t.max(1), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Dispatchers
// ---------------------------------------------------------------------------

/// `out (m,n) += alpha * a (m,k) @ b (k,n)`.
pub fn mm_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, alpha: f32) {
    match mode() {
        Mode::Tiled => tiled::mm_acc(out, a, b, m, k, n, alpha),
        Mode::Naive => naive::mm_acc(out, a, b, m, k, n, alpha),
        Mode::Simd => simd::mm_acc(out, a, b, m, k, n, alpha),
    }
}

/// `out (m,n) += alpha * a (m,k) @ b^T` with `b` stored `(n,k)`.
pub fn mm_nt_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, alpha: f32) {
    match mode() {
        Mode::Tiled => tiled::mm_nt_acc(out, a, b, m, k, n, alpha),
        Mode::Naive => naive::mm_nt_acc(out, a, b, m, k, n, alpha),
        Mode::Simd => simd::mm_nt_acc(out, a, b, m, k, n, alpha),
    }
}

/// `out (m,n) += alpha * a^T @ b` with `a` stored `(k,m)`, `b` `(k,n)`.
pub fn mm_tn_acc(out: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize, alpha: f32) {
    match mode() {
        Mode::Tiled => tiled::mm_tn_acc(out, a, b, k, m, n, alpha),
        Mode::Naive => naive::mm_tn_acc(out, a, b, k, m, n, alpha),
        Mode::Simd => simd::mm_tn_acc(out, a, b, k, m, n, alpha),
    }
}

/// Rows `[r0, r0 + rl)` of [`mm_tn_acc`]'s `(m,n)` output: `out` is the
/// row-aligned chunk for exactly that range while `a`/`b` stay the full
/// `(k,m)` / `(k,n)` operands. Restricting the row loop never touches any
/// element's own ascending-k chain, so a union of row-range calls is
/// bit-identical to one full call — this is the building block under both
/// [`mm_tn_acc_par`] and the [`batched`] drivers.
#[allow(clippy::too_many_arguments)]
pub fn mm_tn_acc_rows(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    alpha: f32,
    r0: usize,
    rl: usize,
) {
    match mode() {
        Mode::Tiled => tiled::mm_tn_acc_rows(out, a, b, k, m, n, alpha, r0, rl),
        Mode::Naive => naive::mm_tn_acc_rows(out, a, b, k, m, n, alpha, r0, rl),
        Mode::Simd => simd::mm_tn_acc_rows(out, a, b, k, m, n, alpha, r0, rl),
    }
}

// ---------------------------------------------------------------------------
// Row-parallel drivers
// ---------------------------------------------------------------------------

/// Don't parallelize calls doing fewer multiply-accumulates than this:
/// dispatching onto the pool still costs queue/latch synchronization, so a
/// region must carry real work before splitting it pays. Below the cutoff
/// the work runs serially — bitwise identical either way, only the wall
/// clock differs (nano-scale steps stay dispatch-free even at
/// `PLORA_THREADS=4`).
pub(crate) const PAR_MIN_WORK: usize = 1 << 20;

/// Split `rows` into at most `nt` contiguous chunks — carving the two
/// row-aligned output buffers (`out1` with `s1` floats per row, `out2`
/// with `s2`; either may be empty with stride 0) along the same
/// boundaries — and run `body(chunk1, chunk2, lo, hi)` on the persistent
/// [`crate::util::threadpool::global`] workers (no per-region thread
/// spawns — the ~10–20 µs spawn cost the old `std::thread::scope` path
/// paid per parallel region). Falls back to one serial
/// `body(out1, out2, 0, rows)` call when `nt` is 1 or the total work
/// (`rows · work_per_row` MACs) is under [`PAR_MIN_WORK`]. Each output
/// row is written by exactly one worker and `body` must keep every row's
/// reduction sequential, so the result is bitwise identical at any `nt`
/// (every caller's `body` is a pure row-range kernel). The pool's last
/// task runs inline on the calling thread, and dispatch from a pool
/// worker degrades to inline serial execution — same results either way.
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_row_chunks<F>(
    rows: usize,
    nt: usize,
    work_per_row: usize,
    out1: &mut [f32],
    s1: usize,
    out2: &mut [f32],
    s2: usize,
    body: F,
) where
    F: Fn(&mut [f32], &mut [f32], usize, usize) + Sync,
{
    let nt = nt.min(rows).max(1);
    if nt <= 1 || rows.saturating_mul(work_per_row) < PAR_MIN_WORK {
        body(out1, out2, 0, rows);
        return;
    }
    let chunk = rows.div_ceil(nt);
    let body = &body;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nt);
    let mut rest1 = out1;
    let mut rest2 = out2;
    let mut lo = 0usize;
    while lo < rows {
        let h = chunk.min(rows - lo);
        let (c1, t1) = std::mem::take(&mut rest1).split_at_mut(h * s1);
        let (c2, t2) = std::mem::take(&mut rest2).split_at_mut(h * s2);
        rest1 = t1;
        rest2 = t2;
        let hi = lo + h;
        tasks.push(Box::new(move || body(c1, c2, lo, hi)));
        lo = hi;
    }
    crate::util::threadpool::global().scoped(tasks);
}

/// Split `m` output rows across scoped threads and run [`mm_acc`] on each
/// chunk. Rows are independent, so the result is bitwise identical to the
/// serial call at any `nt`.
#[allow(clippy::too_many_arguments)]
pub fn mm_acc_par(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    nt: usize,
) {
    let mut none = [0.0f32; 0];
    par_row_chunks(m, nt, k * n, out, n, &mut none, 0, |oc, _, lo, hi| {
        mm_acc(oc, &a[lo * k..hi * k], b, hi - lo, k, n, alpha)
    });
}

/// Row-parallel [`mm_nt_acc`] (same contract as [`mm_acc_par`]).
#[allow(clippy::too_many_arguments)]
pub fn mm_nt_acc_par(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    nt: usize,
) {
    let mut none = [0.0f32; 0];
    par_row_chunks(m, nt, k * n, out, n, &mut none, 0, |oc, _, lo, hi| {
        mm_nt_acc(oc, &a[lo * k..hi * k], b, hi - lo, k, n, alpha)
    });
}

/// Row-parallel [`mm_tn_acc`] (same contract as [`mm_acc_par`]). The `m`
/// output rows split across pool workers; every worker reads the full
/// column-strided `a` and full `b` but writes only its own row chunk via
/// [`mm_tn_acc_rows`], so the result is bitwise identical at any `nt`.
/// Re-entrant dispatch (calling from a pool worker) degrades to inline
/// serial execution exactly like the sibling drivers.
#[allow(clippy::too_many_arguments)]
pub fn mm_tn_acc_par(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    alpha: f32,
    nt: usize,
) {
    let mut none = [0.0f32; 0];
    par_row_chunks(m, nt, k * n, out, n, &mut none, 0, |oc, _, lo, hi| {
        mm_tn_acc_rows(oc, a, b, k, m, n, alpha, lo, hi - lo)
    });
}

// ---------------------------------------------------------------------------
// Batched multi-adapter drivers
// ---------------------------------------------------------------------------

/// Batched multi-adapter `Aᵀ·B` GEMMs: `nb` independent same-shape
/// problems — the packed bucket's per-adapter `dA`/`dB` weight-gradient
/// reductions — walked by one entry point over densely-strided operands
/// (`a_i` at `i·k·m`, `b_i` at `i·k·n`, `out_i` at `i·m·n`).
///
/// Each adapter's elements keep exactly the op sequence the per-adapter
/// [`super::mm_tn_acc`] loop gave them (same mode-dispatched kernel, same
/// per-adapter `alpha`, ascending-k chains, `f == 0.0` zero-rank-padding
/// skip), so the fused path is bit-identical — only the *walk order across
/// adapters* and the parallel split change. The `_par` driver splits the
/// combined `nb·m` output-row space at row granularity, so one big adapter
/// no longer serializes behind `nt.min(nb)` adapter-granular tasks.
pub mod batched {
    use super::*;

    /// `out_i (m,n) += alphas[i] * a_i^T @ b_i` for `i in 0..nb`, with
    /// `a` stored `(nb,k,m)` and `b` `(nb,k,n)`. `alphas: None` means 1.0
    /// for every adapter.
    #[allow(clippy::too_many_arguments)]
    pub fn mm_tn_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        nb: usize,
        k: usize,
        m: usize,
        n: usize,
        alphas: Option<&[f32]>,
    ) {
        rows(out, a, b, k, m, n, alphas, 0, nb * m);
    }

    /// Rows `[lo, hi)` of the adapter-major `(nb·m, n)` combined output
    /// space (row `ρ` belongs to adapter `ρ / m`); `out` is the
    /// row-aligned chunk for exactly that range.
    #[allow(clippy::too_many_arguments)]
    fn rows(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alphas: Option<&[f32]>,
        lo: usize,
        hi: usize,
    ) {
        let mut row = lo;
        while row < hi {
            let i = row / m; // adapter owning this row group
            let end = ((i + 1) * m).min(hi);
            let alpha = alphas.map_or(1.0, |s| s[i]);
            let oc = &mut out[(row - lo) * n..(end - lo) * n];
            super::mm_tn_acc_rows(
                oc,
                &a[i * k * m..(i + 1) * k * m],
                &b[i * k * n..(i + 1) * k * n],
                k,
                m,
                n,
                alpha,
                row - i * m,
                end - row,
            );
            row = end;
        }
    }

    /// Row-parallel batched driver: the `nb·m` combined output rows split
    /// across pool workers through the same [`super::par_row_chunks`]
    /// guards (work-size cutoff, re-entrancy degrading to inline) as every
    /// `_par` driver. Bitwise identical to [`mm_tn_acc`] at any `nt`.
    #[allow(clippy::too_many_arguments)]
    pub fn mm_tn_acc_par(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        nb: usize,
        k: usize,
        m: usize,
        n: usize,
        alphas: Option<&[f32]>,
        nt: usize,
    ) {
        let total = nb * m;
        let mut none = [0.0f32; 0];
        par_row_chunks(total, nt, k * n, out, n, &mut none, 0, |oc, _, lo, hi| {
            rows(oc, a, b, k, m, n, alphas, lo, hi)
        });
    }
}

// ---------------------------------------------------------------------------
// Naive reference kernels (the pre-tiling implementations, verbatim)
// ---------------------------------------------------------------------------

/// The original triple-loop kernels. Kept compiled as the bit-exact ground
/// truth for the property tests and the `train_step` bench baseline.
pub mod naive {
    /// `out (m,n) += alpha * a (m,k) @ b (k,n)`.
    pub fn mm_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, alpha: f32) {
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let or = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let br = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += f * bv;
                }
            }
        }
    }

    /// `out (m,n) += alpha * a (m,k) @ b^T` with `b` stored `(n,k)`.
    pub fn mm_nt_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        alpha: f32,
    ) {
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let or = &mut out[i * n..(i + 1) * n];
            for (j, o) in or.iter_mut().enumerate() {
                let br = &b[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (av, bv) in ar.iter().zip(br) {
                    s += av * bv;
                }
                *o += alpha * s;
            }
        }
    }

    /// `out (m,n) += alpha * a^T @ b` with `a` stored `(k,m)`, `b` `(k,n)`.
    pub fn mm_tn_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
    ) {
        for kk in 0..k {
            let ar = &a[kk * m..(kk + 1) * m];
            let br = &b[kk * n..(kk + 1) * n];
            for (i, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let or = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += f * bv;
                }
            }
        }
    }

    /// Rows `[r0, r0 + rl)` of [`mm_tn_acc`]; `out` is the row-aligned
    /// chunk. Same loops restricted to the range — each element keeps its
    /// exact op sequence.
    #[allow(clippy::too_many_arguments)]
    pub fn mm_tn_acc_rows(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
        r0: usize,
        rl: usize,
    ) {
        for kk in 0..k {
            let ar = &a[kk * m + r0..kk * m + r0 + rl];
            let br = &b[kk * n..(kk + 1) * n];
            for (i, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let or = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in or.iter_mut().zip(br) {
                    *o += f * bv;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tiled kernels
// ---------------------------------------------------------------------------

/// Blocked implementations. Tile geometry:
///
/// - `KC` — reduction panel. A `KC × NC` panel of `b` stays L1/L2-resident
///   while every output row streams over it, and output elements are
///   loaded/stored once per panel instead of once per k step.
/// - `NC` — output-column panel bounding the resident `b` panel.
/// - `NR` — register accumulator width for the axpy-style kernels.
/// - `IR × JR` — the dot-product micro-tile of [`tiled::mm_nt_acc`]:
///   16 independent k-sequential accumulation chains hide FMA latency
///   (the naive kernel runs a single chain and is latency-bound).
pub mod tiled {
    /// Reduction (k) panel length.
    const KC: usize = 64;
    /// Output-column panel width (`KC × NC` f32 panel of `b` = 64 KiB).
    const NC: usize = 256;
    /// Register accumulator width (axpy kernels).
    const NR: usize = 16;
    /// Dot-product micro-tile rows of `a`.
    const IR: usize = 4;
    /// Dot-product micro-tile rows of `b`.
    const JR: usize = 4;

    /// `out (m,n) += alpha * a (m,k) @ b (k,n)`.
    ///
    /// Loop order: k-panel → column-panel → row → register block. Each
    /// output element still receives its k contributions in ascending k
    /// order with the naive kernel's `f == 0.0` skip, so the result is
    /// bit-identical; the panel loops only bound the working set.
    pub fn mm_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, alpha: f32) {
        let mut kb = 0usize;
        while kb < k {
            let kh = KC.min(k - kb);
            let mut jc = 0usize;
            while jc < n {
                let jw = NC.min(n - jc);
                for i in 0..m {
                    let ar = &a[i * k + kb..i * k + kb + kh];
                    let or = &mut out[i * n + jc..i * n + jc + jw];
                    axpy_panel(or, ar, b, kb, n, jc, jw, alpha);
                }
                jc += jw;
            }
            kb += kh;
        }
    }

    /// One row × one column panel of the axpy kernel: accumulates
    /// `or[j] += alpha*a[kk] * b[kb+kk][jc+j]` over the k panel, walking
    /// `or` in `NR`-wide register blocks.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn axpy_panel(
        or: &mut [f32],
        ar: &[f32],
        b: &[f32],
        kb: usize,
        n: usize,
        jc: usize,
        jw: usize,
        alpha: f32,
    ) {
        let mut j = 0usize;
        // Full-width register blocks (fixed-size loops vectorize).
        while j + NR <= jw {
            let mut acc = [0.0f32; NR];
            acc.copy_from_slice(&or[j..j + NR]);
            for (dk, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let br = &b[(kb + dk) * n + jc + j..(kb + dk) * n + jc + j + NR];
                for t in 0..NR {
                    acc[t] += f * br[t];
                }
            }
            or[j..j + NR].copy_from_slice(&acc);
            j += NR;
        }
        // Remainder columns (same per-element op sequence, dynamic width).
        if j < jw {
            let w = jw - j;
            let mut acc = [0.0f32; NR];
            acc[..w].copy_from_slice(&or[j..jw]);
            for (dk, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let br = &b[(kb + dk) * n + jc + j..(kb + dk) * n + jc + jw];
                for (x, &bv) in acc[..w].iter_mut().zip(br) {
                    *x += f * bv;
                }
            }
            or[j..jw].copy_from_slice(&acc[..w]);
        }
    }

    /// `out (m,n) += alpha * a (m,k) @ b^T` with `b` stored `(n,k)`.
    ///
    /// `IR × JR` dot products run as independent k-sequential chains; each
    /// chain is rounded exactly like the naive kernel's single chain
    /// (full-k partial sum, then one `out += alpha * s`), so results are
    /// bit-identical.
    pub fn mm_nt_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        alpha: f32,
    ) {
        let mut i = 0usize;
        while i < m {
            let ih = IR.min(m - i);
            let mut j = 0usize;
            while j < n {
                let jh = JR.min(n - j);
                if ih == IR && jh == JR {
                    nt_micro_full(out, a, b, k, n, alpha, i, j);
                } else {
                    nt_micro_edge(out, a, b, k, n, alpha, i, j, ih, jh);
                }
                j += jh;
            }
            i += ih;
        }
    }

    /// Full `IR × JR` dot micro-tile (fixed-size loops).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn nt_micro_full(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        alpha: f32,
        i: usize,
        j: usize,
    ) {
        let mut acc = [[0.0f32; JR]; IR];
        for kk in 0..k {
            let mut bv = [0.0f32; JR];
            for jj in 0..JR {
                bv[jj] = b[(j + jj) * k + kk];
            }
            for ii in 0..IR {
                let av = a[(i + ii) * k + kk];
                for jj in 0..JR {
                    acc[ii][jj] += av * bv[jj];
                }
            }
        }
        for ii in 0..IR {
            for jj in 0..JR {
                out[(i + ii) * n + j + jj] += alpha * acc[ii][jj];
            }
        }
    }

    /// Edge micro-tile (`ih × jh` < `IR × JR`), same op sequence.
    #[allow(clippy::too_many_arguments)]
    fn nt_micro_edge(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        alpha: f32,
        i: usize,
        j: usize,
        ih: usize,
        jh: usize,
    ) {
        let mut acc = [[0.0f32; JR]; IR];
        for kk in 0..k {
            for ii in 0..ih {
                let av = a[(i + ii) * k + kk];
                for jj in 0..jh {
                    acc[ii][jj] += av * b[(j + jj) * k + kk];
                }
            }
        }
        for ii in 0..ih {
            for jj in 0..jh {
                out[(i + ii) * n + j + jj] += alpha * acc[ii][jj];
            }
        }
    }

    /// `out (m,n) += alpha * a^T @ b` with `a` stored `(k,m)`, `b` `(k,n)`.
    ///
    /// Same structure as [`tiled::mm_acc`] with `a` read column-strided;
    /// per-element contributions stay in ascending k order with the
    /// `f == 0.0` skip intact.
    pub fn mm_tn_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
    ) {
        mm_tn_acc_rows(out, a, b, k, m, n, alpha, 0, m);
    }

    /// Rows `[r0, r0 + rl)` of [`mm_tn_acc`]; `out` is the row-aligned
    /// chunk. The row loop is the innermost panel loop, so restricting it
    /// leaves every element's panel/k walk unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn mm_tn_acc_rows(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
        r0: usize,
        rl: usize,
    ) {
        let mut kb = 0usize;
        while kb < k {
            let kh = KC.min(k - kb);
            let mut jc = 0usize;
            while jc < n {
                let jw = NC.min(n - jc);
                for i in 0..rl {
                    let or = &mut out[i * n + jc..i * n + jc + jw];
                    tn_panel(or, a, b, kb, kh, m, n, r0 + i, jc, jw, alpha);
                }
                jc += jw;
            }
            kb += kh;
        }
    }

    /// One row × column panel of the transposed-A axpy kernel.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn tn_panel(
        or: &mut [f32],
        a: &[f32],
        b: &[f32],
        kb: usize,
        kh: usize,
        m: usize,
        n: usize,
        i: usize,
        jc: usize,
        jw: usize,
        alpha: f32,
    ) {
        let mut j = 0usize;
        while j + NR <= jw {
            let mut acc = [0.0f32; NR];
            acc.copy_from_slice(&or[j..j + NR]);
            for dk in 0..kh {
                let f = alpha * a[(kb + dk) * m + i];
                if f == 0.0 {
                    continue;
                }
                let br = &b[(kb + dk) * n + jc + j..(kb + dk) * n + jc + j + NR];
                for t in 0..NR {
                    acc[t] += f * br[t];
                }
            }
            or[j..j + NR].copy_from_slice(&acc);
            j += NR;
        }
        if j < jw {
            let w = jw - j;
            let mut acc = [0.0f32; NR];
            acc[..w].copy_from_slice(&or[j..jw]);
            for dk in 0..kh {
                let f = alpha * a[(kb + dk) * m + i];
                if f == 0.0 {
                    continue;
                }
                let br = &b[(kb + dk) * n + jc + j..(kb + dk) * n + jc + jw];
                for (x, &bv) in acc[..w].iter_mut().zip(br) {
                    *x += f * bv;
                }
            }
            or[j..jw].copy_from_slice(&acc[..w]);
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD kernels
// ---------------------------------------------------------------------------

/// Explicit-vector implementations (`PLORA_GEMM=simd`): the tiled panel
/// structure with an 8-lane inner microkernel.
///
/// **Lane-reduction-order contract** (DESIGN.md §14). Vector lanes always
/// span *output columns* `j`, never the reduction dimension `k`: lane `t`
/// of a register is output element `j + t`'s accumulation chain and
/// nothing else, every element keeps exactly one sequential ascending-k
/// chain, and each multiply and add is rounded separately (`a + b * c` as
/// two ops — never `mul_add`/FMA). Horizontal lane reductions are never
/// used. Under that contract each lane performs the identical f32 op
/// sequence as the scalar kernels, so `simd` is bit-identical to
/// [`naive`]/[`tiled`] — property-tested in `rust/tests/properties.rs`
/// and re-pinned on random shapes in this file's tests.
///
/// On stable toolchains [`V8`](self) is a fixed `[f32; 8]` with fully
/// unrolled per-lane ops (the shape LLVM auto-vectorizes); with
/// `--features portable-simd` (nightly) it is `std::simd::f32x8`. The
/// feature flips codegen only — per-lane semantics, and therefore results,
/// are identical.
pub mod simd {
    /// Vector width in f32 lanes.
    pub const LANES: usize = 8;
    /// Columns per register block (two `V8` accumulators).
    const JB: usize = 2 * LANES;
    /// Reduction (k) panel length — matches [`super::tiled`].
    const KC: usize = 64;
    /// Output-column panel width — matches [`super::tiled`].
    const NC: usize = 256;
    /// Rows of `a` per dot-product micro-tile ([`mm_nt_acc`]).
    const IR: usize = 4;

    /// Eight f32 lanes. Ops are per-lane and separately rounded; there is
    /// deliberately no FMA and no horizontal reduction in the API.
    #[cfg(feature = "portable-simd")]
    #[derive(Clone, Copy)]
    struct V8(std::simd::f32x8);

    /// Stable-toolchain `V8`: a fixed array with fully unrolled per-lane
    /// ops — identical per-lane semantics, so identical results.
    #[cfg(not(feature = "portable-simd"))]
    #[derive(Clone, Copy)]
    struct V8([f32; LANES]);

    #[cfg(feature = "portable-simd")]
    impl V8 {
        #[inline(always)]
        fn splat(v: f32) -> V8 {
            V8(std::simd::f32x8::splat(v))
        }
        #[inline(always)]
        fn load(s: &[f32]) -> V8 {
            V8(std::simd::f32x8::from_slice(s))
        }
        #[inline(always)]
        fn store(self, s: &mut [f32]) {
            self.0.copy_to_slice(s);
        }
        /// `self + a * b` — `Simd::mul` then `Simd::add`, each lane
        /// rounded separately at both steps (no contraction).
        #[inline(always)]
        fn mul_acc(self, a: V8, b: V8) -> V8 {
            V8(self.0 + a.0 * b.0)
        }
    }

    #[cfg(not(feature = "portable-simd"))]
    impl V8 {
        #[inline(always)]
        fn splat(v: f32) -> V8 {
            V8([v; LANES])
        }
        #[inline(always)]
        fn load(s: &[f32]) -> V8 {
            let mut l = [0.0f32; LANES];
            l.copy_from_slice(&s[..LANES]);
            V8(l)
        }
        #[inline(always)]
        fn store(self, s: &mut [f32]) {
            s[..LANES].copy_from_slice(&self.0);
        }
        /// `self + a * b` — per lane one mul then one add, separately
        /// rounded (Rust never contracts to FMA by default).
        #[inline(always)]
        fn mul_acc(mut self, a: V8, b: V8) -> V8 {
            for t in 0..LANES {
                self.0[t] += a.0[t] * b.0[t];
            }
            self
        }
    }

    /// `out (m,n) += alpha * a (m,k) @ b (k,n)` — [`super::tiled::mm_acc`]'s
    /// panel walk with the vector axpy inner loop.
    pub fn mm_acc(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize, alpha: f32) {
        let mut kb = 0usize;
        while kb < k {
            let kh = KC.min(k - kb);
            let mut jc = 0usize;
            while jc < n {
                let jw = NC.min(n - jc);
                for i in 0..m {
                    let ar = &a[i * k + kb..i * k + kb + kh];
                    let or = &mut out[i * n + jc..i * n + jc + jw];
                    axpy_panel(or, ar, b, kb, n, jc, jw, alpha);
                }
                jc += jw;
            }
            kb += kh;
        }
    }

    /// One row × column panel: `JB`-wide vector blocks, scalar tail with
    /// the identical per-element op sequence. The `f == 0.0` skip is
    /// scalar (one `f` per k step, shared by every lane), exactly like the
    /// scalar kernels.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn axpy_panel(
        or: &mut [f32],
        ar: &[f32],
        b: &[f32],
        kb: usize,
        n: usize,
        jc: usize,
        jw: usize,
        alpha: f32,
    ) {
        let mut j = 0usize;
        while j + JB <= jw {
            let mut acc0 = V8::load(&or[j..]);
            let mut acc1 = V8::load(&or[j + LANES..]);
            for (dk, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let fv = V8::splat(f);
                let base = (kb + dk) * n + jc + j;
                acc0 = acc0.mul_acc(fv, V8::load(&b[base..]));
                acc1 = acc1.mul_acc(fv, V8::load(&b[base + LANES..]));
            }
            acc0.store(&mut or[j..]);
            acc1.store(&mut or[j + LANES..]);
            j += JB;
        }
        if j < jw {
            for (dk, &av) in ar.iter().enumerate() {
                let f = alpha * av;
                if f == 0.0 {
                    continue;
                }
                let base = (kb + dk) * n + jc;
                for t in j..jw {
                    or[t] += f * b[base + t];
                }
            }
        }
    }

    /// `out (m,n) += alpha * a (m,k) @ b^T` with `b` stored `(n,k)`.
    ///
    /// `IR` row chains × 8 column lanes per micro-tile; the `b` values are
    /// gathered lane-wise (stride `k`) — the strided loads are the price
    /// of keeping lanes on output elements instead of on `k`. Each lane's
    /// chain is zero-initialized, accumulated in ascending k, then folded
    /// with one `out += alpha * s` — the naive kernel's exact sequence.
    pub fn mm_nt_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        alpha: f32,
    ) {
        let mut i = 0usize;
        while i < m {
            let ih = IR.min(m - i);
            let mut j = 0usize;
            while j + LANES <= n {
                nt_micro(out, a, b, k, n, alpha, i, ih, j);
                j += LANES;
            }
            if j < n {
                nt_edge(out, a, b, k, n, alpha, i, ih, j, n - j);
            }
            i += ih;
        }
    }

    /// `ih × LANES` dot micro-tile.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn nt_micro(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        alpha: f32,
        i: usize,
        ih: usize,
        j: usize,
    ) {
        let mut acc = [V8::splat(0.0); IR];
        for kk in 0..k {
            let mut bl = [0.0f32; LANES];
            for (t, x) in bl.iter_mut().enumerate() {
                *x = b[(j + t) * k + kk];
            }
            let bv = V8::load(&bl);
            for (ii, chain) in acc.iter_mut().enumerate().take(ih) {
                let av = V8::splat(a[(i + ii) * k + kk]);
                *chain = chain.mul_acc(av, bv);
            }
        }
        let av = V8::splat(alpha);
        for (ii, chain) in acc.iter().enumerate().take(ih) {
            let o = &mut out[(i + ii) * n + j..(i + ii) * n + j + LANES];
            V8::load(o).mul_acc(av, *chain).store(o);
        }
    }

    /// Scalar edge tile (`jw < LANES` trailing columns), naive op order.
    #[allow(clippy::too_many_arguments)]
    fn nt_edge(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        alpha: f32,
        i: usize,
        ih: usize,
        j: usize,
        jw: usize,
    ) {
        for ii in 0..ih {
            let ar = &a[(i + ii) * k..(i + ii + 1) * k];
            for jj in j..j + jw {
                let br = &b[jj * k..(jj + 1) * k];
                let mut s = 0.0f32;
                for (av, bv) in ar.iter().zip(br) {
                    s += av * bv;
                }
                out[(i + ii) * n + jj] += alpha * s;
            }
        }
    }

    /// `out (m,n) += alpha * a^T @ b` with `a` stored `(k,m)`, `b` `(k,n)`.
    pub fn mm_tn_acc(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
    ) {
        mm_tn_acc_rows(out, a, b, k, m, n, alpha, 0, m);
    }

    /// Rows `[r0, r0 + rl)` of [`mm_tn_acc`]; `out` is the row-aligned
    /// chunk. Panel walk as in [`super::tiled`], vector inner loop.
    #[allow(clippy::too_many_arguments)]
    pub fn mm_tn_acc_rows(
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        k: usize,
        m: usize,
        n: usize,
        alpha: f32,
        r0: usize,
        rl: usize,
    ) {
        let mut kb = 0usize;
        while kb < k {
            let kh = KC.min(k - kb);
            let mut jc = 0usize;
            while jc < n {
                let jw = NC.min(n - jc);
                for i in 0..rl {
                    let or = &mut out[i * n + jc..i * n + jc + jw];
                    tn_panel(or, a, b, kb, kh, m, n, r0 + i, jc, jw, alpha);
                }
                jc += jw;
            }
            kb += kh;
        }
    }

    /// One row × column panel of the transposed-A vector axpy kernel.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn tn_panel(
        or: &mut [f32],
        a: &[f32],
        b: &[f32],
        kb: usize,
        kh: usize,
        m: usize,
        n: usize,
        i: usize,
        jc: usize,
        jw: usize,
        alpha: f32,
    ) {
        let mut j = 0usize;
        while j + JB <= jw {
            let mut acc0 = V8::load(&or[j..]);
            let mut acc1 = V8::load(&or[j + LANES..]);
            for dk in 0..kh {
                let f = alpha * a[(kb + dk) * m + i];
                if f == 0.0 {
                    continue;
                }
                let fv = V8::splat(f);
                let base = (kb + dk) * n + jc + j;
                acc0 = acc0.mul_acc(fv, V8::load(&b[base..]));
                acc1 = acc1.mul_acc(fv, V8::load(&b[base + LANES..]));
            }
            acc0.store(&mut or[j..]);
            acc1.store(&mut or[j + LANES..]);
            j += JB;
        }
        if j < jw {
            for dk in 0..kh {
                let f = alpha * a[(kb + dk) * m + i];
                if f == 0.0 {
                    continue;
                }
                let base = (kb + dk) * n + jc;
                for t in j..jw {
                    or[t] += f * b[base + t];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    type MmFn = fn(&mut [f32], &[f32], &[f32], usize, usize, usize, f32);

    #[test]
    fn mm_variants_match_hand_computation() {
        // a = [[1,2,3],[4,5,6]] (2x3), b = [[7,8],[9,10],[11,12]] (3x2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        for f in [naive::mm_acc as MmFn, tiled::mm_acc as MmFn, simd::mm_acc as MmFn] {
            let mut out = [0.0f32; 4];
            f(&mut out, &a, &b, 2, 3, 2, 1.0);
            assert_eq!(out, [58.0, 64.0, 139.0, 154.0]);
        }

        // a (2x3) @ b^T with b stored (2x3): out[i][j] = row_i . row_j
        let bt = [1.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        for f in [naive::mm_nt_acc as MmFn, tiled::mm_nt_acc as MmFn, simd::mm_nt_acc as MmFn] {
            let mut out = [0.0f32; 4];
            f(&mut out, &a, &bt, 2, 3, 2, 1.0);
            assert_eq!(out, [4.0, 4.0, 10.0, 10.0]);
        }

        // a^T (3x2 from a stored 2x3) @ b2 (2x2)
        let b2 = [1.0, 2.0, 3.0, 4.0];
        for f in [naive::mm_tn_acc as MmFn, tiled::mm_tn_acc as MmFn, simd::mm_tn_acc as MmFn] {
            let mut out = [0.0f32; 6];
            f(&mut out, &a, &b2, 2, 3, 2, 1.0);
            // a^T = [[1,4],[2,5],[3,6]]; a^T@b2 = [[13,18],[17,24],[21,30]]
            assert_eq!(out, [13.0, 18.0, 17.0, 24.0, 21.0, 30.0]);
        }
    }

    fn rand_buf(rng: &mut Rng, len: usize, zero_frac: f64) -> Vec<f32> {
        (0..len)
            .map(|_| if rng.f64() < zero_frac { 0.0 } else { rng.normal() as f32 })
            .collect()
    }

    /// Tiled and SIMD kernels are bit-identical to the naive kernels on
    /// shapes that straddle every tile/lane boundary, including alpha = 0
    /// and zeroed rows; the tn row-range splits and the batched
    /// multi-adapter driver reproduce the same bits.
    #[test]
    fn tiled_matches_naive_bitwise_across_tile_boundaries() {
        let mut rng = Rng::new(0x9e2e);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (4, 64, 16),
            (5, 65, 17),
            (7, 130, 33),
            (16, 16, 300),
            (2, 257, 12),
        ] {
            for &alpha in &[1.0f32, -0.75, 0.0] {
                let a = rand_buf(&mut rng, m * k, 0.25);
                let b = rand_buf(&mut rng, k * n, 0.0);
                let init = rand_buf(&mut rng, m * n, 0.0);

                let mut o1 = init.clone();
                naive::mm_acc(&mut o1, &a, &b, m, k, n, alpha);
                for f in [tiled::mm_acc as MmFn, simd::mm_acc as MmFn] {
                    let mut o2 = init.clone();
                    f(&mut o2, &a, &b, m, k, n, alpha);
                    assert_eq!(o1, o2, "mm_acc {m}x{k}x{n} alpha={alpha}");
                }

                let bt = rand_buf(&mut rng, n * k, 0.0);
                let mut o1 = init.clone();
                naive::mm_nt_acc(&mut o1, &a, &bt, m, k, n, alpha);
                for f in [tiled::mm_nt_acc as MmFn, simd::mm_nt_acc as MmFn] {
                    let mut o2 = init.clone();
                    f(&mut o2, &a, &bt, m, k, n, alpha);
                    assert_eq!(o1, o2, "mm_nt_acc {m}x{k}x{n} alpha={alpha}");
                }

                let at = rand_buf(&mut rng, k * m, 0.25);
                let mut o1 = init.clone();
                naive::mm_tn_acc(&mut o1, &at, &b, k, m, n, alpha);
                for f in [tiled::mm_tn_acc as MmFn, simd::mm_tn_acc as MmFn] {
                    let mut o2 = init.clone();
                    f(&mut o2, &at, &b, k, m, n, alpha);
                    assert_eq!(o1, o2, "mm_tn_acc {m}x{k}x{n} alpha={alpha}");
                }

                // Row-range union == full call, for every implementation.
                let split = 1 + m / 2;
                type RowsFn =
                    fn(&mut [f32], &[f32], &[f32], usize, usize, usize, f32, usize, usize);
                for f in [
                    naive::mm_tn_acc_rows as RowsFn,
                    tiled::mm_tn_acc_rows as RowsFn,
                    simd::mm_tn_acc_rows as RowsFn,
                ] {
                    let mut o2 = init.clone();
                    let (top, bot) = o2.split_at_mut(split.min(m) * n);
                    f(top, &at, &b, k, m, n, alpha, 0, split.min(m));
                    if split < m {
                        f(bot, &at, &b, k, m, n, alpha, split, m - split);
                    }
                    assert_eq!(o1, o2, "mm_tn_acc_rows {m}x{k}x{n} alpha={alpha}");
                }
            }
        }
    }

    /// The batched multi-adapter driver is bit-identical to the per-adapter
    /// `mm_tn_acc` loop it replaces — including per-adapter alphas (with
    /// zeros), zero-padded trailing ranks (whole zero columns of `a_i`, the
    /// `f == 0.0` skip), and the row-parallel split at any worker count.
    #[test]
    fn batched_matches_per_adapter_loop_bitwise() {
        let mut rng = Rng::new(0x51bd);
        for &(nb, k, m, n) in
            &[(1usize, 7usize, 5usize, 9usize), (3, 32, 17, 24), (4, 65, 8, 33), (5, 16, 21, 16)]
        {
            let mut a = rand_buf(&mut rng, nb * k * m, 0.2);
            let b = rand_buf(&mut rng, nb * k * n, 0.0);
            // Zero-padded ranks: adapter i keeps only m - i of its m rows
            // (columns of the stored (k, m) slice), like a rank mask.
            for i in 0..nb {
                for kk in 0..k {
                    for c in m.saturating_sub(i)..m {
                        a[i * k * m + kk * m + c] = 0.0;
                    }
                }
            }
            let alphas: Vec<f32> = (0..nb).map(|i| [1.0f32, -0.6, 0.0, 2.5][i % 4]).collect();
            let init = rand_buf(&mut rng, nb * m * n, 0.0);

            let mut want = init.clone();
            for i in 0..nb {
                naive::mm_tn_acc(
                    &mut want[i * m * n..(i + 1) * m * n],
                    &a[i * k * m..(i + 1) * k * m],
                    &b[i * k * n..(i + 1) * k * n],
                    k,
                    m,
                    n,
                    alphas[i],
                );
            }
            for md in [Mode::Naive, Mode::Tiled, Mode::Simd] {
                set_mode(md);
                let mut got = init.clone();
                batched::mm_tn_acc(&mut got, &a, &b, nb, k, m, n, Some(&alphas));
                assert_eq!(want, got, "batched {md:?} nb={nb} {m}x{k}x{n}");
                for nt in [2usize, 3, 16] {
                    let mut got = init.clone();
                    batched::mm_tn_acc_par(&mut got, &a, &b, nb, k, m, n, Some(&alphas), nt);
                    assert_eq!(want, got, "batched par {md:?} nb={nb} nt={nt}");
                }
            }
            set_mode(Mode::Tiled);

            // alphas: None == all-ones.
            let ones = vec![1.0f32; nb];
            let mut w1 = init.clone();
            batched::mm_tn_acc(&mut w1, &a, &b, nb, k, m, n, Some(&ones));
            let mut w2 = init.clone();
            batched::mm_tn_acc(&mut w2, &a, &b, nb, k, m, n, None);
            assert_eq!(w1, w2, "alphas None != all-ones at nb={nb}");
        }
    }

    /// Row-parallel drivers are bitwise identical to the serial call at
    /// several worker counts (including more workers than rows), and the
    /// chunked spawn path itself (forced past the work-size guard) splits
    /// both output buffers on row boundaries without overlap.
    #[test]
    fn parallel_rows_are_bitwise_identical() {
        let mut rng = Rng::new(77);
        let (m, k, n) = (13usize, 37usize, 21usize);
        let a = rand_buf(&mut rng, m * k, 0.1);
        let b = rand_buf(&mut rng, k * n, 0.0);
        let bt = rand_buf(&mut rng, n * k, 0.0);
        let init = rand_buf(&mut rng, m * n, 0.0);

        let at = rand_buf(&mut rng, k * m, 0.1);
        let mut want = init.clone();
        mm_acc(&mut want, &a, &b, m, k, n, 0.9);
        let mut want_nt = init.clone();
        mm_nt_acc(&mut want_nt, &a, &bt, m, k, n, 0.9);
        let mut want_tn = init.clone();
        mm_tn_acc(&mut want_tn, &at, &b, k, m, n, 0.9);
        for nt in [1usize, 2, 4, 32] {
            let mut got = init.clone();
            mm_acc_par(&mut got, &a, &b, m, k, n, 0.9, nt);
            assert_eq!(want, got, "mm_acc_par nt={nt}");
            let mut got = init.clone();
            mm_nt_acc_par(&mut got, &a, &bt, m, k, n, 0.9, nt);
            assert_eq!(want_nt, got, "mm_nt_acc_par nt={nt}");
            let mut got = init.clone();
            mm_tn_acc_par(&mut got, &at, &b, k, m, n, 0.9, nt);
            assert_eq!(want_tn, got, "mm_tn_acc_par nt={nt}");
        }

        // Force real spawning: work_per_row = PAR_MIN_WORK clears the
        // guard at any row count, so this genuinely runs on 4 workers.
        let mut got = init.clone();
        let mut mid = vec![0.0f32; m * 2];
        par_row_chunks(m, 4, PAR_MIN_WORK, &mut got, n, &mut mid, 2, |oc, mc, lo, hi| {
            mm_acc(oc, &a[lo * k..hi * k], b, hi - lo, k, n, 0.9);
            for (t, x) in mc.iter_mut().enumerate() {
                *x = (lo * 2 + t) as f32; // row-aligned chunk offsets line up
            }
        });
        assert_eq!(want, got, "forced-spawn par_row_chunks");
        let expect: Vec<f32> = (0..m * 2).map(|t| t as f32).collect();
        assert_eq!(mid, expect, "second buffer split on the same row boundaries");
    }

    #[test]
    fn knobs_clamp_and_default() {
        // mode() resolves to a concrete implementation either way.
        let m = mode();
        assert!(m == Mode::Tiled || m == Mode::Naive || m == Mode::Simd);
        // Other tests toggle the global knobs concurrently (harmless:
        // every setting is bit-identical), so only assert the invariant
        // that survives any interleaving — the clamp floor.
        set_threads(0);
        assert!(threads() >= 1, "set_threads clamps to >= 1");
        set_threads(1);
        // The fusion knob round-trips through its setter.
        set_fused(false);
        assert!(!fused());
        set_fused(true);
        assert!(fused());
    }
}
