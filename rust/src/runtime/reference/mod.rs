//! The **reference execution backend**: a pure-Rust interpreter of the
//! manifest's packed-LoRA computations.
//!
//! It implements the exact artifact contract the AOT/PJRT path compiles —
//! fused TinyLM train/eval steps ([`tinylm`]) and the standalone packed
//! kernels (`y = α·(x·A)·B` forward + the four backward cases of
//! `python/compile/kernels/ref.py`) — with no native dependencies, so the
//! whole system runs end-to-end on an offline machine.
//!
//! When no `artifacts/` directory exists it also *synthesizes* the
//! manifest ([`builtin_manifest`]: the `aot.py` bucket grid, token layout
//! and model table) and deterministic base weights
//! ([`synth_base_weights`]: the `model.py::init_base` distributions under
//! `util::rng`). With `make artifacts` the same backend reads the
//! pretrained weight containers instead — only execution is interpreted.

pub mod gemm;
pub mod tinylm;
pub mod workspace;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::backend::{
    take_buf, AdamOut, BackendExecutable, ExecutionBackend, GradStep, Scratch, ShardStepExec,
    StageStepExec,
};
use crate::runtime::manifest::{
    ArtifactInfo, ArtifactKind, Manifest, ModelInfo, TensorSpec, TokenLayout,
};
use crate::runtime::state::lora_shape;
use crate::runtime::tensor::{DType, HostTensor};
use crate::runtime::LORA_ORDER;
use crate::util::json::Json;
use crate::util::rng::Rng;

use self::tinylm::Spec;
use self::workspace::Workspace;

const NB: usize = 12; // BASE_ORDER tensors
const NL: usize = 14; // LORA_ORDER tensors

/// The reference backend (stateless; all state lives in the executables).
pub struct RefBackend;

impl ExecutionBackend for RefBackend {
    fn platform(&self) -> String {
        "ref-cpu".to_string()
    }

    fn load(&self, manifest: &Manifest, info: &ArtifactInfo) -> Result<Box<dyn BackendExecutable>> {
        match info.kind {
            ArtifactKind::Train | ArtifactKind::Eval => {
                let model = info
                    .meta_str("model")
                    .ok_or_else(|| anyhow!("{}: missing 'model' meta", info.name))?;
                let mi = manifest.model(model)?;
                let spec = Spec {
                    vocab: mi.vocab,
                    d_model: mi.d_model,
                    n_layers: mi.n_layers,
                    n_heads: mi.n_heads,
                    d_ff: mi.d_ff,
                    seq: mi.seq,
                };
                spec.check()?;
                let get = |k: &str| {
                    info.meta_usize(k).ok_or_else(|| anyhow!("{}: missing '{k}' meta", info.name))
                };
                Ok(Box::new(TrainEvalExec {
                    spec,
                    n: get("n")?,
                    r: get("r")?,
                    bs: get("bs")?,
                    train: info.kind == ArtifactKind::Train,
                }))
            }
            ArtifactKind::KernelFwd | ArtifactKind::KernelBwd => {
                let get = |k: &str| {
                    info.meta_usize(k).ok_or_else(|| anyhow!("{}: missing '{k}' meta", info.name))
                };
                Ok(Box::new(KernelExec {
                    n: get("n")?,
                    d: get("d")?,
                    k: get("k")?,
                    r: get("r")?,
                    m: get("m")?,
                    bwd: info.kind == ArtifactKind::KernelBwd,
                }))
            }
        }
    }

    /// The reference interpreter executes any `(n, r, bs)` shape directly
    /// (no AOT compilation), so the two halves of the train step are
    /// available at exact shard shapes — `ShardedState` never has to pad a
    /// shard up to a grid bucket, which is what keeps a shard's
    /// per-adapter row set identical to the fused step's.
    fn shard(
        &self,
        manifest: &Manifest,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
    ) -> Result<Option<Box<dyn ShardStepExec>>> {
        let mi = manifest.model(model)?;
        let spec = Spec {
            vocab: mi.vocab,
            d_model: mi.d_model,
            n_layers: mi.n_layers,
            n_heads: mi.n_heads,
            d_ff: mi.d_ff,
            seq: mi.seq,
        };
        spec.check()?;
        Ok(Some(Box::new(ShardExec { spec, n, r, bs })))
    }

    /// The interpreter runs any contiguous layer range directly, so
    /// stage-pipelined execution is available at exact `(n, r, bs)`
    /// shapes — one [`RefStage`] per range, each owning its own workspace
    /// arena and its layer slice of the gradient accumulators.
    fn stages(
        &self,
        manifest: &Manifest,
        model: &str,
        n: usize,
        r: usize,
        bs: usize,
        ranges: &[(usize, usize)],
    ) -> Result<Option<Vec<Box<dyn StageStepExec>>>> {
        let mi = manifest.model(model)?;
        let spec = Spec {
            vocab: mi.vocab,
            d_model: mi.d_model,
            n_layers: mi.n_layers,
            n_heads: mi.n_heads,
            d_ff: mi.d_ff,
            seq: mi.seq,
        };
        spec.check()?;
        if ranges.is_empty() {
            return Err(anyhow!("stages: empty range list"));
        }
        let mut expect = 0usize;
        let mut out: Vec<Box<dyn StageStepExec>> = Vec::with_capacity(ranges.len());
        for (k, &(lo, hi)) in ranges.iter().enumerate() {
            if lo != expect || hi <= lo || hi > spec.n_layers {
                return Err(anyhow!("stages: bad range [{lo}, {hi}) at stage {k}"));
            }
            expect = hi;
            let sub = Spec { n_layers: hi - lo, ..spec };
            out.push(Box::new(RefStage {
                spec,
                sub,
                lo,
                hi,
                n,
                r,
                bs,
                last: hi == spec.n_layers,
                ws: Workspace::new(),
            }));
        }
        if expect != spec.n_layers {
            return Err(anyhow!(
                "stages: ranges cover [0, {expect}) of {} layers",
                spec.n_layers
            ));
        }
        Ok(Some(out))
    }
}

// ---------------------------------------------------------------------------
// Sharded train-step halves (data-parallel execution, DESIGN.md §11)
// ---------------------------------------------------------------------------

/// The two halves of one train step at an exact `(n, r, bs)` shape: the
/// forward/backward gradient computation one shard worker runs over its
/// slot slice, and the AdamW application over externally reduced
/// gradients. Both call the exact `tinylm` routines the fused
/// [`TrainEvalExec`] calls, in the same order — the fused step *is*
/// `run_grads` + `run_adamw`, so a slot-partitioned sharded step is
/// bitwise identical to it (each adapter's gradient accumulates over only
/// its own rows; see `proj_bwd_wgrads`).
struct ShardExec {
    spec: Spec,
    n: usize,
    r: usize,
    bs: usize,
}

impl ShardStepExec for ShardExec {
    fn run_grads(
        &self,
        base: &[HostTensor],
        lora_t: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<GradStep> {
        let (n, r, bs) = (self.n, self.r, self.bs);
        if lora_t.len() != NL || base.len() != NB || scale.len() != n {
            bail_shapes("run_grads", lora_t.len(), base.len(), scale.len(), n)?;
        }
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let lora_refs: Vec<&HostTensor> = lora_t.iter().collect();
        let lora = lora_slices(&lora_refs)?;
        let tokens_i = tokens.as_i32()?;
        let targets_i = targets.as_i32()?;
        let mask_f = mask.as_f32()?;
        let (ws, pool) = scratch.parts(Workspace::new);
        let per = grads_core(
            &self.spec, &base_refs, &lora, scale, tokens_i, targets_i, mask_f, n, bs, r, ws,
        )?;
        // Copy the workspace gradients out through the recycled-buffer
        // pool (the caller returns them via `Scratch::recycle` after the
        // reduction, so steady-state steps allocate nothing).
        let mut grads = Vec::with_capacity(NL);
        for k in 0..NL {
            let mut buf = take_buf(pool, ws.grads[k].len());
            buf.copy_from_slice(&ws.grads[k]);
            grads.push(HostTensor::f32(lora_t[k].shape.clone(), buf)?);
        }
        Ok(GradStep { grads, per_loss: per })
    }

    fn run_eval(
        &self,
        base: &[HostTensor],
        lora_t: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        mask: &HostTensor,
        scale: &[f32],
        scratch: &mut Scratch,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let (n, r, bs) = (self.n, self.r, self.bs);
        if lora_t.len() != NL || base.len() != NB || scale.len() != n {
            bail_shapes("run_eval", lora_t.len(), base.len(), scale.len(), n)?;
        }
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let lora_refs: Vec<&HostTensor> = lora_t.iter().collect();
        let lora = lora_slices(&lora_refs)?;
        let tokens_i = tokens.as_i32()?;
        let targets_i = targets.as_i32()?;
        let mask_f = mask.as_f32()?;
        // The exact logits-only forward the fused eval executable runs
        // ([`TrainEvalExec::run`], eval branch), at the shard shape. Every
        // slot's logits/loss/acc depend only on its own rows, so the
        // shard-sliced eval is bitwise identical to the fused one.
        let (ws, _) = scratch.parts(Workspace::new);
        tinylm::forward_logits(&self.spec, &base_refs, &lora, scale, tokens_i, n, bs, r, ws)?;
        let (loss, acc) = tinylm::loss_and_acc(&self.spec, &ws.logits, targets_i, mask_f, n, bs);
        Ok(Some((loss, acc)))
    }

    fn run_adamw(
        &self,
        lora_t: &[HostTensor],
        m_t: &[HostTensor],
        v_t: &[HostTensor],
        t: &[f32],
        grads: &[HostTensor],
        lr: &[f32],
        rmask: &HostTensor,
        scratch: &mut Scratch,
    ) -> Result<AdamOut> {
        let (n, r) = (self.n, self.r);
        if lora_t.len() != NL || grads.len() != NL || t.len() != n || lr.len() != n {
            bail_shapes("run_adamw", lora_t.len(), grads.len(), t.len(), n)?;
        }
        let lora_refs: Vec<&HostTensor> = lora_t.iter().collect();
        let m_refs: Vec<&HostTensor> = m_t.iter().collect();
        let v_refs: Vec<&HostTensor> = v_t.iter().collect();
        let grad_slices: Vec<&[f32]> =
            grads.iter().map(|g| g.as_f32()).collect::<Result<_>>()?;
        adamw_core(
            &lora_refs,
            &m_refs,
            &v_refs,
            t,
            &grad_slices,
            lr,
            rmask.as_f32()?,
            n,
            r,
            scratch.pool(),
        )
    }
}

/// Shared arity-error path of the [`ShardExec`] entry points.
fn bail_shapes(what: &str, a: usize, b: usize, c: usize, n: usize) -> Result<()> {
    Err(anyhow!("{what}: bad arity (got {a}/{b}/{c} for n={n})"))
}

// ---------------------------------------------------------------------------
// Pipeline-stage executor (stage-parallel execution, DESIGN.md §15)
// ---------------------------------------------------------------------------

/// One pipeline stage of the train step: layers `[lo, hi)` of the stack
/// at an exact `(n, r, bs)` shape, driven one slot window at a time.
/// Calls the same windowed `tinylm` routines the monolithic
/// forward/backward call at `slo=0, nw=n` — each activation/gradient
/// element is produced by exactly one `(stage, microbatch)` call with an
/// unchanged reduction order, so a stage-pipelined step is bitwise
/// identical to the fused one. The workspace arena is sized by the
/// stage's `sub` spec (`n_layers = hi - lo`), so its `layers` saves and
/// `grads` accumulators hold only this stage's slice.
struct RefStage {
    spec: Spec,
    sub: Spec,
    lo: usize,
    hi: usize,
    n: usize,
    r: usize,
    bs: usize,
    last: bool,
    ws: Workspace,
}

impl StageStepExec for RefStage {
    fn layer_range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    fn begin_step(&mut self) -> Result<()> {
        self.ws.ensure(&self.sub, self.n, self.bs, self.r, true);
        for g in self.ws.grads.iter_mut() {
            g.fill(0.0);
        }
        Ok(())
    }

    fn run_fwd(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        lora_t: &[HostTensor],
        scale: &[f32],
        tokens: &HostTensor,
        x_in: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let (n, r, bs) = (self.n, self.r, self.bs);
        if lora_t.len() != NL || base.len() != NB || scale.len() != n || slo + nw > n {
            bail_shapes("stage run_fwd", lora_t.len(), base.len(), scale.len(), n)?;
        }
        let spec = self.spec;
        let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
        let m = bs * s;
        let rd = m * d;
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let lora_refs: Vec<&HostTensor> = lora_t.iter().collect();
        let lora = lora_slices(&lora_refs)?;
        let (lo, hi, last) = (self.lo, self.hi, self.last);
        let ws = &mut self.ws;
        ws.ensure(&self.sub, n, bs, r, true);
        let xw = &mut ws.x[slo * rd..(slo + nw) * rd];
        match x_in {
            Some(xv) => {
                if xv.len() != xw.len() {
                    return Err(anyhow!(
                        "stage run_fwd: boundary activation len {} != {}",
                        xv.len(),
                        xw.len()
                    ));
                }
                xw.copy_from_slice(xv);
            }
            None => {
                let embed = base_refs[tinylm::EMBED].as_f32()?;
                let pos = base_refs[tinylm::POS].as_f32()?;
                let toks = tokens.as_i32()?;
                tinylm::embed_fwd(
                    embed,
                    pos,
                    &toks[slo * m..(slo + nw) * m],
                    xw,
                    nw,
                    bs,
                    s,
                    d,
                    v,
                )?;
            }
        }
        let tw = &mut ws.tmp[slo * rd..(slo + nw) * rd];
        for l in lo..hi {
            let lw = tinylm::layer_weights(&base_refs, l, d, f)?;
            tinylm::layer_fwd(
                &spec,
                &lw,
                &lora,
                scale,
                l,
                n,
                slo,
                nw,
                bs,
                r,
                xw,
                tw,
                &mut ws.att,
                &mut ws.layers[l - lo],
            );
        }
        if last {
            let embed = base_refs[tinylm::EMBED].as_f32()?;
            let lnf = base_refs[tinylm::LNF].as_f32()?;
            let hw = &mut ws.h[slo * rd..(slo + nw) * rd];
            let xhw = &mut ws.xhatf[slo * rd..(slo + nw) * rd];
            let invw = &mut ws.invf[slo * m..(slo + nw) * m];
            let logw = &mut ws.logits[slo * m * v..(slo + nw) * m * v];
            tinylm::head_fwd(embed, lnf, xw, hw, xhw, invw, logw, nw * m, d, v);
            Ok(Vec::new())
        } else {
            Ok(xw.to_vec())
        }
    }

    fn run_loss(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        targets: &HostTensor,
        mask: &HostTensor,
    ) -> Result<Vec<f32>> {
        if !self.last {
            return Err(anyhow!("run_loss on non-final stage [{}, {})", self.lo, self.hi));
        }
        let bs = self.bs;
        let spec = self.spec;
        let (d, s, v) = (spec.d_model, spec.seq, spec.vocab);
        let m = bs * s;
        let rd = m * d;
        let targets_i = targets.as_i32()?;
        let mask_f = mask.as_f32()?;
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let embed = base_refs[tinylm::EMBED].as_f32()?;
        let lnf = base_refs[tinylm::LNF].as_f32()?;
        let ws = &mut self.ws;
        let logw = &ws.logits[slo * m * v..(slo + nw) * m * v];
        let dlogw = &mut ws.dlogits[slo * m * v..(slo + nw) * m * v];
        let per = tinylm::loss_dlogits(
            &spec,
            logw,
            &targets_i[slo * m..(slo + nw) * m],
            &mask_f[slo * m..(slo + nw) * m],
            nw,
            bs,
            dlogw,
        );
        let xhw = &ws.xhatf[slo * rd..(slo + nw) * rd];
        let invw = &ws.invf[slo * m..(slo + nw) * m];
        let dxaw = &mut ws.dxa[slo * rd..(slo + nw) * rd];
        let dxbw = &mut ws.dxb[slo * rd..(slo + nw) * rd];
        tinylm::head_bwd(embed, lnf, dlogw, xhw, invw, dxaw, dxbw, &mut ws.dln, nw * m, d, v);
        Ok(per)
    }

    fn run_bwd(
        &mut self,
        slo: usize,
        nw: usize,
        base: &[HostTensor],
        lora_t: &[HostTensor],
        scale: &[f32],
        dx_in: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let (n, r, bs) = (self.n, self.r, self.bs);
        if lora_t.len() != NL || base.len() != NB || scale.len() != n || slo + nw > n {
            bail_shapes("stage run_bwd", lora_t.len(), base.len(), scale.len(), n)?;
        }
        let spec = self.spec;
        let (d, f, s) = (spec.d_model, spec.d_ff, spec.seq);
        let m = bs * s;
        let rd = m * d;
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let lora_refs: Vec<&HostTensor> = lora_t.iter().collect();
        let lora = lora_slices(&lora_refs)?;
        let (lo, hi) = (self.lo, self.hi);
        let ws = &mut self.ws;
        if let Some(dxv) = dx_in {
            let dxw = &mut ws.dxa[slo * rd..(slo + nw) * rd];
            if dxv.len() != dxw.len() {
                return Err(anyhow!(
                    "stage run_bwd: boundary gradient len {} != {}",
                    dxv.len(),
                    dxw.len()
                ));
            }
            dxw.copy_from_slice(dxv);
        }
        let (grads_a, grads_b) = ws.grads.split_at_mut(tinylm::B_DOWN);
        let mut bufs = tinylm::BwdBufs {
            dxa: &mut ws.dxa,
            dxb: &mut ws.dxb,
            dact: &mut ws.dact,
            dup: &mut ws.dup,
            dgate: &mut ws.dgate,
            dh2: &mut ws.dh2,
            dmid: &mut ws.dmid,
            dq: &mut ws.dq,
            dk: &mut ws.dk,
            dv: &mut ws.dv,
            dh: &mut ws.dh,
            dp: &mut ws.dp,
            dln: &mut ws.dln,
            tmp: &mut ws.tmp,
        };
        for l in (lo..hi).rev() {
            let lw = tinylm::layer_weights(&base_refs, l, d, f)?;
            tinylm::layer_bwd(
                &spec,
                &lw,
                &lora,
                scale,
                l,
                l - lo,
                n,
                slo,
                nw,
                bs,
                r,
                &ws.layers[l - lo],
                &mut bufs,
                grads_a,
                grads_b,
            );
        }
        Ok(if lo == 0 {
            // Stage 0: the embedding inputs are frozen — no upstream
            // boundary gradient to hand off.
            Vec::new()
        } else {
            ws.dxa[slo * rd..(slo + nw) * rd].to_vec()
        })
    }

    fn stage_grads(&self) -> &[Vec<f32>] {
        &self.ws.grads
    }
}

/// The forward/backward half shared by the fused [`TrainEvalExec`] and
/// [`ShardExec`]: per-adapter losses, with the `LORA_ORDER` gradients
/// left in the workspace arena. One copy of the glue, so the fused and
/// split paths cannot drift — the bitwise device-count-invariance
/// contract (DESIGN.md §11) holds by construction.
#[allow(clippy::too_many_arguments)]
fn grads_core(
    spec: &Spec,
    base: &[&HostTensor],
    lora: &[&[f32]; NL],
    scale: &[f32],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
    r: usize,
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    tinylm::forward(spec, base, lora, scale, tokens, n, bs, r, ws)?;
    tinylm::backward(spec, base, lora, scale, targets, mask, n, bs, r, ws)
}

/// The optimizer half shared by the fused [`TrainEvalExec`] and
/// [`ShardExec`]: one AdamW update across the `LORA_ORDER` set, output
/// buffers drawn from the recycled pool. `t_in` is the per-adapter step
/// counter vector *before* the update.
#[allow(clippy::too_many_arguments)]
fn adamw_core(
    lora_t: &[&HostTensor],
    m_t: &[&HostTensor],
    v_t: &[&HostTensor],
    t_in: &[f32],
    grads: &[&[f32]],
    lr: &[f32],
    rmask: &[f32],
    n: usize,
    r: usize,
    pool: &mut Vec<Vec<f32>>,
) -> Result<AdamOut> {
    let t_new: Vec<f32> = t_in.iter().map(|&x| x + 1.0).collect();
    let mut out_lora = Vec::with_capacity(NL);
    let mut out_m = Vec::with_capacity(NL);
    let mut out_v = Vec::with_capacity(NL);
    for k in 0..NL {
        let shape = lora_t[k].shape.clone();
        let (d2, d3) = (shape[2], shape[3]);
        let len = lora_t[k].len();
        let mut nl = take_buf(pool, len);
        let mut nm = take_buf(pool, len);
        let mut nv = take_buf(pool, len);
        tinylm::adamw_update(
            lora_t[k].as_f32()?,
            m_t[k].as_f32()?,
            v_t[k].as_f32()?,
            grads[k],
            lr,
            rmask,
            n,
            d2,
            d3,
            r,
            LORA_ORDER[k].starts_with("a_"),
            &t_new,
            &mut nl,
            &mut nm,
            &mut nv,
        );
        out_lora.push(HostTensor::f32(shape.clone(), nl)?);
        out_m.push(HostTensor::f32(shape.clone(), nm)?);
        out_v.push(HostTensor::f32(shape, nv)?);
    }
    Ok(AdamOut { lora: out_lora, m: out_m, v: out_v, t: t_new })
}

// ---------------------------------------------------------------------------
// Train / eval executable
// ---------------------------------------------------------------------------

/// Interprets one `(model, n, r, bs)` train or eval bucket. Input layout is
/// `aot.py::train_signature` / `eval_signature` — validated upstream by
/// `Executable::check_inputs` against the manifest.
struct TrainEvalExec {
    spec: Spec,
    n: usize,
    r: usize,
    bs: usize,
    train: bool,
}

fn lora_slices<'a>(tensors: &'a [&HostTensor]) -> Result<[&'a [f32]; NL]> {
    let v: Vec<&[f32]> = tensors.iter().map(|t| t.as_f32()).collect::<Result<_>>()?;
    v.try_into().map_err(|_| anyhow!("expected {NL} lora tensors"))
}

impl BackendExecutable for TrainEvalExec {
    fn run(&self, inputs: &[&HostTensor], scratch: &mut Scratch) -> Result<Vec<HostTensor>> {
        let (n, r, bs) = (self.n, self.r, self.bs);
        let base = &inputs[..NB];
        let lora_t = &inputs[NB..NB + NL];
        let lora = lora_slices(lora_t)?;

        if !self.train {
            // base, lora, tokens, targets, loss_mask, scale. Eval never
            // backprops, so it takes the logits-only forward: no LayerSave
            // buffers, activations reused across layers from the arena.
            let tokens = inputs[NB + NL].as_i32()?;
            let targets = inputs[NB + NL + 1].as_i32()?;
            let mask = inputs[NB + NL + 2].as_f32()?;
            let scale = inputs[NB + NL + 3].as_f32()?;
            let (ws, _) = scratch.parts(Workspace::new);
            tinylm::forward_logits(&self.spec, base, &lora, scale, tokens, n, bs, r, ws)?;
            let (loss, acc) =
                tinylm::loss_and_acc(&self.spec, &ws.logits, targets, mask, n, bs);
            return Ok(vec![
                HostTensor::f32(vec![n], loss)?,
                HostTensor::f32(vec![n], acc)?,
            ]);
        }

        // base, lora, m, v, t, tokens, targets, loss_mask, scale, lr, rmask
        let m_t = &inputs[NB + NL..NB + 2 * NL];
        let v_t = &inputs[NB + 2 * NL..NB + 3 * NL];
        let off = NB + 3 * NL;
        // Per-adapter step counters (n,): each slot's AdamW bias
        // correction runs on its own clock (mid-job admission, §10).
        let t_in = inputs[off].as_f32()?;
        let tokens = inputs[off + 1].as_i32()?;
        let targets = inputs[off + 2].as_i32()?;
        let mask = inputs[off + 3].as_f32()?;
        let scale = inputs[off + 4].as_f32()?;
        let lr = inputs[off + 5].as_f32()?;
        let rmask = inputs[off + 6].as_f32()?;

        // Activations + gradients live in the step-persistent arena; the
        // AdamW outputs cycle through the scratch pool (`TrainState::step`
        // recycles the previous state's buffers), so the steady state of a
        // job phase performs no allocation at all. The fused step *is*
        // [`grads_core`] followed by [`adamw_core`] — the exact halves
        // the sharded path runs — so device-count invariance holds by
        // construction, not by parallel maintenance.
        let (ws, pool) = scratch.parts(Workspace::new);
        let per = grads_core(&self.spec, base, &lora, scale, tokens, targets, mask, n, bs, r, ws)?;

        let grad_slices: Vec<&[f32]> = ws.grads.iter().map(|g| g.as_slice()).collect();
        let out = adamw_core(lora_t, m_t, v_t, t_in, &grad_slices, lr, rmask, n, r, pool)?;
        let mut outs = out.lora;
        outs.extend(out.m);
        outs.extend(out.v);
        outs.push(HostTensor::f32(vec![n], out.t)?);
        outs.push(HostTensor::f32(vec![n], per)?);
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Standalone packed-kernel executable (Table 7/8 microbenchmarks)
// ---------------------------------------------------------------------------

/// Packed-LoRA kernel: forward `y_i = α_i (x_i A_i) B_i`, backward the four
/// grad cases of `ref.py::ref_grads` fused into `(dx, da, db)`.
struct KernelExec {
    n: usize,
    d: usize,
    k: usize,
    r: usize,
    m: usize,
    bwd: bool,
}

impl BackendExecutable for KernelExec {
    fn run(&self, inputs: &[&HostTensor], _scratch: &mut Scratch) -> Result<Vec<HostTensor>> {
        let (n, d, k, r, m) = (self.n, self.d, self.k, self.r, self.m);
        let x = inputs[0].as_f32()?;
        let a = inputs[1].as_f32()?;
        let b = inputs[2].as_f32()?;
        let alpha = inputs[3].as_f32()?;

        // mid_i = x_i @ a_i, shared by forward and backward.
        let mut mid = vec![0.0f32; n * m * r];
        for i in 0..n {
            gemm::mm_acc(
                &mut mid[i * m * r..(i + 1) * m * r],
                &x[i * m * d..(i + 1) * m * d],
                &a[i * d * r..(i + 1) * d * r],
                m,
                d,
                r,
                1.0,
            );
        }

        if !self.bwd {
            let mut y = vec![0.0f32; n * m * k];
            for i in 0..n {
                gemm::mm_acc(
                    &mut y[i * m * k..(i + 1) * m * k],
                    &mid[i * m * r..(i + 1) * m * r],
                    &b[i * r * k..(i + 1) * r * k],
                    m,
                    r,
                    k,
                    alpha[i],
                );
            }
            return Ok(vec![HostTensor::f32(vec![n, m, k], y)?]);
        }

        let g = inputs[4].as_f32()?;
        let mut dx = vec![0.0f32; n * m * d];
        let mut da = vec![0.0f32; n * d * r];
        let mut db = vec![0.0f32; n * r * k];
        let mut dh = vec![0.0f32; m * r];
        for i in 0..n {
            let gi = &g[i * m * k..(i + 1) * m * k];
            let xi = &x[i * m * d..(i + 1) * m * d];
            let ai = &a[i * d * r..(i + 1) * d * r];
            let bi = &b[i * r * k..(i + 1) * r * k];
            let midi = &mid[i * m * r..(i + 1) * m * r];
            // case 1: db = α h^T g
            gemm::mm_tn_acc(&mut db[i * r * k..(i + 1) * r * k], midi, gi, m, r, k, alpha[i]);
            // case 2: dh = α g b^T
            dh.fill(0.0);
            gemm::mm_nt_acc(&mut dh, gi, bi, m, k, r, alpha[i]);
            // case 3: da = x^T dh
            gemm::mm_tn_acc(&mut da[i * d * r..(i + 1) * d * r], xi, &dh, m, d, r, 1.0);
            // case 4: dx = dh a^T
            gemm::mm_nt_acc(&mut dx[i * m * d..(i + 1) * m * d], &dh, ai, m, r, d, 1.0);
        }
        Ok(vec![
            HostTensor::f32(vec![n, m, d], dx)?,
            HostTensor::f32(vec![n, d, r], da)?,
            HostTensor::f32(vec![n, r, k], db)?,
        ])
    }
}

// ---------------------------------------------------------------------------
// Built-in manifest (mirror of aot.py's grids/tables)
// ---------------------------------------------------------------------------

struct BuiltinModel {
    name: &'static str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    seq: usize,
}

/// `model.py::MODELS`.
const BUILTIN_MODELS: [BuiltinModel; 4] = [
    BuiltinModel {
        name: "nano",
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 256,
        seq: 32,
    },
    BuiltinModel {
        name: "tiny",
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 512,
        seq: 64,
    },
    BuiltinModel {
        name: "small",
        vocab: 1024,
        d_model: 256,
        n_layers: 6,
        n_heads: 8,
        d_ff: 1024,
        seq: 64,
    },
    BuiltinModel {
        name: "base",
        vocab: 4096,
        d_model: 512,
        n_layers: 8,
        n_heads: 8,
        d_ff: 2048,
        seq: 128,
    },
];

/// `aot.py::TRAIN_GRID` — the `(n, r_pad, bs)` bucket grid per model.
fn train_grid(model: &str) -> Vec<(usize, usize, usize)> {
    match model {
        "nano" => vec![(1, 8, 1), (2, 8, 1), (4, 8, 1), (2, 8, 2)],
        "tiny" => {
            let mut g = vec![];
            for n in [1usize, 2, 4, 8] {
                for r in [8usize, 32] {
                    for b in [1usize, 4] {
                        g.push((n, r, b));
                    }
                }
            }
            g
        }
        "small" => vec![(1, 32, 1), (4, 32, 1), (8, 32, 1)],
        "base" => vec![(1, 32, 1), (2, 32, 1)],
        _ => vec![],
    }
}

/// `aot.py` kernel microbenchmark grid: (geom, d, k), pack sizes, rank, m.
const KERNEL_GEOMS: [(&str, usize, usize); 2] = [("attn", 256, 256), ("mlp", 256, 1024)];
const KERNEL_NS: [usize; 4] = [1, 2, 8, 32];
const KERNEL_R: usize = 16;
const KERNEL_M: usize = 16;

fn ts(name: &str, dtype: DType, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.to_string(), dtype, shape }
}

fn lora_specs(mi: &ModelInfo, n: usize, r: usize, prefix: &str) -> Vec<TensorSpec> {
    LORA_ORDER
        .iter()
        .copied()
        .map(|name| ts(&format!("{prefix}{name}"), DType::F32, lora_shape(mi, name, n, r)))
        .collect()
}

fn base_specs(mi: &ModelInfo) -> Vec<TensorSpec> {
    let (v, d, l, f, s) = (mi.vocab, mi.d_model, mi.n_layers, mi.d_ff, mi.seq);
    vec![
        ts("embed", DType::F32, vec![v, d]),
        ts("pos", DType::F32, vec![s, d]),
        ts("ln1", DType::F32, vec![l, d]),
        ts("ln2", DType::F32, vec![l, d]),
        ts("wq", DType::F32, vec![l, d, d]),
        ts("wk", DType::F32, vec![l, d, d]),
        ts("wv", DType::F32, vec![l, d, d]),
        ts("wo", DType::F32, vec![l, d, d]),
        ts("wup", DType::F32, vec![l, d, f]),
        ts("wgate", DType::F32, vec![l, d, f]),
        ts("wdown", DType::F32, vec![l, f, d]),
        ts("lnf", DType::F32, vec![d]),
    ]
}

fn train_meta(model: &str, n: usize, r: usize, bs: usize, seq: usize) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("model".to_string(), Json::str(model));
    m.insert("n".to_string(), Json::num(n as f64));
    m.insert("r".to_string(), Json::num(r as f64));
    m.insert("bs".to_string(), Json::num(bs as f64));
    m.insert("seq".to_string(), Json::num(seq as f64));
    m
}

fn train_artifact(mi: &ModelInfo, n: usize, r: usize, bs: usize) -> ArtifactInfo {
    let mut inputs = base_specs(mi);
    inputs.extend(lora_specs(mi, n, r, ""));
    inputs.extend(lora_specs(mi, n, r, "m_"));
    inputs.extend(lora_specs(mi, n, r, "v_"));
    inputs.push(ts("t", DType::F32, vec![n]));
    inputs.push(ts("tokens", DType::I32, vec![n, bs, mi.seq]));
    inputs.push(ts("targets", DType::I32, vec![n, bs, mi.seq]));
    inputs.push(ts("loss_mask", DType::F32, vec![n, bs, mi.seq]));
    inputs.push(ts("scale", DType::F32, vec![n]));
    inputs.push(ts("lr", DType::F32, vec![n]));
    inputs.push(ts("rmask", DType::F32, vec![n, r]));
    let mut outputs = lora_specs(mi, n, r, "");
    outputs.extend(lora_specs(mi, n, r, "m_"));
    outputs.extend(lora_specs(mi, n, r, "v_"));
    outputs.push(ts("t", DType::F32, vec![n]));
    outputs.push(ts("per_loss", DType::F32, vec![n]));
    let name = format!("train_{}_n{n}_r{r}_b{bs}", mi.name);
    ArtifactInfo {
        path: format!("{name}.hlo.txt"),
        name,
        kind: ArtifactKind::Train,
        inputs,
        outputs,
        meta: train_meta(&mi.name, n, r, bs, mi.seq),
    }
}

fn eval_artifact(mi: &ModelInfo, n: usize, r: usize, bs: usize) -> ArtifactInfo {
    let mut inputs = base_specs(mi);
    inputs.extend(lora_specs(mi, n, r, ""));
    inputs.push(ts("tokens", DType::I32, vec![n, bs, mi.seq]));
    inputs.push(ts("targets", DType::I32, vec![n, bs, mi.seq]));
    inputs.push(ts("loss_mask", DType::F32, vec![n, bs, mi.seq]));
    inputs.push(ts("scale", DType::F32, vec![n]));
    let outputs = vec![ts("loss", DType::F32, vec![n]), ts("acc", DType::F32, vec![n])];
    let name = format!("eval_{}_n{n}_r{r}_b{bs}", mi.name);
    ArtifactInfo {
        path: format!("{name}.hlo.txt"),
        name,
        kind: ArtifactKind::Eval,
        inputs,
        outputs,
        meta: train_meta(&mi.name, n, r, bs, mi.seq),
    }
}

fn kernel_artifacts(geom: &str, d: usize, k: usize, n: usize) -> [ArtifactInfo; 2] {
    let (r, m) = (KERNEL_R, KERNEL_M);
    let mut meta = BTreeMap::new();
    meta.insert("geom".to_string(), Json::str(geom));
    meta.insert("n".to_string(), Json::num(n as f64));
    meta.insert("d".to_string(), Json::num(d as f64));
    meta.insert("k".to_string(), Json::num(k as f64));
    meta.insert("r".to_string(), Json::num(r as f64));
    meta.insert("m".to_string(), Json::num(m as f64));
    let fwd_inputs = vec![
        ts("x", DType::F32, vec![n, m, d]),
        ts("a", DType::F32, vec![n, d, r]),
        ts("b", DType::F32, vec![n, r, k]),
        ts("alpha", DType::F32, vec![n]),
    ];
    let mut bwd_inputs = fwd_inputs.clone();
    bwd_inputs.push(ts("g", DType::F32, vec![n, m, k]));
    let fwd = ArtifactInfo {
        name: format!("kfwd_{geom}_n{n}"),
        kind: ArtifactKind::KernelFwd,
        path: format!("kfwd_{geom}_n{n}.hlo.txt"),
        inputs: fwd_inputs,
        outputs: vec![ts("y", DType::F32, vec![n, m, k])],
        meta: meta.clone(),
    };
    let bwd = ArtifactInfo {
        name: format!("kbwd_{geom}_n{n}"),
        kind: ArtifactKind::KernelBwd,
        path: format!("kbwd_{geom}_n{n}.hlo.txt"),
        inputs: bwd_inputs,
        outputs: vec![
            ts("dx", DType::F32, vec![n, m, d]),
            ts("da", DType::F32, vec![n, d, r]),
            ts("db", DType::F32, vec![n, r, k]),
        ],
        meta,
    };
    [fwd, bwd]
}

/// Synthesize the manifest `aot.py` would emit — same token layout, task
/// list, model table, train/eval bucket grid and kernel artifacts — so the
/// runtime comes up with zero build-time artifacts on disk.
pub fn builtin_manifest(dir: &Path) -> Manifest {
    let tokens = TokenLayout { pad: 0, bos: 1, sep: 2, eos: 3, alpha0: 8 };
    let tasks: Vec<String> =
        crate::train::tasks::TASKS.iter().map(|s| s.to_string()).collect();

    let mut models = BTreeMap::new();
    let mut artifacts = vec![];
    for b in &BUILTIN_MODELS {
        let (v, d, l, f, s) = (b.vocab, b.d_model, b.n_layers, b.d_ff, b.seq);
        let params = v * d + s * d + l * (4 * d * d + 3 * d * f + 2 * d) + d;
        let mi = ModelInfo {
            name: b.name.to_string(),
            vocab: v,
            d_model: d,
            n_layers: l,
            n_heads: b.n_heads,
            d_ff: f,
            seq: s,
            params,
            weights: format!("weights_{}.bin", b.name),
        };
        for (n, r, bs) in train_grid(b.name) {
            artifacts.push(train_artifact(&mi, n, r, bs));
            artifacts.push(eval_artifact(&mi, n, r, bs));
        }
        models.insert(b.name.to_string(), mi);
    }
    for (geom, d, k) in KERNEL_GEOMS {
        for n in KERNEL_NS {
            artifacts.extend(kernel_artifacts(geom, d, k, n));
        }
    }
    Manifest { dir: dir.to_path_buf(), tokens, tasks, models, artifacts }
}

// ---------------------------------------------------------------------------
// Deterministic base-weight synthesis
// ---------------------------------------------------------------------------

fn fnv1a(s: &str) -> u64 {
    s.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Deterministic frozen base weights with the `model.py::init_base`
/// distributions (embed/pos ~ N(0, 0.02²), projections ~ N(0, 1/d_in),
/// LayerNorm gains = 1). Used when no pretrained `weights_<model>.bin`
/// exists; seeded by the model name so every run agrees.
pub fn synth_base_weights(mi: &ModelInfo) -> Vec<HostTensor> {
    let (v, d, l, f, s) = (mi.vocab, mi.d_model, mi.n_layers, mi.d_ff, mi.seq);
    let mut rng = Rng::new(fnv1a(&mi.name) ^ 0x706c_6f72_6100_0000);
    let mut norm = |shape: Vec<usize>, std: f64| {
        let count: usize = shape.iter().product();
        let data = (0..count).map(|_| (rng.normal() * std) as f32).collect();
        HostTensor::f32(shape, data).unwrap()
    };
    let ones = |shape: Vec<usize>| {
        let count: usize = shape.iter().product();
        HostTensor::f32(shape, vec![1.0; count]).unwrap()
    };
    let dstd = (d as f64).powf(-0.5);
    let fstd = (f as f64).powf(-0.5);
    vec![
        norm(vec![v, d], 0.02),
        norm(vec![s, d], 0.02),
        ones(vec![l, d]),
        ones(vec![l, d]),
        norm(vec![l, d, d], dstd),
        norm(vec![l, d, d], dstd),
        norm(vec![l, d, d], dstd),
        norm(vec![l, d, d], dstd),
        norm(vec![l, d, f], dstd),
        norm(vec![l, d, f], dstd),
        norm(vec![l, f, d], fstd),
        ones(vec![d]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        builtin_manifest(&PathBuf::from("/nonexistent/plora-builtin"))
    }

    #[test]
    fn builtin_manifest_mirrors_aot_grid() {
        let m = manifest();
        assert_eq!(m.tokens.pad, 0);
        assert_eq!(m.tokens.bos, 1);
        assert_eq!(m.tokens.alpha0, 8);
        assert_eq!(m.tasks, vec!["modadd", "copy", "parity", "needle"]);
        assert_eq!(m.models.len(), 4);
        let nano = m.model("nano").unwrap();
        assert_eq!((nano.d_model, nano.n_layers, nano.seq), (64, 2, 32));

        // Bucket selection behaves exactly like the real manifest's.
        let b = m.train_bucket("tiny", 3, 8, 1).unwrap();
        assert_eq!(
            (b.meta_usize("n"), b.meta_usize("r"), b.meta_usize("bs")),
            (Some(4), Some(8), Some(1))
        );
        assert!(m.train_bucket("tiny", 9, 8, 1).is_none());
        assert_eq!(m.max_bucket_n("nano"), 4);

        // Every train bucket has its paired eval artifact.
        for a in m.by_kind(ArtifactKind::Train) {
            let e = m.eval_for(a).unwrap();
            assert_eq!(e.kind, ArtifactKind::Eval);
        }

        // Kernel artifacts for both geometries at all pack sizes.
        for (geom, _, _) in KERNEL_GEOMS {
            for n in KERNEL_NS {
                assert!(m.artifact(&format!("kfwd_{geom}_n{n}")).is_ok());
                assert!(m.artifact(&format!("kbwd_{geom}_n{n}")).is_ok());
            }
        }
    }

    #[test]
    fn train_signature_shape_sanity() {
        let m = manifest();
        let t = m.train_bucket("tiny", 2, 8, 1).unwrap();
        let tok = t.input("tokens").unwrap();
        assert_eq!(tok.dtype, DType::I32);
        let mi = m.model("tiny").unwrap();
        assert_eq!(tok.shape, vec![2, 1, mi.seq]);
        // outputs: 14 lora + 14 m + 14 v + t + per_loss
        assert_eq!(t.outputs.len(), 44);
        // inputs: 12 base + 42 lora/m/v + 7 step args
        assert_eq!(t.inputs.len(), 61);
    }

    #[test]
    fn kernel_bwd_matches_ref_py_closed_form() {
        let m = manifest();
        let info = m.artifact("kbwd_attn_n2").unwrap().clone();
        let exe = RefBackend.load(&m, &info).unwrap();
        let (n, d, k, r, mm) = (2usize, 256usize, 256usize, 16usize, 16usize);
        let alpha = [2.0f32, 0.5];
        let inputs = vec![
            HostTensor::f32(vec![n, mm, d], vec![0.01; n * mm * d]).unwrap(),
            HostTensor::f32(vec![n, d, r], vec![0.02; n * d * r]).unwrap(),
            HostTensor::f32(vec![n, r, k], vec![0.03; n * r * k]).unwrap(),
            HostTensor::f32(vec![n], alpha.to_vec()).unwrap(),
            HostTensor::f32(vec![n, mm, k], vec![0.05; n * mm * k]).unwrap(),
        ];
        let input_refs: Vec<&HostTensor> = inputs.iter().collect();
        let outs = exe.run(&input_refs, &mut Scratch::new()).unwrap();
        assert_eq!(outs.len(), 3);
        // Closed forms for constant tensors (see ref.py::ref_grads):
        // h = d*x*a; dh = α*k*g*b; db = α*m*h*g; da = m*x*dh; dx = r*dh*a.
        for (i, &al) in alpha.iter().enumerate() {
            let h = d as f32 * 0.01 * 0.02;
            let dh = al * k as f32 * 0.05 * 0.03;
            let want_db = al * mm as f32 * h * 0.05;
            let want_da = mm as f32 * 0.01 * dh;
            let want_dx = r as f32 * dh * 0.02;
            let got_dx = outs[0].as_f32().unwrap()[i * mm * d];
            let got_da = outs[1].as_f32().unwrap()[i * d * r];
            let got_db = outs[2].as_f32().unwrap()[i * r * k];
            let close = |a: f32, b: f32| (a - b).abs() <= 1e-3 * b.abs().max(1e-3);
            assert!(close(got_dx, want_dx), "dx[{i}]: {got_dx} vs {want_dx}");
            assert!(close(got_da, want_da), "da[{i}]: {got_da} vs {want_da}");
            assert!(close(got_db, want_db), "db[{i}]: {got_db} vs {want_db}");
        }
    }

    /// The TinyLM dimension table exists in two Rust copies (BUILTIN_MODELS
    /// here, the GEOMS rows in config::geometry) — pin them together.
    #[test]
    fn builtin_models_agree_with_geometry_table() {
        let m = manifest();
        for (name, mi) in &m.models {
            let g = crate::config::geometry::geom(name)
                .unwrap_or_else(|| panic!("no ModelGeom for TinyLM '{name}'"));
            assert_eq!(g.n_layers, mi.n_layers, "{name}: n_layers");
            assert_eq!(g.d_model, mi.d_model, "{name}: d_model");
            assert_eq!(g.d_ff, mi.d_ff, "{name}: d_ff");
            assert_eq!(g.n_heads, mi.n_heads, "{name}: n_heads");
            assert_eq!(g.vocab, mi.vocab, "{name}: vocab");
            assert_eq!(g.seq, mi.seq, "{name}: seq");
        }
    }

    /// A full train step is bitwise invariant to the GEMM implementation,
    /// the worker count and the adapter-fusion knob — the load-bearing
    /// guarantee behind `PLORA_GEMM`/`PLORA_THREADS`/`PLORA_FUSED`
    /// (tiling/vector lanes/threading/batching never reorder any output
    /// element's reduction).
    #[test]
    fn train_step_is_bitwise_invariant_to_gemm_mode_and_threads() {
        use crate::runtime::state::TrainState;
        use crate::runtime::Runtime;

        let dir = std::env::temp_dir().join("plora-no-artifacts-gemm");
        let rt = Runtime::load(&dir).unwrap();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 2, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;

        let run_steps = |mode: gemm::Mode, threads: usize, fused: bool| -> Vec<Vec<f32>> {
            gemm::set_mode(mode);
            gemm::set_threads(threads);
            gemm::set_fused(fused);
            let mut st = TrainState::init_per_adapter(&mi, 2, 8, &[5, 9], &[8, 4]).unwrap();
            let rmask = st.rank_mask(&[8, 4]).unwrap();
            let mut rng = crate::util::rng::Rng::new(3);
            for _ in 0..2 {
                let tokens: Vec<i32> =
                    (0..2 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![2, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![2, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![2, 1, seq], vec![1.0; 2 * seq]).unwrap();
                st.step(&exe, &base, &tok, &tgt, &msk, &[1.0, 0.5], &[2e-3, 1e-3], &rmask)
                    .unwrap();
            }
            st.lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect()
        };

        let want = run_steps(gemm::Mode::Tiled, 1, true);
        for (mode, threads, fused) in [
            (gemm::Mode::Naive, 1, true),
            (gemm::Mode::Tiled, 4, true),
            (gemm::Mode::Naive, 4, true),
            (gemm::Mode::Simd, 1, true),
            (gemm::Mode::Simd, 4, true),
            (gemm::Mode::Tiled, 1, false),
            (gemm::Mode::Tiled, 4, false),
            (gemm::Mode::Simd, 1, false),
        ] {
            let got = run_steps(mode, threads, fused);
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    a, b,
                    "lora[{k}] diverged under {mode:?}/{threads} threads/fused={fused}"
                );
            }
        }
        gemm::set_mode(gemm::Mode::Tiled);
        gemm::set_threads(1);
        gemm::set_fused(true);
    }

    #[test]
    fn synth_weights_are_deterministic_and_shaped() {
        let m = manifest();
        let mi = m.model("nano").unwrap();
        let w1 = synth_base_weights(mi);
        let w2 = synth_base_weights(mi);
        assert_eq!(w1.len(), 12);
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        // LayerNorm gains are exactly ones; projections are not.
        assert!(w1[2].as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(w1[4].as_f32().unwrap().iter().any(|&x| x != 0.0 && x != 1.0));
        // Different models draw different weights.
        let tiny = synth_base_weights(m.model("tiny").unwrap());
        assert_ne!(&w1[0].as_f32().unwrap()[..8], &tiny[0].as_f32().unwrap()[..8]);
    }
}
