//! Pure-Rust TinyLM: the packed multi-adapter LoRA forward/backward and
//! the fused train/eval steps the reference backend interprets.
//!
//! This is the Rust twin of `python/compile/model.py` — same architecture
//! (pre-LN attention + gated-SiLU MLP, tied embedding head), same packed
//! layout (`n` adapters, ranks zero-padded to the bucket rank, batches
//! padded with a zero loss mask), same AdamW semantics, same argument
//! order (`aot.py::train_signature`). The backward pass was derived by
//! hand and cross-checked against `jax.value_and_grad` of the Python
//! model; the in-file finite-difference test re-verifies it on every
//! `cargo test`.
//!
//! Everything is f32 over flat row-major buffers. All activations,
//! gradients and scratch live in a step-persistent
//! [`super::workspace::Workspace`] arena (zero steady-state allocation),
//! and the matmuls go through the register-blocked kernels in
//! [`super::gemm`]. Projection forward/backward passes optionally split
//! their `n·bs·seq` row dimension across the persistent worker pool
//! (`gemm::threads()`, the `PLORA_THREADS` knob); every output element's
//! reduction order is independent of tiling and threading, so results are
//! bitwise identical at any setting — see the `gemm` module docs.

use anyhow::{bail, Result};

use super::gemm;
use super::workspace::{LayerSave, Workspace};
use crate::runtime::tensor::HostTensor;

/// Indices of the `LORA_ORDER` tensors (sorted `{a,b}_{proj}` names).
const A_DOWN: usize = 0;
const A_GATE: usize = 1;
const A_K: usize = 2;
const A_O: usize = 3;
const A_Q: usize = 4;
const A_UP: usize = 5;
const A_V: usize = 6;
pub(crate) const B_DOWN: usize = 7;
const B_GATE: usize = 8;
const B_K: usize = 9;
const B_O: usize = 10;
const B_Q: usize = 11;
const B_UP: usize = 12;
const B_V: usize = 13;

/// Indices of the `BASE_ORDER` tensors.
pub(crate) const EMBED: usize = 0;
pub(crate) const POS: usize = 1;
const LN1: usize = 2;
const LN2: usize = 3;
const WQ: usize = 4;
const WK: usize = 5;
const WV: usize = 6;
const WO: usize = 7;
const WUP: usize = 8;
const WGATE: usize = 9;
const WDOWN: usize = 10;
pub(crate) const LNF: usize = 11;

pub(crate) const ADAM_B1: f32 = 0.9;
pub(crate) const ADAM_B2: f32 = 0.999;
pub(crate) const ADAM_EPS: f32 = 1e-8;
const LN_EPS: f32 = 1e-5;

/// TinyLM geometry (mirrors `model.py::ModelSpec`).
#[derive(Debug, Clone, Copy)]
pub struct Spec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
}

impl Spec {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn check(&self) -> Result<()> {
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("spec: d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// LayerNorm + activations
// ---------------------------------------------------------------------------

/// LayerNorm forward over `rows` rows of width `d`: `h = xhat * g`,
/// saving `xhat` and `inv = 1/sqrt(var + eps)` for the backward pass.
fn ln_fwd(
    x: &[f32],
    g: &[f32],
    rows: usize,
    d: usize,
    h: &mut [f32],
    xhat: &mut [f32],
    inv: &mut [f32],
) {
    let df = d as f32;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= df;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= df;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let hr = &mut h[r * d..(r + 1) * d];
        for c in 0..d {
            let v = (xr[c] - mu) * iv;
            xh[c] = v;
            hr[c] = v * g[c];
        }
    }
}

/// LayerNorm backward: `dx += inv * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))`
/// with `dxhat = dy * g` (the gain `g` is frozen — no `dg`). `dxh` is a
/// `d`-float row scratch (`Workspace::dln`).
#[allow(clippy::too_many_arguments)]
fn ln_bwd_acc(
    dx: &mut [f32],
    dy: &[f32],
    g: &[f32],
    xhat: &[f32],
    inv: &[f32],
    rows: usize,
    d: usize,
    dxh: &mut [f32],
) {
    let df = d as f32;
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for c in 0..d {
            let v = dyr[c] * g[c];
            dxh[c] = v;
            m1 += v;
            m2 += v * xh[c];
        }
        m1 /= df;
        m2 /= df;
        let iv = inv[r];
        let dxr = &mut dx[r * d..(r + 1) * d];
        for c in 0..d {
            dxr[c] += iv * (dxh[c] - m1 - xh[c] * m2);
        }
    }
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn silu(z: f32) -> f32 {
    z * sigmoid(z)
}

fn dsilu(z: f32) -> f32 {
    let s = sigmoid(z);
    s * (1.0 + z * (1.0 - s))
}

// ---------------------------------------------------------------------------
// Packed-LoRA projection
// ---------------------------------------------------------------------------

/// Packed projection forward: per adapter `i`,
/// `out_i = input_i @ w + scale_i * (input_i @ a_i) @ b_i`, with the rank-r
/// intermediate saved in `mid` for the backward pass. `a`/`b` are the
/// layer-`l` slices `(n, din, r)` / `(n, r, dout)`.
///
/// The `n·m` output rows are split across `gemm::threads()` persistent
/// pool workers; each row is produced by exactly one worker with an
/// unchanged reduction order, so the result is bitwise
/// thread-count-invariant.
#[allow(clippy::too_many_arguments)]
fn proj_fwd(
    out: &mut [f32],
    mid: &mut [f32],
    input: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
) {
    let rows = n * m;
    gemm::par_row_chunks(
        rows,
        gemm::threads(),
        din * dout,
        out,
        dout,
        mid,
        r,
        |oc, mc, lo, hi| proj_fwd_rows(oc, mc, input, w, a, b, scale, m, din, dout, r, lo, hi),
    );
}

/// Rows `[lo, hi)` of the packed projection forward. `out`/`mid` are the
/// row-aligned chunks for exactly that range.
#[allow(clippy::too_many_arguments)]
fn proj_fwd_rows(
    out: &mut [f32],
    mid: &mut [f32],
    input: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
    lo: usize,
    hi: usize,
) {
    out.fill(0.0);
    mid.fill(0.0);
    let fused = gemm::fused();
    if fused {
        // The base weight `w` is shared by every adapter, so the base GEMM
        // fuses across adapter boundaries into one call over the whole row
        // range. Each output element still receives base contributions
        // first (ascending k), then its adapter's B contributions — the
        // same per-element sequence as the per-adapter loop below.
        gemm::mm_acc(out, &input[lo * din..hi * din], w, hi - lo, din, dout, 1.0);
    }
    let mut row = lo;
    while row < hi {
        let i = row / m; // adapter owning this row group
        let end = ((i + 1) * m).min(hi);
        let h = end - row;
        let xi = &input[row * din..end * din];
        let oi = &mut out[(row - lo) * dout..(end - lo) * dout];
        let mi = &mut mid[(row - lo) * r..(end - lo) * r];
        if !fused {
            gemm::mm_acc(oi, xi, w, h, din, dout, 1.0);
        }
        gemm::mm_acc(mi, xi, &a[i * din * r..(i + 1) * din * r], h, din, r, 1.0);
        gemm::mm_acc(oi, mi, &b[i * r * dout..(i + 1) * r * dout], h, r, dout, scale[i]);
        row = end;
    }
}

/// Packed projection backward: accumulates `dinput`, `da` and `db` (the
/// layer-`l` gradient slices) from the upstream `dy`. Matches
/// `python/compile/kernels/ref.py::ref_grads` composed with the base GEMM.
///
/// Two phases: the row-local part (`dmid`, `dinput`) splits the `n·m` rows
/// across scoped workers like [`proj_fwd`]; the `da`/`db` reductions run
/// as one batched multi-adapter GEMM per projection ([`proj_bwd_wgrads`],
/// [`gemm::batched`]) whose combined output rows fan out across the
/// persistent [`crate::util::threadpool::global`] workers. Every output
/// element keeps one sequential ascending-k reduction on exactly one
/// worker, so results stay bitwise invariant at any `PLORA_THREADS`
/// setting and with fusion on or off (`PLORA_FUSED`).
#[allow(clippy::too_many_arguments)]
fn proj_bwd(
    dinput: &mut [f32],
    da: &mut [f32],
    db: &mut [f32],
    dmid: &mut [f32],
    dy: &[f32],
    input: &[f32],
    mid: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
) {
    let rows = n * m;
    gemm::par_row_chunks(
        rows,
        gemm::threads(),
        din * dout,
        dinput,
        din,
        &mut dmid[..],
        r,
        |dic, dmc, lo, hi| proj_bwd_rows(dic, dmc, dy, w, a, b, scale, m, din, dout, r, lo, hi),
    );
    proj_bwd_wgrads(da, db, dy, input, mid, dmid, scale, n, m, din, dout, r);
}

/// The weight-gradient phase of [`proj_bwd`]:
/// `da_i += input_i^T @ dmid_i` (case 3), `db_i += scale_i * mid_i^T @
/// dy_i` (case 1), per adapter.
///
/// **Fused (default):** all `n` adapters' disjoint `da`/`db` slices are
/// walked by one [`gemm::batched`] call per projection — two batched GEMMs
/// replace `2n` small ones, and the `_par` driver splits the combined
/// output rows at *row* granularity, so parallelism is no longer capped at
/// `threads().min(n)` adapter-sized tasks. Per-element k-order, per-adapter
/// `scale` and the zero-rank-padding `f == 0.0` skip are untouched (same
/// mode-dispatched kernels), so the result is bit-identical to the
/// per-adapter loop. **Unfused (`PLORA_FUSED=0`):** the original loop —
/// adapters split across the global pool when the region is large enough
/// (the [`gemm::PAR_MIN_WORK`] guard keeps nano-scale steps dispatch-free),
/// each adapter's two reductions back-to-back on exactly one worker. Kept
/// as the fusion bench baseline and for bisecting.
#[allow(clippy::too_many_arguments)]
fn proj_bwd_wgrads(
    da: &mut [f32],
    db: &mut [f32],
    dy: &[f32],
    input: &[f32],
    mid: &[f32],
    dmid: &[f32],
    scale: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
) {
    if gemm::fused() {
        let nt = gemm::threads();
        gemm::batched::mm_tn_acc_par(da, input, dmid, n, m, din, r, None, nt);
        gemm::batched::mm_tn_acc_par(db, mid, dy, n, m, r, dout, Some(scale), nt);
        return;
    }
    let ka = din * r; // per-adapter da length
    let kb = r * dout; // per-adapter db length
    let per_adapter = |da_i: &mut [f32], db_i: &mut [f32], i: usize| {
        let dyi = &dy[i * m * dout..(i + 1) * m * dout];
        let xi = &input[i * m * din..(i + 1) * m * din];
        let midi = &mid[i * m * r..(i + 1) * m * r];
        let dmidi = &dmid[i * m * r..(i + 1) * m * r];
        gemm::mm_tn_acc(da_i, xi, dmidi, m, din, r, 1.0);
        gemm::mm_tn_acc(db_i, midi, dyi, m, r, dout, scale[i]);
    };
    let nt = gemm::threads().min(n);
    let work = n * m * (din + dout) * r;
    if nt <= 1 || work < gemm::PAR_MIN_WORK {
        for (i, (da_i, db_i)) in da.chunks_mut(ka).zip(db.chunks_mut(kb)).enumerate() {
            per_adapter(da_i, db_i, i);
        }
        return;
    }
    // One task per contiguous adapter chunk on the persistent pool.
    let chunk = n.div_ceil(nt);
    let per_adapter = &per_adapter;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nt);
    let (mut da_rest, mut db_rest) = (da, db);
    let mut i0 = 0usize;
    while i0 < n {
        let take = chunk.min(n - i0);
        let (da_c, da_r) = da_rest.split_at_mut(take * ka);
        let (db_c, db_r) = db_rest.split_at_mut(take * kb);
        da_rest = da_r;
        db_rest = db_r;
        let lo = i0;
        tasks.push(Box::new(move || {
            let pairs = da_c.chunks_mut(ka).zip(db_c.chunks_mut(kb));
            for (j, (da_i, db_i)) in pairs.enumerate() {
                per_adapter(da_i, db_i, lo + j);
            }
        }));
        i0 += take;
    }
    crate::util::threadpool::global().scoped(tasks);
}

/// Rows `[lo, hi)` of the row-local projection backward: `dmid` (case 2)
/// and the `dinput` accumulation (base GEMM + case 4). `dinput`/`dmid` are
/// the row-aligned chunks; `dinput` arrives with prior accumulated
/// contributions and is NOT zeroed here.
#[allow(clippy::too_many_arguments)]
fn proj_bwd_rows(
    dinput: &mut [f32],
    dmid: &mut [f32],
    dy: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
    lo: usize,
    hi: usize,
) {
    let fused = gemm::fused();
    if fused {
        // Shared-base fusion (see `proj_fwd_rows`): `w` is adapter-
        // independent, so `dinput += dy @ w^T` runs once over the whole
        // row range. Each element's order is unchanged — prior
        // accumulated contributions, then the w term, then its adapter's
        // a term.
        gemm::mm_nt_acc(dinput, &dy[lo * dout..hi * dout], w, hi - lo, dout, din, 1.0);
    }
    let mut row = lo;
    while row < hi {
        let i = row / m;
        let end = ((i + 1) * m).min(hi);
        let h = end - row;
        let dyi = &dy[row * dout..end * dout];
        let dmi = &mut dmid[(row - lo) * r..(end - lo) * r];
        // dh_mid = scale * dy @ b^T (case 2 of ref.py)
        dmi.fill(0.0);
        gemm::mm_nt_acc(dmi, dyi, &b[i * r * dout..(i + 1) * r * dout], h, dout, r, scale[i]);
        let di = &mut dinput[(row - lo) * din..(end - lo) * din];
        // dinput += dy @ w^T + dh_mid @ a^T (base GEMM + case 4)
        if !fused {
            gemm::mm_nt_acc(di, dyi, w, h, dout, din, 1.0);
        }
        gemm::mm_nt_acc(di, dmi, &a[i * din * r..(i + 1) * din * r], h, r, din, 1.0);
        row = end;
    }
}

// ---------------------------------------------------------------------------
// Forward pass
// ---------------------------------------------------------------------------

/// Embedding + positional encoding into the residual stream `x`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn embed_fwd(
    embed: &[f32],
    pos: &[f32],
    tokens: &[i32],
    x: &mut [f32],
    n: usize,
    bs: usize,
    s: usize,
    d: usize,
    v: usize,
) -> Result<()> {
    for i in 0..n {
        for b in 0..bs {
            for t in 0..s {
                let tok = tokens[(i * bs + b) * s + t];
                if tok < 0 || tok as usize >= v {
                    bail!("token {tok} out of vocab {v}");
                }
                let erow = &embed[tok as usize * d..(tok as usize + 1) * d];
                let prow = &pos[t * d..(t + 1) * d];
                let off = ((i * bs + b) * s + t) * d;
                let xrow = &mut x[off..off + d];
                for c in 0..d {
                    xrow[c] = erow[c] + prow[c];
                }
            }
        }
    }
    Ok(())
}

/// One transformer layer's frozen base weights (the layer-`l` slices of
/// the `BASE_ORDER` tensors) — the unit both the monolithic layer loop and
/// a pipeline stage's layer loop consume.
pub(crate) struct LayerWeights<'a> {
    pub ln1: &'a [f32],
    pub ln2: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub wup: &'a [f32],
    pub wgate: &'a [f32],
    pub wdown: &'a [f32],
}

/// Slice layer `l`'s base weights out of the full `BASE_ORDER` set.
pub(crate) fn layer_weights<'a>(
    base: &[&'a HostTensor],
    l: usize,
    d: usize,
    f: usize,
) -> Result<LayerWeights<'a>> {
    Ok(LayerWeights {
        ln1: &base[LN1].as_f32()?[l * d..(l + 1) * d],
        ln2: &base[LN2].as_f32()?[l * d..(l + 1) * d],
        wq: &base[WQ].as_f32()?[l * d * d..(l + 1) * d * d],
        wk: &base[WK].as_f32()?[l * d * d..(l + 1) * d * d],
        wv: &base[WV].as_f32()?[l * d * d..(l + 1) * d * d],
        wo: &base[WO].as_f32()?[l * d * d..(l + 1) * d * d],
        wup: &base[WUP].as_f32()?[l * d * f..(l + 1) * d * f],
        wgate: &base[WGATE].as_f32()?[l * d * f..(l + 1) * d * f],
        wdown: &base[WDOWN].as_f32()?[l * f * d..(l + 1) * f * d],
    })
}

/// One transformer layer's forward over the slot window `[slo, slo+nw)`
/// of a pack of `n_full` adapters: pre-LN attention + gated-SiLU MLP with
/// residuals, all backward state written into `save`'s windowed slices.
///
/// Every flat buffer in the pack is slot-major, so a slot window of it is
/// one contiguous range and the windowed call runs the *identical*
/// per-element arithmetic the monolithic (`slo=0, nw=n_full`) call runs —
/// each output element is produced by exactly one window with an unchanged
/// reduction order. This is what makes stage-pipelined execution (one
/// microbatch = one slot window) bitwise identical to the fused step
/// (DESIGN.md §15). `x`/`tmp` are the *pre-windowed* `(nw·bs·seq, d)`
/// residual stream and scratch; `att` is `≥ seq` scratch; `lora`/`scale`
/// are full-pack and windowed internally.
#[allow(clippy::too_many_arguments)]
pub(crate) fn layer_fwd(
    spec: &Spec,
    lw: &LayerWeights,
    lora: &[&[f32]; 14],
    scale_full: &[f32],
    l: usize,
    n_full: usize,
    slo: usize,
    nw: usize,
    bs: usize,
    r: usize,
    x: &mut [f32],
    tmp: &mut [f32],
    att: &mut [f32],
    save: &mut LayerSave,
) {
    let (d, f, s) = (spec.d_model, spec.d_ff, spec.seq);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s; // rows per adapter
    let n = nw;
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();
    let scale = &scale_full[slo..slo + nw];
    // Per-slot row strides of the save buffers; all are slot-major, so the
    // window of each is one contiguous slice.
    let (rd, rf, rr) = (m * d, m * f, m * r);
    let rp = bs * nh * s * s;
    let h = &mut save.h[slo * rd..(slo + nw) * rd];
    let xhat1 = &mut save.xhat1[slo * rd..(slo + nw) * rd];
    let inv1 = &mut save.inv1[slo * m..(slo + nw) * m];
    let q = &mut save.q[slo * rd..(slo + nw) * rd];
    let k = &mut save.k[slo * rd..(slo + nw) * rd];
    let v = &mut save.v[slo * rd..(slo + nw) * rd];
    let o = &mut save.o[slo * rd..(slo + nw) * rd];
    let p = &mut save.p[slo * rp..(slo + nw) * rp];
    let mid_q = &mut save.mid_q[slo * rr..(slo + nw) * rr];
    let mid_k = &mut save.mid_k[slo * rr..(slo + nw) * rr];
    let mid_v = &mut save.mid_v[slo * rr..(slo + nw) * rr];
    let mid_o = &mut save.mid_o[slo * rr..(slo + nw) * rr];
    let mid_up = &mut save.mid_up[slo * rr..(slo + nw) * rr];
    let mid_gate = &mut save.mid_gate[slo * rr..(slo + nw) * rr];
    let mid_down = &mut save.mid_down[slo * rr..(slo + nw) * rr];
    let xhat2 = &mut save.xhat2[slo * rd..(slo + nw) * rd];
    let inv2 = &mut save.inv2[slo * m..(slo + nw) * m];
    let h2 = &mut save.h2[slo * rd..(slo + nw) * rd];
    let up = &mut save.up[slo * rf..(slo + nw) * rf];
    let gate = &mut save.gate[slo * rf..(slo + nw) * rf];
    let act = &mut save.act[slo * rf..(slo + nw) * rf];
    // Window-local LoRA slices: layer `l`, slots `[slo, slo+nw)` of the
    // flat `(L, n_full, din, r)` / `(L, n_full, r, dout)` tensors.
    let la = |idx: usize, din: usize| {
        &lora[idx][(l * n_full + slo) * din * r..(l * n_full + slo + nw) * din * r]
    };
    let lb = |idx: usize, dout: usize| {
        &lora[idx][(l * n_full + slo) * r * dout..(l * n_full + slo + nw) * r * dout]
    };

    ln_fwd(x, lw.ln1, nm, d, h, xhat1, inv1);

    proj_fwd(q, mid_q, h, lw.wq, la(A_Q, d), lb(B_Q, d), scale, n, m, d, d, r);
    proj_fwd(k, mid_k, h, lw.wk, la(A_K, d), lb(B_K, d), scale, n, m, d, d, r);
    proj_fwd(v, mid_v, h, lw.wv, la(A_V, d), lb(B_V, d), scale, n, m, d, d, r);

    // Causal attention per (adapter, batch, head), probabilities saved.
    o.fill(0.0);
    let logit_buf = &mut att[..s];
    for i in 0..n {
        for b in 0..bs {
            for hh in 0..nh {
                for t in 0..s {
                    let base_t = ((i * bs + b) * s + t) * d + hh * dh;
                    let qrow = &q[base_t..base_t + dh];
                    let mut mx = f32::NEG_INFINITY;
                    for (u, lv) in logit_buf.iter_mut().enumerate().take(t + 1) {
                        let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                        let krow = &k[base_u..base_u + dh];
                        let mut dot = 0.0f32;
                        for c in 0..dh {
                            dot += qrow[c] * krow[c];
                        }
                        let val = dot / sqrt_dh;
                        *lv = val;
                        if val > mx {
                            mx = val;
                        }
                    }
                    let mut sum = 0.0f32;
                    for lv in logit_buf.iter_mut().take(t + 1) {
                        *lv = (*lv - mx).exp();
                        sum += *lv;
                    }
                    let poff = (((i * bs + b) * nh + hh) * s + t) * s;
                    let prow = &mut p[poff..poff + s];
                    for (u, &e) in logit_buf.iter().enumerate().take(t + 1) {
                        prow[u] = e / sum;
                    }
                    let orow = &mut o[base_t..base_t + dh];
                    for (u, &w) in prow.iter().enumerate().take(t + 1) {
                        if w == 0.0 {
                            continue;
                        }
                        let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                        let vrow = &v[base_u..base_u + dh];
                        for c in 0..dh {
                            orow[c] += w * vrow[c];
                        }
                    }
                }
            }
        }
    }

    // Attention output projection + residual.
    proj_fwd(tmp, mid_o, o, lw.wo, la(A_O, d), lb(B_O, d), scale, n, m, d, d, r);
    for (xv, av) in x.iter_mut().zip(tmp.iter()) {
        *xv += *av;
    }

    // MLP: pre-LN, gated SiLU, down projection + residual.
    ln_fwd(x, lw.ln2, nm, d, h2, xhat2, inv2);
    proj_fwd(up, mid_up, h2, lw.wup, la(A_UP, d), lb(B_UP, f), scale, n, m, d, f, r);
    let (ga, gb) = (la(A_GATE, d), lb(B_GATE, f));
    proj_fwd(gate, mid_gate, h2, lw.wgate, ga, gb, scale, n, m, d, f, r);
    for j in 0..nm * f {
        act[j] = silu(gate[j]) * up[j];
    }
    let (da_, db_) = (la(A_DOWN, f), lb(B_DOWN, d));
    proj_fwd(tmp, mid_down, act, lw.wdown, da_, db_, scale, n, m, f, d, r);
    for (xv, dv) in x.iter_mut().zip(tmp.iter()) {
        *xv += *dv;
    }
}

/// Final LN + tied-embedding head over `rows` residual rows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn head_fwd(
    embed: &[f32],
    lnf: &[f32],
    x: &[f32],
    h: &mut [f32],
    xhatf: &mut [f32],
    invf: &mut [f32],
    logits: &mut [f32],
    rows: usize,
    d: usize,
    v: usize,
) {
    ln_fwd(x, lnf, rows, d, h, xhatf, invf);
    logits.fill(0.0);
    // logits = xf @ embed^T, embed stored (v, d).
    gemm::mm_nt_acc_par(logits, h, embed, rows, d, v, 1.0, gemm::threads());
}

/// Packed forward. `base` in `BASE_ORDER`, `lora` 14 flat slices in
/// `LORA_ORDER` (shapes `(L, n, din, r)` / `(L, n, r, dout)`), `tokens`
/// `(n, bs, s)`. Leaves logits `(n, bs, s, vocab)` in `ws.logits` and
/// everything the backward pass needs in `ws.layers`/`ws.xhatf`/`ws.invf`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward(
    spec: &Spec,
    base: &[&HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    tokens: &[i32],
    n: usize,
    bs: usize,
    r: usize,
    ws: &mut Workspace,
) -> Result<()> {
    spec.check()?;
    ws.ensure(spec, n, bs, r, true);
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let m = bs * s; // rows per adapter
    let nm = n * m;

    let embed = base[EMBED].as_f32()?;
    let pos = base[POS].as_f32()?;
    let Workspace { x, h, xhatf, invf, logits, att, tmp, layers, .. } = ws;
    embed_fwd(embed, pos, tokens, x, n, bs, s, d, v)?;

    for l in 0..spec.n_layers {
        let lw = layer_weights(base, l, d, f)?;
        layer_fwd(spec, &lw, lora, scale, l, n, 0, n, bs, r, x, tmp, att, &mut layers[l]);
    }

    // Final LN + tied-embedding head.
    let lnf = base[LNF].as_f32()?;
    head_fwd(embed, lnf, x, h, xhatf, invf, logits, nm, d, v);
    Ok(())
}

/// Logits-only packed forward for the eval path: the same math as
/// [`forward`], with no backward state saved — activations live in the
/// workspace's small flat buffer set reused across layers instead of one
/// `LayerSave` per layer (the full forward keeps ~O(L·n·bs·seq·(d+f))
/// floats it never reads on eval). Accumulation order matches [`forward`]
/// exactly, so eval loss is bit-identical to a zero-lr train step's loss.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_logits(
    spec: &Spec,
    base: &[&HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    tokens: &[i32],
    n: usize,
    bs: usize,
    r: usize,
    ws: &mut Workspace,
) -> Result<()> {
    spec.check()?;
    ws.ensure(spec, n, bs, r, false);
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s;
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();

    let embed = base[EMBED].as_f32()?;
    let pos = base[POS].as_f32()?;
    let Workspace { x, h, xhat, inv, mid, q, k, v: vv, o, tmp, up, gate, act, att, logits, .. } =
        ws;
    embed_fwd(embed, pos, tokens, x, n, bs, s, d, v)?;

    for l in 0..spec.n_layers {
        let ln1 = &base[LN1].as_f32()?[l * d..(l + 1) * d];
        let ln2 = &base[LN2].as_f32()?[l * d..(l + 1) * d];
        let wq = &base[WQ].as_f32()?[l * d * d..(l + 1) * d * d];
        let wk = &base[WK].as_f32()?[l * d * d..(l + 1) * d * d];
        let wv = &base[WV].as_f32()?[l * d * d..(l + 1) * d * d];
        let wo = &base[WO].as_f32()?[l * d * d..(l + 1) * d * d];
        let wup = &base[WUP].as_f32()?[l * d * f..(l + 1) * d * f];
        let wgate = &base[WGATE].as_f32()?[l * d * f..(l + 1) * d * f];
        let wdown = &base[WDOWN].as_f32()?[l * f * d..(l + 1) * f * d];
        let la = |idx: usize, din: usize| &lora[idx][l * n * din * r..(l + 1) * n * din * r];
        let lb = |idx: usize, dout: usize| &lora[idx][l * n * r * dout..(l + 1) * n * r * dout];

        ln_fwd(x, ln1, nm, d, h, xhat, inv);
        proj_fwd(q, mid, h, wq, la(A_Q, d), lb(B_Q, d), scale, n, m, d, d, r);
        proj_fwd(k, mid, h, wk, la(A_K, d), lb(B_K, d), scale, n, m, d, d, r);
        proj_fwd(vv, mid, h, wv, la(A_V, d), lb(B_V, d), scale, n, m, d, d, r);

        // Causal attention per (adapter, batch, head).
        o.fill(0.0);
        let (logit_buf, prow) = att.split_at_mut(s);
        for i in 0..n {
            for b in 0..bs {
                for hh in 0..nh {
                    for t in 0..s {
                        let base_t = ((i * bs + b) * s + t) * d + hh * dh;
                        let qrow = &q[base_t..base_t + dh];
                        let mut mx = f32::NEG_INFINITY;
                        for (u, lv) in logit_buf.iter_mut().enumerate().take(t + 1) {
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            let krow = &k[base_u..base_u + dh];
                            let mut dot = 0.0f32;
                            for c in 0..dh {
                                dot += qrow[c] * krow[c];
                            }
                            let val = dot / sqrt_dh;
                            *lv = val;
                            if val > mx {
                                mx = val;
                            }
                        }
                        let mut sum = 0.0f32;
                        for lv in logit_buf.iter_mut().take(t + 1) {
                            *lv = (*lv - mx).exp();
                            sum += *lv;
                        }
                        for (u, &e) in logit_buf.iter().enumerate().take(t + 1) {
                            prow[u] = e / sum;
                        }
                        let orow = &mut o[base_t..base_t + dh];
                        for (u, &w) in prow.iter().enumerate().take(t + 1) {
                            if w == 0.0 {
                                continue;
                            }
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            let vrow = &vv[base_u..base_u + dh];
                            for c in 0..dh {
                                orow[c] += w * vrow[c];
                            }
                        }
                    }
                }
            }
        }

        // Attention output projection + residual.
        proj_fwd(tmp, mid, o, wo, la(A_O, d), lb(B_O, d), scale, n, m, d, d, r);
        for (xv, av) in x.iter_mut().zip(tmp.iter()) {
            *xv += *av;
        }

        // MLP: pre-LN, gated SiLU, down projection + residual.
        ln_fwd(x, ln2, nm, d, h, xhat, inv);
        proj_fwd(up, mid, h, wup, la(A_UP, d), lb(B_UP, f), scale, n, m, d, f, r);
        let (ga, gb) = (la(A_GATE, d), lb(B_GATE, f));
        proj_fwd(gate, mid, h, wgate, ga, gb, scale, n, m, d, f, r);
        for j in 0..nm * f {
            act[j] = silu(gate[j]) * up[j];
        }
        let (dna, dnb) = (la(A_DOWN, f), lb(B_DOWN, d));
        proj_fwd(tmp, mid, act, wdown, dna, dnb, scale, n, m, f, d, r);
        for (xv, dv) in x.iter_mut().zip(tmp.iter()) {
            *xv += *dv;
        }
    }

    // Final LN + tied-embedding head.
    let lnf = base[LNF].as_f32()?;
    ln_fwd(x, lnf, nm, d, h, xhat, inv);
    logits.fill(0.0);
    gemm::mm_nt_acc_par(logits, h, embed, nm, d, v, 1.0, gemm::threads());
    Ok(())
}

// ---------------------------------------------------------------------------
// Loss, metrics, backward
// ---------------------------------------------------------------------------

/// Per-adapter masked mean CE loss and (token accuracy on masked positions).
pub(crate) fn loss_and_acc(
    spec: &Spec,
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
) -> (Vec<f32>, Vec<f32>) {
    let v = spec.vocab;
    let m = bs * spec.seq;
    let mut loss = vec![0.0f32; n];
    let mut acc = vec![0.0f32; n];
    for i in 0..n {
        let mut denom = 0.0f32;
        for row in 0..m {
            denom += mask[i * m + row];
        }
        let denom = denom.max(1.0);
        for row in 0..m {
            let mk = mask[i * m + row];
            if mk == 0.0 {
                continue;
            }
            let lrow = &logits[(i * m + row) * v..(i * m + row + 1) * v];
            let tg = targets[i * m + row].clamp(0, v as i32 - 1) as usize;
            let mut mx = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, &lv) in lrow.iter().enumerate() {
                if lv > mx {
                    mx = lv;
                    arg = j;
                }
            }
            let mut se = 0.0f32;
            for &lv in lrow {
                se += (lv - mx).exp();
            }
            let lse = se.ln();
            loss[i] += -(lrow[tg] - mx - lse) * mk;
            if arg == tg {
                acc[i] += mk;
            }
        }
        loss[i] /= denom;
        acc[i] /= denom;
    }
    (loss, acc)
}

/// Per-adapter losses + the loss gradient w.r.t. the logits. Zeroes and
/// fills `dlogits`; masked-out rows stay zero. Each adapter's mean-CE
/// denominator spans only its own `bs·seq` rows, so a slot window of the
/// pack computes exactly the values the full pack computes for those slots.
pub(crate) fn loss_dlogits(
    spec: &Spec,
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
    dlogits: &mut [f32],
) -> Vec<f32> {
    let v = spec.vocab;
    let m = bs * spec.seq;
    let mut per = vec![0.0f32; n];
    dlogits.fill(0.0);
    for i in 0..n {
        let mut denom = 0.0f32;
        for row in 0..m {
            denom += mask[i * m + row];
        }
        let denom = denom.max(1.0);
        for row in 0..m {
            let mk = mask[i * m + row];
            let lrow = &logits[(i * m + row) * v..(i * m + row + 1) * v];
            let tg = targets[i * m + row].clamp(0, v as i32 - 1) as usize;
            if mk == 0.0 {
                continue;
            }
            let mut mx = f32::NEG_INFINITY;
            for &lv in lrow {
                if lv > mx {
                    mx = lv;
                }
            }
            let mut se = 0.0f32;
            for &lv in lrow {
                se += (lv - mx).exp();
            }
            let lse = se.ln();
            per[i] += -(lrow[tg] - mx - lse) * mk;
            let w = mk / denom;
            let drow = &mut dlogits[(i * m + row) * v..(i * m + row + 1) * v];
            for j in 0..v {
                drow[j] = (lrow[j] - mx - lse).exp() * w;
            }
            drow[tg] -= w;
        }
        per[i] /= denom;
    }
    per
}

/// Head + final-LN backward: seeds the running residual gradient `dxa`
/// from `dlogits` (`dxb` is the dxf staging buffer, `dln` a `d`-row
/// scratch).
#[allow(clippy::too_many_arguments)]
pub(crate) fn head_bwd(
    embed: &[f32],
    lnf: &[f32],
    dlogits: &[f32],
    xhatf: &[f32],
    invf: &[f32],
    dxa: &mut [f32],
    dxb: &mut [f32],
    dln: &mut [f32],
    rows: usize,
    d: usize,
    v: usize,
) {
    dxb.fill(0.0);
    gemm::mm_acc_par(dxb, dlogits, embed, rows, v, d, 1.0, gemm::threads());
    dxa.fill(0.0);
    ln_bwd_acc(dxa, dxb, lnf, xhatf, invf, rows, d, dln);
}

/// The backward-pass gradient/scratch buffer set [`layer_bwd`] works in —
/// full-pack flat buffers (the `Workspace` fields of the same names);
/// `layer_bwd` windows them per call. `dxa` carries the running residual
/// gradient: on entry dL/d(layer output), on exit dL/d(layer input).
pub(crate) struct BwdBufs<'a> {
    pub dxa: &'a mut [f32],
    pub dxb: &'a mut [f32],
    pub dact: &'a mut [f32],
    pub dup: &'a mut [f32],
    pub dgate: &'a mut [f32],
    pub dh2: &'a mut [f32],
    pub dmid: &'a mut [f32],
    pub dq: &'a mut [f32],
    pub dk: &'a mut [f32],
    pub dv: &'a mut [f32],
    pub dh: &'a mut [f32],
    pub dp: &'a mut [f32],
    pub dln: &'a mut [f32],
    pub tmp: &'a mut [f32],
}

/// One transformer layer's backward over the slot window `[slo, slo+nw)`,
/// mirroring [`layer_fwd`]'s windowing: reads the windowed `save` state,
/// advances the windowed `dxa` from dL/d(output) to dL/d(input), and
/// accumulates this window's LoRA gradients into `grads_a`/`grads_b`
/// (the `grads.split_at_mut(B_DOWN)` halves). `lg` is the layer's index
/// within the gradient buffers — `l` for full-stack buffers, `l - lo` for
/// a pipeline stage holding layers `[lo, hi)` only. Slot windows of the
/// flat `(Lg, n_full, ·, ·)` gradient tensors are disjoint contiguous
/// ranges, and `proj_bwd` accumulates only within its window, so windowed
/// calls partition the gradient work element-exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn layer_bwd(
    spec: &Spec,
    lw: &LayerWeights,
    lora: &[&[f32]; 14],
    scale_full: &[f32],
    l: usize,
    lg: usize,
    n_full: usize,
    slo: usize,
    nw: usize,
    bs: usize,
    r: usize,
    save: &LayerSave,
    bufs: &mut BwdBufs,
    grads_a: &mut [Vec<f32>],
    grads_b: &mut [Vec<f32>],
) {
    let (d, f, s) = (spec.d_model, spec.d_ff, spec.seq);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s;
    let n = nw;
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();
    let scale = &scale_full[slo..slo + nw];
    let (rd, rf, rr) = (m * d, m * f, m * r);
    let rp = bs * nh * s * s;
    // Slot windows of the gradient/scratch buffers (disjoint fields, so
    // the mutable borrows coexist) and of the saved forward state.
    let dxa = &mut bufs.dxa[slo * rd..(slo + nw) * rd];
    let dxb = &mut bufs.dxb[slo * rd..(slo + nw) * rd];
    let dact = &mut bufs.dact[slo * rf..(slo + nw) * rf];
    let dup = &mut bufs.dup[slo * rf..(slo + nw) * rf];
    let dgate = &mut bufs.dgate[slo * rf..(slo + nw) * rf];
    let dh2 = &mut bufs.dh2[slo * rd..(slo + nw) * rd];
    let dmid = &mut bufs.dmid[slo * rr..(slo + nw) * rr];
    let dq = &mut bufs.dq[slo * rd..(slo + nw) * rd];
    let dk = &mut bufs.dk[slo * rd..(slo + nw) * rd];
    let dv = &mut bufs.dv[slo * rd..(slo + nw) * rd];
    let dhbuf = &mut bufs.dh[slo * rd..(slo + nw) * rd];
    let tmp = &mut bufs.tmp[slo * rd..(slo + nw) * rd];
    let dp = &mut bufs.dp[..];
    let dln = &mut bufs.dln[..];
    let sv_h = &save.h[slo * rd..(slo + nw) * rd];
    let sv_xhat1 = &save.xhat1[slo * rd..(slo + nw) * rd];
    let sv_inv1 = &save.inv1[slo * m..(slo + nw) * m];
    let sv_q = &save.q[slo * rd..(slo + nw) * rd];
    let sv_k = &save.k[slo * rd..(slo + nw) * rd];
    let sv_v = &save.v[slo * rd..(slo + nw) * rd];
    let sv_o = &save.o[slo * rd..(slo + nw) * rd];
    let sv_p = &save.p[slo * rp..(slo + nw) * rp];
    let sv_mid_q = &save.mid_q[slo * rr..(slo + nw) * rr];
    let sv_mid_k = &save.mid_k[slo * rr..(slo + nw) * rr];
    let sv_mid_v = &save.mid_v[slo * rr..(slo + nw) * rr];
    let sv_mid_o = &save.mid_o[slo * rr..(slo + nw) * rr];
    let sv_mid_up = &save.mid_up[slo * rr..(slo + nw) * rr];
    let sv_mid_gate = &save.mid_gate[slo * rr..(slo + nw) * rr];
    let sv_mid_down = &save.mid_down[slo * rr..(slo + nw) * rr];
    let sv_xhat2 = &save.xhat2[slo * rd..(slo + nw) * rd];
    let sv_inv2 = &save.inv2[slo * m..(slo + nw) * m];
    let sv_h2 = &save.h2[slo * rd..(slo + nw) * rd];
    let sv_up = &save.up[slo * rf..(slo + nw) * rf];
    let sv_gate = &save.gate[slo * rf..(slo + nw) * rf];
    let sv_act = &save.act[slo * rf..(slo + nw) * rf];
    let la = |idx: usize, din: usize| {
        &lora[idx][(l * n_full + slo) * din * r..(l * n_full + slo + nw) * din * r]
    };
    let lb = |idx: usize, dout: usize| {
        &lora[idx][(l * n_full + slo) * r * dout..(l * n_full + slo + nw) * r * dout]
    };
    macro_rules! ga {
        ($idx:expr, $din:expr) => {
            &mut grads_a[$idx]
                [(lg * n_full + slo) * $din * r..(lg * n_full + slo + nw) * $din * r]
        };
    }
    macro_rules! gb {
        ($idx:expr, $dout:expr) => {
            &mut grads_b[$idx - B_DOWN]
                [(lg * n_full + slo) * r * $dout..(lg * n_full + slo + nw) * r * $dout]
        };
    }

    // MLP branch: x2 = x1 + down(act).
    dact.fill(0.0);
    proj_bwd(
        dact,
        ga!(A_DOWN, f),
        gb!(B_DOWN, d),
        dmid,
        dxa,
        sv_act,
        sv_mid_down,
        lw.wdown,
        la(A_DOWN, f),
        lb(B_DOWN, d),
        scale,
        n,
        m,
        f,
        d,
        r,
    );
    for j in 0..nm * f {
        dup[j] = dact[j] * silu(sv_gate[j]);
        dgate[j] = dact[j] * sv_up[j] * dsilu(sv_gate[j]);
    }
    dh2.fill(0.0);
    proj_bwd(
        dh2,
        ga!(A_UP, d),
        gb!(B_UP, f),
        dmid,
        dup,
        sv_h2,
        sv_mid_up,
        lw.wup,
        la(A_UP, d),
        lb(B_UP, f),
        scale,
        n,
        m,
        d,
        f,
        r,
    );
    proj_bwd(
        dh2,
        ga!(A_GATE, d),
        gb!(B_GATE, f),
        dmid,
        dgate,
        sv_h2,
        sv_mid_gate,
        lw.wgate,
        la(A_GATE, d),
        lb(B_GATE, f),
        scale,
        n,
        m,
        d,
        f,
        r,
    );
    // dx1 = dx (residual) + LN2 backward of dh2 — staged in dxb.
    dxb.copy_from_slice(dxa);
    ln_bwd_acc(dxb, dh2, lw.ln2, sv_xhat2, sv_inv2, nm, d, dln);

    // Attention branch: x1 = x0 + o_proj(o). `tmp` plays do_.
    tmp.fill(0.0);
    proj_bwd(
        tmp,
        ga!(A_O, d),
        gb!(B_O, d),
        dmid,
        dxb,
        sv_o,
        sv_mid_o,
        lw.wo,
        la(A_O, d),
        lb(B_O, d),
        scale,
        n,
        m,
        d,
        d,
        r,
    );

    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    for i in 0..n {
        for b in 0..bs {
            for hh in 0..nh {
                for t in 0..s {
                    let base_t = ((i * bs + b) * s + t) * d + hh * dh;
                    let dorow = &tmp[base_t..base_t + dh];
                    let prow = &sv_p[(((i * bs + b) * nh + hh) * s + t) * s
                        ..(((i * bs + b) * nh + hh) * s + t) * s + s];
                    // dP and softmax backward.
                    let mut ds = 0.0f32;
                    for u in 0..=t {
                        let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                        let vrow = &sv_v[base_u..base_u + dh];
                        let mut dot = 0.0f32;
                        for c in 0..dh {
                            dot += dorow[c] * vrow[c];
                        }
                        dp[u] = dot;
                        ds += dot * prow[u];
                        // dv += P[t,u] * do
                        let dvrow = &mut dv[base_u..base_u + dh];
                        for c in 0..dh {
                            dvrow[c] += prow[u] * dorow[c];
                        }
                    }
                    for u in 0..=t {
                        let datt = prow[u] * (dp[u] - ds) / sqrt_dh;
                        if datt == 0.0 {
                            continue;
                        }
                        let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                        // dq[t] += datt * k[u]; dk[u] += datt * q[t]
                        let krow = &sv_k[base_u..base_u + dh];
                        let qrow = &sv_q[base_t..base_t + dh];
                        let dqrow = &mut dq[base_t..base_t + dh];
                        for c in 0..dh {
                            dqrow[c] += datt * krow[c];
                        }
                        let dkrow = &mut dk[base_u..base_u + dh];
                        for c in 0..dh {
                            dkrow[c] += datt * qrow[c];
                        }
                    }
                }
            }
        }
    }

    dhbuf.fill(0.0);
    proj_bwd(
        dhbuf,
        ga!(A_Q, d),
        gb!(B_Q, d),
        dmid,
        dq,
        sv_h,
        sv_mid_q,
        lw.wq,
        la(A_Q, d),
        lb(B_Q, d),
        scale,
        n,
        m,
        d,
        d,
        r,
    );
    proj_bwd(
        dhbuf,
        ga!(A_K, d),
        gb!(B_K, d),
        dmid,
        dk,
        sv_h,
        sv_mid_k,
        lw.wk,
        la(A_K, d),
        lb(B_K, d),
        scale,
        n,
        m,
        d,
        d,
        r,
    );
    proj_bwd(
        dhbuf,
        ga!(A_V, d),
        gb!(B_V, d),
        dmid,
        dv,
        sv_h,
        sv_mid_v,
        lw.wv,
        la(A_V, d),
        lb(B_V, d),
        scale,
        n,
        m,
        d,
        d,
        r,
    );
    // dx0 = dx1 (residual) + LN1 backward of dh — back into dxa.
    dxa.copy_from_slice(dxb);
    ln_bwd_acc(dxa, dhbuf, lw.ln1, sv_xhat1, sv_inv1, nm, d, dln);
}

/// Backward pass over the state [`forward`] left in the workspace:
/// returns per-adapter losses and leaves the gradients of every LoRA
/// tensor in `ws.grads` (14 flat buffers in `LORA_ORDER`, shapes matching
/// the inputs). The loss is the *sum* of per-adapter masked mean CE —
/// adapter `i`'s gradient is independent of its pack neighbours (§3.2).
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward(
    spec: &Spec,
    base: &[&HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
    r: usize,
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let m = bs * s;
    let nm = n * m;
    let embed = base[EMBED].as_f32()?;
    let lnf = base[LNF].as_f32()?;
    let Workspace {
        layers,
        xhatf,
        invf,
        logits,
        tmp,
        dlogits,
        dxa,
        dxb,
        dact,
        dup,
        dgate,
        dh2,
        dmid,
        dq,
        dk,
        dv,
        dh: dhbuf,
        dp,
        dln,
        grads,
        ..
    } = ws;

    // Per-adapter losses + dlogits, then head + final LN: dxf staged in
    // dxb, running dx in dxa.
    let per = loss_dlogits(spec, logits, targets, mask, n, bs, dlogits);
    head_bwd(embed, lnf, dlogits, xhatf, invf, dxa, dxb, dln, nm, d, v);

    // LoRA gradient buffers, zeroed for this step. Split at the a_*/b_*
    // boundary so one projection's backward can borrow its `da` and `db`
    // slices simultaneously.
    for g in grads.iter_mut() {
        g.fill(0.0);
    }
    let (grads_a, grads_b) = grads.split_at_mut(B_DOWN);

    let mut bufs = BwdBufs {
        dxa,
        dxb,
        dact,
        dup,
        dgate,
        dh2,
        dmid,
        dq,
        dk,
        dv,
        dh: dhbuf,
        dp,
        dln,
        tmp,
    };
    for l in (0..spec.n_layers).rev() {
        let lw = layer_weights(base, l, d, f)?;
        layer_bwd(
            spec, &lw, lora, scale, l, l, n, 0, n, bs, r, &layers[l], &mut bufs, grads_a,
            grads_b,
        );
    }

    Ok(per)
}

// ---------------------------------------------------------------------------
// AdamW (per-adapter learning rate, padded-rank masking)
// ---------------------------------------------------------------------------

/// One AdamW update over a flat LoRA tensor of shape `(L, n, d2, d3)`,
/// written into the caller-provided `out_*` buffers (recycled through the
/// `Scratch` pool — every element is overwritten). `rank_axis_last` is
/// true for `a_*` tensors (rank on the last axis).
///
/// `t_new` is the **per-adapter** step counter `(n,)` — each adapter's
/// bias correction runs on its own clock, so an adapter admitted into a
/// running pack mid-job starts at its own step 1 and its trajectory is
/// bit-identical to a solo run (DESIGN.md §10).
#[allow(clippy::too_many_arguments)]
pub(crate) fn adamw_update(
    lora: &[f32],
    m: &[f32],
    v: &[f32],
    grad: &[f32],
    lr: &[f32],
    rmask: &[f32],
    n: usize,
    d2: usize,
    d3: usize,
    r: usize,
    rank_axis_last: bool,
    t_new: &[f32],
    out_l: &mut [f32],
    out_m: &mut [f32],
    out_v: &mut [f32],
) {
    let layers = lora.len() / (n * d2 * d3);
    for l in 0..layers {
        for i in 0..n {
            let lri = lr[i];
            let bc1 = 1.0 - ADAM_B1.powf(t_new[i]);
            let bc2 = 1.0 - ADAM_B2.powf(t_new[i]);
            for x2 in 0..d2 {
                for x3 in 0..d3 {
                    let idx = ((l * n + i) * d2 + x2) * d3 + x3;
                    let rank_idx = if rank_axis_last { x3 } else { x2 };
                    let km = rmask[i * r + rank_idx];
                    let g = grad[idx] * km;
                    let m1 = ADAM_B1 * m[idx] + (1.0 - ADAM_B1) * g;
                    let v1 = ADAM_B2 * v[idx] + (1.0 - ADAM_B2) * g * g;
                    let mh = m1 / bc1;
                    let vh = v1 / bc2;
                    let upd = lri * mh / (vh.sqrt() + ADAM_EPS);
                    out_l[idx] = (lora[idx] - upd) * km;
                    out_m[idx] = m1;
                    out_v[idx] = v1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::state::{lora_shape, proj_dims};
    use crate::runtime::{ModelInfo, LORA_ORDER};
    use crate::util::rng::Rng;

    #[test]
    fn layernorm_forward_is_normalized() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let g = [1.0f32; 4];
        let mut h = [0.0f32; 4];
        let mut xhat = [0.0f32; 4];
        let mut inv = [0.0f32; 1];
        ln_fwd(&x, &g, 1, 4, &mut h, &mut xhat, &mut inv);
        let mean: f32 = h.iter().sum::<f32>() / 4.0;
        let var: f32 = h.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    fn tiny_mi() -> ModelInfo {
        ModelInfo {
            name: "fd".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            seq: 6,
            params: 0,
            weights: String::new(),
        }
    }

    fn tiny_spec(mi: &ModelInfo) -> Spec {
        Spec {
            vocab: mi.vocab,
            d_model: mi.d_model,
            n_layers: mi.n_layers,
            n_heads: mi.n_heads,
            d_ff: mi.d_ff,
            seq: mi.seq,
        }
    }

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, std: f64) -> HostTensor {
        let count: usize = shape.iter().product();
        let data = (0..count).map(|_| (rng.normal() * std) as f32).collect();
        HostTensor::f32(shape, data).unwrap()
    }

    fn rand_base(mi: &ModelInfo, rng: &mut Rng) -> Vec<HostTensor> {
        let (v, d, l, f, s) = (mi.vocab, mi.d_model, mi.n_layers, mi.d_ff, mi.seq);
        let ones_ish = |rng: &mut Rng, shape: Vec<usize>| {
            let count: usize = shape.iter().product();
            let data = (0..count).map(|_| 1.0 + (rng.normal() * 0.1) as f32).collect();
            HostTensor::f32(shape, data).unwrap()
        };
        vec![
            rand_tensor(rng, vec![v, d], 0.3),
            rand_tensor(rng, vec![s, d], 0.3),
            ones_ish(rng, vec![l, d]),
            ones_ish(rng, vec![l, d]),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, f], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, f], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, f, d], (f as f64).powf(-0.5)),
            ones_ish(rng, vec![d]),
        ]
    }

    fn rand_lora(mi: &ModelInfo, rng: &mut Rng, n: usize, r: usize) -> Vec<HostTensor> {
        let mut lora_t: Vec<HostTensor> = Vec::new();
        for name in LORA_ORDER {
            let shape = lora_shape(mi, name, n, r);
            // Both A and B nonzero so every backward path is exercised.
            let (_, p) = name.split_once('_').unwrap();
            let din = proj_dims(mi, p).0 as f64;
            lora_t.push(rand_tensor(rng, shape, 0.5 / din.sqrt()));
        }
        lora_t
    }

    /// Finite-difference check of the hand-derived backward pass: perturb
    /// sampled LoRA coordinates and compare (L(θ+ε) − L(θ−ε)) / 2ε against
    /// the analytic gradient. This is the in-tree guarantee that the
    /// reference backend's gradients match `ref.py`/autodiff semantics.
    #[test]
    fn finite_difference_gradient_check() {
        let mi = tiny_mi();
        let spec = tiny_spec(&mi);
        let (n, r, bs) = (2usize, 3usize, 1usize);
        let mut rng = Rng::new(42);

        let base = rand_base(&mi, &mut rng);
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let mut lora_t = rand_lora(&mi, &mut rng, n, r);
        let scale = vec![1.0f32, 0.7];
        let m = bs * spec.seq;
        let tokens: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let mask: Vec<f32> = (0..n * m).map(|_| if rng.f64() < 0.6 { 1.0 } else { 0.0 }).collect();

        let total_loss = |lora_t: &[HostTensor], base_refs: &[&HostTensor]| -> f32 {
            let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
            let mut ws = Workspace::new();
            forward(&spec, base_refs, &lora, &scale, &tokens, n, bs, r, &mut ws).unwrap();
            let (loss, _) = loss_and_acc(&spec, &ws.logits, &targets, &mask, n, bs);
            loss.iter().sum()
        };

        let mut ws = Workspace::new();
        {
            let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
            forward(&spec, &base_refs, &lora, &scale, &tokens, n, bs, r, &mut ws).unwrap();
            backward(&spec, &base_refs, &lora, &scale, &targets, &mask, n, bs, r, &mut ws)
                .unwrap();
        }
        let grads = std::mem::take(&mut ws.grads);

        let gmax = grads
            .iter()
            .flat_map(|g| g.iter())
            .fold(0.0f32, |acc, &g| acc.max(g.abs()));
        assert!(gmax > 1e-4, "gradients unexpectedly all ~zero (gmax {gmax})");

        let eps = 1e-2f32;
        let mut checked = 0usize;
        let mut check_rng = Rng::new(7);
        for _ in 0..400 {
            let k = check_rng.usize_below(14);
            let idx = check_rng.usize_below(lora_t[k].len());
            let g = grads[k][idx];
            if g.abs() < 0.03 * gmax {
                continue; // too small for f32 finite differences
            }
            let orig = lora_t[k].as_f32().unwrap()[idx];
            lora_t[k].as_f32_mut().unwrap()[idx] = orig + eps;
            let lp = total_loss(&lora_t, &base_refs);
            lora_t[k].as_f32_mut().unwrap()[idx] = orig - eps;
            let lm = total_loss(&lora_t, &base_refs);
            lora_t[k].as_f32_mut().unwrap()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let rel = (fd - g).abs() / g.abs().max(fd.abs()).max(1e-6);
            assert!(
                rel < 0.25,
                "grad mismatch at {}[{idx}]: analytic {g:.5}, fd {fd:.5} (rel {rel:.3})",
                LORA_ORDER[k]
            );
            checked += 1;
            if checked >= 24 {
                break;
            }
        }
        assert!(checked >= 6, "only {checked} coordinates were large enough to check");
    }

    /// The logits-only eval forward reproduces the full forward's logits
    /// bit-for-bit (same op order, shared workspace arena, no saved
    /// state), and both are bitwise invariant to the worker count.
    #[test]
    fn forward_logits_matches_full_forward_at_any_thread_count() {
        let mi = tiny_mi();
        let spec = tiny_spec(&mi);
        let (n, r, bs) = (2usize, 3usize, 2usize);
        let mut rng = Rng::new(77);
        let base = rand_base(&mi, &mut rng);
        let base_refs: Vec<&HostTensor> = base.iter().collect();
        let lora_t = rand_lora(&mi, &mut rng, n, r);
        let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
        let scale = vec![0.9f32, 1.3];
        let m = bs * spec.seq;
        let tokens: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();

        let mut ws = Workspace::new();
        forward(&spec, &base_refs, &lora, &scale, &tokens, n, bs, r, &mut ws).unwrap();
        let full = ws.logits.clone();

        let mut fresh = Workspace::new();
        for threads in [1usize, 4] {
            gemm::set_threads(threads);
            // A fresh arena and a reused train-sized arena must agree.
            for ws in [&mut fresh, &mut ws] {
                forward_logits(&spec, &base_refs, &lora, &scale, &tokens, n, bs, r, ws)
                    .unwrap();
                assert_eq!(full.len(), ws.logits.len());
                for (i, (a, b)) in full.iter().zip(&ws.logits).enumerate() {
                    assert_eq!(a, b, "logit {i} diverged (threads {threads}): {a} vs {b}");
                }
            }
        }
        gemm::set_threads(1);
    }

    #[test]
    fn adamw_first_step_is_signed_descent_and_masks_padding() {
        // With zero moments and t=0 -> t_new=1, AdamW's first update is
        // lr * g/(|g| + eps') ≈ lr * sign(g).
        let lora = vec![1.0f32; 8]; // (L=1, n=1, d2=2, d3=4), rank axis last
        let m = vec![0.0f32; 8];
        let v = vec![0.0f32; 8];
        let grad = vec![0.5f32, -0.5, 0.5, -0.5, 0.5, -0.5, 0.5, -0.5];
        let rmask = vec![1.0f32, 1.0, 0.0, 0.0]; // true rank 2 of padded 4
        let mut nl = vec![9.0f32; 8]; // stale contents must be overwritten
        let mut nm = vec![9.0f32; 8];
        let mut nv = vec![9.0f32; 8];
        adamw_update(
            &lora, &m, &v, &grad, &[0.1], &rmask, 1, 2, 4, 4, true, &[1.0], &mut nl, &mut nm,
            &mut nv,
        );
        // Unmasked columns move by ~lr against the gradient sign.
        assert!((nl[0] - 0.9).abs() < 1e-3, "{}", nl[0]);
        assert!((nl[1] - 1.1).abs() < 1e-3, "{}", nl[1]);
        // Padded rank columns are zeroed outright.
        assert_eq!(nl[2], 0.0);
        assert_eq!(nl[3], 0.0);
        assert_eq!(nm[2], 0.0);
        assert_eq!(nv[3], 0.0);
    }
}
