//! Pure-Rust TinyLM: the packed multi-adapter LoRA forward/backward and
//! the fused train/eval steps the reference backend interprets.
//!
//! This is the Rust twin of `python/compile/model.py` — same architecture
//! (pre-LN attention + gated-SiLU MLP, tied embedding head), same packed
//! layout (`n` adapters, ranks zero-padded to the bucket rank, batches
//! padded with a zero loss mask), same AdamW semantics, same argument
//! order (`aot.py::train_signature`). The backward pass was derived by
//! hand and cross-checked against `jax.value_and_grad` of the Python
//! model; the in-file finite-difference test re-verifies it on every
//! `cargo test`.
//!
//! Everything is f32 over flat row-major `Vec<f32>` buffers; shapes are
//! small (TinyLM scale), so plain loops are fast enough and keep the
//! interpreter dependency-free.

use anyhow::{anyhow, bail, Result};

use crate::runtime::tensor::HostTensor;
use crate::runtime::LORA_ORDER;

/// Indices of the `LORA_ORDER` tensors (sorted `{a,b}_{proj}` names).
const A_DOWN: usize = 0;
const A_GATE: usize = 1;
const A_K: usize = 2;
const A_O: usize = 3;
const A_Q: usize = 4;
const A_UP: usize = 5;
const A_V: usize = 6;
const B_DOWN: usize = 7;
const B_GATE: usize = 8;
const B_K: usize = 9;
const B_O: usize = 10;
const B_Q: usize = 11;
const B_UP: usize = 12;
const B_V: usize = 13;

/// Indices of the `BASE_ORDER` tensors.
const EMBED: usize = 0;
const POS: usize = 1;
const LN1: usize = 2;
const LN2: usize = 3;
const WQ: usize = 4;
const WK: usize = 5;
const WV: usize = 6;
const WO: usize = 7;
const WUP: usize = 8;
const WGATE: usize = 9;
const WDOWN: usize = 10;
const LNF: usize = 11;

pub(crate) const ADAM_B1: f32 = 0.9;
pub(crate) const ADAM_B2: f32 = 0.999;
pub(crate) const ADAM_EPS: f32 = 1e-8;
const LN_EPS: f32 = 1e-5;

/// TinyLM geometry (mirrors `model.py::ModelSpec`).
#[derive(Debug, Clone, Copy)]
pub struct Spec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
}

impl Spec {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn check(&self) -> Result<()> {
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("spec: d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Flat-buffer linear algebra
// ---------------------------------------------------------------------------

/// `out (m,n) += alpha * a (m,k) @ b (k,n)`.
pub(crate) fn mm_acc(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
) {
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in ar.iter().enumerate() {
            let f = alpha * av;
            if f == 0.0 {
                continue;
            }
            let br = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += f * bv;
            }
        }
    }
}

/// `out (m,n) += alpha * a (m,k) @ b^T` with `b` stored `(n,k)`.
pub(crate) fn mm_nt_acc(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
) {
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for (j, o) in or.iter_mut().enumerate() {
            let br = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (av, bv) in ar.iter().zip(br) {
                s += av * bv;
            }
            *o += alpha * s;
        }
    }
}

/// `out (m,n) += alpha * a^T @ b` with `a` stored `(k,m)`, `b` `(k,n)`.
pub(crate) fn mm_tn_acc(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    alpha: f32,
) {
    for kk in 0..k {
        let ar = &a[kk * m..(kk + 1) * m];
        let br = &b[kk * n..(kk + 1) * n];
        for (i, &av) in ar.iter().enumerate() {
            let f = alpha * av;
            if f == 0.0 {
                continue;
            }
            let or = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in or.iter_mut().zip(br) {
                *o += f * bv;
            }
        }
    }
}

/// LayerNorm forward over `rows` rows of width `d`: `h = xhat * g`,
/// saving `xhat` and `inv = 1/sqrt(var + eps)` for the backward pass.
fn ln_fwd(
    x: &[f32],
    g: &[f32],
    rows: usize,
    d: usize,
    h: &mut [f32],
    xhat: &mut [f32],
    inv: &mut [f32],
) {
    let df = d as f32;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= df;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= df;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[r] = iv;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let hr = &mut h[r * d..(r + 1) * d];
        for c in 0..d {
            let v = (xr[c] - mu) * iv;
            xh[c] = v;
            hr[c] = v * g[c];
        }
    }
}

/// LayerNorm backward: `dx += inv * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))`
/// with `dxhat = dy * g` (the gain `g` is frozen — no `dg`).
fn ln_bwd_acc(
    dx: &mut [f32],
    dy: &[f32],
    g: &[f32],
    xhat: &[f32],
    inv: &[f32],
    rows: usize,
    d: usize,
) {
    let df = d as f32;
    let mut dxh = vec![0.0f32; d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for c in 0..d {
            let v = dyr[c] * g[c];
            dxh[c] = v;
            m1 += v;
            m2 += v * xh[c];
        }
        m1 /= df;
        m2 /= df;
        let iv = inv[r];
        let dxr = &mut dx[r * d..(r + 1) * d];
        for c in 0..d {
            dxr[c] += iv * (dxh[c] - m1 - xh[c] * m2);
        }
    }
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn silu(z: f32) -> f32 {
    z * sigmoid(z)
}

fn dsilu(z: f32) -> f32 {
    let s = sigmoid(z);
    s * (1.0 + z * (1.0 - s))
}

// ---------------------------------------------------------------------------
// Packed-LoRA projection
// ---------------------------------------------------------------------------

/// Packed projection forward: per adapter `i`,
/// `out_i = input_i @ w + scale_i * (input_i @ a_i) @ b_i`, with the rank-r
/// intermediate saved in `mid` for the backward pass. `a`/`b` are the
/// layer-`l` slices `(n, din, r)` / `(n, r, dout)`.
#[allow(clippy::too_many_arguments)]
fn proj_fwd(
    out: &mut [f32],
    mid: &mut [f32],
    input: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
) {
    for i in 0..n {
        let xi = &input[i * m * din..(i + 1) * m * din];
        let oi = &mut out[i * m * dout..(i + 1) * m * dout];
        oi.fill(0.0);
        mm_acc(oi, xi, w, m, din, dout, 1.0);
        let mi = &mut mid[i * m * r..(i + 1) * m * r];
        mi.fill(0.0);
        mm_acc(mi, xi, &a[i * din * r..(i + 1) * din * r], m, din, r, 1.0);
        mm_acc(oi, mi, &b[i * r * dout..(i + 1) * r * dout], m, r, dout, scale[i]);
    }
}

/// Packed projection backward: accumulates `dinput`, `da` and `db` (the
/// layer-`l` gradient slices) from the upstream `dy`. Matches
/// `python/compile/kernels/ref.py::ref_grads` composed with the base GEMM.
#[allow(clippy::too_many_arguments)]
fn proj_bwd(
    dinput: &mut [f32],
    da: &mut [f32],
    db: &mut [f32],
    dy: &[f32],
    input: &[f32],
    mid: &[f32],
    w: &[f32],
    a: &[f32],
    b: &[f32],
    scale: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    r: usize,
    dmid: &mut Vec<f32>,
) {
    dmid.clear();
    dmid.resize(m * r, 0.0);
    for i in 0..n {
        let dyi = &dy[i * m * dout..(i + 1) * m * dout];
        let xi = &input[i * m * din..(i + 1) * m * din];
        let midi = &mid[i * m * r..(i + 1) * m * r];
        let ai = &a[i * din * r..(i + 1) * din * r];
        let bi = &b[i * r * dout..(i + 1) * r * dout];
        // dh_mid = scale * dy @ b^T  (case 2 of ref.py)
        dmid.fill(0.0);
        mm_nt_acc(dmid, dyi, bi, m, dout, r, scale[i]);
        // da += input^T @ dh_mid  (case 3)
        mm_tn_acc(&mut da[i * din * r..(i + 1) * din * r], xi, dmid, m, din, r, 1.0);
        // db += scale * mid^T @ dy  (case 1)
        mm_tn_acc(&mut db[i * r * dout..(i + 1) * r * dout], midi, dyi, m, r, dout, scale[i]);
        let di = &mut dinput[i * m * din..(i + 1) * m * din];
        // dinput += dy @ w^T + dh_mid @ a^T  (base GEMM + case 4)
        mm_nt_acc(di, dyi, w, m, dout, din, 1.0);
        mm_nt_acc(di, dmid, ai, m, r, din, 1.0);
    }
}

// ---------------------------------------------------------------------------
// Forward pass
// ---------------------------------------------------------------------------

/// Saved per-layer activations for the backward pass. (The residual-stream
/// values themselves are not needed: residual adds backprop as identity.)
struct LayerSave {
    xhat1: Vec<f32>,
    inv1: Vec<f32>,
    h: Vec<f32>,
    mid_q: Vec<f32>,
    mid_k: Vec<f32>,
    mid_v: Vec<f32>,
    mid_o: Vec<f32>,
    mid_up: Vec<f32>,
    mid_gate: Vec<f32>,
    mid_down: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    p: Vec<f32>,
    o: Vec<f32>,
    xhat2: Vec<f32>,
    inv2: Vec<f32>,
    h2: Vec<f32>,
    up: Vec<f32>,
    gate: Vec<f32>,
    act: Vec<f32>,
}

/// Full forward-pass state (activations + logits).
pub(crate) struct Forward {
    layers: Vec<LayerSave>,
    xhatf: Vec<f32>,
    invf: Vec<f32>,
    pub logits: Vec<f32>,
}

/// Packed forward. `base` in `BASE_ORDER`, `lora` 14 flat slices in
/// `LORA_ORDER` (shapes `(L, n, din, r)` / `(L, n, r, dout)`), `tokens`
/// `(n, bs, s)`. Produces logits `(n, bs, s, vocab)` plus everything the
/// backward pass needs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward(
    spec: &Spec,
    base: &[HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    tokens: &[i32],
    n: usize,
    bs: usize,
    r: usize,
) -> Result<Forward> {
    spec.check()?;
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s; // rows per adapter
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();

    let embed = base[EMBED].as_f32()?;
    let pos = base[POS].as_f32()?;

    // Embedding + positional encoding.
    let mut x = vec![0.0f32; nm * d];
    for i in 0..n {
        for b in 0..bs {
            for t in 0..s {
                let tok = tokens[(i * bs + b) * s + t];
                if tok < 0 || tok as usize >= v {
                    bail!("token {tok} out of vocab {v}");
                }
                let erow = &embed[tok as usize * d..(tok as usize + 1) * d];
                let prow = &pos[t * d..(t + 1) * d];
                let xrow = &mut x[((i * bs + b) * s + t) * d..((i * bs + b) * s + t + 1) * d];
                for c in 0..d {
                    xrow[c] = erow[c] + prow[c];
                }
            }
        }
    }

    let mut layers = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let ln1 = &base[LN1].as_f32()?[l * d..(l + 1) * d];
        let ln2 = &base[LN2].as_f32()?[l * d..(l + 1) * d];
        let wq = &base[WQ].as_f32()?[l * d * d..(l + 1) * d * d];
        let wk = &base[WK].as_f32()?[l * d * d..(l + 1) * d * d];
        let wv = &base[WV].as_f32()?[l * d * d..(l + 1) * d * d];
        let wo = &base[WO].as_f32()?[l * d * d..(l + 1) * d * d];
        let wup = &base[WUP].as_f32()?[l * d * f..(l + 1) * d * f];
        let wgate = &base[WGATE].as_f32()?[l * d * f..(l + 1) * d * f];
        let wdown = &base[WDOWN].as_f32()?[l * f * d..(l + 1) * f * d];
        // Layer-l LoRA slices: (n, din, r) / (n, r, dout).
        let la = |idx: usize, din: usize| &lora[idx][l * n * din * r..(l + 1) * n * din * r];
        let lb = |idx: usize, dout: usize| &lora[idx][l * n * r * dout..(l + 1) * n * r * dout];

        let x0 = x.clone();
        let mut h = vec![0.0f32; nm * d];
        let mut xhat1 = vec![0.0f32; nm * d];
        let mut inv1 = vec![0.0f32; nm];
        ln_fwd(&x0, ln1, nm, d, &mut h, &mut xhat1, &mut inv1);

        let mut q = vec![0.0f32; nm * d];
        let mut k = vec![0.0f32; nm * d];
        let mut vv = vec![0.0f32; nm * d];
        let mut mid_q = vec![0.0f32; nm * r];
        let mut mid_k = vec![0.0f32; nm * r];
        let mut mid_v = vec![0.0f32; nm * r];
        proj_fwd(&mut q, &mut mid_q, &h, wq, la(A_Q, d), lb(B_Q, d), scale, n, m, d, d, r);
        proj_fwd(&mut k, &mut mid_k, &h, wk, la(A_K, d), lb(B_K, d), scale, n, m, d, d, r);
        proj_fwd(&mut vv, &mut mid_v, &h, wv, la(A_V, d), lb(B_V, d), scale, n, m, d, d, r);

        // Causal attention per (adapter, batch, head).
        let mut p = vec![0.0f32; n * bs * nh * s * s];
        let mut o = vec![0.0f32; nm * d];
        let mut logit_buf = vec![0.0f32; s];
        for i in 0..n {
            for b in 0..bs {
                for hh in 0..nh {
                    for t in 0..s {
                        let qoff = ((i * bs + b) * s + t) * d + hh * dh;
                        let qrow = &q[qoff..qoff + dh];
                        let mut mx = f32::NEG_INFINITY;
                        for (u, lv) in logit_buf.iter_mut().enumerate().take(t + 1) {
                            let krow = &k[((i * bs + b) * s + u) * d + hh * dh
                                ..((i * bs + b) * s + u) * d + hh * dh + dh];
                            let mut dot = 0.0f32;
                            for c in 0..dh {
                                dot += qrow[c] * krow[c];
                            }
                            let val = dot / sqrt_dh;
                            *lv = val;
                            if val > mx {
                                mx = val;
                            }
                        }
                        let mut sum = 0.0f32;
                        for lv in logit_buf.iter_mut().take(t + 1) {
                            *lv = (*lv - mx).exp();
                            sum += *lv;
                        }
                        let prow = &mut p[(((i * bs + b) * nh + hh) * s + t) * s
                            ..(((i * bs + b) * nh + hh) * s + t) * s + s];
                        for (u, &e) in logit_buf.iter().enumerate().take(t + 1) {
                            prow[u] = e / sum;
                        }
                        let orow = &mut o[((i * bs + b) * s + t) * d + hh * dh
                            ..((i * bs + b) * s + t) * d + hh * dh + dh];
                        for (u, &w) in prow.iter().enumerate().take(t + 1) {
                            if w == 0.0 {
                                continue;
                            }
                            let vrow = &vv[((i * bs + b) * s + u) * d + hh * dh
                                ..((i * bs + b) * s + u) * d + hh * dh + dh];
                            for c in 0..dh {
                                orow[c] += w * vrow[c];
                            }
                        }
                    }
                }
            }
        }

        // Attention output projection + residual.
        let mut ao = vec![0.0f32; nm * d];
        let mut mid_o = vec![0.0f32; nm * r];
        proj_fwd(&mut ao, &mut mid_o, &o, wo, la(A_O, d), lb(B_O, d), scale, n, m, d, d, r);
        let mut x1 = x0.clone();
        for (xv, av) in x1.iter_mut().zip(&ao) {
            *xv += av;
        }

        // MLP: pre-LN, gated SiLU, down projection + residual.
        let mut h2 = vec![0.0f32; nm * d];
        let mut xhat2 = vec![0.0f32; nm * d];
        let mut inv2 = vec![0.0f32; nm];
        ln_fwd(&x1, ln2, nm, d, &mut h2, &mut xhat2, &mut inv2);

        let mut up = vec![0.0f32; nm * f];
        let mut gate = vec![0.0f32; nm * f];
        let mut mid_up = vec![0.0f32; nm * r];
        let mut mid_gate = vec![0.0f32; nm * r];
        proj_fwd(&mut up, &mut mid_up, &h2, wup, la(A_UP, d), lb(B_UP, f), scale, n, m, d, f, r);
        let (ga, gb) = (la(A_GATE, d), lb(B_GATE, f));
        proj_fwd(&mut gate, &mut mid_gate, &h2, wgate, ga, gb, scale, n, m, d, f, r);
        let mut act = vec![0.0f32; nm * f];
        for j in 0..nm * f {
            act[j] = silu(gate[j]) * up[j];
        }

        let mut dn = vec![0.0f32; nm * d];
        let mut mid_down = vec![0.0f32; nm * r];
        let (da_, db_) = (la(A_DOWN, f), lb(B_DOWN, d));
        proj_fwd(&mut dn, &mut mid_down, &act, wdown, da_, db_, scale, n, m, f, d, r);
        let mut x2 = x1.clone();
        for (xv, dv) in x2.iter_mut().zip(&dn) {
            *xv += dv;
        }

        x = x2;
        layers.push(LayerSave {
            xhat1,
            inv1,
            h,
            mid_q,
            mid_k,
            mid_v,
            mid_o,
            mid_up,
            mid_gate,
            mid_down,
            q,
            k,
            v: vv,
            p,
            o,
            xhat2,
            inv2,
            h2,
            up,
            gate,
            act,
        });
    }

    // Final LN + tied-embedding head.
    let lnf = base[LNF].as_f32()?;
    let mut xf = vec![0.0f32; nm * d];
    let mut xhatf = vec![0.0f32; nm * d];
    let mut invf = vec![0.0f32; nm];
    ln_fwd(&x, lnf, nm, d, &mut xf, &mut xhatf, &mut invf);
    let mut logits = vec![0.0f32; nm * v];
    // logits = xf @ embed^T, embed stored (v, d).
    mm_nt_acc(&mut logits, &xf, embed, nm, d, v, 1.0);

    Ok(Forward { layers, xhatf, invf, logits })
}

/// Logits-only packed forward for the eval path: the same math as
/// [`forward`], with no backward state saved — activations live in a small
/// set of buffers reused across layers instead of one `LayerSave` per layer
/// (the full forward keeps ~O(L·n·bs·seq·(d+f)) floats it never reads on
/// eval). Accumulation order matches [`forward`] exactly, so eval loss is
/// bit-identical to a zero-lr train step's loss.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_logits(
    spec: &Spec,
    base: &[HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    tokens: &[i32],
    n: usize,
    bs: usize,
    r: usize,
) -> Result<Vec<f32>> {
    spec.check()?;
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s;
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();

    let embed = base[EMBED].as_f32()?;
    let pos = base[POS].as_f32()?;

    // Embedding + positional encoding.
    let mut x = vec![0.0f32; nm * d];
    for i in 0..n {
        for b in 0..bs {
            for t in 0..s {
                let tok = tokens[(i * bs + b) * s + t];
                if tok < 0 || tok as usize >= v {
                    bail!("token {tok} out of vocab {v}");
                }
                let erow = &embed[tok as usize * d..(tok as usize + 1) * d];
                let prow = &pos[t * d..(t + 1) * d];
                let off = ((i * bs + b) * s + t) * d;
                let xrow = &mut x[off..off + d];
                for c in 0..d {
                    xrow[c] = erow[c] + prow[c];
                }
            }
        }
    }

    // Reused scratch (no per-layer saves).
    let mut h = vec![0.0f32; nm * d];
    let mut xhat = vec![0.0f32; nm * d];
    let mut inv = vec![0.0f32; nm];
    let mut mid = vec![0.0f32; nm * r];
    let mut q = vec![0.0f32; nm * d];
    let mut k = vec![0.0f32; nm * d];
    let mut vv = vec![0.0f32; nm * d];
    let mut o = vec![0.0f32; nm * d];
    let mut ao = vec![0.0f32; nm * d];
    let mut up = vec![0.0f32; nm * f];
    let mut gate = vec![0.0f32; nm * f];
    let mut act = vec![0.0f32; nm * f];
    let mut logit_buf = vec![0.0f32; s];
    let mut prow = vec![0.0f32; s];

    for l in 0..spec.n_layers {
        let ln1 = &base[LN1].as_f32()?[l * d..(l + 1) * d];
        let ln2 = &base[LN2].as_f32()?[l * d..(l + 1) * d];
        let wq = &base[WQ].as_f32()?[l * d * d..(l + 1) * d * d];
        let wk = &base[WK].as_f32()?[l * d * d..(l + 1) * d * d];
        let wv = &base[WV].as_f32()?[l * d * d..(l + 1) * d * d];
        let wo = &base[WO].as_f32()?[l * d * d..(l + 1) * d * d];
        let wup = &base[WUP].as_f32()?[l * d * f..(l + 1) * d * f];
        let wgate = &base[WGATE].as_f32()?[l * d * f..(l + 1) * d * f];
        let wdown = &base[WDOWN].as_f32()?[l * f * d..(l + 1) * f * d];
        let la = |idx: usize, din: usize| &lora[idx][l * n * din * r..(l + 1) * n * din * r];
        let lb = |idx: usize, dout: usize| &lora[idx][l * n * r * dout..(l + 1) * n * r * dout];

        ln_fwd(&x, ln1, nm, d, &mut h, &mut xhat, &mut inv);
        proj_fwd(&mut q, &mut mid, &h, wq, la(A_Q, d), lb(B_Q, d), scale, n, m, d, d, r);
        proj_fwd(&mut k, &mut mid, &h, wk, la(A_K, d), lb(B_K, d), scale, n, m, d, d, r);
        proj_fwd(&mut vv, &mut mid, &h, wv, la(A_V, d), lb(B_V, d), scale, n, m, d, d, r);

        // Causal attention per (adapter, batch, head).
        o.fill(0.0);
        for i in 0..n {
            for b in 0..bs {
                for hh in 0..nh {
                    for t in 0..s {
                        let base_t = ((i * bs + b) * s + t) * d + hh * dh;
                        let qrow = &q[base_t..base_t + dh];
                        let mut mx = f32::NEG_INFINITY;
                        for (u, lv) in logit_buf.iter_mut().enumerate().take(t + 1) {
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            let krow = &k[base_u..base_u + dh];
                            let mut dot = 0.0f32;
                            for c in 0..dh {
                                dot += qrow[c] * krow[c];
                            }
                            let val = dot / sqrt_dh;
                            *lv = val;
                            if val > mx {
                                mx = val;
                            }
                        }
                        let mut sum = 0.0f32;
                        for lv in logit_buf.iter_mut().take(t + 1) {
                            *lv = (*lv - mx).exp();
                            sum += *lv;
                        }
                        for (u, &e) in logit_buf.iter().enumerate().take(t + 1) {
                            prow[u] = e / sum;
                        }
                        let orow = &mut o[base_t..base_t + dh];
                        for (u, &w) in prow.iter().enumerate().take(t + 1) {
                            if w == 0.0 {
                                continue;
                            }
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            let vrow = &vv[base_u..base_u + dh];
                            for c in 0..dh {
                                orow[c] += w * vrow[c];
                            }
                        }
                    }
                }
            }
        }

        // Attention output projection + residual.
        proj_fwd(&mut ao, &mut mid, &o, wo, la(A_O, d), lb(B_O, d), scale, n, m, d, d, r);
        for (xv, av) in x.iter_mut().zip(&ao) {
            *xv += av;
        }

        // MLP: pre-LN, gated SiLU, down projection + residual.
        ln_fwd(&x, ln2, nm, d, &mut h, &mut xhat, &mut inv);
        proj_fwd(&mut up, &mut mid, &h, wup, la(A_UP, d), lb(B_UP, f), scale, n, m, d, f, r);
        let (ga, gb) = (la(A_GATE, d), lb(B_GATE, f));
        proj_fwd(&mut gate, &mut mid, &h, wgate, ga, gb, scale, n, m, d, f, r);
        for j in 0..nm * f {
            act[j] = silu(gate[j]) * up[j];
        }
        let (dna, dnb) = (la(A_DOWN, f), lb(B_DOWN, d));
        proj_fwd(&mut ao, &mut mid, &act, wdown, dna, dnb, scale, n, m, f, d, r);
        for (xv, dv) in x.iter_mut().zip(&ao) {
            *xv += dv;
        }
    }

    // Final LN + tied-embedding head.
    let lnf = base[LNF].as_f32()?;
    ln_fwd(&x, lnf, nm, d, &mut h, &mut xhat, &mut inv);
    let mut logits = vec![0.0f32; nm * v];
    mm_nt_acc(&mut logits, &h, embed, nm, d, v, 1.0);
    Ok(logits)
}

// ---------------------------------------------------------------------------
// Loss, metrics, backward
// ---------------------------------------------------------------------------

/// Per-adapter masked mean CE loss and (token accuracy on masked positions).
pub(crate) fn loss_and_acc(
    spec: &Spec,
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
) -> (Vec<f32>, Vec<f32>) {
    let v = spec.vocab;
    let m = bs * spec.seq;
    let mut loss = vec![0.0f32; n];
    let mut acc = vec![0.0f32; n];
    for i in 0..n {
        let mut denom = 0.0f32;
        for row in 0..m {
            denom += mask[i * m + row];
        }
        let denom = denom.max(1.0);
        for row in 0..m {
            let mk = mask[i * m + row];
            if mk == 0.0 {
                continue;
            }
            let lrow = &logits[(i * m + row) * v..(i * m + row + 1) * v];
            let tg = targets[i * m + row].clamp(0, v as i32 - 1) as usize;
            let mut mx = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, &lv) in lrow.iter().enumerate() {
                if lv > mx {
                    mx = lv;
                    arg = j;
                }
            }
            let mut se = 0.0f32;
            for &lv in lrow {
                se += (lv - mx).exp();
            }
            let lse = se.ln();
            loss[i] += -(lrow[tg] - mx - lse) * mk;
            if arg == tg {
                acc[i] += mk;
            }
        }
        loss[i] /= denom;
        acc[i] /= denom;
    }
    (loss, acc)
}

/// Backward pass: per-adapter losses plus gradients of every LoRA tensor
/// (14 flat buffers in `LORA_ORDER`, shapes matching the inputs). The loss
/// is the *sum* of per-adapter masked mean CE — adapter `i`'s gradient is
/// independent of its pack neighbours (paper §3.2).
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward(
    spec: &Spec,
    fwd: &Forward,
    base: &[HostTensor],
    lora: &[&[f32]; 14],
    scale: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    bs: usize,
    r: usize,
) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
    let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
    let (nh, dh) = (spec.n_heads, spec.d_head());
    let m = bs * s;
    let nm = n * m;
    let sqrt_dh = (dh as f32).sqrt();
    let embed = base[EMBED].as_f32()?;

    // Per-adapter losses + dlogits.
    let mut per = vec![0.0f32; n];
    let mut dlogits = vec![0.0f32; nm * v];
    for i in 0..n {
        let mut denom = 0.0f32;
        for row in 0..m {
            denom += mask[i * m + row];
        }
        let denom = denom.max(1.0);
        for row in 0..m {
            let mk = mask[i * m + row];
            let lrow = &fwd.logits[(i * m + row) * v..(i * m + row + 1) * v];
            let tg = targets[i * m + row].clamp(0, v as i32 - 1) as usize;
            if mk == 0.0 {
                continue;
            }
            let mut mx = f32::NEG_INFINITY;
            for &lv in lrow {
                if lv > mx {
                    mx = lv;
                }
            }
            let mut se = 0.0f32;
            for &lv in lrow {
                se += (lv - mx).exp();
            }
            let lse = se.ln();
            per[i] += -(lrow[tg] - mx - lse) * mk;
            let w = mk / denom;
            let drow = &mut dlogits[(i * m + row) * v..(i * m + row + 1) * v];
            for j in 0..v {
                drow[j] = (lrow[j] - mx - lse).exp() * w;
            }
            drow[tg] -= w;
        }
        per[i] /= denom;
    }

    // Head + final LN.
    let mut dxf = vec![0.0f32; nm * d];
    mm_acc(&mut dxf, &dlogits, embed, nm, v, d, 1.0);
    let lnf = base[LNF].as_f32()?;
    let mut dx = vec![0.0f32; nm * d];
    ln_bwd_acc(&mut dx, &dxf, lnf, &fwd.xhatf, &fwd.invf, nm, d);

    // LoRA gradient buffers, shapes matching the inputs. Split at the
    // a_*/b_* boundary so one projection's backward can borrow its `da`
    // and `db` slices simultaneously.
    let mut grads: Vec<Vec<f32>> =
        (0..LORA_ORDER.len()).map(|i| vec![0.0f32; lora[i].len()]).collect();
    let (grads_a, grads_b) = grads.split_at_mut(B_DOWN);
    let mut dmid = Vec::new();

    for l in (0..spec.n_layers).rev() {
        let save = &fwd.layers[l];
        let ln1 = &base[LN1].as_f32()?[l * d..(l + 1) * d];
        let ln2 = &base[LN2].as_f32()?[l * d..(l + 1) * d];
        let wq = &base[WQ].as_f32()?[l * d * d..(l + 1) * d * d];
        let wk = &base[WK].as_f32()?[l * d * d..(l + 1) * d * d];
        let wv = &base[WV].as_f32()?[l * d * d..(l + 1) * d * d];
        let wo = &base[WO].as_f32()?[l * d * d..(l + 1) * d * d];
        let wup = &base[WUP].as_f32()?[l * d * f..(l + 1) * d * f];
        let wgate = &base[WGATE].as_f32()?[l * d * f..(l + 1) * d * f];
        let wdown = &base[WDOWN].as_f32()?[l * f * d..(l + 1) * f * d];
        let la = |idx: usize, din: usize| &lora[idx][l * n * din * r..(l + 1) * n * din * r];
        let lb = |idx: usize, dout: usize| &lora[idx][l * n * r * dout..(l + 1) * n * r * dout];
        macro_rules! ga {
            ($idx:expr, $din:expr) => {
                &mut grads_a[$idx][l * n * $din * r..(l + 1) * n * $din * r]
            };
        }
        macro_rules! gb {
            ($idx:expr, $dout:expr) => {
                &mut grads_b[$idx - B_DOWN][l * n * r * $dout..(l + 1) * n * r * $dout]
            };
        }

        // MLP branch: x2 = x1 + down(act).
        let mut dact = vec![0.0f32; nm * f];
        proj_bwd(
            &mut dact,
            ga!(A_DOWN, f),
            gb!(B_DOWN, d),
            &dx,
            &save.act,
            &save.mid_down,
            wdown,
            la(A_DOWN, f),
            lb(B_DOWN, d),
            scale,
            n,
            m,
            f,
            d,
            r,
            &mut dmid,
        );
        let mut dup = vec![0.0f32; nm * f];
        let mut dgate = vec![0.0f32; nm * f];
        for j in 0..nm * f {
            dup[j] = dact[j] * silu(save.gate[j]);
            dgate[j] = dact[j] * save.up[j] * dsilu(save.gate[j]);
        }
        let mut dh2 = vec![0.0f32; nm * d];
        proj_bwd(
            &mut dh2,
            ga!(A_UP, d),
            gb!(B_UP, f),
            &dup,
            &save.h2,
            &save.mid_up,
            wup,
            la(A_UP, d),
            lb(B_UP, f),
            scale,
            n,
            m,
            d,
            f,
            r,
            &mut dmid,
        );
        proj_bwd(
            &mut dh2,
            ga!(A_GATE, d),
            gb!(B_GATE, f),
            &dgate,
            &save.h2,
            &save.mid_gate,
            wgate,
            la(A_GATE, d),
            lb(B_GATE, f),
            scale,
            n,
            m,
            d,
            f,
            r,
            &mut dmid,
        );
        // dx1 = dx (residual) + LN2 backward of dh2.
        let mut dx1 = dx.clone();
        ln_bwd_acc(&mut dx1, &dh2, ln2, &save.xhat2, &save.inv2, nm, d);

        // Attention branch: x1 = x0 + o_proj(o).
        let mut do_ = vec![0.0f32; nm * d];
        proj_bwd(
            &mut do_,
            ga!(A_O, d),
            gb!(B_O, d),
            &dx1,
            &save.o,
            &save.mid_o,
            wo,
            la(A_O, d),
            lb(B_O, d),
            scale,
            n,
            m,
            d,
            d,
            r,
            &mut dmid,
        );

        let mut dq = vec![0.0f32; nm * d];
        let mut dk = vec![0.0f32; nm * d];
        let mut dv = vec![0.0f32; nm * d];
        let mut dp = vec![0.0f32; s];
        for i in 0..n {
            for b in 0..bs {
                for hh in 0..nh {
                    for t in 0..s {
                        let base_t = ((i * bs + b) * s + t) * d + hh * dh;
                        let dorow = &do_[base_t..base_t + dh];
                        let prow = &save.p[(((i * bs + b) * nh + hh) * s + t) * s
                            ..(((i * bs + b) * nh + hh) * s + t) * s + s];
                        // dP and softmax backward.
                        let mut ds = 0.0f32;
                        for u in 0..=t {
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            let vrow = &save.v[base_u..base_u + dh];
                            let mut dot = 0.0f32;
                            for c in 0..dh {
                                dot += dorow[c] * vrow[c];
                            }
                            dp[u] = dot;
                            ds += dot * prow[u];
                            // dv += P[t,u] * do
                            let dvrow = &mut dv[base_u..base_u + dh];
                            for c in 0..dh {
                                dvrow[c] += prow[u] * dorow[c];
                            }
                        }
                        for u in 0..=t {
                            let datt = prow[u] * (dp[u] - ds) / sqrt_dh;
                            if datt == 0.0 {
                                continue;
                            }
                            let base_u = ((i * bs + b) * s + u) * d + hh * dh;
                            // dq[t] += datt * k[u]; dk[u] += datt * q[t]
                            let krow = &save.k[base_u..base_u + dh];
                            let qrow = &save.q[base_t..base_t + dh];
                            let dqrow = &mut dq[base_t..base_t + dh];
                            for c in 0..dh {
                                dqrow[c] += datt * krow[c];
                            }
                            let dkrow = &mut dk[base_u..base_u + dh];
                            for c in 0..dh {
                                dkrow[c] += datt * qrow[c];
                            }
                        }
                    }
                }
            }
        }

        let mut dh = vec![0.0f32; nm * d];
        proj_bwd(
            &mut dh,
            ga!(A_Q, d),
            gb!(B_Q, d),
            &dq,
            &save.h,
            &save.mid_q,
            wq,
            la(A_Q, d),
            lb(B_Q, d),
            scale,
            n,
            m,
            d,
            d,
            r,
            &mut dmid,
        );
        proj_bwd(
            &mut dh,
            ga!(A_K, d),
            gb!(B_K, d),
            &dk,
            &save.h,
            &save.mid_k,
            wk,
            la(A_K, d),
            lb(B_K, d),
            scale,
            n,
            m,
            d,
            d,
            r,
            &mut dmid,
        );
        proj_bwd(
            &mut dh,
            ga!(A_V, d),
            gb!(B_V, d),
            &dv,
            &save.h,
            &save.mid_v,
            wv,
            la(A_V, d),
            lb(B_V, d),
            scale,
            n,
            m,
            d,
            d,
            r,
            &mut dmid,
        );
        // dx0 = dx1 (residual) + LN1 backward of dh.
        let mut dx0 = dx1.clone();
        ln_bwd_acc(&mut dx0, &dh, ln1, &save.xhat1, &save.inv1, nm, d);
        dx = dx0;
    }

    Ok((per, grads))
}

// ---------------------------------------------------------------------------
// AdamW (per-adapter learning rate, padded-rank masking)
// ---------------------------------------------------------------------------

/// One AdamW update over a flat LoRA tensor of shape `(L, n, d2, d3)`.
/// `rank_axis_last` is true for `a_*` tensors (rank on the last axis).
#[allow(clippy::too_many_arguments)]
pub(crate) fn adamw_update(
    lora: &[f32],
    m: &[f32],
    v: &[f32],
    grad: &[f32],
    lr: &[f32],
    rmask: &[f32],
    n: usize,
    d2: usize,
    d3: usize,
    r: usize,
    rank_axis_last: bool,
    t_new: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let bc1 = 1.0 - ADAM_B1.powf(t_new);
    let bc2 = 1.0 - ADAM_B2.powf(t_new);
    let layers = lora.len() / (n * d2 * d3);
    let mut out_l = vec![0.0f32; lora.len()];
    let mut out_m = vec![0.0f32; lora.len()];
    let mut out_v = vec![0.0f32; lora.len()];
    for l in 0..layers {
        for i in 0..n {
            let lri = lr[i];
            for x2 in 0..d2 {
                for x3 in 0..d3 {
                    let idx = ((l * n + i) * d2 + x2) * d3 + x3;
                    let rank_idx = if rank_axis_last { x3 } else { x2 };
                    let km = rmask[i * r + rank_idx];
                    let g = grad[idx] * km;
                    let m1 = ADAM_B1 * m[idx] + (1.0 - ADAM_B1) * g;
                    let v1 = ADAM_B2 * v[idx] + (1.0 - ADAM_B2) * g * g;
                    let mh = m1 / bc1;
                    let vh = v1 / bc2;
                    let upd = lri * mh / (vh.sqrt() + ADAM_EPS);
                    out_l[idx] = (lora[idx] - upd) * km;
                    out_m[idx] = m1;
                    out_v[idx] = v1;
                }
            }
        }
    }
    (out_l, out_m, out_v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::state::{lora_shape, proj_dims};
    use crate::runtime::ModelInfo;
    use crate::util::rng::Rng;

    #[test]
    fn mm_variants_match_hand_computation() {
        // a = [[1,2,3],[4,5,6]] (2x3), b = [[7,8],[9,10],[11,12]] (3x2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let mut out = [0.0f32; 4];
        mm_acc(&mut out, &a, &b, 2, 3, 2, 1.0);
        assert_eq!(out, [58.0, 64.0, 139.0, 154.0]);

        // a (2x3) @ b^T with b stored (2x3): out[i][j] = row_i . row_j
        let bt = [1.0, 0.0, 1.0, 0.0, 2.0, 0.0];
        let mut out = [0.0f32; 4];
        mm_nt_acc(&mut out, &a, &bt, 2, 3, 2, 1.0);
        assert_eq!(out, [4.0, 4.0, 10.0, 10.0]);

        // a^T (3x2 from a stored 2x3) @ b2 (2x2)
        let b2 = [1.0, 2.0, 3.0, 4.0];
        let mut out = [0.0f32; 6];
        mm_tn_acc(&mut out, &a, &b2, 2, 3, 2, 1.0);
        // a^T = [[1,4],[2,5],[3,6]]; a^T@b2 = [[13,18],[17,24],[21,30]]
        assert_eq!(out, [13.0, 18.0, 17.0, 24.0, 21.0, 30.0]);
    }

    #[test]
    fn layernorm_forward_is_normalized() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let g = [1.0f32; 4];
        let mut h = [0.0f32; 4];
        let mut xhat = [0.0f32; 4];
        let mut inv = [0.0f32; 1];
        ln_fwd(&x, &g, 1, 4, &mut h, &mut xhat, &mut inv);
        let mean: f32 = h.iter().sum::<f32>() / 4.0;
        let var: f32 = h.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    fn tiny_mi() -> ModelInfo {
        ModelInfo {
            name: "fd".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            seq: 6,
            params: 0,
            weights: String::new(),
        }
    }

    fn tiny_spec(mi: &ModelInfo) -> Spec {
        Spec {
            vocab: mi.vocab,
            d_model: mi.d_model,
            n_layers: mi.n_layers,
            n_heads: mi.n_heads,
            d_ff: mi.d_ff,
            seq: mi.seq,
        }
    }

    fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, std: f64) -> HostTensor {
        let count: usize = shape.iter().product();
        let data = (0..count).map(|_| (rng.normal() * std) as f32).collect();
        HostTensor::f32(shape, data).unwrap()
    }

    fn rand_base(mi: &ModelInfo, rng: &mut Rng) -> Vec<HostTensor> {
        let (v, d, l, f, s) = (mi.vocab, mi.d_model, mi.n_layers, mi.d_ff, mi.seq);
        let ones_ish = |rng: &mut Rng, shape: Vec<usize>| {
            let count: usize = shape.iter().product();
            let data = (0..count).map(|_| 1.0 + (rng.normal() * 0.1) as f32).collect();
            HostTensor::f32(shape, data).unwrap()
        };
        vec![
            rand_tensor(rng, vec![v, d], 0.3),
            rand_tensor(rng, vec![s, d], 0.3),
            ones_ish(rng, vec![l, d]),
            ones_ish(rng, vec![l, d]),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, d], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, f], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, d, f], (d as f64).powf(-0.5)),
            rand_tensor(rng, vec![l, f, d], (f as f64).powf(-0.5)),
            ones_ish(rng, vec![d]),
        ]
    }

    /// Finite-difference check of the hand-derived backward pass: perturb
    /// sampled LoRA coordinates and compare (L(θ+ε) − L(θ−ε)) / 2ε against
    /// the analytic gradient. This is the in-tree guarantee that the
    /// reference backend's gradients match `ref.py`/autodiff semantics.
    #[test]
    fn finite_difference_gradient_check() {
        let mi = tiny_mi();
        let spec = tiny_spec(&mi);
        let (n, r, bs) = (2usize, 3usize, 1usize);
        let mut rng = Rng::new(42);

        let base = rand_base(&mi, &mut rng);
        let mut lora_t: Vec<HostTensor> = Vec::new();
        for name in LORA_ORDER {
            let shape = lora_shape(&mi, name, n, r);
            // Both A and B nonzero so every backward path is exercised.
            let (_, p) = name.split_once('_').unwrap();
            let din = proj_dims(&mi, p).0 as f64;
            lora_t.push(rand_tensor(&mut rng, shape, 0.5 / din.sqrt()));
        }
        let scale = vec![1.0f32, 0.7];
        let m = bs * spec.seq;
        let tokens: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let mask: Vec<f32> = (0..n * m).map(|_| if rng.f64() < 0.6 { 1.0 } else { 0.0 }).collect();

        let total_loss = |lora_t: &[HostTensor]| -> f32 {
            let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
            let fwd = forward(&spec, &base, &lora, &scale, &tokens, n, bs, r).unwrap();
            let (loss, _) = loss_and_acc(&spec, &fwd.logits, &targets, &mask, n, bs);
            loss.iter().sum()
        };

        let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
        let fwd = forward(&spec, &base, &lora, &scale, &tokens, n, bs, r).unwrap();
        let (_, grads) =
            backward(&spec, &fwd, &base, &lora, &scale, &targets, &mask, n, bs, r).unwrap();

        let gmax = grads
            .iter()
            .flat_map(|g| g.iter())
            .fold(0.0f32, |acc, &g| acc.max(g.abs()));
        assert!(gmax > 1e-4, "gradients unexpectedly all ~zero (gmax {gmax})");

        let eps = 1e-2f32;
        let mut checked = 0usize;
        let mut check_rng = Rng::new(7);
        for _ in 0..400 {
            let k = check_rng.usize_below(14);
            let idx = check_rng.usize_below(lora_t[k].len());
            let g = grads[k][idx];
            if g.abs() < 0.03 * gmax {
                continue; // too small for f32 finite differences
            }
            let orig = lora_t[k].as_f32().unwrap()[idx];
            lora_t[k].as_f32_mut().unwrap()[idx] = orig + eps;
            let lp = total_loss(&lora_t);
            lora_t[k].as_f32_mut().unwrap()[idx] = orig - eps;
            let lm = total_loss(&lora_t);
            lora_t[k].as_f32_mut().unwrap()[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let rel = (fd - g).abs() / g.abs().max(fd.abs()).max(1e-6);
            assert!(
                rel < 0.25,
                "grad mismatch at {}[{idx}]: analytic {g:.5}, fd {fd:.5} (rel {rel:.3})",
                LORA_ORDER[k]
            );
            checked += 1;
            if checked >= 24 {
                break;
            }
        }
        assert!(checked >= 6, "only {checked} coordinates were large enough to check");
    }

    /// The logits-only eval forward reproduces the full forward's logits
    /// bit-for-bit (same op order, no saved state).
    #[test]
    fn forward_logits_matches_full_forward() {
        let mi = tiny_mi();
        let spec = tiny_spec(&mi);
        let (n, r, bs) = (2usize, 3usize, 2usize);
        let mut rng = Rng::new(77);
        let base = rand_base(&mi, &mut rng);
        let mut lora_t: Vec<HostTensor> = Vec::new();
        for name in LORA_ORDER {
            let shape = lora_shape(&mi, name, n, r);
            let (_, p) = name.split_once('_').unwrap();
            let din = proj_dims(&mi, p).0 as f64;
            lora_t.push(rand_tensor(&mut rng, shape, 0.5 / din.sqrt()));
        }
        let lora: [&[f32]; 14] = std::array::from_fn(|i| lora_t[i].as_f32().unwrap());
        let scale = vec![0.9f32, 1.3];
        let m = bs * spec.seq;
        let tokens: Vec<i32> =
            (0..n * m).map(|_| rng.below(spec.vocab as u64) as i32).collect();
        let full = forward(&spec, &base, &lora, &scale, &tokens, n, bs, r).unwrap();
        let lean = forward_logits(&spec, &base, &lora, &scale, &tokens, n, bs, r).unwrap();
        assert_eq!(full.logits.len(), lean.len());
        for (i, (a, b)) in full.logits.iter().zip(&lean).enumerate() {
            assert_eq!(a, b, "logit {i} diverged: {a} vs {b}");
        }
    }

    #[test]
    fn adamw_first_step_is_signed_descent_and_masks_padding() {
        // With zero moments and t=0 -> t_new=1, AdamW's first update is
        // lr * g/(|g| + eps') ≈ lr * sign(g).
        let lora = vec![1.0f32; 8]; // (L=1, n=1, d2=2, d3=4), rank axis last
        let m = vec![0.0f32; 8];
        let v = vec![0.0f32; 8];
        let grad = vec![0.5f32, -0.5, 0.5, -0.5, 0.5, -0.5, 0.5, -0.5];
        let rmask = vec![1.0f32, 1.0, 0.0, 0.0]; // true rank 2 of padded 4
        let (nl, nm, nv) =
            adamw_update(&lora, &m, &v, &grad, &[0.1], &rmask, 1, 2, 4, 4, true, 1.0);
        // Unmasked columns move by ~lr against the gradient sign.
        assert!((nl[0] - 0.9).abs() < 1e-3, "{}", nl[0]);
        assert!((nl[1] - 1.1).abs() < 1e-3, "{}", nl[1]);
        // Padded rank columns are zeroed outright.
        assert_eq!(nl[2], 0.0);
        assert_eq!(nl[3], 0.0);
        assert_eq!(nm[2], 0.0);
        assert_eq!(nv[3], 0.0);
    }
}
