//! Step-persistent workspace arena for the reference backend.
//!
//! One [`Workspace`] holds every activation, gradient and scratch buffer a
//! `(Spec, n, bs, r)` train/eval bucket needs, sized once and reused for
//! the life of the job: after the first step of a phase, the interpreter
//! runs with zero steady-state allocation (the pre-arena code allocated
//! ~30 `Vec<f32>`s per layer per step). The arena rides inside the opaque
//! [`crate::runtime::backend::Scratch`] owned by
//! [`crate::runtime::TrainState`], so a re-bucket (`TrainState::repack`)
//! drops it with the old state and the next step re-derives it at the new
//! bucket shape.
//!
//! Buffer groups:
//!
//! - **stream/head** (`x`, `h`, `xhatf`, `invf`, `logits`, `att`) — shared
//!   by the train forward and the logits-only eval forward.
//! - **flat activations** (`xhat`..`act`) — the eval forward's per-layer
//!   reuse set (no backward state).
//! - **`layers`** ([`LayerSave`]) — the train forward's saved activations,
//!   one per layer, read by the backward pass. Only sized when a train
//!   bucket asks for them.
//! - **backward scratch + `grads`** — gradient propagation buffers and the
//!   14 `LORA_ORDER` gradient accumulators.

use super::tinylm::Spec;
use crate::runtime::LORA_ORDER;

/// Saved per-layer activations for the backward pass. (The residual-stream
/// values themselves are not needed: residual adds backprop as identity.)
#[derive(Default)]
pub(crate) struct LayerSave {
    pub xhat1: Vec<f32>,
    pub inv1: Vec<f32>,
    pub h: Vec<f32>,
    pub mid_q: Vec<f32>,
    pub mid_k: Vec<f32>,
    pub mid_v: Vec<f32>,
    pub mid_o: Vec<f32>,
    pub mid_up: Vec<f32>,
    pub mid_gate: Vec<f32>,
    pub mid_down: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub p: Vec<f32>,
    pub o: Vec<f32>,
    pub xhat2: Vec<f32>,
    pub inv2: Vec<f32>,
    pub h2: Vec<f32>,
    pub up: Vec<f32>,
    pub gate: Vec<f32>,
    pub act: Vec<f32>,
}

impl LayerSave {
    fn ensure(&mut self, nm: usize, d: usize, f: usize, r: usize, p_len: usize) {
        self.xhat1.resize(nm * d, 0.0);
        self.inv1.resize(nm, 0.0);
        self.h.resize(nm * d, 0.0);
        for mid in [
            &mut self.mid_q,
            &mut self.mid_k,
            &mut self.mid_v,
            &mut self.mid_o,
            &mut self.mid_up,
            &mut self.mid_gate,
            &mut self.mid_down,
        ] {
            mid.resize(nm * r, 0.0);
        }
        self.q.resize(nm * d, 0.0);
        self.k.resize(nm * d, 0.0);
        self.v.resize(nm * d, 0.0);
        self.p.resize(p_len, 0.0);
        self.o.resize(nm * d, 0.0);
        self.xhat2.resize(nm * d, 0.0);
        self.inv2.resize(nm, 0.0);
        self.h2.resize(nm * d, 0.0);
        self.up.resize(nm * f, 0.0);
        self.gate.resize(nm * f, 0.0);
        self.act.resize(nm * f, 0.0);
    }
}

/// Shape key a workspace was last sized for.
type Key = (usize, usize, usize, usize, usize, usize, usize, usize, usize);

fn key_of(spec: &Spec, n: usize, bs: usize, r: usize) -> Key {
    (spec.vocab, spec.d_model, spec.n_layers, spec.n_heads, spec.d_ff, spec.seq, n, bs, r)
}

/// The arena (see module docs). All fields are plain `Vec<f32>` buffers;
/// `ensure` is idempotent and only touches memory when the bucket shape
/// changes (i.e. never in the steady state of a job phase).
#[derive(Default)]
pub struct Workspace {
    key: Option<Key>,
    has_layers: bool,

    // Residual stream + head (both forwards).
    pub(crate) x: Vec<f32>,
    pub(crate) h: Vec<f32>,
    pub(crate) xhatf: Vec<f32>,
    pub(crate) invf: Vec<f32>,
    pub(crate) logits: Vec<f32>,
    /// Attention probe scratch: `[logit_buf(s) | prow(s)]`.
    pub(crate) att: Vec<f32>,

    // Flat per-layer activation reuse (logits-only eval forward).
    pub(crate) xhat: Vec<f32>,
    pub(crate) inv: Vec<f32>,
    pub(crate) mid: Vec<f32>,
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) o: Vec<f32>,
    /// Projection output staging (attention out / MLP down) before the
    /// residual add; also the backward's `do_` buffer.
    pub(crate) tmp: Vec<f32>,
    pub(crate) up: Vec<f32>,
    pub(crate) gate: Vec<f32>,
    pub(crate) act: Vec<f32>,

    // Train forward saves.
    pub(crate) layers: Vec<LayerSave>,

    // Backward scratch.
    pub(crate) dlogits: Vec<f32>,
    pub(crate) dxa: Vec<f32>,
    pub(crate) dxb: Vec<f32>,
    pub(crate) dact: Vec<f32>,
    pub(crate) dup: Vec<f32>,
    pub(crate) dgate: Vec<f32>,
    pub(crate) dh2: Vec<f32>,
    /// Rank-space upstream gradient, `(n·m, r)` adapter-major — exactly
    /// the densely-strided `b` operand the fused `gemm::batched` `dA`
    /// reduction consumes, so the batched path needs no extra packing
    /// scratch (likewise `mid`/`dy` for `dB`).
    pub(crate) dmid: Vec<f32>,
    pub(crate) dq: Vec<f32>,
    pub(crate) dk: Vec<f32>,
    pub(crate) dv: Vec<f32>,
    pub(crate) dh: Vec<f32>,
    pub(crate) dp: Vec<f32>,
    /// LayerNorm-backward row scratch (`d_model` floats).
    pub(crate) dln: Vec<f32>,
    /// LoRA gradient accumulators in `LORA_ORDER` (packed shapes).
    pub(crate) grads: Vec<Vec<f32>>,
}

/// Flat element count of LoRA tensor `name` for `(spec, n, r)` — the
/// `runtime::state::lora_shape` product, derived from the `Spec` alone.
pub(crate) fn lora_len(spec: &Spec, name: &str, n: usize, r: usize) -> usize {
    let (kind, p) = name.split_once('_').expect("lora tensor name");
    let (d, f) = (spec.d_model, spec.d_ff);
    let (din, dout) = match p {
        "q" | "k" | "v" | "o" => (d, d),
        "up" | "gate" => (d, f),
        "down" => (f, d),
        other => panic!("unknown projection '{other}'"),
    };
    match kind {
        "a" => spec.n_layers * n * din * r,
        "b" => spec.n_layers * n * r * dout,
        other => panic!("unknown lora tensor kind '{other}'"),
    }
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Size every buffer for a `(spec, n, bs, r)` bucket. `train` also
    /// sizes the per-layer saves, the backward scratch and the gradient
    /// accumulators. No-op when already sized for the same key.
    pub(crate) fn ensure(&mut self, spec: &Spec, n: usize, bs: usize, r: usize, train: bool) {
        let key = key_of(spec, n, bs, r);
        if self.key != Some(key) {
            self.key = Some(key);
            self.has_layers = false;
            let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
            let nm = n * bs * s;
            self.x.resize(nm * d, 0.0);
            self.h.resize(nm * d, 0.0);
            self.xhatf.resize(nm * d, 0.0);
            self.invf.resize(nm, 0.0);
            self.logits.resize(nm * v, 0.0);
            self.att.resize(2 * s, 0.0);
            self.xhat.resize(nm * d, 0.0);
            self.inv.resize(nm, 0.0);
            self.mid.resize(nm * r, 0.0);
            self.q.resize(nm * d, 0.0);
            self.k.resize(nm * d, 0.0);
            self.v.resize(nm * d, 0.0);
            self.o.resize(nm * d, 0.0);
            self.tmp.resize(nm * d, 0.0);
            self.up.resize(nm * f, 0.0);
            self.gate.resize(nm * f, 0.0);
            self.act.resize(nm * f, 0.0);
        }
        if train && !self.has_layers {
            self.has_layers = true;
            let (d, f, s, v) = (spec.d_model, spec.d_ff, spec.seq, spec.vocab);
            let nm = n * bs * s;
            let p_len = n * bs * spec.n_heads * s * s;
            self.layers.resize_with(spec.n_layers, LayerSave::default);
            for l in &mut self.layers {
                l.ensure(nm, d, f, r, p_len);
            }
            self.dlogits.resize(nm * v, 0.0);
            self.dxa.resize(nm * d, 0.0);
            self.dxb.resize(nm * d, 0.0);
            self.dact.resize(nm * f, 0.0);
            self.dup.resize(nm * f, 0.0);
            self.dgate.resize(nm * f, 0.0);
            self.dh2.resize(nm * d, 0.0);
            self.dmid.resize(nm * r, 0.0);
            self.dq.resize(nm * d, 0.0);
            self.dk.resize(nm * d, 0.0);
            self.dv.resize(nm * d, 0.0);
            self.dh.resize(nm * d, 0.0);
            self.dp.resize(s, 0.0);
            self.dln.resize(d, 0.0);
            self.grads.resize_with(LORA_ORDER.len(), Vec::new);
            for (g, name) in self.grads.iter_mut().zip(LORA_ORDER.iter()) {
                g.resize(lora_len(spec, name, n, r), 0.0);
            }
        }
    }

    /// Total f32 elements currently held — memory accounting / tests.
    pub fn elements(&self) -> usize {
        let flat = [
            &self.x,
            &self.h,
            &self.xhatf,
            &self.invf,
            &self.logits,
            &self.att,
            &self.xhat,
            &self.inv,
            &self.mid,
            &self.q,
            &self.k,
            &self.v,
            &self.o,
            &self.tmp,
            &self.up,
            &self.gate,
            &self.act,
            &self.dlogits,
            &self.dxa,
            &self.dxb,
            &self.dact,
            &self.dup,
            &self.dgate,
            &self.dh2,
            &self.dmid,
            &self.dq,
            &self.dk,
            &self.dv,
            &self.dh,
            &self.dp,
            &self.dln,
        ];
        let mut total: usize = flat.iter().map(|b| b.len()).sum();
        total += self.grads.iter().map(|g| g.len()).sum::<usize>();
        for l in &self.layers {
            total += l.xhat1.len()
                + l.inv1.len()
                + l.h.len()
                + l.mid_q.len()
                + l.mid_k.len()
                + l.mid_v.len()
                + l.mid_o.len()
                + l.mid_up.len()
                + l.mid_gate.len()
                + l.mid_down.len()
                + l.q.len()
                + l.k.len()
                + l.v.len()
                + l.p.len()
                + l.o.len()
                + l.xhat2.len()
                + l.inv2.len()
                + l.h2.len()
                + l.up.len()
                + l.gate.len()
                + l.act.len();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec { vocab: 16, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 12, seq: 6 }
    }

    #[test]
    fn ensure_sizes_once_and_is_idempotent() {
        let mut ws = Workspace::new();
        ws.ensure(&spec(), 2, 1, 3, false);
        let eval_elems = ws.elements();
        assert!(eval_elems > 0);
        assert!(ws.layers.is_empty(), "eval buckets do not pay for layer saves");

        ws.ensure(&spec(), 2, 1, 3, true);
        let train_elems = ws.elements();
        assert!(train_elems > eval_elems);
        assert_eq!(ws.layers.len(), 2);
        assert_eq!(ws.grads.len(), LORA_ORDER.len());

        // Same key again: nothing changes (steady state).
        ws.ensure(&spec(), 2, 1, 3, true);
        assert_eq!(ws.elements(), train_elems);
    }

    #[test]
    fn rekey_resizes_for_new_bucket() {
        let mut ws = Workspace::new();
        ws.ensure(&spec(), 2, 1, 3, true);
        let s = spec();
        let nm = 2 * 1 * s.seq;
        assert_eq!(ws.x.len(), nm * s.d_model);
        ws.ensure(&spec(), 1, 1, 2, true);
        let nm = s.seq;
        assert_eq!(ws.x.len(), nm * s.d_model);
        assert_eq!(ws.mid.len(), nm * 2);
        assert_eq!(ws.grads[4].len(), lora_len(&s, "a_q", 1, 2)); // a_q
    }

    #[test]
    fn lora_len_matches_state_shapes() {
        let s = spec();
        // a_q: (L, n, d, r); b_down: (L, n, r, d).
        assert_eq!(lora_len(&s, "a_q", 3, 4), 2 * 3 * 8 * 4);
        assert_eq!(lora_len(&s, "b_down", 3, 4), 2 * 3 * 4 * 8);
        assert_eq!(lora_len(&s, "a_up", 1, 2), 2 * 1 * 8 * 2);
        assert_eq!(lora_len(&s, "b_up", 1, 2), 2 * 1 * 2 * 12);
    }
}
