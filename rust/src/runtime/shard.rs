//! **Data-parallel sharded execution** (DESIGN.md §11): the layer that
//! turns an allocation's device set from a modeled quantity into an
//! executed one.
//!
//! A [`ShardedState`] wraps one packed [`TrainState`] and splits the
//! pack's `n·batch` training rows across `d` shard workers — one
//! persistent [`crate::util::threadpool`] worker per allocated device,
//! each with its own [`Scratch`]/workspace arena. Every step:
//!
//! 1. **scatter** — each shard receives its contiguous slot range
//!    `[lo, hi)` of the packed LoRA tensors and batch rows;
//! 2. **forward/backward per shard** — shards run concurrently through
//!    the backend's [`ShardStepExec::run_grads`] half;
//! 3. **deterministic reduction** — shard gradients are installed into
//!    the full-bucket gradient tensors in fixed shard order `0..d-1`.
//!    Shard boundaries sit at bucket-*slot* granularity, where every
//!    gradient element receives all of its row contributions from exactly
//!    one shard (a packed adapter's `dA`/`dB` accumulate over only its
//!    own rows), so the reduction preserves every element's contribution
//!    order exactly and costs no floating-point reassociation;
//! 4. **single AdamW update** — one [`ShardStepExec::run_adamw`] over the
//!    full state and the reduced gradients.
//!
//! Because step 3 never reorders any element's reduction, a sharded step
//! is **bitwise identical** to the fused single-device step — every
//! adapter's trajectory is the same at `d = 1, 2, 4`, across uneven slot
//! splits, and across mid-run device retargets (`rust/tests/session.rs`
//! pins this). Sub-slot row splits would break that: a gradient element
//! summed across shards acquires a `d`-dependent association tree, so
//! slot granularity is exactly the finest split at which device-count
//! invariance is achievable at zero numeric cost.
//!
//! Eval runs data-parallel over the same shard workers
//! ([`ShardStepExec::run_eval`]): each shard computes its slots' `(loss,
//! acc)` from its own rows only, gathered in fixed shard order — no
//! reduction at all, so sharded eval is bitwise identical to the fused
//! eval executable. Checkpoint extraction reads the wrapped
//! [`TrainState`] directly (single-pass and device-count invariant by
//! construction). When the allocation has one device — or the backend
//! cannot split its fused step ([`crate::runtime::ExecutionBackend::shard`]
//! returns `None`, e.g. AOT-compiled PJRT artifacts) — `step` and `eval`
//! run the fused executables unchanged.
//!
//! **Stage composition** (DESIGN.md §15): with a requested pipeline depth
//! `s > 1` ([`ShardedState::new_with_stages`], the `PLORA_STAGES` knob),
//! each shard's executor is a [`PipelinedExec`] that streams the shard's
//! slot slice through `s` layer-stage workers — the `d × s` composition.
//! Both axes preserve every element's reduction order, so trajectories
//! stay bitwise identical at any `(d, s)`.

use anyhow::{bail, Result};

use crate::runtime::backend::{GradStep, Scratch, ShardStepExec};
use crate::runtime::pipeline::PipelinedExec;
use crate::runtime::state::lora_shape;
use crate::runtime::tensor::HostTensor;
use crate::runtime::{Executable, Runtime, TrainState, LORA_ORDER};
use crate::util::threadpool::ThreadPool;

/// One shard worker's persistent state: its slot range, executor, scratch
/// arena and step-refilled input buffers.
struct Shard {
    /// Device id this shard stands for (observability only — on the
    /// reference backend the "device" is a worker thread).
    device: usize,
    /// Slot range `[lo, hi)` of the full bucket this shard owns.
    lo: usize,
    hi: usize,
    exe: Box<dyn ShardStepExec>,
    scratch: Scratch,
    /// Packed LoRA tensors at the shard shape `(L, hi-lo, ·, ·)`.
    lora: Vec<HostTensor>,
    tokens: HostTensor,
    targets: HostTensor,
    mask: HostTensor,
    scale: Vec<f32>,
    /// Last step's outcome (taken by the reduction).
    out: Option<Result<GradStep>>,
    /// Last eval's outcome (taken by the gather; `Ok(None)` means the
    /// backend cannot eval at shard granularity — fused fallback).
    eval_out: Option<Result<Option<(Vec<f32>, Vec<f32>)>>>,
    /// Last step's wall time on this shard (observability: shard-balance
    /// diagnosis via [`ShardedState::shard_secs`]).
    secs: f64,
}

impl Shard {
    fn nw(&self) -> usize {
        self.hi - self.lo
    }
}

/// A [`TrainState`] executing data-parallel across an allocation's
/// devices (see module docs).
pub struct ShardedState {
    inner: TrainState,
    devices: Vec<usize>,
    /// Shard workers; empty means fused single-device execution.
    shards: Vec<Shard>,
    /// Full-bucket optimizer half (present iff `shards` is non-empty).
    opt: Option<Box<dyn ShardStepExec>>,
    /// One persistent worker per shard (present iff sharded).
    pool: Option<ThreadPool>,
    /// Full-bucket gradient gather buffers (`LORA_ORDER`).
    grads: Vec<HostTensor>,
    /// Scratch pool the AdamW outputs cycle through.
    opt_scratch: Scratch,
    /// The batch size the shard buffers were built for.
    bs: usize,
    /// Requested pipeline depth (`PLORA_STAGES`); 1 = layer-monolithic.
    stages: usize,
    /// Effective pipeline depth the shard executors run with after
    /// clamping to the layer count and backend support (1 = monolithic).
    stages_eff: usize,
}

/// Copy slots `[lo, hi)` of a packed `(L, n, d2, d3)` tensor into the
/// `(L, hi-lo, d2, d3)` shard tensor (slot panels are contiguous per
/// layer, so this is one memcpy per layer).
fn scatter_slots(
    full: &HostTensor,
    shard: &mut HostTensor,
    n: usize,
    lo: usize,
    hi: usize,
) -> Result<()> {
    let (l, d2, d3) = (full.shape[0], full.shape[2], full.shape[3]);
    let nw = hi - lo;
    let src = full.as_f32()?;
    let dst = shard.as_f32_mut()?;
    let panel = d2 * d3;
    for li in 0..l {
        let s = (li * n + lo) * panel;
        let d = li * nw * panel;
        dst[d..d + nw * panel].copy_from_slice(&src[s..s + nw * panel]);
    }
    Ok(())
}

/// The reduction's placement primitive: install a shard's `(L, nw, d2,
/// d3)` gradient tensor into slots `[lo, hi)` of the full `(L, n, d2,
/// d3)` buffer. Each full-buffer element is written by exactly one shard.
fn gather_slots(
    shard: &HostTensor,
    full: &mut HostTensor,
    n: usize,
    lo: usize,
    hi: usize,
) -> Result<()> {
    let (l, d2, d3) = (full.shape[0], full.shape[2], full.shape[3]);
    let nw = hi - lo;
    let src = shard.as_f32()?;
    let dst = full.as_f32_mut()?;
    let panel = d2 * d3;
    for li in 0..l {
        let s = li * nw * panel;
        let d = (li * n + lo) * panel;
        dst[d..d + nw * panel].copy_from_slice(&src[s..s + nw * panel]);
    }
    Ok(())
}

impl ShardedState {
    /// Wrap `inner` for execution on `devices` (the job's real
    /// [`crate::cluster::Allocation`] device set). `bs` is the bucket
    /// batch size the step tensors will carry. Falls back to fused
    /// single-device execution when the allocation has one device, the
    /// bucket has fewer slots than devices can use, or the backend
    /// cannot split its fused step.
    pub fn new(
        rt: &Runtime,
        model: &str,
        inner: TrainState,
        bs: usize,
        devices: &[usize],
    ) -> Result<ShardedState> {
        ShardedState::new_with_stages(rt, model, inner, bs, devices, 1)
    }

    /// Like [`ShardedState::new`], but with a requested pipeline depth
    /// `stages` (the `PLORA_STAGES` knob): each shard's executor streams
    /// its slot slice through `stages` layer-stage workers
    /// ([`PipelinedExec`]) — the `d × s` composition. Falls back to
    /// layer-monolithic shard executors (and, with one device, to fused
    /// execution) when the backend cannot stage-split; trajectories are
    /// bitwise identical either way.
    pub fn new_with_stages(
        rt: &Runtime,
        model: &str,
        inner: TrainState,
        bs: usize,
        devices: &[usize],
        stages: usize,
    ) -> Result<ShardedState> {
        let mut st = ShardedState {
            inner,
            devices: devices.to_vec(),
            shards: vec![],
            opt: None,
            pool: None,
            grads: vec![],
            opt_scratch: Scratch::new(),
            bs,
            stages: stages.max(1),
            stages_eff: 1,
        };
        st.build(rt, model)?;
        Ok(st)
    }

    /// Rebuild the shard set for a new device list (a boundary device
    /// retarget: the pack grew onto freed devices, or handed some back).
    /// The wrapped training state is untouched — only the execution
    /// layout changes, so trajectories stay bitwise identical.
    pub fn set_devices(&mut self, rt: &Runtime, model: &str, devices: &[usize]) -> Result<()> {
        self.devices = devices.to_vec();
        self.build(rt, model)
    }

    /// Rebuild the shard executors for a new pipeline depth (a boundary
    /// stage retarget). Like [`ShardedState::set_devices`], the wrapped
    /// training state is untouched — only the execution layout changes,
    /// so trajectories stay bitwise identical.
    pub fn set_stages(&mut self, rt: &Runtime, model: &str, stages: usize) -> Result<()> {
        self.stages = stages.max(1);
        self.build(rt, model)
    }

    fn build(&mut self, rt: &Runtime, model: &str) -> Result<()> {
        self.shards.clear();
        self.opt = None;
        self.pool = None;
        self.grads.clear();
        self.stages_eff = 1;
        let (n, r, bs) = (self.inner.n, self.inner.r, self.bs);
        let s_req = self.stages.clamp(1, self.inner.model.n_layers.max(1));
        let d_eff = self.devices.len().min(n.max(1));
        if d_eff <= 1 && s_req <= 1 {
            return Ok(());
        }
        let Some(opt) = rt.shard_exec(model, n, r, bs)? else {
            return Ok(()); // backend cannot split: fused fallback
        };
        let mi = self.inner.model.clone();
        let seq = mi.seq;
        // With one device the bucket stays whole — a single full-range
        // "shard" whose executor is the stage pipeline (pure `s` axis).
        let devs: Vec<usize> = if d_eff <= 1 {
            vec![self.devices.first().copied().unwrap_or(0)]
        } else {
            self.devices.iter().take(d_eff).copied().collect()
        };
        let d_w = devs.len();
        let mut shards = Vec::with_capacity(d_w);
        let mut s_eff = 1usize;
        let base_n = n / d_w;
        let rem = n % d_w;
        let mut lo = 0usize;
        for (w, &dev) in devs.iter().enumerate() {
            let nw = base_n + usize::from(w < rem);
            if nw == 0 {
                continue;
            }
            let hi = lo + nw;
            let exe: Box<dyn ShardStepExec> = if s_req > 1 {
                match PipelinedExec::build(rt, model, nw, r, bs, s_req)? {
                    Some(pe) => {
                        s_eff = s_eff.max(pe.stages());
                        Box::new(pe)
                    }
                    // Backend cannot stage-split. With one device neither
                    // axis engages — fused fallback; with several, fall
                    // back to layer-monolithic shard executors.
                    None if d_w <= 1 => {
                        self.shards.clear();
                        return Ok(());
                    }
                    None => {
                        let Some(exe) = rt.shard_exec(model, nw, r, bs)? else {
                            self.shards.clear();
                            return Ok(());
                        };
                        exe
                    }
                }
            } else {
                let Some(exe) = rt.shard_exec(model, nw, r, bs)? else {
                    self.shards.clear();
                    return Ok(());
                };
                exe
            };
            let lora: Vec<HostTensor> = LORA_ORDER
                .iter()
                .map(|name| {
                    let shape = lora_shape(&mi, name, nw, r);
                    let count: usize = shape.iter().product();
                    HostTensor::f32(shape, vec![0.0; count]).unwrap()
                })
                .collect();
            shards.push(Shard {
                device: dev,
                lo,
                hi,
                exe,
                scratch: Scratch::new(),
                lora,
                tokens: HostTensor::i32(vec![nw, bs, seq], vec![0; nw * bs * seq])?,
                targets: HostTensor::i32(vec![nw, bs, seq], vec![0; nw * bs * seq])?,
                mask: HostTensor::f32(vec![nw, bs, seq], vec![0.0; nw * bs * seq])?,
                scale: vec![0.0; nw],
                out: None,
                eval_out: None,
                secs: 0.0,
            });
            lo = hi;
        }
        self.grads = LORA_ORDER
            .iter()
            .map(|name| {
                let shape = lora_shape(&mi, name, n, r);
                let count: usize = shape.iter().product();
                HostTensor::f32(shape, vec![0.0; count]).unwrap()
            })
            .collect();
        // One persistent worker per device shard (the issue's "devices").
        self.pool = Some(ThreadPool::new(shards.len()));
        self.opt = Some(opt);
        self.shards = shards;
        self.stages_eff = s_eff;
        Ok(())
    }

    /// The wrapped single-bucket training state (eval, checkpointing and
    /// repack run against it directly — all device-count invariant).
    pub fn inner(&self) -> &TrainState {
        &self.inner
    }

    /// Unwrap (the driver returns a plain [`TrainState`] to callers).
    pub fn into_inner(self) -> TrainState {
        self.inner
    }

    /// Effective data-parallel width this state executes with (1 = fused).
    pub fn parallelism(&self) -> usize {
        self.shards.len().max(1)
    }

    /// Effective pipeline depth the shard executors run with (1 =
    /// layer-monolithic execution).
    pub fn stages(&self) -> usize {
        self.stages_eff
    }

    /// The requested pipeline depth (before clamping to the layer count
    /// and backend support) — what a rebuild would ask for again.
    pub fn stages_requested(&self) -> usize {
        self.stages
    }

    /// The allocation's device ids this state was built for.
    pub fn devices(&self) -> &[usize] {
        &self.devices
    }

    /// Last step's `(device id, wall secs)` per shard, in shard order
    /// (empty when running fused) — observability for shard-balance
    /// diagnosis. The dp-efficiency calibration (`Calib::dp_fit`) is fed
    /// *whole-step* times per shard count by the driver's `DpStat`
    /// recording, not these.
    pub fn shard_secs(&self) -> Vec<(usize, f64)> {
        self.shards.iter().map(|s| (s.device, s.secs)).collect()
    }

    /// See [`TrainState::rank_mask`].
    pub fn rank_mask(&self, ranks: &[usize]) -> Result<HostTensor> {
        self.inner.rank_mask(ranks)
    }

    /// One training step — the same contract as [`TrainState::step`].
    /// With shards, runs scatter → per-shard forward/backward →
    /// fixed-order reduction → single AdamW (module docs); without, the
    /// fused `exe` path unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        exe: &Executable,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
        lr: &[f32],
        rmask: &HostTensor,
    ) -> Result<Vec<f32>> {
        if self.shards.is_empty() {
            return self.inner.step(exe, base, tokens, targets, loss_mask, scale, lr, rmask);
        }
        let ShardedState { inner, shards, pool, opt, grads, opt_scratch, bs, .. } = self;
        let n = inner.n;
        if tokens.shape != [n, *bs, inner.model.seq] {
            bail!(
                "sharded step: batch tensors {:?} do not match the built ({n}, {bs}, {}) layout",
                tokens.shape,
                inner.model.seq
            );
        }
        if scale.len() != n || lr.len() != n {
            bail!("sharded step: {} scale / {} lr entries for pack of {n}", scale.len(), lr.len());
        }
        let row = *bs * inner.model.seq;

        // 1. Scatter: slot panels of the LoRA params, batch rows, scales.
        let tok = tokens.as_i32()?;
        let tgt = targets.as_i32()?;
        let msk = loss_mask.as_f32()?;
        for sh in shards.iter_mut() {
            for (full, dst) in inner.lora.iter().zip(sh.lora.iter_mut()) {
                scatter_slots(full, dst, n, sh.lo, sh.hi)?;
            }
            sh.tokens.as_i32_mut()?.copy_from_slice(&tok[sh.lo * row..sh.hi * row]);
            sh.targets.as_i32_mut()?.copy_from_slice(&tgt[sh.lo * row..sh.hi * row]);
            sh.mask.as_f32_mut()?.copy_from_slice(&msk[sh.lo * row..sh.hi * row]);
            sh.scale.copy_from_slice(&scale[sh.lo..sh.hi]);
            sh.out = None;
        }

        // 2. Forward/backward per shard, one persistent worker per device.
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(shards.len());
            for sh in shards.iter_mut() {
                tasks.push(Box::new(move || {
                    let t0 = std::time::Instant::now();
                    let r = sh.exe.run_grads(
                        base,
                        &sh.lora,
                        &sh.tokens,
                        &sh.targets,
                        &sh.mask,
                        &sh.scale,
                        &mut sh.scratch,
                    );
                    sh.secs = t0.elapsed().as_secs_f64();
                    sh.out = Some(r);
                }));
            }
            pool.as_ref().expect("shard pool").scoped(tasks);
        }

        // 3. Deterministic reduction: shard 0..d-1 in fixed order. Every
        //    gradient element has exactly one producing shard (slot
        //    granularity), so per-element contribution order is preserved
        //    exactly — the step is bitwise identical at any d.
        let mut per = vec![0.0f32; n];
        for sh in shards.iter_mut() {
            let out = sh.out.take().expect("shard executed")?;
            if out.per_loss.len() != sh.nw() {
                bail!("shard returned {} losses for {} slots", out.per_loss.len(), sh.nw());
            }
            per[sh.lo..sh.hi].copy_from_slice(&out.per_loss);
            for (g, full) in out.grads.into_iter().zip(grads.iter_mut()) {
                gather_slots(&g, full, n, sh.lo, sh.hi)?;
                if let Some(buf) = g.into_f32_vec() {
                    sh.scratch.recycle(buf);
                }
            }
        }

        // 4. One AdamW update over the full state and reduced gradients.
        let out = opt.as_ref().expect("optimizer half").run_adamw(
            &inner.lora,
            &inner.m,
            &inner.v,
            &inner.t,
            grads,
            lr,
            rmask,
            opt_scratch,
        )?;
        let old_l = std::mem::replace(&mut inner.lora, out.lora);
        let old_m = std::mem::replace(&mut inner.m, out.m);
        let old_v = std::mem::replace(&mut inner.v, out.v);
        inner.t = out.t;
        for spent in old_l.into_iter().chain(old_m).chain(old_v) {
            if let Some(buf) = spent.into_f32_vec() {
                opt_scratch.recycle(buf);
            }
        }
        Ok(per)
    }

    /// See [`TrainState::eval`] — but, like [`ShardedState::step`], run
    /// data-parallel across the shard workers when sharded. Eval has no
    /// cross-slot reduction at all (per-slot loss/acc over the slot's own
    /// rows), so the slot-partitioned eval is bitwise identical to the
    /// fused path; `rust/tests/session.rs` and the tests below pin it.
    /// Falls back to the fused eval when running fused or when the
    /// backend cannot eval at shard granularity.
    #[allow(clippy::too_many_arguments)]
    pub fn eval(
        &mut self,
        exe: &Executable,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if self.shards.is_empty() {
            return self.inner.eval(exe, base, tokens, targets, loss_mask, scale);
        }
        let ShardedState { inner, shards, pool, bs, .. } = self;
        let n = inner.n;
        if tokens.shape != [n, *bs, inner.model.seq] {
            bail!(
                "sharded eval: batch tensors {:?} do not match the built ({n}, {bs}, {}) layout",
                tokens.shape,
                inner.model.seq
            );
        }
        if scale.len() != n {
            bail!("sharded eval: {} scale entries for pack of {n}", scale.len());
        }
        let row = *bs * inner.model.seq;

        // Scatter: current LoRA params (they changed since the last step's
        // scatter), batch rows and scales — same layout as `step`.
        let tok = tokens.as_i32()?;
        let tgt = targets.as_i32()?;
        let msk = loss_mask.as_f32()?;
        for sh in shards.iter_mut() {
            for (full, dst) in inner.lora.iter().zip(sh.lora.iter_mut()) {
                scatter_slots(full, dst, n, sh.lo, sh.hi)?;
            }
            sh.tokens.as_i32_mut()?.copy_from_slice(&tok[sh.lo * row..sh.hi * row]);
            sh.targets.as_i32_mut()?.copy_from_slice(&tgt[sh.lo * row..sh.hi * row]);
            sh.mask.as_f32_mut()?.copy_from_slice(&msk[sh.lo * row..sh.hi * row]);
            sh.scale.copy_from_slice(&scale[sh.lo..sh.hi]);
            sh.eval_out = None;
        }

        // Logits-only forward per shard, one persistent worker per device.
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(shards.len());
            for sh in shards.iter_mut() {
                tasks.push(Box::new(move || {
                    let r = sh.exe.run_eval(
                        base,
                        &sh.lora,
                        &sh.tokens,
                        &sh.targets,
                        &sh.mask,
                        &sh.scale,
                        &mut sh.scratch,
                    );
                    sh.eval_out = Some(r);
                }));
            }
            pool.as_ref().expect("shard pool").scoped(tasks);
        }

        // Gather per-slot (loss, acc) in fixed shard order 0..d-1. Each
        // slot's metrics come from exactly one shard — no reduction, no
        // reassociation, bitwise identity with the fused eval.
        let mut loss = vec![0.0f32; n];
        let mut acc = vec![0.0f32; n];
        for sh in shards.iter_mut() {
            match sh.eval_out.take().expect("shard evaluated")? {
                Some((l, a)) => {
                    if l.len() != sh.nw() {
                        bail!("shard returned {} eval losses for {} slots", l.len(), sh.nw());
                    }
                    loss[sh.lo..sh.hi].copy_from_slice(&l);
                    acc[sh.lo..sh.hi].copy_from_slice(&a);
                }
                None => {
                    // Backend splits grads but not eval: fused fallback.
                    return inner.eval(exe, base, tokens, targets, loss_mask, scale);
                }
            }
        }
        Ok((loss, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn runtime() -> Runtime {
        Runtime::load(&std::env::temp_dir().join("plora-shard-tests")).unwrap()
    }

    /// The tentpole invariant at the runtime layer: the same pack stepped
    /// at d = 1 (fused), 2, 3 (uneven) and 4 produces bitwise-identical
    /// params, moments, per-adapter step counters and losses.
    #[test]
    fn sharded_steps_are_bitwise_identical_across_device_counts() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 4, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;
        let seeds = [3u64, 5, 7, 9];
        let ranks = [8usize, 4, 8, 6];

        #[allow(clippy::type_complexity)]
        let run = |devs: usize| -> (Vec<Vec<f32>>, Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
            let inner = TrainState::init_per_adapter(&mi, 4, 8, &seeds, &ranks).unwrap();
            let devices: Vec<usize> = (0..devs).collect();
            let mut st = ShardedState::new(&rt, "nano", inner, 1, &devices).unwrap();
            assert_eq!(st.parallelism(), devs.min(4).max(1));
            let rmask = st.rank_mask(&ranks).unwrap();
            let mut rng = Rng::new(41);
            let mut losses = vec![];
            for _ in 0..3 {
                let tokens: Vec<i32> =
                    (0..4 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![4, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![4, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![4, 1, seq], vec![1.0; 4 * seq]).unwrap();
                let per = st
                    .step(
                        &exe,
                        &base,
                        &tok,
                        &tgt,
                        &msk,
                        &[1.0, 0.5, 1.0, 0.8],
                        &[2e-3, 1e-3, 2e-3, 1e-3],
                        &rmask,
                    )
                    .unwrap();
                losses.push(per);
            }
            let inner = st.into_inner();
            let lora = inner.lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
            let moments = inner.m.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
            (lora, inner.t.clone(), moments, losses)
        };

        let (want_l, want_t, want_m, want_per) = run(1);
        assert_eq!(want_t, vec![3.0; 4]);
        assert!(want_per.iter().flatten().all(|l| l.is_finite()));
        for d in [2usize, 3, 4, 8] {
            let (got_l, got_t, got_m, got_per) = run(d);
            assert_eq!(want_t, got_t, "step counters diverged at d={d}");
            assert_eq!(want_per, got_per, "per-adapter losses diverged at d={d}");
            for (k, (a, b)) in want_l.iter().zip(&got_l).enumerate() {
                assert_eq!(a, b, "lora[{k}] diverged at d={d}");
            }
            for (k, (a, b)) in want_m.iter().zip(&got_m).enumerate() {
                assert_eq!(a, b, "m[{k}] diverged at d={d}");
            }
        }
    }

    /// Satellite invariant: the eval pass sharded across d = 2, 3
    /// (uneven), 4 and 8 devices returns bitwise-identical per-slot
    /// (loss, acc) to the fused d = 1 eval — including mid-trajectory,
    /// after params have moved.
    #[test]
    fn sharded_eval_is_bitwise_identical_across_device_counts() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 4, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let eval_exe = rt.executable(&rt.manifest.eval_for(&info).unwrap().name.clone()).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;
        let seeds = [3u64, 5, 7, 9];
        let ranks = [8usize, 4, 8, 6];
        let scale = [1.0f32, 0.5, 1.0, 0.8];

        let run = |devs: usize| -> Vec<(Vec<u32>, Vec<u32>)> {
            let inner = TrainState::init_per_adapter(&mi, 4, 8, &seeds, &ranks).unwrap();
            let devices: Vec<usize> = (0..devs).collect();
            let mut st = ShardedState::new(&rt, "nano", inner, 1, &devices).unwrap();
            let rmask = st.rank_mask(&ranks).unwrap();
            let mut rng = Rng::new(23);
            let mut evals = vec![];
            for _ in 0..2 {
                let tokens: Vec<i32> =
                    (0..4 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![4, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![4, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![4, 1, seq], vec![1.0; 4 * seq]).unwrap();
                // Eval both before and after a param-moving step.
                let (l, a) = st.eval(&eval_exe, &base, &tok, &tgt, &msk, &scale).unwrap();
                evals.push((
                    l.iter().map(|x| x.to_bits()).collect(),
                    a.iter().map(|x| x.to_bits()).collect(),
                ));
                st.step(&exe, &base, &tok, &tgt, &msk, &scale, &[2e-3, 1e-3, 2e-3, 1e-3], &rmask)
                    .unwrap();
            }
            let (l, a) = {
                let tokens: Vec<i32> =
                    (0..4 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![4, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![4, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![4, 1, seq], vec![1.0; 4 * seq]).unwrap();
                st.eval(&eval_exe, &base, &tok, &tgt, &msk, &scale).unwrap()
            };
            evals.push((
                l.iter().map(|x| x.to_bits()).collect(),
                a.iter().map(|x| x.to_bits()).collect(),
            ));
            evals
        };

        let want = run(1);
        assert!(want.iter().all(|(l, _)| l.iter().all(|&b| f32::from_bits(b).is_finite())));
        for d in [2usize, 3, 4, 8] {
            assert_eq!(want, run(d), "sharded eval diverged from fused at d={d}");
        }
    }

    /// A mid-run device retarget (1 -> 2 -> 1 devices) leaves the
    /// trajectory bitwise unchanged, and per-shard timings surface.
    #[test]
    fn device_retarget_mid_run_is_bitwise_invariant() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 2, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;

        let run = |retarget: bool| -> Vec<Vec<f32>> {
            let inner = TrainState::init_per_adapter(&mi, 2, 8, &[5, 9], &[8, 4]).unwrap();
            let mut st = ShardedState::new(&rt, "nano", inner, 1, &[0]).unwrap();
            let rmask = st.rank_mask(&[8, 4]).unwrap();
            let mut rng = Rng::new(13);
            for step in 0..4 {
                if retarget && step == 2 {
                    st.set_devices(&rt, "nano", &[0, 1]).unwrap();
                    assert_eq!(st.parallelism(), 2);
                }
                if retarget && step == 3 {
                    st.set_devices(&rt, "nano", &[1]).unwrap();
                    assert_eq!(st.parallelism(), 1);
                }
                let tokens: Vec<i32> =
                    (0..2 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![2, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![2, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![2, 1, seq], vec![1.0; 2 * seq]).unwrap();
                st.step(&exe, &base, &tok, &tgt, &msk, &[1.0, 0.5], &[2e-3, 1e-3], &rmask)
                    .unwrap();
                if retarget && step == 2 {
                    let secs = st.shard_secs();
                    assert_eq!(secs.len(), 2, "per-shard timings recorded");
                    assert_eq!(secs[0].0, 0, "shard 0 stands for device 0");
                    assert_eq!(secs[1].0, 1, "shard 1 stands for device 1");
                    assert!(secs.iter().all(|&(_, s)| s >= 0.0));
                }
            }
            st.into_inner().lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect()
        };
        let plain = run(false);
        let moved = run(true);
        for (k, (a, b)) in plain.iter().zip(&moved).enumerate() {
            assert_eq!(a, b, "lora[{k}] diverged across the device retarget");
        }
    }

    /// The `d × s` composition: the same pack stepped fused, pure
    /// data-parallel (d=2), pure stage-pipelined (s=2) and composed
    /// (d=2 × s=2) produces bitwise-identical trajectories and losses.
    #[test]
    fn stage_and_device_axes_compose_bitwise() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 4, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;
        let seeds = [3u64, 5, 7, 9];
        let ranks = [8usize, 4, 8, 6];

        #[allow(clippy::type_complexity)]
        let run = |devs: usize, stages: usize| -> (Vec<Vec<f32>>, Vec<f32>, Vec<Vec<f32>>) {
            let inner = TrainState::init_per_adapter(&mi, 4, 8, &seeds, &ranks).unwrap();
            let devices: Vec<usize> = (0..devs).collect();
            let mut st =
                ShardedState::new_with_stages(&rt, "nano", inner, 1, &devices, stages).unwrap();
            if stages > 1 {
                assert_eq!(st.stages(), stages.min(mi.n_layers), "pipeline depth engaged");
            }
            let rmask = st.rank_mask(&ranks).unwrap();
            let mut rng = Rng::new(41);
            let mut losses = vec![];
            for _ in 0..3 {
                let tokens: Vec<i32> =
                    (0..4 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![4, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![4, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![4, 1, seq], vec![1.0; 4 * seq]).unwrap();
                let per = st
                    .step(
                        &exe,
                        &base,
                        &tok,
                        &tgt,
                        &msk,
                        &[1.0, 0.5, 1.0, 0.8],
                        &[2e-3, 1e-3, 2e-3, 1e-3],
                        &rmask,
                    )
                    .unwrap();
                losses.push(per);
            }
            let inner = st.into_inner();
            let lora = inner.lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
            let t = inner.t.clone();
            let mut flat = vec![];
            for l in losses {
                flat.extend(l);
            }
            (lora, t, vec![flat])
        };

        let want = run(1, 1);
        for (d, s) in [(1usize, 2usize), (2, 1), (2, 2), (2, 4), (3, 2)] {
            let got = run(d, s);
            assert_eq!(want.1, got.1, "step counters diverged at d={d} s={s}");
            assert_eq!(want.2, got.2, "losses diverged at d={d} s={s}");
            for (k, (a, b)) in want.0.iter().zip(&got.0).enumerate() {
                assert_eq!(a, b, "lora[{k}] diverged at d={d} s={s}");
            }
        }
    }

    /// A mid-run stage retarget (s: 1 -> 2 -> 1) leaves the trajectory
    /// bitwise unchanged — the pipeline analogue of the device retarget.
    #[test]
    fn stage_retarget_mid_run_is_bitwise_invariant() {
        let rt = runtime();
        let mi = rt.manifest.model("nano").unwrap().clone();
        let info = rt.manifest.train_bucket("nano", 2, 8, 1).unwrap().clone();
        let exe = rt.executable(&info.name).unwrap();
        let base = rt.base_weights("nano").unwrap();
        let seq = mi.seq;

        let run = |retarget: bool| -> Vec<Vec<f32>> {
            let inner = TrainState::init_per_adapter(&mi, 2, 8, &[5, 9], &[8, 4]).unwrap();
            let mut st = ShardedState::new(&rt, "nano", inner, 1, &[0]).unwrap();
            let rmask = st.rank_mask(&[8, 4]).unwrap();
            let mut rng = Rng::new(13);
            for step in 0..4 {
                if retarget && step == 2 {
                    st.set_stages(&rt, "nano", 2).unwrap();
                    assert_eq!(st.stages(), 2);
                    assert_eq!(st.parallelism(), 1, "pipelining leaves the d axis alone");
                }
                if retarget && step == 3 {
                    st.set_stages(&rt, "nano", 1).unwrap();
                    assert_eq!(st.stages(), 1);
                }
                let tokens: Vec<i32> =
                    (0..2 * seq).map(|_| rng.below(mi.vocab as u64) as i32).collect();
                let mut targets = tokens.clone();
                targets.rotate_left(1);
                let tok = HostTensor::i32(vec![2, 1, seq], tokens).unwrap();
                let tgt = HostTensor::i32(vec![2, 1, seq], targets).unwrap();
                let msk = HostTensor::f32(vec![2, 1, seq], vec![1.0; 2 * seq]).unwrap();
                st.step(&exe, &base, &tok, &tgt, &msk, &[1.0, 0.5], &[2e-3, 1e-3], &rmask)
                    .unwrap();
            }
            st.into_inner().lora.iter().map(|t| t.as_f32().unwrap().to_vec()).collect()
        };
        let plain = run(false);
        let moved = run(true);
        for (k, (a, b)) in plain.iter().zip(&moved).enumerate() {
            assert_eq!(a, b, "lora[{k}] diverged across the stage retarget");
        }
    }
}
