//! On-host training state for one packed fine-tuning job: LoRA parameters,
//! AdamW moments, and the step counter, in the exact argument order of the
//! train/eval artifacts (`aot.py::train_signature`).
//!
//! Per-adapter heterogeneity enters through runtime *inputs*, not shapes:
//! `scale` (α/r), `lr`, the rank mask (true rank ≤ padded bucket rank) and
//! the loss mask (true batch ≤ padded bucket batch) — DESIGN.md §2.

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::runtime::backend::Scratch;
use crate::runtime::manifest::ModelInfo;
use crate::runtime::tensor::HostTensor;
use crate::runtime::{Executable, LORA_ORDER};
use crate::util::hash::Fnv64;
use crate::util::rng::Rng;

/// `(d_in, d_out)` of one LoRA-able projection.
pub fn proj_dims(mi: &ModelInfo, p: &str) -> (usize, usize) {
    let (d, f) = (mi.d_model, mi.d_ff);
    match p {
        "q" | "k" | "v" | "o" => (d, d),
        "up" | "gate" => (d, f),
        "down" => (f, d),
        other => panic!("unknown projection '{other}'"),
    }
}

/// Shape of LoRA tensor `name` (an `LORA_ORDER` entry) for a pack of `n`
/// adapters at padded rank `r`.
pub fn lora_shape(mi: &ModelInfo, name: &str, n: usize, r: usize) -> Vec<usize> {
    let (kind, p) = name.split_once('_').expect("lora tensor name");
    let (din, dout) = proj_dims(mi, p);
    match kind {
        "a" => vec![mi.n_layers, n, din, r],
        "b" => vec![mi.n_layers, n, r, dout],
        other => panic!("unknown lora tensor kind '{other}'"),
    }
}

/// One adapter's full training state at its true rank — what a preemption
/// checkpoint carries and what `repack_merge` restores into a (possibly
/// different) bucket. Tensors are `LORA_ORDER`-ordered true-rank slices
/// (`a_*`: `(L, d_in, rank)`, `b_*`: `(L, rank, d_out)`).
#[derive(Debug, Clone)]
pub struct MemberState {
    pub rank: usize,
    pub lora: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    /// The adapter's own AdamW step counter.
    pub t: f32,
}

impl MemberState {
    /// FNV-1a fingerprint of the final LoRA parameters: rank, then every
    /// `LORA_ORDER` tensor's f32 bit patterns in storage order. Moments
    /// and the step counter are excluded — two trainings are "the same"
    /// when they produce the same weights. Bit patterns (not values) make
    /// the hash exact, NaN included, and platform-stable.
    pub fn param_hash(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(self.rank);
        for t in &self.lora {
            if let Ok(xs) = t.as_f32() {
                for &x in xs {
                    h.write_u32(x.to_bits());
                }
            }
        }
        h.finish()
    }
}

/// One joiner entering a bucket via [`TrainState::repack_merge`].
pub enum JoinSource<'a> {
    /// A brand-new adapter: `A` drawn from its own `(seed)` stream at
    /// `rank` (exactly [`TrainState::init_per_adapter`]'s draw order),
    /// `B = 0`, moments zero, `t = 0`.
    Fresh { seed: u64, rank: usize },
    /// A previously checkpointed adapter (preempted or migrated): params,
    /// moments and step counter restored verbatim.
    Restore { member: &'a MemberState },
}

/// Draw slot `slot`'s `A` tensors from its own `seed` stream at true rank
/// `rank` into zero-initialized packed `lora` tensors of a `(n, r)`
/// bucket. The per-adapter draw order (each `a_*` tensor in `LORA_ORDER`
/// order; layers, rows, then rank columns inside it) is the contract that
/// makes an adapter's init independent of when and where it enters a pack:
/// `init_per_adapter` and `repack_merge`'s fresh joiners both call this,
/// so an adapter admitted mid-job starts from the exact state a solo run
/// starts from.
fn fill_fresh_adapter(
    mi: &ModelInfo,
    lora: &mut [HostTensor],
    slot: usize,
    n: usize,
    r: usize,
    seed: u64,
    rank: usize,
) -> Result<()> {
    let mut rng = Rng::new(seed);
    for (k, name) in LORA_ORDER.iter().enumerate() {
        if !name.starts_with("a_") {
            continue;
        }
        let p = name.split_once('_').unwrap().1;
        let din = proj_dims(mi, p).0;
        let std = 1.0 / (din as f64).sqrt();
        let buf = lora[k].as_f32_mut()?;
        for l in 0..mi.n_layers {
            let base = (l * n + slot) * din * r;
            for row in 0..din {
                for c in 0..rank {
                    buf[base + row * r + c] = (rng.normal() * std) as f32;
                }
            }
        }
    }
    Ok(())
}

/// Copy one adapter's true-rank tensor set (`LORA_ORDER`-ordered, shapes
/// `(L, rows, cols)`) into slot `slot` of packed `(n, r)` bucket tensors.
fn install_member(
    mi: &ModelInfo,
    dst: &mut [HostTensor],
    src: &[HostTensor],
    slot: usize,
    n: usize,
    r: usize,
) -> Result<()> {
    for ((name, d), s) in LORA_ORDER.iter().zip(dst).zip(src) {
        let shape = lora_shape(mi, name, n, r);
        let (d2, d3) = (shape[2], shape[3]);
        let (l, rows, cols) = (s.shape[0], s.shape[1], s.shape[2]);
        if l != shape[0] || rows > d2 || cols > d3 {
            bail!(
                "install_member: {name} checkpoint {:?} does not fit bucket slice ({},{},{})",
                s.shape,
                shape[0],
                d2,
                d3
            );
        }
        let sb = s.as_f32()?;
        let db = d.as_f32_mut()?;
        for li in 0..l {
            let so = li * rows * cols;
            let do_ = (li * n + slot) * d2 * d3;
            for row in 0..rows {
                for col in 0..cols {
                    db[do_ + row * d3 + col] = sb[so + row * cols + col];
                }
            }
        }
    }
    Ok(())
}

/// The mutable state of one packed job between steps.
pub struct TrainState {
    pub model: ModelInfo,
    /// Packed adapter count (bucket `n`).
    pub n: usize,
    /// Padded rank (bucket `r`).
    pub r: usize,
    /// LoRA params in `LORA_ORDER`.
    pub lora: Vec<HostTensor>,
    /// AdamW first moments, same order.
    pub m: Vec<HostTensor>,
    /// AdamW second moments, same order.
    pub v: Vec<HostTensor>,
    /// Per-adapter step counters `(n,)`, as the artifact expects: each
    /// slot's AdamW bias correction runs on its own clock, so a joiner
    /// admitted mid-job starts at its own step 0.
    pub t: Vec<f32>,
    /// Step-persistent backend scratch: the reference backend's workspace
    /// arena plus the recycled-output pool (zero steady-state allocation
    /// on the train path). Derived state — `init`/`repack` start fresh, so
    /// a re-bucketed job re-derives the arena at its new shape on the
    /// first step.
    scratch: Mutex<Scratch>,
}

impl TrainState {
    /// Fresh state: `A ~ N(0, 1/d_in)`, `B = 0` (standard LoRA init — the
    /// delta starts at exactly zero), moments zeroed.
    pub fn init(mi: &ModelInfo, n: usize, r: usize, seed: u64) -> TrainState {
        let mut rng = Rng::new(seed);
        let mut lora = Vec::with_capacity(LORA_ORDER.len());
        for name in LORA_ORDER {
            let shape = lora_shape(mi, name, n, r);
            let count: usize = shape.iter().product();
            let tensor = if name.starts_with("a_") {
                let p = name.split_once('_').unwrap().1;
                let din = proj_dims(mi, p).0 as f64;
                let std = 1.0 / din.sqrt();
                let data = (0..count).map(|_| (rng.normal() * std) as f32).collect();
                HostTensor::f32(shape, data).unwrap()
            } else {
                HostTensor::f32(shape, vec![0.0; count]).unwrap()
            };
            lora.push(tensor);
        }
        let m = lora
            .iter()
            .map(|t| HostTensor::f32(t.shape.clone(), vec![0.0; t.len()]).unwrap())
            .collect();
        let v = lora
            .iter()
            .map(|t| HostTensor::f32(t.shape.clone(), vec![0.0; t.len()]).unwrap())
            .collect();
        TrainState {
            model: mi.clone(),
            n,
            r,
            lora,
            m,
            v,
            t: vec![0.0; n],
            scratch: Mutex::new(Scratch::new()),
        }
    }

    /// Like [`TrainState::init`], but adapter slot `i` draws its `A` values
    /// from its *own* stream `seeds[i]`, restricted to its true rank
    /// `ranks[i]` (padded columns and unused slots start at exactly zero).
    ///
    /// This makes an adapter's initial parameters — and therefore, together
    /// with per-adapter data streams, its whole trajectory — independent of
    /// the bucket shape and of its pack neighbours (§3.2: "computation of
    /// each adapter is identical to single-adapter fine-tuning"), which is
    /// what lets the session re-bucket packs mid-job without perturbing any
    /// surviving adapter.
    pub fn init_per_adapter(
        mi: &ModelInfo,
        n: usize,
        r: usize,
        seeds: &[u64],
        ranks: &[usize],
    ) -> Result<TrainState> {
        if seeds.len() != ranks.len() {
            bail!("init_per_adapter: {} seeds for {} ranks", seeds.len(), ranks.len());
        }
        if seeds.len() > n {
            bail!("init_per_adapter: {} adapters exceed bucket n={n}", seeds.len());
        }
        if let Some(&bad) = ranks.iter().find(|&&rk| rk > r) {
            bail!("init_per_adapter: adapter rank {bad} exceeds padded rank {r}");
        }
        let mut lora = Vec::with_capacity(LORA_ORDER.len());
        for name in LORA_ORDER {
            let shape = lora_shape(mi, name, n, r);
            let count: usize = shape.iter().product();
            lora.push(HostTensor::f32(shape, vec![0.0; count]).unwrap());
        }
        for (i, (&seed, &rank)) in seeds.iter().zip(ranks).enumerate() {
            fill_fresh_adapter(mi, &mut lora, i, n, r, seed, rank)?;
        }
        let m = lora
            .iter()
            .map(|t| HostTensor::f32(t.shape.clone(), vec![0.0; t.len()]).unwrap())
            .collect();
        let v = lora
            .iter()
            .map(|t| HostTensor::f32(t.shape.clone(), vec![0.0; t.len()]).unwrap())
            .collect();
        Ok(TrainState {
            model: mi.clone(),
            n,
            r,
            lora,
            m,
            v,
            t: vec![0.0; n],
            scratch: Mutex::new(Scratch::new()),
        })
    }

    /// A zero-member shell (bucket `n = 0`): the cheap starting point for
    /// building a populated state through [`TrainState::repack_merge`] —
    /// all tensors are zero-length, so no full-bucket allocation is paid
    /// twice on the job-start path.
    pub fn empty(mi: &ModelInfo, r: usize) -> TrainState {
        let lora: Vec<HostTensor> = LORA_ORDER
            .iter()
            .map(|name| HostTensor::f32(lora_shape(mi, name, 0, r), vec![]).unwrap())
            .collect();
        TrainState {
            model: mi.clone(),
            n: 0,
            r,
            m: lora.clone(),
            v: lora.clone(),
            lora,
            t: vec![],
            scratch: Mutex::new(Scratch::new()),
        }
    }

    /// Re-pack surviving adapters into a fresh `(n_new, r_new)` bucket
    /// state (shrink-only compatibility wrapper over
    /// [`TrainState::repack_merge`] with no joiners).
    pub fn repack(
        &self,
        keep: &[(usize, usize)],
        n_new: usize,
        r_new: usize,
    ) -> Result<TrainState> {
        self.repack_merge(keep, &[], n_new, r_new)
    }

    /// The elastic generalization of `repack` (§4, DESIGN.md §10): carry
    /// surviving adapters **and merge newly admitted ones** onto a
    /// possibly larger `(n_new, r_new)` bucket.
    ///
    /// - `keep[i] = (old_slot, true_rank)` places survivor `i` into new
    ///   slot `i`, copying LoRA params, AdamW moments and its per-adapter
    ///   step counter at its true rank (zero-padded to `r_new`);
    /// - `joiners[j]` fills slot `keep.len() + j`: either a fresh adapter
    ///   (its own `A` init stream, `B = 0`, zero moments, `t = 0` — the
    ///   exact state a solo run starts from) or a restored checkpoint
    ///   ([`MemberState`], e.g. a preemption victim re-entering).
    pub fn repack_merge(
        &self,
        keep: &[(usize, usize)],
        joiners: &[JoinSource<'_>],
        n_new: usize,
        r_new: usize,
    ) -> Result<TrainState> {
        if keep.len() + joiners.len() > n_new {
            bail!(
                "repack_merge: {} survivors + {} joiners exceed bucket n={n_new}",
                keep.len(),
                joiners.len()
            );
        }
        for &(slot, rank) in keep {
            if slot >= self.n {
                bail!("repack_merge: slot {slot} out of pack of {}", self.n);
            }
            if rank > r_new || rank > self.r {
                bail!("repack_merge: rank {rank} exceeds padded rank {} -> {r_new}", self.r);
            }
        }
        for j in joiners {
            let rank = match j {
                JoinSource::Fresh { rank, .. } => *rank,
                JoinSource::Restore { member } => {
                    if member.lora.len() != LORA_ORDER.len() {
                        bail!(
                            "repack_merge: restored member has {} lora tensors, want {}",
                            member.lora.len(),
                            LORA_ORDER.len()
                        );
                    }
                    member.rank
                }
            };
            if rank > r_new {
                bail!("repack_merge: joiner rank {rank} exceeds padded rank {r_new}");
            }
        }
        let model = self.model.clone();
        let remap = |tensors: &[HostTensor]| -> Result<Vec<HostTensor>> {
            LORA_ORDER
                .iter()
                .zip(tensors)
                .map(|(name, t)| {
                    let (l, d2, d3) = (t.shape[0], t.shape[2], t.shape[3]);
                    let is_a = name.starts_with("a_");
                    let new_shape = lora_shape(&model, name, n_new, r_new);
                    let (nd2, nd3) = (new_shape[2], new_shape[3]);
                    let src = t.as_f32()?;
                    let mut data = vec![0.0f32; l * n_new * nd2 * nd3];
                    for li in 0..l {
                        for (ni, &(slot, rank)) in keep.iter().enumerate() {
                            let so = (li * self.n + slot) * d2 * d3;
                            let do_ = (li * n_new + ni) * nd2 * nd3;
                            let (rows, cols) = if is_a { (d2, rank) } else { (rank, d3) };
                            for row in 0..rows {
                                for col in 0..cols {
                                    data[do_ + row * nd3 + col] = src[so + row * d3 + col];
                                }
                            }
                        }
                    }
                    HostTensor::f32(new_shape, data)
                })
                .collect()
        };
        let mut lora = remap(&self.lora)?;
        let mut m = remap(&self.m)?;
        let mut v = remap(&self.v)?;
        let mut t = vec![0.0f32; n_new];
        for (ni, &(slot, _)) in keep.iter().enumerate() {
            t[ni] = self.t[slot];
        }
        for (j, join) in joiners.iter().enumerate() {
            let slot = keep.len() + j;
            match join {
                JoinSource::Fresh { seed, rank } => {
                    fill_fresh_adapter(&model, &mut lora, slot, n_new, r_new, *seed, *rank)?;
                }
                JoinSource::Restore { member } => {
                    install_member(&model, &mut lora, &member.lora, slot, n_new, r_new)?;
                    install_member(&model, &mut m, &member.m, slot, n_new, r_new)?;
                    install_member(&model, &mut v, &member.v, slot, n_new, r_new)?;
                    t[slot] = member.t;
                }
            }
        }
        Ok(TrainState {
            model: self.model.clone(),
            n: n_new,
            r: r_new,
            lora,
            m,
            v,
            t,
            scratch: Mutex::new(Scratch::new()),
        })
    }

    /// Drop the step-persistent scratch (arena + recycled buffers); the
    /// next step re-derives it. Benches use this to reproduce the
    /// pre-arena allocate-every-step behavior as a baseline.
    pub fn reset_scratch(&self) {
        self.scratch.lock().unwrap().reset();
    }

    /// Rank mask `(n, r_pad)`: adapter `i` keeps columns `< ranks[i]`.
    pub fn rank_mask(&self, ranks: &[usize]) -> Result<HostTensor> {
        if ranks.len() != self.n {
            bail!("rank_mask: {} ranks for pack of {}", ranks.len(), self.n);
        }
        let mut data = vec![0.0f32; self.n * self.r];
        for (i, &rk) in ranks.iter().enumerate() {
            if rk > self.r {
                bail!("rank_mask: adapter rank {rk} exceeds padded rank {}", self.r);
            }
            for c in 0..rk {
                data[i * self.r + c] = 1.0;
            }
        }
        HostTensor::f32(vec![self.n, self.r], data)
    }

    /// One training step. `base` is the frozen weight list (`BASE_ORDER`);
    /// `tokens`/`targets` are `(n, bs, seq)` i32; `loss_mask` `(n, bs, seq)`
    /// f32; `scale`/`lr` per-adapter `(n,)`. Returns per-adapter losses.
    ///
    /// Inputs are borrowed (no state deep-copies) and the run carries this
    /// state's persistent [`Scratch`]; the previous step's parameter and
    /// moment buffers are recycled into the scratch pool, where the
    /// backend's AdamW takes its output buffers from — so steady-state
    /// steps perform no allocation.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        exe: &Executable,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
        lr: &[f32],
        rmask: &HostTensor,
    ) -> Result<Vec<f32>> {
        let t_t = HostTensor::f32(vec![self.n], self.t.clone())?;
        let scale_t = HostTensor::f32(vec![self.n], scale.to_vec())?;
        let lr_t = HostTensor::f32(vec![self.n], lr.to_vec())?;
        let mut outs = {
            let mut inputs: Vec<&HostTensor> = Vec::with_capacity(12 + 3 * 14 + 7);
            inputs.extend(base.iter());
            inputs.extend(self.lora.iter());
            inputs.extend(self.m.iter());
            inputs.extend(self.v.iter());
            inputs.push(&t_t);
            inputs.push(tokens);
            inputs.push(targets);
            inputs.push(loss_mask);
            inputs.push(&scale_t);
            inputs.push(&lr_t);
            inputs.push(rmask);
            let mut scratch = self.scratch.lock().unwrap();
            exe.run_scratch(&inputs, &mut scratch)?
        };
        // Outputs: 14 lora, 14 m, 14 v, t, per_loss (train_output_names()).
        if outs.len() != 3 * LORA_ORDER.len() + 2 {
            bail!("train step returned {} outputs", outs.len());
        }
        let per = outs.pop().unwrap();
        let t = outs.pop().unwrap();
        self.t = t.as_f32()?.to_vec();
        let nl = LORA_ORDER.len();
        let old_v = std::mem::replace(&mut self.v, outs.split_off(2 * nl));
        let old_m = std::mem::replace(&mut self.m, outs.split_off(nl));
        let old_l = std::mem::replace(&mut self.lora, outs);
        // Close the allocation cycle: the spent state buffers become the
        // next step's output buffers.
        let mut scratch = self.scratch.lock().unwrap();
        for spent in old_l.into_iter().chain(old_m).chain(old_v) {
            if let Some(buf) = spent.into_f32_vec() {
                scratch.recycle(buf);
            }
        }
        Ok(per.as_f32()?.to_vec())
    }

    /// Per-adapter eval: returns `(loss, accuracy)` vectors. Shares this
    /// state's persistent [`Scratch`] (the eval forward reuses the same
    /// workspace arena the train steps run in).
    pub fn eval(
        &self,
        exe: &Executable,
        base: &[HostTensor],
        tokens: &HostTensor,
        targets: &HostTensor,
        loss_mask: &HostTensor,
        scale: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let scale_t = HostTensor::f32(vec![self.n], scale.to_vec())?;
        let mut inputs: Vec<&HostTensor> = Vec::with_capacity(12 + 14 + 4);
        inputs.extend(base.iter());
        inputs.extend(self.lora.iter());
        inputs.push(tokens);
        inputs.push(targets);
        inputs.push(loss_mask);
        inputs.push(&scale_t);
        let outs = {
            let mut scratch = self.scratch.lock().unwrap();
            exe.run_scratch(&inputs, &mut scratch)?
        };
        if outs.len() != 2 {
            bail!("eval step returned {} outputs", outs.len());
        }
        Ok((outs[0].as_f32()?.to_vec(), outs[1].as_f32()?.to_vec()))
    }

    /// Extract adapter `slot`'s LoRA tensors at its true rank — the
    /// checkpoint written to the Checkpoint Pool when a job completes (§4).
    pub fn extract_adapter(&self, slot: usize, rank: usize) -> Result<Vec<(String, HostTensor)>> {
        if slot >= self.n || rank > self.r {
            bail!("extract_adapter: slot {slot}/{} rank {rank}/{}", self.n, self.r);
        }
        let slices = self.slice_slot(&self.lora, slot, rank)?;
        Ok(LORA_ORDER.iter().map(|n| n.to_string()).zip(slices).collect())
    }

    /// Extract adapter `slot`'s **full training state** at its true rank —
    /// params, AdamW moments and its per-adapter step counter. This is the
    /// preemption checkpoint: [`TrainState::repack_merge`] with
    /// [`JoinSource::Restore`] resumes the adapter bit-identically, in any
    /// bucket.
    pub fn extract_member(&self, slot: usize, rank: usize) -> Result<MemberState> {
        if slot >= self.n || rank > self.r {
            bail!("extract_member: slot {slot}/{} rank {rank}/{}", self.n, self.r);
        }
        Ok(MemberState {
            rank,
            lora: self.slice_slot(&self.lora, slot, rank)?,
            m: self.slice_slot(&self.m, slot, rank)?,
            v: self.slice_slot(&self.v, slot, rank)?,
            t: self.t[slot],
        })
    }

    /// True-rank slices of one slot across an `LORA_ORDER` tensor set.
    fn slice_slot(
        &self,
        tensors: &[HostTensor],
        slot: usize,
        rank: usize,
    ) -> Result<Vec<HostTensor>> {
        LORA_ORDER
            .iter()
            .zip(tensors)
            .map(|(name, tensor)| {
                let (kind, _) = name.split_once('_').unwrap();
                let (l, n, d2, d3) =
                    (tensor.shape[0], tensor.shape[1], tensor.shape[2], tensor.shape[3]);
                let src = tensor.as_f32()?;
                let (rows, cols) = if kind == "a" { (d2, rank) } else { (rank, d3) };
                let mut data = Vec::with_capacity(l * rows * cols);
                for layer in 0..l {
                    let base_off = (layer * n + slot) * d2 * d3;
                    for i in 0..rows {
                        let row = &src[base_off + i * d3..base_off + i * d3 + d3];
                        data.extend_from_slice(&row[..cols]);
                    }
                }
                HostTensor::f32(vec![l, rows, cols], data)
            })
            .collect()
    }

    /// Total f32 elements held (params + moments) — memory accounting.
    pub fn elements(&self) -> usize {
        3 * self.lora.iter().map(|t| t.len()).sum::<usize>() + self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mi() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            vocab: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 16,
            seq: 8,
            params: 0,
            weights: String::new(),
        }
    }

    #[test]
    fn init_shapes_and_b_zero() {
        let st = TrainState::init(&mi(), 3, 4, 7);
        assert_eq!(st.lora.len(), 14);
        // a_q: (L=2, n=3, d=8, r=4); b_q: (2, 3, 4, 8)
        let aq = &st.lora[LORA_ORDER.iter().position(|x| *x == "a_q").unwrap()];
        assert_eq!(aq.shape, vec![2, 3, 8, 4]);
        let bq = &st.lora[LORA_ORDER.iter().position(|x| *x == "b_q").unwrap()];
        assert_eq!(bq.shape, vec![2, 3, 4, 8]);
        assert!(bq.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(aq.as_f32().unwrap().iter().any(|&x| x != 0.0));
        // moments zeroed
        assert!(st.m.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn rank_mask_marks_true_ranks() {
        let st = TrainState::init(&mi(), 2, 4, 1);
        let m = st.rank_mask(&[2, 4]).unwrap();
        assert_eq!(m.as_f32().unwrap(), &[1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        assert!(st.rank_mask(&[5, 1]).is_err());
        assert!(st.rank_mask(&[1]).is_err());
    }

    /// Per-adapter init: a given (seed, rank) draws the same A values no
    /// matter the bucket shape or slot population around it.
    #[test]
    fn per_adapter_init_is_shape_independent() {
        let m = mi();
        let solo = TrainState::init_per_adapter(&m, 1, 4, &[7], &[3]).unwrap();
        let packed = TrainState::init_per_adapter(&m, 3, 8, &[9, 7], &[4, 3]).unwrap();
        let idx = LORA_ORDER.iter().position(|x| *x == "a_q").unwrap();
        let (sa, pa) = (solo.lora[idx].as_f32().unwrap(), packed.lora[idx].as_f32().unwrap());
        // Solo: (L=2, n=1, d=8, r=4); packed: (L=2, n=3, d=8, r=8), slot 1.
        for l in 0..2 {
            for row in 0..8 {
                for c in 0..3 {
                    let s = sa[(l * 8 + row) * 4 + c];
                    let p = pa[((l * 3 + 1) * 8 + row) * 8 + c];
                    assert_eq!(s, p, "a_q[{l},{row},{c}] diverged across shapes");
                }
                // Padded columns start at exactly zero.
                assert_eq!(sa[(l * 8 + row) * 4 + 3], 0.0);
            }
        }
        // B tensors and unused slots are zero.
        let bidx = LORA_ORDER.iter().position(|x| *x == "b_q").unwrap();
        assert!(packed.lora[bidx].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(TrainState::init_per_adapter(&m, 1, 4, &[1, 2], &[4, 4]).is_err());
        assert!(TrainState::init_per_adapter(&m, 2, 4, &[1], &[5]).is_err());
    }

    /// Repack moves a survivor to a smaller bucket with params, moments
    /// and its own step counter intact at its true rank.
    #[test]
    fn repack_carries_params_and_moments() {
        let m = mi();
        let mut st = TrainState::init_per_adapter(&m, 2, 8, &[3, 4], &[4, 8]).unwrap();
        st.t = vec![5.0, 9.0];
        // Plant a recognizable moment value for slot 0.
        let idx = LORA_ORDER.iter().position(|x| *x == "a_q").unwrap();
        st.m[idx].as_f32_mut().unwrap()[0] = 0.25; // layer 0, slot 0, row 0, col 0
        let small = st.repack(&[(0, 4)], 1, 4).unwrap();
        assert_eq!((small.n, small.r), (1, 4));
        assert_eq!(small.t, vec![5.0], "per-adapter t travels with its slot");
        let (big, sm) = (st.lora[idx].as_f32().unwrap(), small.lora[idx].as_f32().unwrap());
        // a_q old (2, 2, 8, 8) -> new (2, 1, 8, 4): slot 0, cols < 4.
        for l in 0..2 {
            for row in 0..8 {
                for c in 0..4 {
                    assert_eq!(sm[(l * 8 + row) * 4 + c], big[((l * 2) * 8 + row) * 8 + c]);
                }
            }
        }
        assert_eq!(small.m[idx].as_f32().unwrap()[0], 0.25);
        assert!(st.repack(&[(2, 4)], 1, 4).is_err());
        assert!(st.repack(&[(0, 8)], 1, 4).is_err());
    }

    /// `repack_merge` with a fresh joiner reproduces the exact state a
    /// solo `init_per_adapter` run starts from (same seed stream, B = 0,
    /// zero moments, t = 0) — and can *grow* the bucket to make room.
    #[test]
    fn repack_merge_fresh_joiner_matches_solo_init() {
        let m = mi();
        let mut st = TrainState::init_per_adapter(&m, 1, 4, &[3], &[4]).unwrap();
        st.t = vec![7.0];
        // Grow (1, 4) -> (3, 8): survivor in slot 0, fresh joiner slot 1.
        let joiners = [JoinSource::Fresh { seed: 11, rank: 3 }];
        let grown = st.repack_merge(&[(0, 4)], &joiners, 3, 8).unwrap();
        assert_eq!((grown.n, grown.r), (3, 8));
        assert_eq!(grown.t, vec![7.0, 0.0, 0.0]);
        // The joiner's A equals a solo init from the same seed.
        let solo = TrainState::init_per_adapter(&m, 1, 4, &[11], &[3]).unwrap();
        let idx = LORA_ORDER.iter().position(|x| *x == "a_q").unwrap();
        let (sa, ga) = (solo.lora[idx].as_f32().unwrap(), grown.lora[idx].as_f32().unwrap());
        for l in 0..2 {
            for row in 0..8 {
                for c in 0..3 {
                    let s = sa[(l * 8 + row) * 4 + c];
                    let g = ga[((l * 3 + 1) * 8 + row) * 8 + c];
                    assert_eq!(s, g, "fresh joiner a_q[{l},{row},{c}] diverged from solo init");
                }
            }
        }
        // Joiner moments are zero; overflow and oversized ranks rejected.
        assert!(grown.m[idx].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(st
            .repack_merge(&[(0, 4)], &[JoinSource::Fresh { seed: 1, rank: 4 }], 1, 4)
            .is_err());
        assert!(st
            .repack_merge(&[], &[JoinSource::Fresh { seed: 1, rank: 9 }], 2, 8)
            .is_err());
    }

    /// `extract_member` + `repack_merge(Restore)` round-trip an adapter's
    /// full training state bit-exactly through a different bucket shape.
    #[test]
    fn extract_member_restore_roundtrip() {
        let m = mi();
        let mut st = TrainState::init_per_adapter(&m, 2, 8, &[5, 6], &[4, 8]).unwrap();
        st.t = vec![3.0, 12.0];
        let idx = LORA_ORDER.iter().position(|x| *x == "b_q").unwrap();
        // b_q slot 1: packed (L=2, n=2, r=8, d=8); plant values in rank
        // rows < true rank.
        st.v[idx].as_f32_mut().unwrap()[(2 + 1) * 8 * 8] = 0.5; // l=1, slot 1
        let member = st.extract_member(1, 8).unwrap();
        assert_eq!(member.t, 12.0);
        assert_eq!(member.lora.len(), 14);
        // Restore into a fresh (1, 8) bucket as the only member.
        let empty = TrainState::init_per_adapter(&m, 1, 8, &[], &[]).unwrap();
        let back = empty
            .repack_merge(&[], &[JoinSource::Restore { member: &member }], 1, 8)
            .unwrap();
        assert_eq!(back.t, vec![12.0]);
        // `back` slot 0 must hold exactly what `st` slot 1 held.
        let rb = back.extract_member(0, 8).unwrap();
        let pairs = member
            .lora
            .iter()
            .zip(&rb.lora)
            .chain(member.m.iter().zip(&rb.m))
            .chain(member.v.iter().zip(&rb.v));
        for (a, b) in pairs {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        assert_eq!(
            back.v[idx].as_f32().unwrap()[8 * 8],
            0.5,
            "second moment survived the round trip (l=1, slot 0)"
        );
        assert!(st.extract_member(2, 8).is_err());
    }

    #[test]
    fn extract_adapter_slices_true_rank() {
        let m = mi();
        let mut st = TrainState::init(&m, 2, 4, 1);
        // Fill a_q with a recognizable pattern: value = slot as f32.
        let idx = LORA_ORDER.iter().position(|x| *x == "a_q").unwrap();
        let t = &mut st.lora[idx];
        let (l, n, d, r) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
        let buf = t.as_f32_mut().unwrap();
        for layer in 0..l {
            for slot in 0..n {
                for i in 0..d * r {
                    buf[(layer * n + slot) * d * r + i] = slot as f32;
                }
            }
        }
        let ckpt = st.extract_adapter(1, 2).unwrap();
        let (name, aq) = &ckpt[idx];
        assert_eq!(name, "a_q");
        assert_eq!(aq.shape, vec![2, 8, 2]); // (L, din, true rank)
        assert!(aq.as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(st.extract_adapter(5, 2).is_err());
    }
}
