//! Host-side tensors — the currency every execution backend trades in.
//! With the `pjrt` feature, conversions to/from `xla::Literal` are
//! compiled in for the PJRT backend.
//!
//! Only the two dtypes the artifacts use exist (f32, i32) — keeping the
//! enum closed lets every call site match exhaustively.

use anyhow::{anyhow, bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use xla::{ElementType, Literal};

/// Element type of a host tensor (mirrors `python/compile/io_bin.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

/// Tensor payload.
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor with shape. The runtime moves these across the PJRT
/// boundary; everything upstream (task generators, LoRA state) works on
/// plain `Vec`s.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<HostTensor> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: TensorData::I32(data) })
    }

    /// All-zero tensor of the given dtype/shape.
    pub fn zeros(dtype: DType, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
        };
        HostTensor { shape, data }
    }

    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::F32(vec![x]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match &mut self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    /// Consume the tensor, handing back its f32 buffer (buffer recycling —
    /// see `runtime::backend::Scratch`). `None` for i32 tensors.
    pub fn into_f32_vec(self) -> Option<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Some(v),
            TensorData::I32(_) => None,
        }
    }

    /// Convert to an `xla::Literal` (rank-0 scalars included).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => Literal::vec1(v),
            TensorData::I32(v) => Literal::vec1(v),
        };
        lit.reshape(&dims).with_context(|| format!("reshape to {:?}", self.shape))
    }

    /// Read back from an `xla::Literal`.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => {
                Ok(HostTensor { shape: dims, data: TensorData::F32(lit.to_vec()?) })
            }
            ElementType::S32 => {
                Ok(HostTensor { shape: dims, data: TensorData::I32(lit.to_vec()?) })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_round_trip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_round_trip_i32_and_scalar() {
        let t = HostTensor::i32(vec![3], vec![7, -1, 5]).unwrap();
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, -1, 5]);

        let s = HostTensor::scalar_f32(2.5);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert!(back.shape.is_empty());
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn zeros_dtypes() {
        let z = HostTensor::zeros(DType::I32, vec![2, 2]);
        assert_eq!(z.as_i32().unwrap(), &[0; 4]);
        assert_eq!(z.dtype(), DType::I32);
    }
}
