//! Reader for the `PLORAT01` tensor container (see
//! `python/compile/io_bin.py` — the two sides must stay in lock-step).
//!
//! Layout: `b"PLORAT01"`, `count u32le`, then per tensor:
//! `name_len u32le, name, dtype u8 (0=f32 1=i32), ndim u8, dims u32le*,
//! raw LE data`.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{HostTensor, TensorData};

const MAGIC: &[u8; 8] = b"PLORAT01";

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

/// Read every tensor in the container, keyed by name.
pub fn read_tensors(path: &Path) -> Result<BTreeMap<String, HostTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    let mut r: &[u8] = &bytes;
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {magic:?}", path.display());
    }
    let count = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let dt = read_u8(&mut r)?;
        let ndim = read_u8(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut raw = vec![0u8; n * 4];
        r.read_exact(&mut raw)
            .with_context(|| format!("{name}: truncated data ({n} elems)"))?;
        let data = match dt {
            0 => TensorData::F32(
                raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
            1 => TensorData::I32(
                raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
            other => bail!("{name}: unsupported dtype tag {other}"),
        };
        out.insert(name, HostTensor { shape: dims, data });
    }
    Ok(out)
}

/// Write tensors in the `PLORAT01` container format (checkpoint pool;
/// readable back by both this module and `io_bin.py`).
pub fn write_tensors(path: &Path, tensors: &[(String, HostTensor)]) -> Result<()> {
    let mut buf: Vec<u8> = vec![];
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        let tag = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
        };
        buf.push(tag);
        buf.push(t.shape.len() as u8);
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, buf).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn write_read_round_trip() {
        let dir = std::env::temp_dir().join("plora_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.bin");
        let tensors = vec![
            ("a".to_string(), HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap()),
            ("b".to_string(), HostTensor::i32(vec![3], vec![-1, 0, 9]).unwrap()),
        ];
        write_tensors(&p, &tensors).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back["a"].as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["a"].shape, vec![2, 2]);
        assert_eq!(back["b"].as_i32().unwrap(), &[-1, 0, 9]);
    }

    fn write_container(tensors: &[(&str, u8, Vec<u32>, Vec<u8>)]) -> Vec<u8> {
        let mut f = vec![];
        f.write_all(MAGIC).unwrap();
        f.write_all(&(tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, dt, dims, data) in tensors {
            f.write_all(&(name.len() as u32).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[*dt, dims.len() as u8]).unwrap();
            for d in dims {
                f.write_all(&d.to_le_bytes()).unwrap();
            }
            f.write_all(data).unwrap();
        }
        f
    }

    #[test]
    fn parses_hand_built_container() {
        let payload: Vec<u8> = [1.5f32, -2.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let ints: Vec<u8> = [3i32].iter().flat_map(|x| x.to_le_bytes()).collect();
        let bytes = write_container(&[
            ("w", 0, vec![2], payload),
            ("idx", 1, vec![1], ints),
        ]);
        let dir = std::env::temp_dir().join("plora_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        std::fs::write(&p, bytes).unwrap();
        let ts = read_tensors(&p).unwrap();
        assert_eq!(ts["w"].as_f32().unwrap(), &[1.5, -2.0]);
        assert_eq!(ts["idx"].as_i32().unwrap(), &[3]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("plora_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC\x00\x00\x00\x00").unwrap();
        assert!(read_tensors(&p).is_err());
    }

    #[test]
    fn reads_real_pretrained_weights_if_present() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights_nano.bin");
        if !p.exists() {
            return; // artifacts not built yet
        }
        let ts = read_tensors(&p).unwrap();
        // BASE_ORDER has 12 tensors (model.py).
        assert_eq!(ts.len(), 12);
        assert_eq!(ts["embed"].shape, vec![256, 64]);
        assert!(ts["wq"].shape.len() == 3);
    }
}
