//! Hyperparameter-quality sweeps — the live reproduction of the paper's
//! empirical study (§2.3): Tables 2, 3, 4, and 6, at testbed scale
//! (TinyLM models + synthetic tasks standing in for Qwen/LLaMa + GLUE,
//! DESIGN.md §3).
//!
//! The sweep runs the paper's own workflow end-to-end: the configurations
//! are planned by [`crate::planner::JobPlanner`] against the live bucket
//! grid, then executed through a [`crate::session::Session`] — so every
//! sweep exercises the planner, the packed engine, and adapter-completion
//! re-bucketing. The system being evaluated is also the system producing
//! its own quality study, exactly as PLoRA is used in the paper.

pub mod tuner;

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{geometry, pool, AdapterSpec, LoraConfig};
use crate::costmodel::{CostModel, TrainBudget};
use crate::metrics::Table;
use crate::runtime::Runtime;
use crate::session::Policy;
use crate::train::AdapterReport;

pub use tuner::{parse_tuner, rung_datasets, Asha, FullSweep, RungSummary, Tuner, TunerOutcome};

/// The default LoRA configuration a practitioner would start from
/// (Unsloth-style defaults — Table 6's middle column). Id-less: bind one
/// with [`AdapterSpec::with_id`] or let a session assign it at submit.
pub fn default_config(task: &str) -> AdapterSpec {
    AdapterSpec::new(task)
}

/// Options for a quality sweep.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    pub budget: TrainBudget,
    pub eval_batches: usize,
    pub seed: u64,
    /// Capacity slots of the live pool the sweep schedules onto.
    pub gpus: usize,
    /// Dispatch policy of the backing session (per-adapter results are
    /// policy-invariant — the bit-identity guarantee — only the timeline
    /// changes).
    pub policy: Policy,
    /// Elastic mid-job admission of queued sweep jobs.
    pub elastic: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            budget: TrainBudget { dataset: 128, epochs: 1 },
            eval_batches: 4,
            seed: 23,
            gpus: 2,
            policy: Policy::Fifo,
            elastic: false,
        }
    }
}

/// The live cost model for a runtime model: TinyLM geometry on the cpu-sim
/// profile, charged at padded static shapes and constrained to the
/// manifest's bucket grid.
pub fn live_cost_model(rt: &Runtime, model: &str) -> Result<CostModel> {
    let geom = match geometry::geom(model) {
        Some(g) => g.clone(),
        None => {
            let mi = rt.manifest.model(model)?;
            geometry::tiny_geom(
                Box::leak(model.to_string().into_boxed_str()),
                mi.n_layers,
                mi.d_model,
                mi.d_ff,
                mi.n_heads,
                mi.vocab,
                mi.seq,
            )
        }
    };
    let mut cm = CostModel::new(&geom, &pool::CPU_SIM);
    cm.charge_padding = true;
    cm.buckets = Some(rt.manifest.train_buckets(model));
    Ok(cm)
}

/// Run every config through the planner + session (packs, re-bucketing and
/// all) and return per-config reports in input-id order. Config ids must
/// be unique within one sweep call. This is the exhaustive [`FullSweep`]
/// tuner; for early-stopping search use [`Asha`] through the [`Tuner`]
/// trait directly.
pub fn sweep(
    rt: &Arc<Runtime>,
    model: &str,
    configs: &[LoraConfig],
    opts: &SweepOptions,
) -> Result<Vec<AdapterReport>> {
    FullSweep::default().run(rt, model, configs, opts, None).map(|o| o.reports)
}

/// Best (highest eval accuracy) report per task.
pub fn best_per_task(reports: &[AdapterReport]) -> BTreeMap<&str, &AdapterReport> {
    let mut best: BTreeMap<&str, &AdapterReport> = Default::default();
    for r in reports {
        let e = best.entry(r.config.task.as_str()).or_insert(r);
        if r.eval_acc > e.eval_acc {
            *e = r;
        }
    }
    best
}

/// Table 2 analogue: per-knob max accuracy delta — for each task, vary one
/// hyperparameter around the best config while fixing the rest.
pub fn table2(reports: &[AdapterReport]) -> Table {
    let mut t = Table::new(
        "Table 2 — max accuracy delta per hyperparameter (1-knob sweeps around the best config)",
        &["task", "LR", "BS", "rank", "alpha"],
    );
    let best = best_per_task(reports);
    for (task, b) in best {
        let knob_delta = |pick: &dyn Fn(&AdapterReport) -> bool| -> f64 {
            let accs: Vec<f64> = reports
                .iter()
                .filter(|r| r.config.task == task && pick(r))
                .map(|r| r.eval_acc as f64)
                .collect();
            if accs.len() < 2 {
                return 0.0;
            }
            accs.iter().cloned().fold(f64::MIN, f64::max)
                - accs.iter().cloned().fold(f64::MAX, f64::min)
        };
        let c = &b.config;
        let lr = knob_delta(&|r: &AdapterReport| {
            r.config.batch == c.batch
                && r.config.rank == c.rank
                && r.config.alpha_ratio == c.alpha_ratio
        });
        let bs = knob_delta(&|r: &AdapterReport| {
            r.config.lr == c.lr && r.config.rank == c.rank && r.config.alpha_ratio == c.alpha_ratio
        });
        let rank = knob_delta(&|r: &AdapterReport| {
            r.config.lr == c.lr
                && r.config.batch == c.batch
                && r.config.alpha_ratio == c.alpha_ratio
        });
        let alpha = knob_delta(&|r: &AdapterReport| {
            r.config.lr == c.lr && r.config.batch == c.batch && r.config.rank == c.rank
        });
        t.row(vec![
            task.to_string(),
            format!("{:.1}%", lr * 100.0),
            format!("{:.1}%", bs * 100.0),
            format!("{:.1}%", rank * 100.0),
            format!("{:.1}%", alpha * 100.0),
        ]);
    }
    t
}

/// Table 3 analogue: base model vs worst vs best LoRA config per task.
pub fn table3(reports: &[AdapterReport]) -> Table {
    let mut t = Table::new(
        "Table 3 — base model vs worst vs best LoRA configuration",
        &["task", "base", "worst", "best", "improve"],
    );
    let mut tasks: Vec<&str> = reports.iter().map(|r| r.config.task.as_str()).collect();
    tasks.sort();
    tasks.dedup();
    for task in tasks {
        let rs: Vec<&AdapterReport> = reports.iter().filter(|r| r.config.task == task).collect();
        let base = rs.iter().map(|r| r.base_acc).fold(f32::MIN, f32::max);
        let worst = rs.iter().map(|r| r.eval_acc).fold(f32::MAX, f32::min);
        let best = rs.iter().map(|r| r.eval_acc).fold(f32::MIN, f32::max);
        t.row(vec![
            task.to_string(),
            format!("{:.1}%", base * 100.0),
            format!("{:.1}%", worst * 100.0),
            format!("{:.1}%", best * 100.0),
            format!("{:+.1}%", (best - base) * 100.0),
        ]);
    }
    t
}

/// Table 4 analogue: the best configuration per task (for a given model).
pub fn table4(model: &str, reports: &[AdapterReport]) -> Table {
    let mut t = Table::new(
        &format!("Table 4 — best LoRA configuration per task ({model})"),
        &["task", "rank", "LR", "BS", "alpha", "acc"],
    );
    for (task, b) in best_per_task(reports) {
        let c = &b.config;
        t.row(vec![
            task.to_string(),
            c.rank.to_string(),
            format!("{:.0e}", c.lr),
            c.batch.to_string(),
            c.alpha_ratio.to_string(),
            format!("{:.1}%", b.eval_acc * 100.0),
        ]);
    }
    t
}

/// Table 6 analogue: base / default-config / best-config quality per task.
pub fn table6(model: &str, reports: &[AdapterReport], defaults: &[AdapterReport]) -> Table {
    let mut t = Table::new(
        &format!("Table 6 — base vs default vs searched LoRA quality ({model})"),
        &["task", "base", "default", "best", "best vs default"],
    );
    let best = best_per_task(reports);
    for (task, b) in best {
        let Some(d) = defaults.iter().find(|r| r.config.task == task) else { continue };
        t.row(vec![
            task.to_string(),
            format!("{:.1}%", d.base_acc * 100.0),
            format!("{:.1}%", d.eval_acc * 100.0),
            format!("{:.1}%", b.eval_acc * 100.0),
            format!("{:+.1}%", (b.eval_acc - d.eval_acc) * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(task: &str, lr: f64, bs: usize, rank: usize, alpha: f64, acc: f32) -> AdapterReport {
        AdapterReport {
            config: LoraConfig {
                id: 0,
                lr,
                batch: bs,
                rank,
                alpha_ratio: alpha,
                task: task.into(),
            },
            steps: 1,
            first_loss: 1.0,
            final_loss: 0.5,
            base_loss: 1.0,
            base_acc: 0.2,
            eval_loss: 0.5,
            eval_acc: acc,
            param_hash: 0,
            curve: vec![],
        }
    }

    #[test]
    fn tables_from_synthetic_reports() {
        let reports = vec![
            rep("modadd", 1e-3, 1, 8, 1.0, 0.50),
            rep("modadd", 2e-3, 1, 8, 1.0, 0.80),
            rep("modadd", 2e-3, 2, 8, 1.0, 0.65),
            rep("modadd", 2e-3, 1, 16, 1.0, 0.70),
            rep("copy", 1e-3, 1, 8, 1.0, 0.40),
            rep("copy", 2e-3, 1, 8, 1.0, 0.30),
        ];
        let best = best_per_task(&reports);
        assert_eq!(best["modadd"].eval_acc, 0.80);
        assert_eq!(best["copy"].eval_acc, 0.40);

        let t2 = table2(&reports);
        assert_eq!(t2.rows.len(), 2);
        // modadd LR knob: (0.80 - 0.50) = 30%
        let modadd = t2.rows.iter().find(|r| r[0] == "modadd").unwrap();
        assert_eq!(modadd[1], "30.0%");

        let t3 = table3(&reports);
        let modadd = t3.rows.iter().find(|r| r[0] == "modadd").unwrap();
        assert_eq!(modadd[1], "20.0%"); // base
        assert_eq!(modadd[2], "50.0%"); // worst
        assert_eq!(modadd[3], "80.0%"); // best
        assert_eq!(modadd[4], "+60.0%");

        let t4 = table4("nano", &reports);
        assert_eq!(t4.rows.len(), 2);

        let defaults =
            vec![rep("modadd", 2e-4, 2, 16, 1.0, 0.60), rep("copy", 2e-4, 2, 16, 1.0, 0.35)];
        let t6 = table6("nano", &reports, &defaults);
        let modadd = t6.rows.iter().find(|r| r[0] == "modadd").unwrap();
        assert_eq!(modadd[4], "+20.0%");
    }
}
