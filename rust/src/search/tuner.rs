//! Pluggable **tuners**: search strategies driving the live
//! [`Session`] (DESIGN.md §16).
//!
//! [`FullSweep`] is the paper's workflow — every configuration trains to
//! its full budget. [`Asha`] layers successive halving on the elastic
//! session: trials run a geometric ladder of *rung* budgets, and at each
//! adapter-completion boundary the tuner ranks finished trials by
//! held-out eval and only the top `1/eta` of each task group continues
//! into the next rung — resumed bit-exactly from the finish-boundary
//! checkpoint ([`Session::submit_promoted`]), so a surviving trial's
//! trajectory is *identical* to its uninterrupted solo run at the full
//! budget.
//!
//! **Determinism is the load-bearing constraint.** Rung decisions depend
//! only on already-finalized eval bit patterns, ranked with a total order
//! (eval-accuracy bits descending, eval-loss bits ascending, config id
//! ascending). Promotion is *dominance-gated*: a trial continues the
//! moment enough of its group has finished that no outcome of the
//! still-running trials can push it out of the top `k` — eager like ASHA
//! (no synchronization barrier on the slowest trial), yet the promoted
//! *set* equals the synchronous successive-halving set exactly, because
//! the dominance condition at full information is precisely "ranked in
//! the top `k`". Timing races move *when* a continuation is submitted,
//! never *which* trials continue — which is what lets `plora replay`
//! re-run a recorded ASHA session and demand a bit-identical digest.
//!
//! Demotion is the kill mechanism: a trial that finished its rung budget
//! and ranked out simply gets no continuation, so there is nothing left
//! to interrupt at decision time — [`Session::cancel`] stays available as
//! a backstop for externally aborted trials but is never needed on the
//! rung path, and (unlike cancelling a provisional continuation) a
//! no-continuation demotion can never race a completion into the digest.

use std::cmp::Reverse;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::cluster::ResourceMonitor;
use crate::config::{pool, LoraConfig};
use crate::costmodel::{CostModel, ExecMode, Pack, TrainBudget};
use crate::engine::CheckpointPool;
use crate::planner::{default_priorities, JobPlanner, PlannedJob};
use crate::runtime::Runtime;
use crate::search::{live_cost_model, SweepOptions};
use crate::session::{Event, Policy, Session, SessionReport};
use crate::trace::TraceRecorder;
use crate::train::{AdapterReport, TrainOptions};

/// What any tuner returns: final per-trial reports (latest rung, sorted
/// by config id), the full session report (timeline, events, makespan),
/// and per-rung occupancy.
#[derive(Debug, Clone)]
pub struct TunerOutcome {
    /// One report per submitted trial — for a demoted trial, its metrics
    /// at the rung it stopped at; for a survivor, its full-budget result
    /// (bit-identical to a solo full-budget run).
    pub reports: Vec<AdapterReport>,
    pub session: SessionReport,
    /// Empty for [`FullSweep`].
    pub rungs: Vec<RungSummary>,
}

/// Occupancy of one rung across all task groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RungSummary {
    pub rung: usize,
    /// Training-dataset budget of this rung.
    pub dataset: usize,
    /// Trials that ran this rung.
    pub trials: usize,
    /// Trials promoted out of it (0 for the final rung).
    pub promoted: usize,
}

/// A search strategy driving one live session over a set of trials.
pub trait Tuner {
    fn name(&self) -> &'static str;

    /// Run every trial per this tuner's schedule. Config ids must be
    /// unique. When `rec` is given, enough provenance is recorded for
    /// `plora replay` to reproduce the run bit-identically.
    fn run(
        &self,
        rt: &Arc<Runtime>,
        model: &str,
        configs: &[LoraConfig],
        opts: &SweepOptions,
        rec: Option<&mut TraceRecorder>,
    ) -> Result<TunerOutcome>;
}

/// Parse a CLI tuner spelling.
pub fn parse_tuner(name: &str, eta: usize, rungs: usize) -> Option<Box<dyn Tuner>> {
    match name.to_ascii_lowercase().as_str() {
        "full" => Some(Box::new(FullSweep::default())),
        "asha" => Some(Box::new(Asha { eta, rungs, ckpt_dir: None })),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// FullSweep
// ---------------------------------------------------------------------------

/// The baseline strategy: plan all trials with [`JobPlanner`] and train
/// every one to the full budget (the pre-tuner `search::sweep` body).
#[derive(Default)]
pub struct FullSweep {
    /// Attach a [`CheckpointPool`] at this dir so finished adapters are
    /// checkpointed (`plora sweep --ckpt DIR` under the default tuner).
    pub ckpt_dir: Option<PathBuf>,
}

impl Tuner for FullSweep {
    fn name(&self) -> &'static str {
        "full"
    }

    fn run(
        &self,
        rt: &Arc<Runtime>,
        model: &str,
        configs: &[LoraConfig],
        opts: &SweepOptions,
        mut rec: Option<&mut TraceRecorder>,
    ) -> Result<TunerOutcome> {
        let mut planner = JobPlanner::new(live_cost_model(rt, model)?, opts.gpus);
        planner.budget = opts.budget;
        let plan = planner.plan(configs)?;

        let mut session = session_for(rt, model, opts);
        if let Some(dir) = &self.ckpt_dir {
            session.checkpoints = Some(CheckpointPool::new(dir, rt.clone())?);
        }
        // Under a priority policy the sweep caller has no priorities to
        // give: derive shortest-job-first ranks from modeled work.
        let jobs: Vec<_> = plan.jobs.iter().map(|j| j.job.clone()).collect();
        let prios = default_priorities(
            &planner.cm,
            &opts.budget,
            &jobs,
            opts.policy != Policy::Fifo,
        );
        for (j, prio) in jobs.into_iter().zip(prios) {
            if let Some(r) = rec.as_deref_mut() {
                r.submit(&j, prio);
            }
            session.submit_planned_at(j, prio)?;
        }
        let report = session.drain()?;
        let mut reports: Vec<AdapterReport> =
            report.outcomes.iter().flat_map(|o| o.report.adapters.clone()).collect();
        reports.sort_by_key(|a| a.config.id);
        Ok(TunerOutcome { reports, session: report, rungs: vec![] })
    }
}

// ---------------------------------------------------------------------------
// Asha
// ---------------------------------------------------------------------------

/// Successive-halving/ASHA over the elastic session (module docs).
pub struct Asha {
    /// Halving factor: each rung keeps the top `1/eta` of a task group
    /// (at least one trial). Clamped to ≥ 2.
    pub eta: usize,
    /// Rung count: rung `k` of `R` trains to `dataset / eta^(R-1-k)`
    /// samples, so the final rung is exactly the full budget. Clamped
    /// to ≥ 1 (1 rung = no early stopping).
    pub rungs: usize,
    /// Where finish-boundary resume payloads live; `None` uses a
    /// process-unique temp dir removed afterwards.
    pub ckpt_dir: Option<PathBuf>,
}

/// The geometric rung ladder: ascending distinct datasets, final entry
/// exactly `full`. Rungs whose integer budget collapses onto the next
/// one are dropped (tiny budgets), so the returned ladder may be shorter
/// than `rungs`.
pub fn rung_datasets(full: usize, eta: usize, rungs: usize) -> Vec<usize> {
    let eta = eta.max(2) as u32;
    let rungs = rungs.max(1) as u32;
    let mut ds: Vec<usize> = (0..rungs)
        .map(|k| (full / (eta as usize).pow(rungs - 1 - k)).max(1))
        .collect();
    ds.dedup();
    ds
}

/// Total-order ranking key: better trials sort *smaller*. Eval metrics
/// are non-negative finite f32s in practice, so comparing bit patterns
/// is comparing values — and stays a total order even for the NaN/inf
/// corners where f32 comparison would not be.
type RankKey = (Reverse<u32>, u32, usize);

fn rank_key(id: usize, eval_acc: f32, eval_loss: f32) -> RankKey {
    (Reverse(eval_acc.to_bits()), eval_loss.to_bits(), id)
}

/// Per-trial tuner state.
struct Trial {
    config: LoraConfig,
    /// Rung currently running (or finalized, until promoted).
    rung: usize,
    /// Ranking key of the finalized result at `rung`.
    key: Option<RankKey>,
    /// Latest finished report (highest rung so far).
    report: Option<AdapterReport>,
    /// Decided: demoted at a rung, or finished the final rung.
    done: bool,
}

/// Monotone suffix for auto-created checkpoint dirs (several ASHA runs
/// may share one process — benches, tests).
static ASHA_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Removes an auto-created checkpoint dir when dropped, so early bails
/// (duplicate ids, failed jobs, resume/submit errors) don't leak temp
/// dirs. Holds `None` when the caller supplied the dir.
struct TempDirGuard(Option<PathBuf>);

impl Drop for TempDirGuard {
    fn drop(&mut self) {
        if let Some(d) = &self.0 {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

impl Asha {
    /// SJF priority from modeled remaining seconds (comparable across
    /// rungs, unlike per-plan rank numbers): shorter remaining work runs
    /// first. Zero under FIFO.
    fn priority(
        &self,
        cm: &CostModel,
        policy: Policy,
        members: &[(LoraConfig, usize)],
        d: usize,
        mode: ExecMode,
    ) -> i32 {
        if policy == Policy::Fifo {
            return 0;
        }
        -(cm.job_time_remaining(members, d, mode) * 1000.0) as i32
    }
}

impl Tuner for Asha {
    fn name(&self) -> &'static str {
        "asha"
    }

    fn run(
        &self,
        rt: &Arc<Runtime>,
        model: &str,
        configs: &[LoraConfig],
        opts: &SweepOptions,
        mut rec: Option<&mut TraceRecorder>,
    ) -> Result<TunerOutcome> {
        let ladder = rung_datasets(opts.budget.dataset, self.eta, self.rungs);
        let n_rungs = ladder.len();
        let eta = self.eta.max(2);
        let budget_for = |r: usize| TrainBudget { dataset: ladder[r], epochs: opts.budget.epochs };

        // Group sizes per rung are static: n_{r+1} = max(1, n_r / eta).
        // That is what makes promotion dominance-checkable before the
        // slow trials of a rung finish.
        let mut group_n: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for c in configs {
            group_n.entry(c.task.clone()).or_insert_with(|| vec![0; n_rungs])[0] += 1;
        }
        for sizes in group_n.values_mut() {
            for r in 1..n_rungs {
                sizes[r] = (sizes[r - 1] / eta).max(1);
            }
        }

        let (ckpt_dir, auto_dir) = match &self.ckpt_dir {
            Some(d) => (d.clone(), false),
            None => {
                let seq = ASHA_DIR_SEQ.fetch_add(1, Ordering::SeqCst);
                let d = std::env::temp_dir()
                    .join(format!("plora-asha-{}-{seq}", std::process::id()));
                (d, true)
            }
        };
        // Auto-created dirs are cleaned on *every* exit path (early bails
        // included), not just success.
        let _dir_guard = TempDirGuard(auto_dir.then(|| ckpt_dir.clone()));
        let ckpt = CheckpointPool::new(&ckpt_dir, rt.clone())?;

        let cm = live_cost_model(rt, model)?;
        let mut planner = JobPlanner::new(cm.clone(), opts.gpus);
        planner.budget = budget_for(0);
        let plan = planner.plan(configs)?;

        let mut session = session_for(rt, model, opts);
        session.options.budget = budget_for(0);
        session.checkpoints = Some(ckpt.clone());
        session.resume_finished = true;
        let events = session.subscribe();
        let reports = session.subscribe_reports();

        let mut trials: BTreeMap<usize, Trial> = configs
            .iter()
            .map(|c| {
                (
                    c.id,
                    Trial { config: c.clone(), rung: 0, key: None, report: None, done: false },
                )
            })
            .collect();
        if trials.len() != configs.len() {
            bail!("asha: duplicate config ids");
        }
        let mut next_job_id = 0usize;
        for pj in plan.jobs.iter().map(|j| j.job.clone()) {
            let members: Vec<(LoraConfig, usize)> = pj
                .pack
                .configs
                .iter()
                .map(|c| (c.clone(), budget_for(0).steps(c.batch)))
                .collect();
            let prio = self.priority(&cm, opts.policy, &members, pj.d, pj.mode);
            next_job_id = next_job_id.max(pj.id + 1);
            if let Some(r) = rec.as_deref_mut() {
                r.submit(&pj, prio);
            }
            session.submit_planned_at(pj, prio)?;
        }
        if let Some(r) = rec.as_deref_mut() {
            r.set_tuner(self.eta, self.rungs);
        }

        let mut promoted_per_rung = vec![0usize; n_rungs];
        // Promoted ids per (task, rung) — the survivors a later
        // `RungDecision` reports (a fast survivor may already sit rungs
        // ahead by the time its old group completes).
        let mut promoted_ids: BTreeMap<(String, usize), Vec<usize>> = BTreeMap::new();
        let mut undecided = trials.len();
        let mut failed = false;
        while undecided > 0 && !failed {
            let rep = match reports.recv_timeout(Duration::from_millis(200)) {
                Ok((_job, rep)) => Some(rep),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    bail!("asha: session report stream closed")
                }
            };
            while let Ok(ev) = events.try_recv() {
                if matches!(ev, Event::JobFailed { .. }) {
                    failed = true;
                }
            }
            let Some(rep) = rep else { continue };

            // Finalize the trial at its current rung.
            let id = rep.config.id;
            let (task, rung) = {
                let t = trials
                    .get_mut(&id)
                    .ok_or_else(|| anyhow!("asha: report for unknown trial {id}"))?;
                t.key = Some(rank_key(id, rep.eval_acc, rep.eval_loss));
                t.report = Some(rep);
                if t.rung + 1 == n_rungs {
                    t.done = true;
                    undecided -= 1;
                }
                (t.config.task.clone(), t.rung)
            };
            if rung + 1 == n_rungs {
                continue;
            }

            // Dominance-gated eager promotion over the (task, rung)
            // group: promote every finalized trial that can no longer
            // rank out of the top k, whatever the still-running trials
            // score. Already-promoted trials left `finalized` (their key
            // cleared and rung advanced), so count them explicitly: they
            // are provably top-k, so they occupy promotion slots exactly
            // like finalized trials ranked above. At full information the
            // condition degenerates to exact top-k membership, so the
            // promoted set is timing-free.
            let n_r = group_n[&task][rung];
            let k = group_n[&task][rung + 1];
            let promoted = promoted_ids.get(&(task.clone(), rung)).map_or(0, |v| v.len());
            let finalized: Vec<(usize, RankKey)> = trials
                .values()
                .filter(|t| t.config.task == task && t.rung == rung)
                .filter_map(|t| t.key.map(|key| (t.config.id, key)))
                .collect();
            let unfinished = n_r - finalized.len() - promoted;
            let mut promote: Vec<usize> = vec![];
            for &(uid, ukey) in &finalized {
                if trials[&uid].done {
                    continue;
                }
                let above = finalized.iter().filter(|&&(_, vkey)| vkey < ukey).count();
                if above + unfinished + promoted < k {
                    promote.push(uid);
                }
            }
            for uid in promote {
                let t = trials.get_mut(&uid).unwrap();
                let config = t.config.clone();
                let steps_done = t.report.as_ref().map(|r| r.steps).unwrap_or(0);
                promoted_per_rung[rung] += 1;
                promoted_ids.entry((task.clone(), rung)).or_default().push(uid);
                session.note(Event::TrialPromoted {
                    rung,
                    adapter: uid,
                    at: session.elapsed(),
                });
                let resume = ckpt.load_resume(model, uid)?;
                let next_budget = budget_for(rung + 1);
                let remaining =
                    next_budget.steps(config.batch).saturating_sub(steps_done);
                let members = vec![(config.clone(), remaining)];
                let prio = self.priority(&cm, opts.policy, &members, 1, ExecMode::Packed);
                session.options.budget = next_budget;
                let pj = PlannedJob {
                    id: next_job_id,
                    pack: Pack::new(vec![config]),
                    d: 1,
                    s: 0,
                    mode: ExecMode::Packed,
                };
                next_job_id += 1;
                session.submit_promoted(pj, prio, vec![(uid, resume)])?;
                let t = trials.get_mut(&uid).unwrap();
                t.rung = rung + 1;
                t.key = None;
            }

            // Group complete at this rung: everyone not promoted is
            // demoted. Record the decision in the event stream — part of
            // the trace a replay reproduces.
            if unfinished == 0 {
                // Promoted trials cleared their key and moved on; the
                // trials still keyed at this rung are exactly the ones
                // ranked out. Report them best-first.
                let mut ranked: Vec<(usize, RankKey)> = trials
                    .values()
                    .filter(|t| t.config.task == task && t.rung == rung)
                    .filter_map(|t| t.key.map(|key| (t.config.id, key)))
                    .collect();
                ranked.sort_by_key(|&(_, key)| key);
                let mut survivors =
                    promoted_ids.get(&(task.clone(), rung)).cloned().unwrap_or_default();
                survivors.sort_unstable();
                let demoted: Vec<usize> = ranked.iter().map(|&(id, _)| id).collect();
                for &id in &demoted {
                    let t = trials.get_mut(&id).unwrap();
                    if !t.done {
                        t.done = true;
                        undecided -= 1;
                    }
                }
                session.note(Event::RungDecision {
                    rung,
                    task: task.clone(),
                    survivors,
                    demoted,
                    at: session.elapsed(),
                });
            }
        }

        let report = session.drain()?;
        if failed {
            bail!("asha: a job failed but the session drained clean");
        }
        let mut out: Vec<AdapterReport> =
            trials.into_values().filter_map(|t| t.report).collect();
        out.sort_by_key(|a| a.config.id);
        let rungs = (0..n_rungs)
            .map(|r| RungSummary {
                rung: r,
                dataset: ladder[r],
                trials: group_n.values().map(|sizes| sizes[r]).sum(),
                promoted: promoted_per_rung[r],
            })
            .collect();
        Ok(TunerOutcome { reports: out, session: report, rungs })
    }
}

/// A fresh session on a simulated CPU pool, configured from sweep
/// options (what both tuners drive).
fn session_for(rt: &Arc<Runtime>, model: &str, opts: &SweepOptions) -> Session {
    let monitor = ResourceMonitor::new(&pool::CPU_SIM, opts.gpus);
    let mut session = Session::new(rt.clone(), monitor, model);
    session.options = TrainOptions {
        budget: opts.budget,
        eval_batches: opts.eval_batches,
        seed: opts.seed,
        log_every: 0,
    };
    session.set_policy(opts.policy);
    session.set_elastic(opts.elastic);
    session
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_ladder_is_geometric_and_ends_full() {
        assert_eq!(rung_datasets(128, 2, 3), vec![32, 64, 128]);
        assert_eq!(rung_datasets(128, 4, 2), vec![32, 128]);
        assert_eq!(rung_datasets(128, 2, 1), vec![128]);
        // Tiny budgets collapse onto later rungs instead of duplicating.
        assert_eq!(rung_datasets(2, 2, 4), vec![1, 2]);
        assert_eq!(rung_datasets(1, 2, 3), vec![1]);
    }

    #[test]
    fn rank_key_orders_acc_desc_then_loss_asc_then_id() {
        let best = rank_key(3, 0.9, 0.2);
        let tied_worse_loss = rank_key(1, 0.9, 0.3);
        let worse_acc = rank_key(0, 0.8, 0.1);
        let mut v = vec![worse_acc, tied_worse_loss, best];
        v.sort();
        assert_eq!(v, vec![best, tied_worse_loss, worse_acc]);
        // Full tie: lower id wins deterministically.
        assert!(rank_key(1, 0.5, 0.5) < rank_key(2, 0.5, 0.5));
    }
}
