//! The **Session** orchestration layer (§4, Figure 3) — the event-driven
//! front door that `Engine::run`, `search::sweep` and `plora serve` are all
//! built on.
//!
//! A [`Session`] owns the runtime, the Resource Monitor and (optionally)
//! the Checkpoint Pool, and exposes:
//!
//! - [`Session::submit`] / [`Session::submit_planned`] — dynamic admission:
//!   jobs may be submitted while others run. A dedicated dispatcher thread
//!   admits jobs FIFO, acquiring devices *before* launch (the LoRA Job
//!   Queue semantics, with backpressure).
//! - a streaming [`Event`] channel ([`Session::subscribe`]): `JobStarted`,
//!   `AdapterFinished`, `Rebucketed`, `JobFinished`, `CalibUpdated`.
//! - [`Session::drain`] — wait for everything submitted so far and return
//!   a [`SessionReport`] (outcomes + makespan + live calib fit + the full
//!   event log).
//!
//! **Preemptive re-bucketing**: when an adapter converges (exhausts its
//! budget) mid-job, the session checkpoints it from the event stream and —
//! via `planner::rebalance::shrink_bucket` — re-packs the survivors onto a
//! smaller `(n, rank, batch)` bucket instead of padding to job end, so the
//! cost model's phase-wise `job_time` is what actually executes. The
//! discrete-event simulator emits the same [`Event`] type, so live and
//! simulated timelines are directly comparable.

use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cluster::{Allocation, ResourceMonitor};
use crate::config::{AdapterSpec, LoraConfig};
use crate::costmodel::throughput::Calib;
use crate::costmodel::{ExecMode, Pack};
use crate::engine::CheckpointPool;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::train::{run_pack_phased, JobReport, PackPhaseEvent, TrainOptions};

/// What a user submits: id-less adapter specs plus execution knobs. The
/// session owns adapter-id allocation (ids are assigned at submit time, so
/// sentinel ids can never reach the checkpoint pool). Pre-planned queues
/// with explicit ids go through [`Session::submit_planned`] instead.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub adapters: Vec<AdapterSpec>,
    /// Parallelism degree `d_j` (devices held for the job's duration).
    pub d: usize,
    pub mode: ExecMode,
}

impl JobSpec {
    pub fn new(adapters: Vec<AdapterSpec>) -> JobSpec {
        JobSpec { adapters, d: 1, mode: ExecMode::Packed }
    }
}

/// Receipt for a submitted job.
#[derive(Debug, Clone)]
pub struct JobHandle {
    pub job: usize,
    /// Adapter ids in slot order (session-assigned for [`Session::submit`]).
    pub adapters: Vec<usize>,
}

/// One entry of the session's event stream. Timestamps are seconds since
/// the session started.
#[derive(Debug, Clone)]
pub enum Event {
    JobStarted { job: usize, n_adapters: usize, devices: Vec<usize>, at: f64 },
    /// An adapter completed its budget (and was checkpointed, if a pool is
    /// attached) — possibly well before its job ends.
    AdapterFinished {
        job: usize,
        adapter: usize,
        task: String,
        steps: usize,
        eval_loss: f32,
        eval_acc: f32,
        at: f64,
    },
    /// Survivors of an adapter-completion boundary moved to a smaller
    /// `(n, rank, batch)` bucket.
    Rebucketed {
        job: usize,
        from: (usize, usize, usize),
        to: (usize, usize, usize),
        survivors: Vec<usize>,
        at: f64,
    },
    JobFinished { job: usize, adapters: usize, wall: f64, at: f64 },
    /// The job errored; its devices were returned to the pool and the
    /// error is re-raised by the next `drain`.
    JobFailed { job: usize, error: String, at: f64 },
    /// The live cost-model fit `t = a + b·tokens + c·n` was refreshed from
    /// accumulated step profiles (§4 calibration).
    CalibUpdated { fit: (f64, f64, f64), samples: usize, at: f64 },
}

impl Event {
    /// Seconds since session start.
    pub fn at(&self) -> f64 {
        match self {
            Event::JobStarted { at, .. }
            | Event::AdapterFinished { at, .. }
            | Event::Rebucketed { at, .. }
            | Event::JobFinished { at, .. }
            | Event::JobFailed { at, .. }
            | Event::CalibUpdated { at, .. } => *at,
        }
    }
}

/// One finished job with its session-side timeline.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: usize,
    pub devices: Vec<usize>,
    /// Seconds after session start when the job launched / finished.
    pub start: f64,
    pub end: f64,
    pub report: JobReport,
}

/// Everything a `drain` returns.
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// Finished jobs, sorted by job id.
    pub outcomes: Vec<JobOutcome>,
    pub makespan: f64,
    /// Live cost-model fit `(a, b, c)` of `t = a + b·tokens + c·n` over all
    /// profiled steps.
    pub calib_fit: (f64, f64, f64),
    /// The full event log up to this drain.
    pub events: Vec<Event>,
}

impl SessionReport {
    pub fn total_adapters(&self) -> usize {
        self.outcomes.iter().map(|o| o.report.adapters.len()).sum()
    }

    /// Number of `Rebucketed` events in the log.
    pub fn rebuckets(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::Rebucketed { .. })).count()
    }
}

/// A submitted job with the options snapshot it will run under.
struct QueuedJob {
    job: PlannedJob,
    opts: TrainOptions,
    rebucket: bool,
    checkpoints: Option<CheckpointPool>,
}

struct Shared {
    runtime: Arc<Runtime>,
    monitor: ResourceMonitor,
    model: String,
    t0: Instant,
    events: Mutex<Vec<Event>>,
    subscribers: Mutex<Vec<mpsc::Sender<Event>>>,
    outcomes: Mutex<Vec<JobOutcome>>,
    errors: Mutex<Vec<String>>,
    profile: Mutex<Vec<(f64, f64, f64)>>,
    done: Mutex<usize>,
    done_cv: Condvar,
}

impl Shared {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn emit(&self, ev: Event) {
        self.subscribers.lock().unwrap().retain(|s| s.send(ev.clone()).is_ok());
        self.events.lock().unwrap().push(ev);
    }

    fn fail(&self, job: usize, e: anyhow::Error) {
        let error = format!("job {job}: {e:#}");
        self.errors.lock().unwrap().push(error.clone());
        self.emit(Event::JobFailed { job, error, at: self.now() });
    }

    fn complete(&self) {
        *self.done.lock().unwrap() += 1;
        self.done_cv.notify_all();
    }
}

/// The session (see module docs).
pub struct Session {
    shared: Arc<Shared>,
    tx: Option<mpsc::Sender<QueuedJob>>,
    /// Training options snapshot applied to jobs at submit time.
    pub options: TrainOptions,
    /// Finished adapters are saved here as they complete, when set.
    pub checkpoints: Option<CheckpointPool>,
    /// Preemptive re-bucketing at adapter-completion boundaries (default
    /// on; off reproduces the pre-session pad-to-job-end engine).
    pub rebucket: bool,
    submitted: usize,
    next_job_id: usize,
    next_adapter_id: usize,
    used_adapter_ids: std::collections::BTreeSet<usize>,
}

impl Session {
    pub fn new(runtime: Arc<Runtime>, monitor: ResourceMonitor, model: &str) -> Session {
        let shared = Arc::new(Shared {
            runtime,
            monitor,
            model: model.to_string(),
            t0: Instant::now(),
            events: Mutex::new(vec![]),
            subscribers: Mutex::new(vec![]),
            outcomes: Mutex::new(vec![]),
            errors: Mutex::new(vec![]),
            profile: Mutex::new(vec![]),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
        });
        let (tx, rx) = mpsc::channel::<QueuedJob>();
        let disp = shared.clone();
        thread::Builder::new()
            .name("plora-session-dispatch".into())
            .spawn(move || {
                // FIFO admission: acquire devices *before* spawning the
                // worker — queue order is preserved and a full pool applies
                // backpressure, exactly like the pre-session engine loop.
                while let Ok(q) = rx.recv() {
                    match disp.monitor.acquire(q.job.d) {
                        Ok(alloc) => {
                            let start = disp.now();
                            let shared = disp.clone();
                            thread::Builder::new()
                                .name(format!("plora-job-{}", q.job.id))
                                .spawn(move || run_job(&shared, q, alloc, start))
                                .expect("spawn job worker");
                        }
                        Err(e) => {
                            disp.fail(q.job.id, e);
                            disp.complete();
                        }
                    }
                }
            })
            .expect("spawn session dispatcher");
        Session {
            shared,
            tx: Some(tx),
            options: TrainOptions::default(),
            checkpoints: None,
            rebucket: true,
            submitted: 0,
            next_job_id: 0,
            next_adapter_id: 0,
            used_adapter_ids: std::collections::BTreeSet::new(),
        }
    }

    /// The model every job of this session fine-tunes.
    pub fn model(&self) -> &str {
        &self.shared.model
    }

    /// Devices currently free in the session's pool.
    pub fn available(&self) -> usize {
        self.shared.monitor.available()
    }

    /// Subscribe to the live event stream. Events emitted after this call
    /// are delivered to the returned receiver (in addition to the log).
    pub fn subscribe(&mut self) -> mpsc::Receiver<Event> {
        let (tx, rx) = mpsc::channel();
        self.shared.subscribers.lock().unwrap().push(tx);
        rx
    }

    /// Submit a job; adapter ids are allocated by the session. Returns
    /// immediately — the job runs as soon as devices free up.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobHandle> {
        if spec.adapters.is_empty() {
            bail!("submit: empty job spec");
        }
        let configs: Vec<LoraConfig> = spec
            .adapters
            .into_iter()
            .map(|a| {
                let id = self.next_adapter_id;
                self.next_adapter_id += 1;
                a.with_id(id)
            })
            .collect();
        let job = PlannedJob {
            id: self.next_job_id,
            pack: Pack::new(configs),
            d: spec.d,
            mode: spec.mode,
        };
        self.next_job_id += 1;
        self.enqueue(job)
    }

    /// Submit a pre-planned job (planner output) with explicit job and
    /// adapter ids. Sentinel and already-used adapter ids are rejected, so
    /// neither can ever reach (or silently overwrite) the checkpoint pool;
    /// the session's own id counters are advanced past accepted ids.
    pub fn submit_planned(&mut self, job: PlannedJob) -> Result<JobHandle> {
        if job.pack.n() == 0 {
            bail!("submit: empty pack in job {}", job.id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for c in &job.pack.configs {
            if c.id == usize::MAX {
                bail!("submit: sentinel adapter id in job {} (task '{}')", job.id, c.task);
            }
            if self.used_adapter_ids.contains(&c.id) || !seen.insert(c.id) {
                bail!("submit: adapter id {} already used in this session", c.id);
            }
        }
        let max_id = job.pack.configs.iter().map(|c| c.id).max().unwrap_or(0);
        self.next_adapter_id = self.next_adapter_id.max(max_id + 1);
        self.next_job_id = self.next_job_id.max(job.id + 1);
        self.enqueue(job)
    }

    fn enqueue(&mut self, job: PlannedJob) -> Result<JobHandle> {
        let total = self.shared.monitor.total();
        if job.d == 0 || job.d > total {
            bail!("submit: job {} wants {} devices, pool has {total}", job.id, job.d);
        }
        let adapters: Vec<usize> = job.pack.configs.iter().map(|c| c.id).collect();
        self.used_adapter_ids.extend(adapters.iter().copied());
        let handle = JobHandle { job: job.id, adapters };
        let q = QueuedJob {
            job,
            opts: self.options.clone(),
            rebucket: self.rebucket,
            checkpoints: self.checkpoints.clone(),
        };
        self.tx
            .as_ref()
            .expect("session dispatcher alive")
            .send(q)
            .map_err(|_| anyhow!("session dispatcher terminated"))?;
        self.submitted += 1;
        Ok(handle)
    }

    /// Wait for every job submitted so far, then report. Errors if any job
    /// failed (devices are always returned to the pool first; the failures
    /// are *taken*, so they are reported exactly once). The session stays
    /// usable: submit more and drain again.
    pub fn drain(&mut self) -> Result<SessionReport> {
        {
            let mut done = self.shared.done.lock().unwrap();
            while *done < self.submitted {
                done = self.shared.done_cv.wait(done).unwrap();
            }
        }
        {
            let errors = std::mem::take(&mut *self.shared.errors.lock().unwrap());
            if let Some(first) = errors.first() {
                bail!("session: {} job(s) failed; first: {first}", errors.len());
            }
        }
        let mut outcomes = self.shared.outcomes.lock().unwrap().clone();
        outcomes.sort_by_key(|o| o.job_id);
        let makespan = outcomes.iter().map(|o| o.end).fold(0.0, f64::max);
        let samples = self.shared.profile.lock().unwrap().clone();
        let calib_fit = Calib::fit_live(&samples);
        let events = self.shared.events.lock().unwrap().clone();
        Ok(SessionReport { outcomes, makespan, calib_fit, events })
    }
}

/// One job's worker: runs the phased driver, checkpoints adapters as they
/// finish, maps driver callbacks onto session events, releases devices.
fn run_job(shared: &Shared, q: QueuedJob, alloc: Allocation, start: f64) {
    let devices = alloc.devices.clone();
    shared.emit(Event::JobStarted {
        job: q.job.id,
        n_adapters: q.job.pack.n(),
        devices: devices.clone(),
        at: start,
    });
    let mut ckpt_err: Option<anyhow::Error> = None;
    let result = {
        let mut on_ev = |ev: PackPhaseEvent<'_>| match ev {
            PackPhaseEvent::AdapterFinished { slot, report, state } => {
                if let Some(ckpt) = &q.checkpoints {
                    let c = &report.config;
                    let saved = ckpt
                        .save_state(&shared.model, state, &[(slot, c.id, c.rank)])
                        .and_then(|_| ckpt.save_adapter(&shared.model, q.job.id, report));
                    if let Err(e) = saved {
                        ckpt_err.get_or_insert(e);
                    }
                }
                shared.emit(Event::AdapterFinished {
                    job: q.job.id,
                    adapter: report.config.id,
                    task: report.config.task.clone(),
                    steps: report.steps,
                    eval_loss: report.eval_loss,
                    eval_acc: report.eval_acc,
                    at: shared.now(),
                });
            }
            PackPhaseEvent::Rebucketed { from, to, survivors } => {
                let at = shared.now();
                shared.emit(Event::Rebucketed { job: q.job.id, from, to, survivors, at });
            }
        };
        run_pack_phased(
            &shared.runtime,
            &shared.model,
            &q.job.pack.configs,
            &q.opts,
            q.rebucket,
            &mut on_ev,
        )
    };
    shared.monitor.release(alloc);
    match result {
        Ok((report, _state)) => {
            if let Some(e) = ckpt_err {
                shared.fail(q.job.id, e);
            } else {
                let end = shared.now();
                let (fit, samples) = {
                    let mut prof = shared.profile.lock().unwrap();
                    prof.extend(report.profile.iter().copied());
                    (Calib::fit_live(prof.as_slice()), prof.len())
                };
                shared.emit(Event::CalibUpdated { fit, samples, at: shared.now() });
                shared.emit(Event::JobFinished {
                    job: q.job.id,
                    adapters: report.adapters.len(),
                    wall: end - start,
                    at: end,
                });
                shared.outcomes.lock().unwrap().push(JobOutcome {
                    job_id: q.job.id,
                    devices,
                    start,
                    end,
                    report,
                });
            }
        }
        Err(e) => shared.fail(q.job.id, e),
    }
    shared.complete();
}
