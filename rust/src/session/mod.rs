//! The **Session** orchestration layer (§4, Figure 3) — the event-driven
//! front door that `Engine::run`, `search::sweep` and `plora serve` are all
//! built on.
//!
//! A [`Session`] owns the runtime, the Resource Monitor and (optionally)
//! the Checkpoint Pool, and exposes:
//!
//! - [`Session::submit`] / [`Session::submit_planned`] — dynamic admission:
//!   jobs may be submitted while others run. A dedicated dispatcher thread
//!   launches queued jobs under a [`Policy`] (FIFO, strict priority, or
//!   priority with preemption), acquiring devices *before* launch (the
//!   LoRA Job Queue semantics, with backpressure).
//! - a streaming [`Event`] channel ([`Session::subscribe`]): `JobStarted`,
//!   `AdapterFinished`, `AdapterAdmitted`, `Rebucketed`, `Preempted`,
//!   `JobFinished`, `CalibUpdated`.
//! - [`Session::drain`] — wait for everything submitted so far and return
//!   a [`SessionReport`] (outcomes + makespan + live calib fit + the full
//!   event log).
//!
//! **Elastic buckets** (DESIGN.md §10): jobs reshape *while running*.
//! When an adapter converges mid-job the session checkpoints it from the
//! event stream and consults `planner::rebalance::retarget_bucket`, which
//! grows or shrinks the `(n, rank, batch)` bucket only when the modeled
//! phase-time saving beats the live-calibrated bucket-switch cost. With
//! [`Session::set_elastic`] on, queued adapters are **offered to
//! compatible running packs** at their completion boundaries
//! (`AdapterAdmitted`) instead of waiting for devices; under
//! [`Policy::PreemptLowest`] a starved high-priority job preempts the
//! lowest-priority running one, whose unfinished adapters are
//! checkpointed back to the queue (`Preempted`) and later resumed
//! bit-identically. The discrete-event simulator emits the same [`Event`]
//! type under the same [`Policy`], so live and simulated timelines are
//! directly comparable.
//!
//! **Devices are executed, not just modeled** (DESIGN.md §11): every job
//! runs data-parallel on its real allocation through the driver's
//! `ShardedState`, bitwise identically at any device count. Boundary
//! offers may *retarget device counts* too: a queued d=2 job can split
//! its adapters across d=1 hosts (cross-`d` admission), and a running
//! pack can grow its shard set onto freed devices (`DeviceRetarget`) —
//! both gated on the live-calibrated data-parallel efficiency fit
//! (`CalibUpdated::dp_fit`) versus the measured device-retarget cost.
//!
//! **Stage pipelining is a second parallelism axis** (DESIGN.md §15):
//! jobs execute at a planner-chosen (or `PLORA_STAGES`-defaulted) depth
//! `s` through the driver's `PipelinedState`, bitwise identically at any
//! depth, and boundary offers may *retarget the depth* of a running pack
//! (`StageRetarget`) when the modeled pipeline-utilization saving beats
//! the measured pipeline-rebuild cost. Stages are workers on the job's
//! existing allocation, so deepening never takes devices from the queue.

use std::cmp::Reverse;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cluster::{Allocation, ResourceMonitor};
use crate::config::{AdapterSpec, LoraConfig};
use crate::costmodel::throughput::Calib;
use crate::costmodel::{CostModel, DpStat, ExecMode, Pack, SwitchCost};
use crate::engine::CheckpointPool;
use crate::planner::rebalance::admits;
use crate::planner::PlannedJob;
use crate::runtime::Runtime;
use crate::train::{
    run_pack_phased, AdapterReport, BoundaryOffer, DeviceOffer, ElasticCtl, JobReport, Joiner,
    MemberResume, PackPhaseEvent, StageOffer, TrainOptions,
};

/// How the dispatcher orders the job queue (and when it preempts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Strict submission order with head-of-line blocking (the
    /// pre-elastic behavior; the default).
    Fifo,
    /// Highest priority first (ties by submission order); a job that
    /// doesn't fit the free devices is skipped in favor of one that does.
    Priority,
    /// [`Policy::Priority`] plus preemption: when the best pending job
    /// cannot get devices, running jobs of *strictly lower* priority are
    /// preempted (checkpointed back to the queue) until it fits.
    PreemptLowest,
}

impl Policy {
    /// Parse a CLI/env spelling (`fifo`, `priority`, `preempt`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(Policy::Fifo),
            "priority" => Some(Policy::Priority),
            "preempt" | "preempt-lowest" | "preemptlowest" => Some(Policy::PreemptLowest),
            _ => None,
        }
    }
}

/// What a user submits: id-less adapter specs plus execution knobs. The
/// session owns adapter-id allocation (ids are assigned at submit time, so
/// sentinel ids can never reach the checkpoint pool). Pre-planned queues
/// with explicit ids go through [`Session::submit_planned`] instead.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub adapters: Vec<AdapterSpec>,
    /// Parallelism degree `d_j` (devices held for the job's duration).
    pub d: usize,
    /// Stage-pipeline depth `s_j` (0 = inherit the `PLORA_STAGES`
    /// default). Depth-invariant trajectories: `s` only moves the
    /// timeline, never the digest.
    pub s: usize,
    pub mode: ExecMode,
    /// Queue priority (higher runs first under non-FIFO policies).
    pub priority: i32,
}

impl JobSpec {
    pub fn new(adapters: Vec<AdapterSpec>) -> JobSpec {
        JobSpec { adapters, d: 1, s: 0, mode: ExecMode::Packed, priority: 0 }
    }

    pub fn with_priority(mut self, priority: i32) -> JobSpec {
        self.priority = priority;
        self
    }
}

/// Receipt for a submitted job.
#[derive(Debug, Clone)]
pub struct JobHandle {
    pub job: usize,
    /// Adapter ids in slot order (session-assigned for [`Session::submit`]).
    pub adapters: Vec<usize>,
}

/// One entry of the session's event stream. Timestamps are seconds since
/// the session started.
#[derive(Debug, Clone)]
pub enum Event {
    JobStarted { job: usize, n_adapters: usize, devices: Vec<usize>, at: f64 },
    /// An adapter completed its budget (and was checkpointed, if a pool is
    /// attached) — possibly well before its job ends.
    AdapterFinished {
        job: usize,
        adapter: usize,
        task: String,
        steps: usize,
        eval_loss: f32,
        eval_acc: f32,
        at: f64,
    },
    /// A queued adapter joined a *running* pack at one of its
    /// adapter-completion boundaries (elastic admission).
    AdapterAdmitted { job: usize, adapter: usize, task: String, from_job: usize, at: f64 },
    /// The pack moved to a different `(n, rank, batch)` bucket (grow or
    /// shrink) at a completion boundary.
    Rebucketed {
        job: usize,
        from: (usize, usize, usize),
        to: (usize, usize, usize),
        survivors: Vec<usize>,
        at: f64,
    },
    /// The job was preempted: the listed adapters were checkpointed back
    /// to the queue and will resume later (same job id).
    Preempted { job: usize, adapters: Vec<usize>, at: f64 },
    /// A running pack retargeted its device count at a boundary (grew its
    /// shard set onto freed devices); the trajectory is unchanged — only
    /// the execution layout moved.
    DeviceRetarget { job: usize, from: usize, to: usize, at: f64 },
    /// A running pack retargeted its stage-pipeline depth at a boundary
    /// (rebuilt its per-stage worker set); like `DeviceRetarget` the
    /// trajectory is unchanged — only the execution layout moved.
    StageRetarget { job: usize, from: usize, to: usize, at: f64 },
    JobFinished { job: usize, adapters: usize, wall: f64, at: f64 },
    /// The job errored; its devices were returned to the pool and the
    /// error is re-raised by the next `drain`.
    JobFailed { job: usize, error: String, at: f64 },
    /// A tuner promoted a trial into the next rung (injected via
    /// [`Session::note`]; the session itself never emits this).
    TrialPromoted { rung: usize, adapter: usize, at: f64 },
    /// A tuner closed a rung for one task group: `survivors` continue
    /// into rung `rung + 1`, `demoted` stop at the rung budget. Decisions
    /// depend only on finalized eval bit patterns under a total order, so
    /// a replay reproduces them exactly (DESIGN.md §16).
    RungDecision {
        rung: usize,
        task: String,
        survivors: Vec<usize>,
        demoted: Vec<usize>,
        at: f64,
    },
    /// The live cost-model fit `t = a + b·tokens + c·n` was refreshed from
    /// accumulated step profiles, together with the running mean of the
    /// measured bucket-switch wall times, the data-parallel efficiency
    /// fit over measured per-shard-count step times (`t_row = a + b/d`),
    /// and the mean device-retarget cost (§4 calibration).
    CalibUpdated {
        fit: (f64, f64, f64),
        samples: usize,
        switch_cost: f64,
        dp_fit: Option<(f64, f64)>,
        device_switch_cost: f64,
        at: f64,
    },
}

impl Event {
    /// Seconds since session start.
    pub fn at(&self) -> f64 {
        match self {
            Event::JobStarted { at, .. }
            | Event::AdapterFinished { at, .. }
            | Event::AdapterAdmitted { at, .. }
            | Event::Rebucketed { at, .. }
            | Event::Preempted { at, .. }
            | Event::DeviceRetarget { at, .. }
            | Event::StageRetarget { at, .. }
            | Event::JobFinished { at, .. }
            | Event::JobFailed { at, .. }
            | Event::TrialPromoted { at, .. }
            | Event::RungDecision { at, .. }
            | Event::CalibUpdated { at, .. } => *at,
        }
    }
}

/// One finished job (or finished segment of a preempted job) with its
/// session-side timeline.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: usize,
    pub devices: Vec<usize>,
    /// Seconds after session start when the job launched / finished.
    pub start: f64,
    pub end: f64,
    pub report: JobReport,
}

/// Everything a `drain` returns.
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// Finished jobs, sorted by job id. A preempted-then-resumed job
    /// contributes one outcome per executed segment (same job id); a job
    /// fully absorbed by elastic admission contributes none (its adapters
    /// report under their host job).
    pub outcomes: Vec<JobOutcome>,
    pub makespan: f64,
    /// Live cost-model fit `(a, b, c)` of `t = a + b·tokens + c·n` over all
    /// profiled steps.
    pub calib_fit: (f64, f64, f64),
    /// Running mean of measured bucket-switch wall times (seconds).
    pub switch_cost: f64,
    /// Data-parallel efficiency fit `t_row = a + b/d` over measured step
    /// times per executed shard count (`None` until steps ran at two or
    /// more distinct device counts).
    pub dp_fit: Option<(f64, f64)>,
    /// Running mean of measured device-retarget wall times (seconds).
    pub device_switch_cost: f64,
    /// Running mean of measured stage-retarget wall times (seconds).
    pub stage_switch_cost: f64,
    /// The full event log up to this drain.
    pub events: Vec<Event>,
}

impl SessionReport {
    pub fn total_adapters(&self) -> usize {
        self.outcomes.iter().map(|o| o.report.adapters.len()).sum()
    }

    /// Number of `Rebucketed` events in the log.
    pub fn rebuckets(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::Rebucketed { .. })).count()
    }

    /// Number of `AdapterAdmitted` events in the log.
    pub fn admissions(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::AdapterAdmitted { .. })).count()
    }

    /// Number of `Preempted` events in the log.
    pub fn preemptions(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::Preempted { .. })).count()
    }

    /// Number of `DeviceRetarget` events in the log.
    pub fn device_retargets(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::DeviceRetarget { .. })).count()
    }

    /// Number of `StageRetarget` events in the log.
    pub fn stage_retargets(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::StageRetarget { .. })).count()
    }

    /// Padded rows summed over all executed segments — the deterministic
    /// work proxy elastic re-bucketing/admission shrinks.
    pub fn padded_rows(&self) -> usize {
        self.outcomes.iter().map(|o| o.report.padded_rows).sum()
    }
}

/// A queued job with the options snapshot it will run under (and, for a
/// preempted continuation, the resume payloads of its members).
struct PendingJob {
    /// Submission order ticket (continuations keep the original's).
    seq: usize,
    job: PlannedJob,
    priority: i32,
    opts: TrainOptions,
    rebucket: bool,
    /// Checkpoint a durable [`MemberResume`] at every adapter's *finish*
    /// boundary too (tuner rung handoffs), not just on preemption.
    resume_finished: bool,
    checkpoints: Option<CheckpointPool>,
    resume: Vec<(usize, MemberResume)>,
}

/// Dispatcher-visible record of a running job.
struct RunningJob {
    job: usize,
    priority: i32,
    d: usize,
    /// Preemption flag shared with the job's driver.
    flag: Arc<AtomicBool>,
}

/// Scheduler state behind one mutex: the queue, the running set and the
/// policy knobs.
struct Sched {
    pending: Vec<PendingJob>,
    running: Vec<RunningJob>,
    policy: Policy,
    elastic: bool,
    shutdown: bool,
    /// Suspended sessions launch nothing: running jobs are being drained
    /// to checkpoints ([`Session::suspend`], the daemon's SIGTERM path)
    /// and queued jobs stay queued.
    suspended: bool,
    /// Jobs flagged for cancellation while running: their preempted
    /// members are dropped instead of re-queued.
    cancelled: std::collections::BTreeSet<usize>,
}

struct Shared {
    runtime: Arc<Runtime>,
    monitor: ResourceMonitor,
    model: String,
    t0: Instant,
    events: Mutex<Vec<Event>>,
    subscribers: Mutex<Vec<mpsc::Sender<Event>>>,
    /// Full-report fan-out: `(host job, report)` per finished adapter.
    /// The streaming [`Event::AdapterFinished`] is a summary; daemons
    /// journaling crash-exact digests need `param_hash` and the loss
    /// curve, which only the driver's [`AdapterReport`] carries.
    report_subs: Mutex<Vec<mpsc::Sender<(usize, AdapterReport)>>>,
    outcomes: Mutex<Vec<JobOutcome>>,
    errors: Mutex<Vec<String>>,
    profile: Mutex<Vec<(f64, f64, f64)>>,
    done: Mutex<usize>,
    done_cv: Condvar,
    submitted: AtomicUsize,
    seq: AtomicUsize,
    sched: Mutex<Sched>,
    sched_cv: Condvar,
    /// Live bucket-switch cost estimator shared by every job's driver.
    switch_cost: SwitchCost,
    /// Live device-retarget cost estimator (shard-set rebuild walls).
    device_cost: SwitchCost,
    /// Live stage-retarget cost estimator (pipeline rebuild walls).
    stage_cost: SwitchCost,
    /// Speed-tier label of this session's host; when set, step samples
    /// feed the per-class calibration behind `Calib::dp_fit_for`.
    device_class: Mutex<Option<String>>,
    /// Live data-parallel efficiency samples (step times per shard count).
    dp_stat: DpStat,
    /// Cost model for device-retarget and cross-`d` admission decisions
    /// (`None` when the model has no live geometry — decisions then stay
    /// conservative: no grows, same-`d` admission only).
    cm: Option<CostModel>,
    /// The model's `(n, r, bs)` bucket grid (admission feasibility).
    buckets: Vec<(usize, usize, usize)>,
}

impl Shared {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn emit(&self, ev: Event) {
        self.subscribers.lock().unwrap().retain(|s| s.send(ev.clone()).is_ok());
        self.events.lock().unwrap().push(ev);
    }

    fn emit_report(&self, job: usize, report: &AdapterReport) {
        self.report_subs
            .lock()
            .unwrap()
            .retain(|s| s.send((job, report.clone())).is_ok());
    }

    fn fail(&self, job: usize, e: anyhow::Error) {
        let error = format!("job {job}: {e:#}");
        self.errors.lock().unwrap().push(error.clone());
        self.emit(Event::JobFailed { job, error, at: self.now() });
    }

    fn complete(&self) {
        *self.done.lock().unwrap() += 1;
        self.done_cv.notify_all();
    }

    fn remove_running(&self, job: usize) {
        self.sched.lock().unwrap().running.retain(|r| r.job != job);
    }

    /// Elastic admission: hand queued adapters to a running pack at one of
    /// its completion boundaries. Walks the queue in policy order and
    /// takes adapters greedily while the combined pack still fits a
    /// bucket (the current one when the host runs without re-bucketing).
    /// Only queue entries with the host's exact options/rebucket/pool
    /// settings and exec mode are compatible — admission must not change
    /// any adapter's seed, budget or checkpoint destination. A queued
    /// job whose **device count differs** may still be absorbed when the
    /// cross-`d` gate approves ([`Shared::cross_d_ok`]): a queued d=2 job
    /// can split its adapters across d=1 hosts rather than wait for two
    /// free devices (trajectories are device-count invariant, so only the
    /// timeline changes). A queued job of *strictly higher* priority is
    /// never absorbed (it would be demoted to the host's priority if the
    /// host is later preempted), and a host already flagged for
    /// preemption gets nothing — it is about to hand its own members
    /// back. Queue jobs emptied by admission are completed in place
    /// (their adapters will report under the host job).
    #[allow(clippy::too_many_arguments)]
    fn offer_joiners(
        &self,
        host_job: usize,
        host_opts: &TrainOptions,
        host_rebucket: bool,
        host_resume_finished: bool,
        host_ckpt: &Option<CheckpointPool>,
        host_mode: ExecMode,
        bo: &BoundaryOffer<'_>,
    ) -> Vec<Joiner> {
        // The pack's *current* width — a device retarget may have grown
        // it past the launch-time request, and the admission gate must
        // price the width the joiners will actually run at.
        let host_d = bo.devices.len();
        let (out, absorbed) = {
            let mut st = self.sched.lock().unwrap();
            if !st.elastic || st.pending.is_empty() {
                return vec![];
            }
            let host = st.running.iter().find(|r| r.job == host_job);
            let host_priority = match host {
                Some(r) if !r.flag.load(Ordering::SeqCst) => r.priority,
                // Flagged (or unknown) host: it is vacating, offer nothing.
                _ => return vec![],
            };
            let mut out: Vec<Joiner> = vec![];
            let mut order: Vec<usize> = (0..st.pending.len()).collect();
            match st.policy {
                Policy::Fifo => order.sort_by_key(|&i| st.pending[i].seq),
                _ => order.sort_by_key(|&i| (Reverse(st.pending[i].priority), st.pending[i].seq)),
            }
            let mut combined: Vec<LoraConfig> = bo.survivors.configs.clone();
            for i in order {
                let compat = {
                    let p = &st.pending[i];
                    p.priority <= host_priority
                        && p.opts == *host_opts
                        && p.rebucket == host_rebucket
                        && p.resume_finished == host_resume_finished
                        && (p.job.d == host_d || self.cross_d_ok(p, host_d, bo))
                        && p.job.mode == host_mode
                        && ckpt_compat(&p.checkpoints, host_ckpt)
                };
                if !compat {
                    continue;
                }
                let mut j = 0usize;
                while j < st.pending[i].job.pack.configs.len() {
                    let cand = st.pending[i].job.pack.configs[j].clone();
                    let mut trial = combined.clone();
                    trial.push(cand.clone());
                    let trial = Pack::new(trial);
                    let fits = if host_rebucket {
                        self.buckets.iter().any(|&b| admits(b, &trial))
                    } else {
                        admits(bo.bucket, &trial)
                    };
                    if !fits {
                        j += 1;
                        continue;
                    }
                    combined.push(cand);
                    let config = st.pending[i].job.pack.configs.remove(j);
                    let from_job = st.pending[i].job.id;
                    let pos =
                        st.pending[i].resume.iter().position(|(id, _)| *id == config.id);
                    let resume = pos.map(|p| st.pending[i].resume.remove(p).1);
                    out.push(Joiner { config, resume, from_job });
                }
            }
            // Queue entries fully absorbed never launch: retire them (a
            // zero-adapter JobFinished keeps the stream invariant "every
            // submitted job ends in JobFinished or JobFailed" for
            // consumers; the adapters report under their host job).
            let absorbed: Vec<usize> = st
                .pending
                .iter()
                .filter(|p| p.job.pack.configs.is_empty())
                .map(|p| p.job.id)
                .collect();
            st.pending.retain(|p| !p.job.pack.configs.is_empty());
            (out, absorbed)
        };
        for job in absorbed {
            self.emit(Event::JobFinished { job, adapters: 0, wall: 0.0, at: self.now() });
            self.complete();
        }
        out
    }

    /// Cross-`d` admission gate: absorbing a queued job into a host
    /// running at a different device count trades the job's requested
    /// parallelism for starting *now*. Modeled with the (live-calibrated)
    /// dp-efficiency term: the per-step penalty of running at the host's
    /// `d` instead of the job's own, summed over the job's steps, must
    /// not exceed the lower bound on what waiting would cost — the
    /// host's longest remaining member holds its devices at least that
    /// long — plus the calibrated device-retarget budget. With no cost
    /// model the gate stays closed (same-`d` admission only).
    fn cross_d_ok(&self, p: &PendingJob, host_d: usize, bo: &BoundaryOffer<'_>) -> bool {
        let Some(cm0) = &self.cm else { return false };
        if p.job.pack.n() == 0 {
            return false;
        }
        let mut cm = cm0.clone();
        if let Some(fit) = self.dp_stat.fit() {
            cm.calib.dp_fit = Some(fit);
        }
        let own = (p.job.pack.n(), p.job.pack.r_pad(), p.job.pack.bs_pad());
        let steps = p
            .job
            .pack
            .configs
            .iter()
            .map(|c| p.opts.budget.steps(c.batch))
            .max()
            .unwrap_or(0);
        cm.cross_d_admit(
            bo.bucket,
            host_d,
            bo.host_remaining,
            own,
            p.job.d,
            steps,
            p.job.mode,
            self.device_cost.estimate(),
        )
    }

    /// Boundary device offer: grow a running pack's shard set onto freed
    /// devices when the modeled phase saving (dp-efficiency term,
    /// live-calibrated) beats the calibrated device-retarget cost.
    /// Conservative by construction: only when the session is elastic,
    /// the queue is empty (pending jobs have first claim on devices), and
    /// the host is not being vacated. Returns the acquired device ids;
    /// the acquisitions are recorded in `grown` for release at job end.
    fn offer_devices(
        &self,
        job: usize,
        mode: ExecMode,
        off: &DeviceOffer,
        grown: &Mutex<Vec<Allocation>>,
    ) -> Option<Vec<usize>> {
        {
            let st = self.sched.lock().unwrap();
            if !st.elastic || !st.pending.is_empty() {
                return None;
            }
            match st.running.iter().find(|r| r.job == job) {
                Some(r) if !r.flag.load(Ordering::SeqCst) => {}
                _ => return None,
            }
        }
        let cm0 = self.cm.as_ref()?;
        let free = self.monitor.available();
        if free == 0 || off.phase_steps == 0 {
            return None;
        }
        let mut cm = cm0.clone();
        if let Some(fit) = self.dp_stat.fit() {
            cm.calib.dp_fit = Some(fit);
        }
        // Grow by at most the current width (doubling keeps shard sizes
        // balanced) and never beyond the bucket's slot count — extra
        // shards past `n` would sit idle.
        let extra = free.min(off.d).min(off.bucket.0.saturating_sub(off.d));
        if extra == 0 {
            return None;
        }
        let to = off.d + extra;
        let t_cur = cm.bucket_step_time(off.bucket, off.d, mode);
        let t_new = cm.bucket_step_time(off.bucket, to, mode);
        let saving = off.phase_steps as f64 * (t_cur - t_new);
        let cost = self.device_cost.estimate().max(cm.calib.device_switch_cost);
        if saving <= cost {
            return None;
        }
        let alloc = self.monitor.try_acquire(extra)?;
        let ids = alloc.devices.clone();
        {
            // Preemption math must see the job's real size.
            let mut st = self.sched.lock().unwrap();
            if let Some(r) = st.running.iter_mut().find(|r| r.job == job) {
                r.d += extra;
            }
        }
        grown.lock().unwrap().push(alloc);
        Some(ids)
    }

    /// Boundary stage offer: deepen a running pack's stage pipeline when
    /// the modeled utilization saving beats the calibrated stage-retarget
    /// cost. Stages are workers on the job's *existing* allocation, so
    /// unlike [`Shared::offer_devices`] no devices are acquired and a
    /// non-empty queue does not block the grow. Depth doubles per offer;
    /// the cost model clamps past the layer stack, so a maxed-out depth
    /// shows zero saving and the offer declines.
    fn offer_stages(&self, job: usize, mode: ExecMode, off: &StageOffer) -> Option<usize> {
        {
            let st = self.sched.lock().unwrap();
            if !st.elastic {
                return None;
            }
            match st.running.iter().find(|r| r.job == job) {
                Some(r) if !r.flag.load(Ordering::SeqCst) => {}
                _ => return None,
            }
        }
        let cm0 = self.cm.as_ref()?;
        if off.phase_steps == 0 {
            return None;
        }
        let mut cm = cm0.clone();
        if let Some(fit) = self.dp_stat.fit() {
            cm.calib.dp_fit = Some(fit);
        }
        let from = off.s.max(1);
        let to = from * 2;
        let t_cur = cm.bucket_step_time_ds(off.bucket, off.d, from, mode);
        let t_new = cm.bucket_step_time_ds(off.bucket, off.d, to, mode);
        let saving = off.phase_steps as f64 * (t_cur - t_new);
        let cost = self.stage_cost.estimate().max(cm.calib.stage_switch_cost);
        if saving <= cost {
            return None;
        }
        Some(to)
    }
}

/// Two checkpoint-pool settings are admission-compatible when both are
/// absent or both point at the same directory.
fn ckpt_compat(a: &Option<CheckpointPool>, b: &Option<CheckpointPool>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => x.dir == y.dir,
        _ => false,
    }
}

/// Next launchable queue index under `policy` with `avail` free devices.
/// FIFO blocks on its submission-order head; `Priority` backfills past a
/// too-big head; `PreemptLowest` blocks on its *priority-order* head —
/// backfilling there would re-occupy devices being vacated for it and
/// livelock the preemption loop.
fn pick_next(pending: &[PendingJob], policy: Policy, avail: usize) -> Option<usize> {
    match policy {
        Policy::Fifo => {
            let (idx, head) = pending.iter().enumerate().min_by_key(|(_, p)| p.seq)?;
            (head.job.d <= avail).then_some(idx)
        }
        Policy::Priority => {
            let mut order: Vec<usize> = (0..pending.len()).collect();
            order.sort_by_key(|&i| (Reverse(pending[i].priority), pending[i].seq));
            order.into_iter().find(|&i| pending[i].job.d <= avail)
        }
        Policy::PreemptLowest => {
            let (idx, head) = pending
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| (Reverse(p.priority), p.seq))?;
            (head.job.d <= avail).then_some(idx)
        }
    }
}

/// Flag running jobs of strictly lower priority for preemption until the
/// best pending job fits (counting devices already being vacated) — but
/// only when enough preemptible capacity actually exists; otherwise
/// flagging would evict jobs without unblocking anyone.
fn preempt_victims(monitor: &ResourceMonitor, st: &mut Sched) {
    let Some(top) = st.pending.iter().min_by_key(|p| (Reverse(p.priority), p.seq)) else {
        return;
    };
    let (need, top_prio) = (top.job.d, top.priority);
    let vacating: usize = st
        .running
        .iter()
        .filter(|r| r.flag.load(Ordering::SeqCst))
        .map(|r| r.d)
        .sum();
    let mut avail = monitor.available() + vacating;
    if avail >= need {
        return; // vacating already; wait for the releases
    }
    let takeable: usize = st
        .running
        .iter()
        .filter(|r| r.priority < top_prio && !r.flag.load(Ordering::SeqCst))
        .map(|r| r.d)
        .sum();
    if avail + takeable < need {
        return; // preemption cannot unblock the starved job
    }
    let mut order: Vec<usize> = (0..st.running.len()).collect();
    order.sort_by_key(|&i| st.running[i].priority);
    for i in order {
        if avail >= need {
            break;
        }
        let r = &st.running[i];
        if r.priority >= top_prio {
            break; // only strictly lower priority is preemptible
        }
        if !r.flag.swap(true, Ordering::SeqCst) {
            avail += r.d;
        }
    }
}

/// The session (see module docs).
pub struct Session {
    shared: Arc<Shared>,
    /// Training options snapshot applied to jobs at submit time.
    pub options: TrainOptions,
    /// Finished adapters are saved here as they complete, when set.
    pub checkpoints: Option<CheckpointPool>,
    /// Consult the switch-cost-aware retarget planner at
    /// adapter-completion boundaries (default on; off reproduces the
    /// pre-session pad-to-job-end engine).
    pub rebucket: bool,
    /// Also checkpoint a durable [`MemberResume`] when an adapter
    /// *finishes* its budget (not just on preemption), so a tuner can
    /// promote it into a larger budget via
    /// [`Session::submit_promoted`]. Requires an attached
    /// checkpoint pool; default off. Snapshotted per job at submit time
    /// (admission compatibility requires equal settings).
    pub resume_finished: bool,
    next_job_id: usize,
    next_adapter_id: usize,
    used_adapter_ids: std::collections::BTreeSet<usize>,
}

impl Session {
    pub fn new(runtime: Arc<Runtime>, monitor: ResourceMonitor, model: &str) -> Session {
        let buckets = runtime.manifest.train_buckets(model);
        let cm = crate::search::live_cost_model(&runtime, model).ok();
        let shared = Arc::new(Shared {
            runtime,
            monitor,
            model: model.to_string(),
            t0: Instant::now(),
            events: Mutex::new(vec![]),
            subscribers: Mutex::new(vec![]),
            report_subs: Mutex::new(vec![]),
            outcomes: Mutex::new(vec![]),
            errors: Mutex::new(vec![]),
            profile: Mutex::new(vec![]),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            submitted: AtomicUsize::new(0),
            seq: AtomicUsize::new(0),
            sched: Mutex::new(Sched {
                pending: vec![],
                running: vec![],
                policy: Policy::Fifo,
                elastic: false,
                shutdown: false,
                suspended: false,
                cancelled: std::collections::BTreeSet::new(),
            }),
            sched_cv: Condvar::new(),
            switch_cost: SwitchCost::new(0.0),
            device_cost: SwitchCost::new(0.0),
            stage_cost: SwitchCost::new(0.0),
            device_class: Mutex::new(None),
            dp_stat: DpStat::new(),
            cm,
            buckets,
        });
        let disp = shared.clone();
        thread::Builder::new()
            .name("plora-session-dispatch".into())
            .spawn(move || dispatcher(disp))
            .expect("spawn session dispatcher");
        Session {
            shared,
            options: TrainOptions::default(),
            checkpoints: None,
            rebucket: true,
            resume_finished: false,
            next_job_id: 0,
            next_adapter_id: 0,
            used_adapter_ids: std::collections::BTreeSet::new(),
        }
    }

    /// The model every job of this session fine-tunes.
    pub fn model(&self) -> &str {
        &self.shared.model
    }

    /// Devices currently free in the session's pool.
    pub fn available(&self) -> usize {
        self.shared.monitor.available()
    }

    /// Total devices in the session's pool.
    pub fn devices(&self) -> usize {
        self.shared.monitor.total()
    }

    /// The queue/preemption policy (default [`Policy::Fifo`]).
    pub fn policy(&self) -> Policy {
        self.shared.sched.lock().unwrap().policy
    }

    pub fn set_policy(&mut self, policy: Policy) {
        self.shared.sched.lock().unwrap().policy = policy;
        self.shared.sched_cv.notify_all();
    }

    /// Elastic admission: offer queued adapters to compatible running
    /// packs at their completion boundaries (default off).
    pub fn elastic(&self) -> bool {
        self.shared.sched.lock().unwrap().elastic
    }

    pub fn set_elastic(&mut self, on: bool) {
        self.shared.sched.lock().unwrap().elastic = on;
    }

    /// Running mean of measured bucket-switch wall times so far.
    pub fn switch_cost(&self) -> f64 {
        self.shared.switch_cost.estimate()
    }

    /// Running mean of measured device-retarget wall times so far.
    pub fn device_switch_cost(&self) -> f64 {
        self.shared.device_cost.estimate()
    }

    /// Running mean of measured stage-retarget wall times so far.
    pub fn stage_switch_cost(&self) -> f64 {
        self.shared.stage_cost.estimate()
    }

    /// Tag this session's host with a device-class (speed tier) label.
    /// Step samples then also feed the per-class accumulator behind
    /// `Calib::dp_fit_for` — the measured per-device-class step times
    /// heterogeneous placement plans on.
    pub fn set_device_class(&mut self, class: Option<String>) {
        *self.shared.device_class.lock().unwrap() = class;
    }

    /// Per-class dp-efficiency fits measured so far (`class → (a, b)`).
    pub fn class_fits(&self) -> std::collections::BTreeMap<String, (f64, f64)> {
        self.shared.dp_stat.class_fits()
    }

    /// Seconds since the session started — the timestamp scale of every
    /// [`Event`] (what callers stamp injected [`Session::note`] events
    /// with).
    pub fn elapsed(&self) -> f64 {
        self.shared.now()
    }

    /// Inject an event into the session's log and live stream. The hook
    /// tuners use to make their rung decisions part of the recorded
    /// provenance ([`Event::RungDecision`], [`Event::TrialPromoted`]) —
    /// the session itself never emits those variants.
    pub fn note(&self, ev: Event) {
        self.shared.emit(ev);
    }

    /// Subscribe to the live event stream. Events emitted after this call
    /// are delivered to the returned receiver (in addition to the log).
    pub fn subscribe(&mut self) -> mpsc::Receiver<Event> {
        let (tx, rx) = mpsc::channel();
        self.shared.subscribers.lock().unwrap().push(tx);
        rx
    }

    /// Subscribe to the full per-adapter reports as they finish, keyed by
    /// the host job id. Unlike the streaming [`Event::AdapterFinished`]
    /// summary this carries `param_hash` and the loss curve — what a
    /// daemon needs to journal crash-exact
    /// [`crate::trace::AdapterDigest`]s.
    pub fn subscribe_reports(&mut self) -> mpsc::Receiver<(usize, AdapterReport)> {
        let (tx, rx) = mpsc::channel();
        self.shared.report_subs.lock().unwrap().push(tx);
        rx
    }

    /// Submit a job; adapter ids are allocated by the session. Returns
    /// immediately — the job runs as soon as the policy grants it devices.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobHandle> {
        if spec.adapters.is_empty() {
            bail!("submit: empty job spec");
        }
        let configs: Vec<LoraConfig> = spec
            .adapters
            .into_iter()
            .map(|a| {
                let id = self.next_adapter_id;
                self.next_adapter_id += 1;
                a.with_id(id)
            })
            .collect();
        let job = PlannedJob {
            id: self.next_job_id,
            pack: Pack::new(configs),
            d: spec.d,
            s: spec.s,
            mode: spec.mode,
        };
        self.next_job_id += 1;
        self.enqueue(job, spec.priority)
    }

    /// Submit a pre-planned job (planner output) with explicit job and
    /// adapter ids at priority 0. Sentinel and already-used adapter ids
    /// are rejected, so neither can ever reach (or silently overwrite)
    /// the checkpoint pool; the session's own id counters are advanced
    /// past accepted ids.
    pub fn submit_planned(&mut self, job: PlannedJob) -> Result<JobHandle> {
        self.submit_planned_at(job, 0)
    }

    /// [`Session::submit_planned`] with an explicit queue priority.
    pub fn submit_planned_at(&mut self, job: PlannedJob, priority: i32) -> Result<JobHandle> {
        self.submit_planned_resume(job, priority, vec![])
    }

    /// [`Session::submit_planned_at`] with resume payloads for members
    /// that already ran part of their budget — mid-job checkpoints from
    /// a previous process (the daemon's crash recovery, `trace`'s
    /// replay-from-checkpoint). Payload ids must name adapters of the
    /// job's pack; members without a payload start from step 0.
    pub fn submit_planned_resume(
        &mut self,
        job: PlannedJob,
        priority: i32,
        resume: Vec<(usize, MemberResume)>,
    ) -> Result<JobHandle> {
        if job.pack.n() == 0 {
            bail!("submit: empty pack in job {}", job.id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for c in &job.pack.configs {
            if c.id == usize::MAX {
                bail!("submit: sentinel adapter id in job {} (task '{}')", job.id, c.task);
            }
            if self.used_adapter_ids.contains(&c.id) || !seen.insert(c.id) {
                bail!("submit: adapter id {} already used in this session", c.id);
            }
        }
        for (id, _) in &resume {
            if !seen.contains(id) {
                bail!("submit: resume payload for adapter {id} not in job {}", job.id);
            }
        }
        let max_id = job.pack.configs.iter().map(|c| c.id).max().unwrap_or(0);
        self.next_adapter_id = self.next_adapter_id.max(max_id + 1);
        self.next_job_id = self.next_job_id.max(job.id + 1);
        self.enqueue_resume(job, priority, resume)
    }

    /// Submit a tuner *promotion*: a job whose members continue adapters
    /// this session already ran (a finished rung's trials resuming into a
    /// larger budget), so — unlike [`Session::submit_planned_resume`] —
    /// already-used adapter ids are expected rather than rejected. Every
    /// member must carry a resume payload: that is what makes the reuse
    /// a continuation of the same trial instead of a conflicting new
    /// adapter. Job ids must still be fresh (provenance stays unambiguous
    /// per executed segment).
    pub fn submit_promoted(
        &mut self,
        job: PlannedJob,
        priority: i32,
        resume: Vec<(usize, MemberResume)>,
    ) -> Result<JobHandle> {
        if job.pack.n() == 0 {
            bail!("submit: empty pack in job {}", job.id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for c in &job.pack.configs {
            if c.id == usize::MAX {
                bail!("submit: sentinel adapter id in job {} (task '{}')", job.id, c.task);
            }
            if !seen.insert(c.id) {
                bail!("submit: adapter id {} duplicated in job {}", c.id, job.id);
            }
            if !resume.iter().any(|(id, _)| *id == c.id) {
                bail!(
                    "submit: promoted adapter {} in job {} has no resume payload",
                    c.id,
                    job.id
                );
            }
        }
        if job.id < self.next_job_id {
            bail!("submit: job id {} already used in this session", job.id);
        }
        self.next_job_id = job.id + 1;
        self.enqueue_resume(job, priority, resume)
    }

    fn enqueue(&mut self, job: PlannedJob, priority: i32) -> Result<JobHandle> {
        self.enqueue_resume(job, priority, vec![])
    }

    fn enqueue_resume(
        &mut self,
        job: PlannedJob,
        priority: i32,
        resume: Vec<(usize, MemberResume)>,
    ) -> Result<JobHandle> {
        let total = self.shared.monitor.total();
        if job.d == 0 || job.d > total {
            bail!("submit: job {} wants {} devices, pool has {total}", job.id, job.d);
        }
        let adapters: Vec<usize> = job.pack.configs.iter().map(|c| c.id).collect();
        self.used_adapter_ids.extend(adapters.iter().copied());
        let handle = JobHandle { job: job.id, adapters };
        let p = PendingJob {
            seq: self.shared.seq.fetch_add(1, Ordering::SeqCst),
            job,
            priority,
            opts: self.options.clone(),
            rebucket: self.rebucket,
            resume_finished: self.resume_finished,
            checkpoints: self.checkpoints.clone(),
            resume,
        };
        self.shared.submitted.fetch_add(1, Ordering::SeqCst);
        self.shared.sched.lock().unwrap().pending.push(p);
        self.shared.sched_cv.notify_all();
        Ok(handle)
    }

    /// Wait for every job submitted so far (including preempted
    /// continuations), then report. Errors if any job failed (devices are
    /// always returned to the pool first; the failures are *taken*, so
    /// they are reported exactly once). The session stays usable: submit
    /// more and drain again.
    pub fn drain(&mut self) -> Result<SessionReport> {
        {
            let mut done = self.shared.done.lock().unwrap();
            while *done < self.shared.submitted.load(Ordering::SeqCst) {
                done = self.shared.done_cv.wait(done).unwrap();
            }
        }
        // The sweep this session hosted is over: release its cached eval
        // streams so a long-running process (the serve daemon, bench
        // loops) doesn't accumulate held-out rows per drained session.
        // Best-effort — a later submit for the same adapter regenerates
        // the identical rows.
        crate::train::evict_eval_rows(self.options.seed, self.used_adapter_ids.iter().copied());
        {
            let errors = std::mem::take(&mut *self.shared.errors.lock().unwrap());
            if let Some(first) = errors.first() {
                bail!("session: {} job(s) failed; first: {first}", errors.len());
            }
        }
        let mut outcomes = self.shared.outcomes.lock().unwrap().clone();
        outcomes.sort_by(|a, b| a.job_id.cmp(&b.job_id).then(a.start.total_cmp(&b.start)));
        let makespan = outcomes.iter().map(|o| o.end).fold(0.0, f64::max);
        let samples = self.shared.profile.lock().unwrap().clone();
        let calib_fit = Calib::fit_live(&samples);
        let events = self.shared.events.lock().unwrap().clone();
        Ok(SessionReport {
            outcomes,
            makespan,
            calib_fit,
            switch_cost: self.shared.switch_cost.estimate(),
            dp_fit: self.shared.dp_stat.fit(),
            device_switch_cost: self.shared.device_cost.estimate(),
            stage_switch_cost: self.shared.stage_cost.estimate(),
            events,
        })
    }

    /// Cancel a job. A queued job is retired in place (the zero-adapter
    /// `JobFinished` idiom elastic absorption uses); a running job is
    /// flagged like a preemption, but its unfinished members are dropped
    /// at the interrupt boundary instead of re-queued — adapters that
    /// already finished stay finished (and checkpointed, if a pool is
    /// attached). Returns whether the job was found queued or running.
    pub fn cancel(&mut self, job: usize) -> bool {
        {
            let mut st = self.shared.sched.lock().unwrap();
            if let Some(idx) = st.pending.iter().position(|p| p.job.id == job) {
                st.pending.remove(idx);
            } else if let Some(r) = st.running.iter().find(|r| r.job == job) {
                st.cancelled.insert(job);
                r.flag.store(true, Ordering::SeqCst);
                self.shared.sched_cv.notify_all();
                return true;
            } else {
                return false;
            }
        }
        // Retired from the queue without running: the zero-adapter
        // JobFinished keeps the stream invariant "every submitted job
        // ends in JobFinished or JobFailed".
        let at = self.shared.now();
        self.shared.emit(Event::JobFinished { job, adapters: 0, wall: 0.0, at });
        self.shared.complete();
        true
    }

    /// Graceful drain (the daemon's SIGTERM path): stop launching queued
    /// jobs and interrupt every running one as if preempted. Their
    /// unfinished members round-trip through the checkpoint pool (when
    /// attached) and re-queue as pending continuations — which, being
    /// suspended, never launch. [`Session::wait_quiesced`] then blocks
    /// until the last running pack has checkpointed and released its
    /// devices, at which point every member is either finished or has a
    /// durable resume payload.
    pub fn suspend(&mut self) {
        let mut st = self.shared.sched.lock().unwrap();
        st.suspended = true;
        for r in &st.running {
            r.flag.store(true, Ordering::SeqCst);
        }
        self.shared.sched_cv.notify_all();
    }

    /// Block until nothing is running — every submission is either done
    /// or parked in the queue. The drain barrier after
    /// [`Session::suspend`].
    pub fn wait_quiesced(&self) {
        loop {
            // Read the queue length *before* taking `done`: the two locks
            // are never held together anywhere, and a stale count only
            // delays one 50 ms re-check.
            let pend = self.shared.sched.lock().unwrap().pending.len();
            let done = self.shared.done.lock().unwrap();
            if *done + pend >= self.shared.submitted.load(Ordering::SeqCst) {
                return;
            }
            let _ = self.shared.done_cv.wait_timeout(done, Duration::from_millis(50)).unwrap();
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.shared.sched.lock().unwrap().shutdown = true;
        self.shared.sched_cv.notify_all();
    }
}

/// The dispatcher loop: launch queued jobs per policy when devices are
/// free; under [`Policy::PreemptLowest`] flag victims for starved
/// higher-priority work; park until submits/releases wake it.
fn dispatcher(shared: Arc<Shared>) {
    let mut st = shared.sched.lock().unwrap();
    loop {
        if st.shutdown {
            break;
        }
        let avail = shared.monitor.available();
        if st.suspended {
            // Drain mode: launch nothing and preempt nothing until the
            // owner lifts the suspension or the session shuts down.
        } else if let Some(idx) = pick_next(&st.pending, st.policy, avail) {
            if let Some(alloc) = shared.monitor.try_acquire(st.pending[idx].job.d) {
                let p = st.pending.remove(idx);
                let flag = Arc::new(AtomicBool::new(false));
                st.running.push(RunningJob {
                    job: p.job.id,
                    priority: p.priority,
                    d: p.job.d,
                    flag: flag.clone(),
                });
                let sh = shared.clone();
                let start = shared.now();
                thread::Builder::new()
                    .name(format!("plora-job-{}", p.job.id))
                    .spawn(move || run_job(&sh, p, alloc, flag, start))
                    .expect("spawn job worker");
                continue; // more queue entries may fit
            }
        } else if st.policy == Policy::PreemptLowest && !st.pending.is_empty() {
            preempt_victims(&shared.monitor, &mut st);
        }
        st = shared.sched_cv.wait(st).unwrap();
    }
}

/// One job's worker: runs the phased driver with the session's elastic
/// control surface, checkpoints adapters as they finish, maps driver
/// callbacks onto session events, re-queues preempted members, releases
/// devices.
fn run_job(
    shared: &Shared,
    mut p: PendingJob,
    alloc: Allocation,
    flag: Arc<AtomicBool>,
    start: f64,
) {
    let devices = alloc.devices.clone();
    shared.emit(Event::JobStarted {
        job: p.job.id,
        n_adapters: p.job.pack.n(),
        devices: devices.clone(),
        at: start,
    });
    let job_id = p.job.id;
    let mut ckpt_err: Option<anyhow::Error> = None;
    // Devices acquired by boundary device retargets, released at job end.
    let grown: Mutex<Vec<Allocation>> = Mutex::new(vec![]);
    let result = {
        let checkpoints = p.checkpoints.clone();
        let opts = p.opts.clone();
        let rebucket = p.rebucket;
        let resume_finished = p.resume_finished;
        let host_mode = p.job.mode;
        let mut offer = |bo: &BoundaryOffer<'_>| -> Vec<Joiner> {
            shared.offer_joiners(
                job_id,
                &opts,
                rebucket,
                resume_finished,
                &checkpoints,
                host_mode,
                bo,
            )
        };
        let mut device_offer = |off: &DeviceOffer| -> Option<Vec<usize>> {
            shared.offer_devices(job_id, host_mode, off, &grown)
        };
        let mut stage_offer = |off: &StageOffer| -> Option<usize> {
            shared.offer_stages(job_id, host_mode, off)
        };
        let mut ctl = ElasticCtl {
            rebucket: p.rebucket,
            switch_cost: Some(shared.switch_cost.clone()),
            preempt: Some(flag),
            offer: Some(&mut offer),
            devices: Some(&mut device_offer),
            device_cost: Some(shared.device_cost.clone()),
            stages0: (p.job.s > 0).then_some(p.job.s),
            stages: Some(&mut stage_offer),
            stage_cost: Some(shared.stage_cost.clone()),
            dp_stat: Some(shared.dp_stat.clone()),
            device_class: shared.device_class.lock().unwrap().clone(),
            resume: std::mem::take(&mut p.resume),
        };
        let mut on_ev = |ev: PackPhaseEvent<'_>| match ev {
            PackPhaseEvent::AdapterFinished { slot, report, state } => {
                if let Some(ckpt) = &p.checkpoints {
                    let c = &report.config;
                    let saved = ckpt
                        .save_state(&shared.model, state, &[(slot, c.id, c.rank)])
                        .and_then(|_| ckpt.save_adapter(&shared.model, job_id, report));
                    if let Err(e) = saved {
                        ckpt_err.get_or_insert(e);
                    }
                    // Rung handoff: a finished adapter leaves a durable
                    // resume payload so a tuner can promote it into a
                    // larger budget exactly where it stopped.
                    if p.resume_finished {
                        let saved = state
                            .extract_member(slot, c.rank)
                            .map(|member| MemberResume {
                                state: member,
                                steps_done: report.steps,
                                first_loss: report.first_loss,
                                base_loss: report.base_loss,
                                base_acc: report.base_acc,
                                curve: report.curve.clone(),
                            })
                            .and_then(|r| ckpt.save_resume(&shared.model, c.id, &r));
                        if let Err(e) = saved {
                            ckpt_err.get_or_insert(e);
                        }
                    }
                }
                shared.emit(Event::AdapterFinished {
                    job: job_id,
                    adapter: report.config.id,
                    task: report.config.task.clone(),
                    steps: report.steps,
                    eval_loss: report.eval_loss,
                    eval_acc: report.eval_acc,
                    at: shared.now(),
                });
                shared.emit_report(job_id, report);
            }
            PackPhaseEvent::AdapterAdmitted { config, from_job } => {
                shared.emit(Event::AdapterAdmitted {
                    job: job_id,
                    adapter: config.id,
                    task: config.task.clone(),
                    from_job,
                    at: shared.now(),
                });
            }
            PackPhaseEvent::Rebucketed { from, to, survivors, .. } => {
                let at = shared.now();
                shared.emit(Event::Rebucketed { job: job_id, from, to, survivors, at });
            }
            PackPhaseEvent::Preempted { remaining } => {
                shared.emit(Event::Preempted {
                    job: job_id,
                    adapters: remaining,
                    at: shared.now(),
                });
            }
            PackPhaseEvent::DeviceRetarget { from, to, .. } => {
                shared.emit(Event::DeviceRetarget {
                    job: job_id,
                    from,
                    to,
                    at: shared.now(),
                });
            }
            PackPhaseEvent::StageRetarget { from, to, .. } => {
                shared.emit(Event::StageRetarget {
                    job: job_id,
                    from,
                    to,
                    at: shared.now(),
                });
            }
        };
        run_pack_phased(
            &shared.runtime,
            &shared.model,
            &p.job.pack.configs,
            &p.opts,
            &alloc,
            &mut ctl,
            &mut on_ev,
        )
    };
    shared.remove_running(job_id);
    shared.monitor.release(alloc);
    for extra in grown.into_inner().unwrap() {
        shared.monitor.release(extra);
    }
    shared.sched_cv.notify_all();
    // Consume any cancellation flagged while we ran, whatever the
    // outcome — cancelled jobs must neither leak set entries nor
    // re-queue their members.
    let was_cancelled = shared.sched.lock().unwrap().cancelled.remove(&job_id);
    match result {
        Ok(out) => {
            if let Some(e) = ckpt_err {
                shared.fail(job_id, e);
                shared.complete();
                return;
            }
            let end = shared.now();
            shared.profile.lock().unwrap().extend(out.report.profile.iter().copied());
            if out.preempted.is_empty() {
                let (fit, samples) = {
                    let prof = shared.profile.lock().unwrap();
                    (Calib::fit_live(prof.as_slice()), prof.len())
                };
                shared.emit(Event::CalibUpdated {
                    fit,
                    samples,
                    switch_cost: shared.switch_cost.estimate(),
                    dp_fit: shared.dp_stat.fit(),
                    device_switch_cost: shared.device_cost.estimate(),
                    at: shared.now(),
                });
                shared.emit(Event::JobFinished {
                    job: job_id,
                    adapters: out.report.adapters.len(),
                    wall: end - start,
                    at: end,
                });
                shared.outcomes.lock().unwrap().push(JobOutcome {
                    job_id,
                    devices,
                    start,
                    end,
                    report: out.report,
                });
                shared.complete();
                return;
            }
            // Cancelled mid-run: drop the unfinished members (the
            // finished ones stay reported and checkpointed) and end the
            // job here instead of re-queuing a continuation.
            if was_cancelled {
                shared.emit(Event::JobFinished {
                    job: job_id,
                    adapters: out.report.adapters.len(),
                    wall: end - start,
                    at: end,
                });
                shared.outcomes.lock().unwrap().push(JobOutcome {
                    job_id,
                    devices,
                    start,
                    end,
                    report: out.report,
                });
                shared.complete();
                return;
            }
            // Preempted: round-trip the members through the checkpoint
            // pool when one is attached, then re-queue the continuation
            // under the same job id/seq/priority.
            let mut resume: Vec<(usize, MemberResume)> = vec![];
            let mut remaining: Vec<LoraConfig> = vec![];
            for (c, r) in out.preempted {
                let payload = match &p.checkpoints {
                    Some(ckpt) => {
                        match ckpt
                            .save_resume(&shared.model, c.id, &r)
                            .and_then(|_| ckpt.load_resume(&shared.model, c.id))
                        {
                            Ok(loaded) => loaded,
                            Err(e) => {
                                shared.fail(job_id, e);
                                shared.complete();
                                return;
                            }
                        }
                    }
                    None => r,
                };
                resume.push((c.id, payload));
                remaining.push(c);
            }
            // Record the executed segment even when no adapter finished in
            // it — its steps/wall/padded rows are real work the report's
            // aggregates (e.g. `padded_rows`) must account for.
            shared.outcomes.lock().unwrap().push(JobOutcome {
                job_id,
                devices,
                start,
                end,
                report: out.report,
            });
            let cont = PendingJob {
                seq: p.seq,
                job: PlannedJob {
                    id: job_id,
                    pack: Pack::new(remaining),
                    d: p.job.d,
                    s: p.job.s,
                    mode: p.job.mode,
                },
                priority: p.priority,
                opts: p.opts,
                rebucket: p.rebucket,
                resume_finished: p.resume_finished,
                checkpoints: p.checkpoints,
                resume,
            };
            shared.submitted.fetch_add(1, Ordering::SeqCst);
            shared.sched.lock().unwrap().pending.push(cont);
            shared.sched_cv.notify_all();
            shared.complete();
        }
        Err(e) => {
            shared.fail(job_id, e);
            shared.complete();
        }
    }
}
